//! Mesh interpolation (§4.2, Fig. 4): predict masked vertex normals by
//! kernel-weighted averaging of the known ones,
//! `F_i = Σ_{j known} f(dist(i,j))·F_j`, with the rational kernel
//! `f(x) = 1/(1+λx²)`, comparing FTFI (on the MST) against the brute
//! graph integrator and a probabilistic tree baseline.
//!
//! Run: `cargo run --release --example mesh_interpolation`

use ftfi::bench_util::time_once;
use ftfi::ftfi::brute::f_distance_matrix_graph;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::mesh;
use ftfi::graph::mst::minimum_spanning_tree;
use ftfi::linalg::matrix::{cosine_similarity, Matrix};
use ftfi::ml::rng::Pcg;
use ftfi::tree::frt::frt_tree;
use ftfi::TreeFieldIntegrator;

/// Keep 20% of normals, predict the rest (the paper masks 80%).
const KNOWN_FRACTION: f64 = 0.2;

fn evaluate(pred: &Matrix, truth: &[[f64; 3]], masked: &[bool]) -> f64 {
    let mut total = 0.0;
    let mut count = 0;
    for (i, m) in masked.iter().enumerate() {
        if *m {
            total += cosine_similarity(pred.row(i), &truth[i]);
            count += 1;
        }
    }
    total / count as f64
}

fn main() {
    let mut rng = Pcg::seed(11);
    for (name, m) in mesh::mesh_zoo(1600, 42) {
        let n = m.n_vertices();
        let g = m.to_graph();
        let tree = minimum_spanning_tree(&g);
        let lambda = 4.0;
        let f = FDist::inverse_quadratic(lambda);

        // Mask 80% of the normals.
        let mut masked = vec![true; n];
        for i in rng.sample_distinct(n, (n as f64 * KNOWN_FRACTION) as usize) {
            masked[i] = false;
        }
        let mut field = Matrix::zeros(n, 3);
        for i in 0..n {
            if !masked[i] {
                field.row_mut(i).copy_from_slice(&m.normals[i]);
            }
        }

        // FTFI on the MST (fallible builder + prepared kernel).
        let (tfi, t_pre) = time_once(|| {
            TreeFieldIntegrator::builder(&tree).build().expect("valid MST")
        });
        let prepared = tfi.prepare_with_channels(&f, 3).expect("plannable kernel");
        let (pred_ftfi, t_int) =
            time_once(|| prepared.integrate(&field).expect("well-shaped field"));
        let cos_ftfi = evaluate(&pred_ftfi, &m.normals, &masked);

        // Brute graph-field integration (exact graph metric).
        let (kmat, t_bgfi) = time_once(|| f_distance_matrix_graph(&g, &f));
        let pred_bgfi = kmat.matmul(&field);
        let cos_bgfi = evaluate(&pred_bgfi, &m.normals, &masked);

        // FRT probabilistic-tree baseline.
        let (emb, t_frt) = time_once(|| frt_tree(&g, &mut rng));
        let frt_int =
            TreeFieldIntegrator::builder(&emb.tree).build().expect("valid FRT tree");
        let pred_frt = emb.restrict_field(
            &frt_int.try_integrate(&f, &emb.lift_field(&field)).expect("well-shaped field"),
        );
        let cos_frt = evaluate(&pred_frt, &m.normals, &masked);

        println!("mesh {name:<8} (n={n}):");
        println!("  FTFI  preprocess {:>7.3}s + integrate {t_int:.3}s  cosine {cos_ftfi:.4}", t_pre);
        println!("  BGFI  preprocess {t_bgfi:>7.3}s                    cosine {cos_bgfi:.4}");
        println!("  FRT   preprocess {t_frt:>7.3}s                    cosine {cos_frt:.4}");
    }
}
