//! END-TO-END DRIVER: exercises every layer of the PJRT stack on a real
//! small workload. Needs the `pjrt` cargo feature (see Cargo.toml for
//! the external crates it pulls in).
//!
//! 1. Loads the AOT-compiled TopViT-mini (JAX/Pallas → HLO text → PJRT).
//! 2. Trains it from rust for a few hundred steps on the synthetic-shapes
//!    corpus — masked (3 extra RPE parameters per layer) AND the unmasked
//!    performer baseline — logging both loss curves.
//! 3. Evaluates held-out accuracy for the Table-1-style comparison.
//! 4. Serves batched classification requests through the coordinator
//!    (router → dynamic batcher → PJRT workers), reporting throughput and
//!    latency percentiles.
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example topological_server`

use ftfi::coordinator::{BatchExecutor, BatcherConfig, InferenceServer};
use ftfi::ml::metrics::accuracy;
use ftfi::ml::rng::Pcg;
use ftfi::ml::shapes;
use ftfi::runtime::topvit::{TopVit, TopVitExecutor, N_CLASSES, TRAIN_BATCH};
use ftfi::runtime::Runtime;
use std::time::Duration;

const TRAIN_STEPS: usize = 300;
const LR: f32 = 0.01;

fn train_and_eval(variant: &str, params_bin: &str) -> anyhow::Result<(Vec<f32>, f64)> {
    let rt = Runtime::cpu()?;
    let mut model = TopVit::load(&rt, "artifacts", params_bin, &[8], true)?;
    // The unmasked baseline keeps its mask frozen at the uniform matrix;
    // otherwise a zero-initialised mask would still be trainable and the
    // comparison would be init-vs-init rather than masked-vs-unmasked.
    model.freeze_mask = variant == "unmasked";
    let mut rng = Pcg::seed(100);
    let train = shapes::dataset(96, &mut rng); // 768 examples
    let test = shapes::dataset(16, &mut rng); // 128 held out
    let mut losses = Vec::with_capacity(TRAIN_STEPS);
    for step in 0..TRAIN_STEPS {
        let (images, labels) = shapes::pack_batch(&train, step * TRAIN_BATCH, TRAIN_BATCH);
        let loss = model.train_step(&images, &labels, LR)?;
        losses.push(loss);
        if step % 50 == 0 {
            println!("  [{variant}] step {step:>4}  loss {loss:.4}");
        }
    }
    // Held-out accuracy via batched forward.
    let mut preds = Vec::new();
    let mut truth = Vec::new();
    for chunk in test.chunks(8) {
        let mut flat = Vec::with_capacity(8 * shapes::IMG * shapes::IMG);
        for ex in chunk {
            flat.extend_from_slice(&ex.pixels);
        }
        flat.resize(8 * shapes::IMG * shapes::IMG, 0.0);
        let p = model.classify(8, &flat)?;
        preds.extend(p.into_iter().take(chunk.len()));
        truth.extend(chunk.iter().map(|e| e.label));
    }
    let acc = accuracy(&preds, &truth);
    println!(
        "  [{variant}] final loss {:.4}, held-out accuracy {:.3}, mask params {:?}",
        losses.last().unwrap(),
        acc,
        model.mask_params()
    );
    if variant == "masked" {
        model.params.save_bin("artifacts/topvit_trained.bin")?;
    }
    Ok((losses, acc))
}

fn main() -> anyhow::Result<()> {
    println!("=== E2E phase 1+2: train TopViT-mini from rust via PJRT ===");
    let (_, acc_masked) = train_and_eval("masked", "topvit_init_masked.bin")?;
    let (_, acc_unmasked) = train_and_eval("unmasked", "topvit_init_unmasked.bin")?;
    println!(
        "\nTable-1-style comparison: masked {acc_masked:.3} vs unmasked {acc_unmasked:.3} \
         (Δ = {:+.3}; paper reports +1.0–1.5% at ImageNet scale)",
        acc_masked - acc_unmasked
    );

    println!("\n=== E2E phase 3: serve batched requests through the coordinator ===");
    // Serve the freshly trained parameters through the coordinator.
    let server = InferenceServer::start(
        vec![Box::new(|| {
            let rt = Runtime::cpu().expect("PJRT");
            let model = TopVit::load(&rt, "artifacts", "topvit_trained.bin", &[8], false)
                .expect("load trained params");
            Box::new(TopVitExecutor::new(model, 8)) as Box<dyn BatchExecutor>
        })],
        BatcherConfig { batch_size: 8, batch_timeout: Duration::from_millis(2) },
        64,
    );
    let mut rng = Pcg::seed(200);
    let data = shapes::dataset(8, &mut rng);
    let n_requests = 512;
    // Paced submission in waves of 64 so reported latency reflects
    // service time under a bounded queue rather than pure queueing delay.
    let mut correct = 0usize;
    for wave in 0..(n_requests / 64) {
        let handles: Vec<_> = (0..64)
            .map(|k| {
                let ex = &data[(wave * 64 + k) % data.len()];
                (ex.label, server.submit_blocking(ex.pixels.clone()).unwrap())
            })
            .collect();
        for (label, h) in handles {
            let logits = h.wait().expect("response");
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred % N_CLASSES == label {
                correct += 1;
            }
        }
    }
    let m = server.metrics();
    println!(
        "served {n_requests} requests: {:.0} req/s, mean batch {:.2}, \
         p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms (served-model acc {:.3})",
        m.throughput_rps,
        m.mean_batch_size,
        m.latency_p50 * 1e3,
        m.latency_p95 * 1e3,
        m.latency_p99 * 1e3,
        correct as f64 / n_requests as f64,
    );
    let _ = std::fs::remove_file("artifacts/topvit_trained.bin");
    server.shutdown();
    println!("\nE2E driver complete — record these numbers in DESIGN.md's measurement log.");
    Ok(())
}
