//! Graph classification (§4.2, Fig. 5 / Tables 3–4): shortest-path-kernel
//! eigenfeatures + random forest over synthetic TU-style datasets,
//! comparing FTFI features (MST metric, Lanczos over the fast integrator)
//! against the exact BGFI features.
//!
//! Run: `cargo run --release --example graph_classification`

use ftfi::bench_util::time_once;
use ftfi::ftfi::brute::f_distance_matrix_graph;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::tu_dataset::{generate, standard_specs, GraphDataset};
use ftfi::graph::Graph;
use ftfi::linalg::eigen::lanczos_smallest;
use ftfi::ml::dataset::{fold_split, stratified_kfold};
use ftfi::ml::metrics::accuracy;
use ftfi::ml::random_forest::{ForestParams, RandomForest};
use ftfi::ml::rng::Pcg;
use ftfi::GraphFieldIntegrator;

const K_EIG: usize = 6;

/// Featurise one graph: k smallest eigenvalues of its f-distance matrix.
fn features(g: &Graph, use_ftfi: bool, rng: &mut Pcg) -> Vec<f64> {
    let f = FDist::Identity; // SP kernel
    if use_ftfi {
        // Prepare once per graph; the Lanczos iteration then hammers the
        // cached plans instead of re-planning every matvec.
        let gfi = GraphFieldIntegrator::try_new(g).expect("connected graph");
        let prepared = gfi.prepare(&f).expect("plannable kernel");
        lanczos_smallest(
            g.n(),
            K_EIG.min(g.n()),
            |v| prepared.integrate_vec(v).expect("field length matches graph"),
            rng,
        )
    } else {
        let m = f_distance_matrix_graph(g, &f);
        lanczos_smallest(g.n(), K_EIG.min(g.n()), |v| m.matvec(v), rng)
    }
    .into_iter()
    .chain(std::iter::repeat(0.0))
    .take(K_EIG)
    .collect()
}

fn evaluate(ds: &GraphDataset, use_ftfi: bool) -> (f64, f64) {
    let mut rng = Pcg::seed(17);
    let (feats, fp_time) = time_once(|| {
        ds.graphs.iter().map(|g| features(g, use_ftfi, &mut rng)).collect::<Vec<_>>()
    });
    // 5-fold stratified CV with a random forest.
    let folds = stratified_kfold(&ds.labels, 5, &mut rng);
    let mut accs = Vec::new();
    for f in 0..folds.len() {
        let (tr, te) = fold_split(&folds, f);
        let xtr: Vec<Vec<f64>> = tr.iter().map(|&i| feats[i].clone()).collect();
        let ytr: Vec<usize> = tr.iter().map(|&i| ds.labels[i]).collect();
        let rf = RandomForest::fit(&xtr, &ytr, &ForestParams::default(), &mut rng);
        let pred: Vec<usize> = te.iter().map(|&i| rf.predict(&feats[i])).collect();
        let truth: Vec<usize> = te.iter().map(|&i| ds.labels[i]).collect();
        accs.push(accuracy(&pred, &truth));
    }
    (accs.iter().sum::<f64>() / accs.len() as f64, fp_time)
}

fn main() {
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>12}",
        "dataset", "acc FTFI", "acc BGFI", "fp FTFI (s)", "fp BGFI (s)"
    );
    for spec in standard_specs().iter().take(5) {
        let ds = generate(spec, 1);
        let (acc_fast, t_fast) = evaluate(&ds, true);
        let (acc_exact, t_exact) = evaluate(&ds, false);
        println!(
            "{:<14} {:>10.3} {:>10.3} {:>12.2} {:>12.2}",
            ds.name, acc_fast, acc_exact, t_fast, t_exact
        );
    }
}
