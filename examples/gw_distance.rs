//! Gromov–Wasserstein with FTFI (Appendix D.2 / Fig. 10): the conditional-
//! gradient GW solver with its inner `C₁·T·C₂` products running through
//! FTFI vs the dense baseline, on random trees of growing size. The FTFI
//! backend freezes both kernels (f(x)=x, f(x)=x²) into prepared handles
//! up front, so the CG loop never re-plans a cross block.
//!
//! Run: `cargo run --release --example gw_distance`

use ftfi::bench_util::time_once;
use ftfi::graph::generators;
use ftfi::ml::rng::Pcg;
use ftfi::ot::gw::{gromov_wasserstein, GwBackend, GwParams};
use ftfi::ot::sinkhorn::uniform_marginal;

fn main() {
    println!(
        "{:>6} {:>12} {:>12} {:>10} {:>10} {:>9}",
        "n", "GW dense", "GW ftfi", "int dense", "int ftfi", "speedup"
    );
    let params = GwParams { max_iter: 15, ..Default::default() };
    for &n in &[50usize, 100, 200, 400] {
        let mut rng = Pcg::seed(5);
        let ta = generators::random_tree(n, 0.1, 1.0, &mut rng);
        let tb = generators::random_tree(n, 0.1, 1.0, &mut rng);
        let p = uniform_marginal(n);
        let (rd, _) =
            time_once(|| gromov_wasserstein(&ta, &tb, &p, &p, GwBackend::Dense, &params));
        let (rf, _) =
            time_once(|| gromov_wasserstein(&ta, &tb, &p, &p, GwBackend::Ftfi, &params));
        let (rd, rf) = (rd.expect("dense GW on well-formed inputs"), rf.expect("ftfi GW"));
        println!(
            "{n:>6} {:>12.5} {:>12.5} {:>9.3}s {:>9.3}s {:>8.1}x",
            rd.discrepancy,
            rf.discrepancy,
            rd.integration_seconds,
            rf.integration_seconds,
            rd.integration_seconds / rf.integration_seconds.max(1e-9)
        );
    }
    println!("\n(Fig. 10 claim: FTFI-GW integration 2–6x faster with no accuracy drop.)");
}
