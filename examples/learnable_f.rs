//! Learnable f-distance matrices (§4.3, Fig. 6): train the coefficients
//! of a rational `f` so that `f(dist_MST)` approximates the true graph
//! metric, and watch the relative Frobenius error drop — the training
//! loss never touches the O(N²) evaluation metric.
//!
//! Run: `cargo run --release --example learnable_f`

use ftfi::graph::{generators, mst::minimum_spanning_tree};
use ftfi::ml::fit_rational::{fit, relative_frobenius_error, sample_pairs, RationalModel};
use ftfi::ml::rng::Pcg;
use ftfi::TreeFieldIntegrator;

fn main() {
    let n = 800;
    let mut rng = Pcg::seed(3);
    // The paper's Fig. 6 middle panel: path(800) + 600 random edges.
    let g = generators::path_plus_random_edges(n, 600, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let data = sample_pairs(&g, &tree, 100, &mut rng);

    println!("graph: path({n}) + 600 random edges; 100 training pairs\n");
    println!("{:<22} {:>8} {:>12} {:>12}", "f parameterisation", "params", "err before", "err after");
    for (num_deg, den_deg) in [(1usize, 1usize), (2, 2), (3, 3)] {
        let mut model = RationalModel::new(num_deg, den_deg);
        let before = relative_frobenius_error(&g, &tree, &model.to_fdist());
        let trace = fit(&mut model, &data, 300, 0.02);
        let after = relative_frobenius_error(&g, &tree, &model.to_fdist());
        println!(
            "{:<22} {:>8} {:>12.4} {:>12.4}   (final MSE {:.4})",
            format!("num:{num_deg} den:{den_deg}"),
            model.n_params(),
            before,
            after,
            trace.loss.last().unwrap()
        );
    }

    // The trained f plugs straight into the fast integrator: the same IT
    // is reused — only the function changed.
    let mut model = RationalModel::new(2, 2);
    fit(&mut model, &data, 300, 0.02);
    let tfi = TreeFieldIntegrator::builder(&tree).build().expect("valid MST");
    let x = ftfi::Matrix::randn(n, 2, &mut rng);
    let out = tfi.try_integrate(&model.to_fdist(), &x).expect("well-shaped field");
    println!(
        "\nintegrated a 2-channel field with the trained f: ‖out‖_F = {:.3}",
        out.frobenius()
    );
}
