//! Quickstart: build a graph, take its MST, integrate a tensor field with
//! several `f` classes through FTFI, and verify exactness against the
//! brute-force reference — both sides driven through the unified
//! `FieldIntegrator` trait, with a prepared handle demonstrating the
//! "plan once, integrate many" path.
//!
//! Run: `cargo run --release --example quickstart`

use ftfi::bench_util::time_once;
use ftfi::ftfi::brute::BruteForceIntegrator;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::{generators, mst::try_minimum_spanning_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::{FieldIntegrator, TreeFieldIntegrator};

fn main() {
    let n = 3000;
    let mut rng = Pcg::seed(7);

    // 1. A general graph: the paper's synthetic family (§4.1).
    let graph = generators::path_plus_random_edges(n, n / 2, &mut rng);
    println!("graph: {} vertices, {} edges", graph.n(), graph.m());

    // 2. Approximate the graph metric by its MST metric (§4) — a
    //    disconnected graph would surface as Err(DisconnectedGraph).
    let tree = try_minimum_spanning_tree(&graph).expect("generator yields connected graphs");

    // 3. Preprocess once — reusable across fields AND functions f.
    let (tfi, secs) = time_once(|| TreeFieldIntegrator::builder(&tree).build());
    let tfi = tfi.expect("valid tree");
    let stats = tfi.stats();
    println!(
        "IntegratorTree built in {secs:.3}s: {} nodes, depth {}, {} leaves",
        stats.nodes, stats.depth, stats.leaves
    );

    // The brute-force reference implements the same FieldIntegrator
    // trait, so the comparison loop below is backend-agnostic.
    let brute = BruteForceIntegrator::from_tree(tree.clone());

    // 4. Integrate a 3-channel tensor field with different f classes.
    let x = Matrix::randn(n, 3, &mut rng);
    let fs: Vec<(&str, FDist)> = vec![
        ("shortest-path kernel f(x)=x", FDist::Identity),
        ("heat kernel f(x)=e^{-x}", FDist::Exponential { lambda: -1.0, scale: 1.0 }),
        ("mesh kernel f(x)=1/(1+x²)", FDist::inverse_quadratic(1.0)),
        ("gaussian f(x)=e^{-x²/4}", FDist::gaussian(0.25)),
    ];
    for (name, f) in fs {
        let (fast, t_fast) = time_once(|| FieldIntegrator::integrate(&tfi, &f, &x));
        let fast = fast.expect("well-shaped field");
        let (slow, t_slow) = time_once(|| brute.integrate(&f, &x));
        let slow = slow.expect("well-shaped field");
        let rel = fast.frobenius_diff(&slow) / (1.0 + slow.frobenius());
        println!("{name:<30} FTFI {t_fast:>7.4}s  brute {t_slow:>7.4}s  rel.err {rel:.1e}");
    }

    // 5. Repeated integration with one f: prepare once, integrate many.
    let f = FDist::inverse_quadratic(1.0);
    let (prepared, t_prep) = time_once(|| tfi.prepare_with_channels(&f, 3));
    let prepared = prepared.expect("plannable kernel");
    let k = 8;
    let (_, t_rep) = time_once(|| {
        for _ in 0..k {
            prepared.integrate(&x).expect("well-shaped field");
        }
    });
    let (_, t_replan) = time_once(|| {
        for _ in 0..k {
            tfi.try_integrate(&f, &x).expect("well-shaped field");
        }
    });
    println!(
        "\nprepared handle ({} plans, {t_prep:.3}s prepare): {k} integrations in {t_rep:.3}s \
         vs {t_replan:.3}s re-planning ({:.1}x)",
        prepared.plans_built(),
        t_replan / t_rep.max(1e-12)
    );
}
