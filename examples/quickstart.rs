//! Quickstart: build a graph, take its MST, integrate a tensor field with
//! several `f` classes through FTFI, and verify exactness against the
//! brute-force integrator.
//!
//! Run: `cargo run --release --example quickstart`

use ftfi::bench_util::time_once;
use ftfi::ftfi::brute::btfi;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::{generators, mst::minimum_spanning_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::TreeFieldIntegrator;

fn main() {
    let n = 3000;
    let mut rng = Pcg::seed(7);

    // 1. A general graph: the paper's synthetic family (§4.1).
    let graph = generators::path_plus_random_edges(n, n / 2, &mut rng);
    println!("graph: {} vertices, {} edges", graph.n(), graph.m());

    // 2. Approximate the graph metric by its MST metric (§4).
    let tree = minimum_spanning_tree(&graph);

    // 3. Preprocess once — reusable across fields AND functions f.
    let (tfi, secs) = time_once(|| TreeFieldIntegrator::new(&tree));
    let stats = tfi.stats();
    println!(
        "IntegratorTree built in {secs:.3}s: {} nodes, depth {}, {} leaves",
        stats.nodes, stats.depth, stats.leaves
    );

    // 4. Integrate a 3-channel tensor field with different f classes.
    let x = Matrix::randn(n, 3, &mut rng);
    let fs: Vec<(&str, FDist)> = vec![
        ("shortest-path kernel f(x)=x", FDist::Identity),
        ("heat kernel f(x)=e^{-x}", FDist::Exponential { lambda: -1.0, scale: 1.0 }),
        ("mesh kernel f(x)=1/(1+x²)", FDist::inverse_quadratic(1.0)),
        ("gaussian f(x)=e^{-x²/4}", FDist::gaussian(0.25)),
    ];
    for (name, f) in fs {
        let (fast, t_fast) = time_once(|| tfi.integrate(&f, &x));
        let (slow, t_slow) = time_once(|| btfi(&tree, &f, &x));
        let rel = fast.frobenius_diff(&slow) / (1.0 + slow.frobenius());
        println!("{name:<30} FTFI {t_fast:>7.4}s  brute {t_slow:>7.4}s  rel.err {rel:.1e}");
    }
}
