//! `cargo xtask` — repo automation as plain Rust (no shell, no deps).
//!
//! The one command that matters for CI is `cargo xtask lint`: a
//! contract linter that machine-checks the determinism, zero-alloc and
//! panic-freedom conventions DESIGN.md promises, on the real source
//! tree. It is deliberately token/structure-based (a scrubbing lexer
//! plus brace matching, not a full parser): cheap, dependency-free and
//! precise enough once comments/strings are blanked out.
//!
//! Rules (see DESIGN.md "Verification & static analysis"):
//!
//! * `nondet-map` — `HashMap`/`HashSet` in the numeric modules
//!   (`ftfi/`, `tree/`, `linalg/`, `ot/`, `graph/`). Iteration order of
//!   hashed containers is seeded per process, and PR 6 turned exactly
//!   that into a cross-process nondeterminism bug twice; numeric code
//!   uses `BTreeMap`/`BTreeSet` or sorted `Vec`s instead.
//! * `alloc-in-hot-path` — allocation-capable calls inside the
//!   zero-alloc contract surface: any `fn` whose name ends in `_into`
//!   plus the hot-path manifest below. Cold validation/error arms are
//!   annotated in place.
//! * `unchecked-panic` — `.unwrap(` / `.expect(` / `panic!` /
//!   `assert!`-family in non-test library code. Strict (CI-failing) in
//!   the burned-down modules; advisory elsewhere; `debug_assert*` is
//!   always fine (that is what the invariants layer is made of).
//! * `unordered-float-reduction` — float reductions (`.sum`/`.fold`/
//!   `.product`) over a variable declared as a hashed container: order
//!   nondeterminism straight into a float accumulator.
//! * `mixed-precision-cast` — bare `as f32` / `as f64` casts in the
//!   numeric core (`ftfi/`, `tree/`, `linalg/`) outside
//!   `linalg/lanes.rs`. The mixed-precision serving tier funnels every
//!   f32↔f64 tier cast through the lane-kernel module so the f32
//!   compute / f64 accumulate semantics are auditable in one place; an
//!   ad-hoc cast anywhere else silently changes a tier's rounding.
//!   Int→float index/size casts are fine but must say so in an
//!   annotation.
//!
//! `cargo xtask bench-gate [artifacts-dir] [thresholds.json]` checks
//! the machine-readable `BENCH_*.json` artifacts the ablation benches
//! emit against committed thresholds (min speedups, max drift,
//! allocation counts). Missing files, missing fields, empty selector
//! matches and non-finite values all fail the gate — a bench that
//! stops reporting a number is treated as a regression, not a pass.
//!
//! Suppression: a `// lint: allow(<rule>) — reason` or
//! `// lint: infallible because <proof>` comment on the offending line
//! or up to [`SUPPRESS_WINDOW`] lines above it. The reason is part of
//! the grammar on purpose: every allowlisted site carries its own
//! justification in the diff.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// A directive covers findings on `[directive_line, directive_line + SUPPRESS_WINDOW]`,
/// so a multi-line justification comment still reaches the code below it.
const SUPPRESS_WINDOW: usize = 5;

/// Hot-path functions under the zero-alloc contract that do not carry
/// the `_into` suffix (the recursive workspace walkers, the pooled
/// entry points, and the `SharedPlans` read-side wrapper every warmed
/// streaming serve goes through after an edge re-plan), pinned by
/// `tests/hotpath_alloc.rs`. The replan-adjacent `*_into` fns
/// themselves (`leaf_apply_into`, `aggregate_into`, `combine_*_into`,
/// and the post-replan `integrate_prepared_into` re-entry) are covered
/// automatically by the `_into` suffix rule. `cache_lookup` is the
/// plan-cache hit path every `OpenGraph` resolves through: a hit must
/// stay key-compare + LRU-stamp + `Arc::clone`, never a rebuild.
const HOT_PATH_MANIFEST: [&str; 6] = [
    "integrate_ws",
    "integrate_ws_delta",
    "integrate_prepared_into_pooled",
    "integrate_delta_prepared_into_pooled",
    "with",
    "cache_lookup",
];

/// Tokens that can allocate. `checkout_workspace`/`checkout_scratch`
/// are deliberately NOT tokens: growing the arena stock is the defined
/// warm-up, and the counting-allocator test pins the warmed steady
/// state.
const ALLOC_TOKENS: [&str; 12] = [
    "Vec::new(",
    "vec![",
    ".to_vec(",
    ".collect(",
    ".clone(",
    ".cloned(",
    "format!(",
    ".to_string(",
    "String::new(",
    "Box::new(",
    ".to_owned(",
    "with_capacity(",
];

/// Numeric modules where hashed containers are banned outright.
const NONDET_MAP_DIRS: [&str; 5] = ["ftfi/", "tree/", "linalg/", "ot/", "graph/"];

/// The numeric core the precision tiers run through: bare `as f32` /
/// `as f64` casts here must either live in the lane-kernel module or
/// carry an annotation saying why they are not a tier cast.
const PRECISION_CAST_DIRS: [&str; 3] = ["ftfi/", "tree/", "linalg/"];

/// The one module allowed to cast between tiers without annotation:
/// every f32-tier product cast is funnelled through the lane kernels.
fn precision_cast_exempt(rel: &str) -> bool {
    rel == "linalg/lanes.rs"
}

/// Modules where `unchecked-panic` fails CI (the completed burn-down
/// surface: fallible APIs exist, every remaining site is annotated).
fn panic_strict(rel: &str) -> bool {
    rel == "ftfi/vandermonde.rs"
        || rel.starts_with("ot/")
        || rel.starts_with("coordinator/")
        || rel == "runtime/pool.rs"
}

/// Modules exempt from `unchecked-panic` entirely: the invariants layer
/// IS assertions by design, and bench_util's counting allocator aborts
/// on misuse on purpose.
fn panic_exempt(rel: &str) -> bool {
    rel == "tree/invariants.rs" || rel == "bench_util.rs"
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Finding {
    rule: &'static str,
    line: usize,
    strict: bool,
    msg: String,
}

// ---------------------------------------------------------------------
// Scrubbing lexer
// ---------------------------------------------------------------------

/// Blank comments and string/char-literal contents with spaces,
/// preserving newlines (and therefore line numbers) exactly. Handles
/// line comments, nested block comments, escapes, raw strings
/// (`r"…"` / `r#"…"#` / `br#"…"#`) and char-literal vs lifetime
/// disambiguation. String delimiters are kept so call tokens like
/// `.expect(` stay visible while their payload does not.
fn scrub(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let blank = |out: &mut String, c: char| out.push(if c == '\n' { '\n' } else { ' ' });
    while i < b.len() {
        let c = b[i];
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and byte-raw) strings: r"…", r#"…"#, br##"…"##.
        if (c == 'r' || c == 'b') && !prev_is_ident(&b, i) {
            let mut j = i;
            if b[j] == 'b' && b.get(j + 1) == Some(&'r') {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while b.get(k) == Some(&'#') {
                    hashes += 1;
                    k += 1;
                }
                if b.get(k) == Some(&'"') {
                    for &p in &b[i..=k] {
                        out.push(p);
                    }
                    i = k + 1;
                    while i < b.len() {
                        if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                            out.push('"');
                            for _ in 0..hashes {
                                out.push('#');
                            }
                            i += 1 + hashes;
                            break;
                        }
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                    continue;
                }
            }
        }
        if c == '"' {
            out.push('"');
            i += 1;
            while i < b.len() {
                if b[i] == '\\' && i + 1 < b.len() {
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        if c == '\'' {
            if b.get(i + 1) == Some(&'\\') {
                // Escaped char literal: '\n', '\u{7f}', …
                out.push('\'');
                i += 1;
                while i < b.len() && b[i] != '\'' {
                    if b[i] == '\\' && i + 1 < b.len() {
                        out.push_str("  ");
                        i += 2;
                    } else {
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                }
                if i < b.len() {
                    out.push('\'');
                    i += 1;
                }
            } else if b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\'') {
                // Plain char literal 'x'.
                out.push_str("' '");
                i += 3;
            } else {
                // Lifetime: keep as-is.
                out.push('\'');
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

// ---------------------------------------------------------------------
// Structure: test spans, fn extents, directives
// ---------------------------------------------------------------------

/// Inclusive 1-indexed line spans of `#[cfg(…test…)]` / `#[test]`
/// items (computed on scrubbed text so braces in strings cannot
/// confuse the matcher).
fn test_spans(scrubbed: &str) -> Vec<(usize, usize)> {
    let b: Vec<char> = scrubbed.chars().collect();
    let line_of = line_index(&b);
    let mut spans = Vec::new();
    let mut i = 0;
    while i + 1 < b.len() {
        if !(b[i] == '#' && b[i + 1] == '[') {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut content = String::new();
        while j < b.len() && depth > 0 {
            match b[j] {
                '[' => depth += 1,
                ']' => depth -= 1,
                c => content.push(c),
            }
            j += 1;
        }
        let is_test_attr = {
            let t = content.trim();
            t == "test" || (t.starts_with("cfg") && has_word(&content, "test"))
        };
        if !is_test_attr {
            i = j;
            continue;
        }
        // Item extent: forward to the first `{` (brace-match) or `;`.
        let mut k = j;
        while k < b.len() && b[k] != '{' && b[k] != ';' {
            k += 1;
        }
        let end = if k < b.len() && b[k] == '{' {
            let mut d = 1usize;
            let mut m = k + 1;
            while m < b.len() && d > 0 {
                match b[m] {
                    '{' => d += 1,
                    '}' => d -= 1,
                    _ => {}
                }
                m += 1;
            }
            m.saturating_sub(1)
        } else {
            k.min(b.len().saturating_sub(1))
        };
        spans.push((line_of[attr_start], line_of[end.min(line_of.len() - 1)]));
        i = j;
    }
    spans
}

#[derive(Debug)]
struct FnExtent {
    name: String,
    start: usize,
    end: usize,
}

/// Extents (inclusive 1-indexed line ranges) of every `fn` item, for
/// innermost-function attribution of hot-path findings. Closures do
/// not open a new extent — a closure inside a `_into` fn is still on
/// the hot path; a nested helper `fn` is not.
fn fn_extents(scrubbed: &str) -> Vec<FnExtent> {
    let b: Vec<char> = scrubbed.chars().collect();
    let line_of = line_index(&b);
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < b.len() {
        let word_fn = b[i] == 'f'
            && b[i + 1] == 'n'
            && !prev_is_ident(&b, i)
            && b.get(i + 2).map_or(true, |c| !(c.is_alphanumeric() || *c == '_'));
        if !word_fn {
            i += 1;
            continue;
        }
        let start_line = line_of[i];
        let mut j = i + 2;
        while j < b.len() && b[j].is_whitespace() {
            j += 1;
        }
        let mut name = String::new();
        while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
            name.push(b[j]);
            j += 1;
        }
        if name.is_empty() {
            // `fn(..)` pointer type, not an item.
            i = j.max(i + 2);
            continue;
        }
        // Signature → first `{` (body) or `;` (trait declaration).
        let mut k = j;
        while k < b.len() && b[k] != '{' && b[k] != ';' {
            k += 1;
        }
        if k >= b.len() || b[k] == ';' {
            i = k.min(b.len());
            continue;
        }
        let mut d = 1usize;
        let mut m = k + 1;
        while m < b.len() && d > 0 {
            match b[m] {
                '{' => d += 1,
                '}' => d -= 1,
                _ => {}
            }
            m += 1;
        }
        let end_line = line_of[m.saturating_sub(1).min(line_of.len() - 1)];
        out.push(FnExtent { name, start: start_line, end: end_line });
        i = j;
    }
    out
}

/// For every char index, the 1-indexed line it sits on.
fn line_index(b: &[char]) -> Vec<usize> {
    let mut out = Vec::with_capacity(b.len());
    let mut line = 1usize;
    for &c in b {
        out.push(line);
        if c == '\n' {
            line += 1;
        }
    }
    if out.is_empty() {
        out.push(1);
    }
    out
}

#[derive(Debug)]
struct Directive {
    line: usize,
    rule: String,
}

/// `// lint:` directives, collected from the RAW source (the scrubber
/// blanks them). `infallible` is shorthand for `allow(unchecked-panic)`.
fn collect_directives(src: &str) -> Vec<Directive> {
    let mut out = Vec::new();
    for (idx, line) in src.lines().enumerate() {
        let Some(pos) = line.find("// lint:") else { continue };
        let rest = line[pos + "// lint:".len()..].trim_start();
        let rule = if rest.starts_with("infallible") {
            "unchecked-panic".to_string()
        } else if let Some(a) = rest.find("allow(") {
            rest[a + "allow(".len()..].split(')').next().unwrap_or("").trim().to_string()
        } else {
            continue;
        };
        out.push(Directive { line: idx + 1, rule });
    }
    out
}

fn suppressed(directives: &[Directive], rule: &str, line: usize) -> bool {
    directives
        .iter()
        .any(|d| d.rule == rule && d.line <= line && line <= d.line + SUPPRESS_WINDOW)
}

// ---------------------------------------------------------------------
// Token helpers
// ---------------------------------------------------------------------

/// Whole-word occurrence (non-identifier chars on both sides).
fn has_word(hay: &str, word: &str) -> bool {
    let hb = hay.as_bytes();
    let mut start = 0;
    while let Some(p) = hay[start..].find(word) {
        let abs = start + p;
        let left_ok = abs == 0 || !(hb[abs - 1].is_ascii_alphanumeric() || hb[abs - 1] == b'_');
        let r = abs + word.len();
        let right_ok = r >= hb.len() || !(hb[r].is_ascii_alphanumeric() || hb[r] == b'_');
        if left_ok && right_ok {
            return true;
        }
        start = abs + word.len();
    }
    false
}

/// First panic-capable token on the line, if any. `debug_assert*` never
/// matches (the `assert!` family is checked with a left word boundary),
/// and `.unwrap_or*` / `.expect_err(` never match the `(`-anchored
/// method tokens.
fn panic_token(line: &str) -> Option<&'static str> {
    for t in [".unwrap(", ".expect("] {
        if line.contains(t) {
            return Some(t);
        }
    }
    let lb = line.as_bytes();
    for t in ["panic!", "assert!", "assert_eq!", "assert_ne!"] {
        let mut start = 0;
        while let Some(p) = line[start..].find(t) {
            let abs = start + p;
            let left_ok =
                abs == 0 || !(lb[abs - 1].is_ascii_alphanumeric() || lb[abs - 1] == b'_');
            if left_ok {
                return Some(t);
            }
            start = abs + t.len();
        }
    }
    None
}

// ---------------------------------------------------------------------
// The linter core
// ---------------------------------------------------------------------

/// Lint one file. `rel` is the path relative to `src/` with `/`
/// separators (e.g. `"tree/integrator_tree.rs"`).
fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let scrubbed = scrub(src);
    let directives = collect_directives(src);
    let tests = test_spans(&scrubbed);
    let fns = fn_extents(&scrubbed);
    let in_test = |line: usize| tests.iter().any(|&(s, e)| s <= line && line <= e);
    let innermost = |line: usize| {
        fns.iter()
            .filter(|f| f.start <= line && line <= f.end)
            .max_by_key(|f| f.start)
    };
    let hot = |name: &str| name.ends_with("_into") || HOT_PATH_MANIFEST.contains(&name);

    let numeric = NONDET_MAP_DIRS.iter().any(|d| rel.starts_with(*d));
    let r3_strict = panic_strict(rel);
    let r3_exempt = panic_exempt(rel);
    let r5_scope =
        PRECISION_CAST_DIRS.iter().any(|d| rel.starts_with(*d)) && !precision_cast_exempt(rel);

    // R4 preparation: variables declared with a hashed-container type.
    let mut hashed_vars: Vec<String> = Vec::new();
    for line in scrubbed.lines() {
        if (line.contains("HashMap") || line.contains("HashSet")) && has_word(line, "let") {
            let after = line.split_once("let ").map(|(_, a)| a).unwrap_or("");
            let after = after.strip_prefix("mut ").unwrap_or(after);
            let name: String = after
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                hashed_vars.push(name);
            }
        }
    }

    let mut findings = Vec::new();
    for (idx, line) in scrubbed.lines().enumerate() {
        let lno = idx + 1;
        if in_test(lno) {
            continue;
        }
        // R1: nondeterministic containers in numeric modules.
        if numeric && (has_word(line, "HashMap") || has_word(line, "HashSet")) {
            if !suppressed(&directives, "nondet-map", lno) {
                findings.push(Finding {
                    rule: "nondet-map",
                    line: lno,
                    strict: true,
                    msg: "hashed container in a numeric module (iteration order is \
                          process-seeded; use BTreeMap/BTreeSet or a sorted Vec)"
                        .to_string(),
                });
            }
        }
        // R2: allocation inside the zero-alloc contract surface.
        if let Some(f) = innermost(lno) {
            if hot(&f.name) {
                for t in ALLOC_TOKENS {
                    if line.contains(t) && !suppressed(&directives, "alloc-in-hot-path", lno) {
                        findings.push(Finding {
                            rule: "alloc-in-hot-path",
                            line: lno,
                            strict: true,
                            msg: format!(
                                "`{t}` inside hot-path fn `{}` (zero-alloc contract; annotate \
                                 cold error arms with `// lint: allow(alloc-in-hot-path)`)",
                                f.name
                            ),
                        });
                        break;
                    }
                }
            }
        }
        // R3: unchecked panics in library code.
        if !r3_exempt {
            if let Some(t) = panic_token(line) {
                if !suppressed(&directives, "unchecked-panic", lno) {
                    findings.push(Finding {
                        rule: "unchecked-panic",
                        line: lno,
                        strict: r3_strict,
                        msg: format!(
                            "`{t}` in non-test library code (return FtfiError/ServerError, or \
                             justify with `// lint: infallible because …`)"
                        ),
                    });
                }
            }
        }
        // R4: float reduction over a hashed container.
        let reduces =
            line.contains(".sum(") || line.contains(".fold(") || line.contains(".product(");
        if reduces {
            let over_hashed = hashed_vars.iter().any(|v| {
                let mut s = 0;
                let needle = format!("{v}.");
                while let Some(p) = line[s..].find(&needle) {
                    let abs = s + p;
                    let lb = line.as_bytes();
                    if abs == 0 || !(lb[abs - 1].is_ascii_alphanumeric() || lb[abs - 1] == b'_') {
                        return true;
                    }
                    s = abs + needle.len();
                }
                false
            });
            if over_hashed && !suppressed(&directives, "unordered-float-reduction", lno) {
                findings.push(Finding {
                    rule: "unordered-float-reduction",
                    line: lno,
                    strict: true,
                    msg: "reduction over a hashed container (iteration order is nondeterministic \
                          and float addition is not associative)"
                        .to_string(),
                });
            }
        }
        // R5: bare tier casts outside the lane-kernel module.
        if r5_scope
            && (has_word(line, "as f32") || has_word(line, "as f64"))
            && !suppressed(&directives, "mixed-precision-cast", lno)
        {
            findings.push(Finding {
                rule: "mixed-precision-cast",
                line: lno,
                strict: true,
                msg: "bare `as f32`/`as f64` in the numeric core (tier casts belong in \
                      linalg/lanes.rs; annotate int→float index/size casts with \
                      `// lint: allow(mixed-precision-cast) — reason`)"
                    .to_string(),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------
// bench-gate: check BENCH_*.json artifacts against committed thresholds
// ---------------------------------------------------------------------
//
// The ablation benches emit flat, hand-written JSON; this is a
// correspondingly small hand-written parser (std-only, like the rest
// of xtask) for exactly that dialect: objects, arrays, strings without
// escapes-we-care-about, bools, null, and numbers including exponent
// notation. Bare `NaN` / `inf` tokens (what `format!` prints for
// non-finite f64s) parse as their float values so the *gate* — not the
// parser — gets to reject them with a useful message.

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn new(src: &'a str) -> Self {
        Self { bytes: src.as_bytes(), pos: 0 }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn eat_word(&mut self, w: &str) -> bool {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(w.as_bytes()) {
            self.pos += w.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') if self.eat_word("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.eat_word("false") => Ok(Json::Bool(false)),
            Some(b'n') if self.eat_word("null") => Ok(Json::Null),
            Some(b'N') if self.eat_word("NaN") => Ok(Json::Num(f64::NAN)),
            Some(b'i') if self.eat_word("inf") => Ok(Json::Num(f64::INFINITY)),
            Some(_) => self.number(),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            let key = self.string()?;
            self.eat(b':')?;
            pairs.push((key, self.value()?));
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b'"' {
                let s = std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| "invalid utf-8 in string".to_string())?
                    .to_string();
                self.pos += 1;
                return Ok(s);
            }
            if b == b'\\' {
                return Err("escape sequences unsupported in bench JSON".to_string());
            }
            self.pos += 1;
        }
        Err("unterminated string".to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        self.skip_ws();
        let start = self.pos;
        if self.bytes.get(self.pos) == Some(&b'-') {
            self.pos += 1;
            if self.eat_word("inf") {
                return Ok(Json::Num(f64::NEG_INFINITY));
            }
            if self.eat_word("NaN") {
                return Ok(Json::Num(f64::NAN));
            }
        }
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "invalid utf-8 in number".to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{text}` at byte {start}"))
    }
}

fn parse_json(src: &str) -> Result<Json, String> {
    let mut p = JsonParser::new(src);
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

/// One threshold check: a field selector into a bench artifact plus
/// optional lower/upper bounds. Selector grammar (dot-separated):
/// `name`, `name[N]`, `name[last]`, `name[*]` — e.g.
/// `results[*].speedup` bounds every row, `results[0].speedup` just
/// the first.
struct Check {
    file: String,
    field: String,
    min: Option<f64>,
    max: Option<f64>,
}

/// Resolve a selector against a parsed artifact. Returns every f64 the
/// selector matches; any structural mismatch (missing key, index out of
/// range, non-numeric leaf) is an error, not an empty match.
fn select(value: &Json, selector: &str) -> Result<Vec<f64>, String> {
    let mut current: Vec<&Json> = vec![value];
    for seg in selector.split('.') {
        let (name, index) = match seg.find('[') {
            Some(open) => {
                let close = seg
                    .rfind(']')
                    .ok_or_else(|| format!("unclosed `[` in selector segment `{seg}`"))?;
                (&seg[..open], Some(&seg[open + 1..close]))
            }
            None => (seg, None),
        };
        if !name.is_empty() {
            current = current
                .iter()
                .map(|v| v.get(name).ok_or_else(|| format!("missing field `{name}`")))
                .collect::<Result<Vec<_>, _>>()?;
        }
        if let Some(idx) = index {
            let mut next = Vec::new();
            for v in &current {
                let Json::Arr(items) = v else {
                    return Err(format!("selector `{seg}` indexes a non-array"));
                };
                match idx {
                    "*" => next.extend(items.iter()),
                    "last" => next.push(
                        items.last().ok_or_else(|| format!("`{seg}` on an empty array"))?,
                    ),
                    n => {
                        let i: usize =
                            n.parse().map_err(|_| format!("bad index `{n}` in `{seg}`"))?;
                        next.push(
                            items.get(i).ok_or_else(|| format!("index {i} out of range"))?,
                        );
                    }
                }
            }
            current = next;
        }
    }
    current
        .iter()
        .map(|v| match v {
            Json::Num(x) => Ok(*x),
            other => Err(format!("selector leaf is not a number: {other:?}")),
        })
        .collect()
}

/// Evaluate one check against a loaded artifact. Every failure mode —
/// unparseable file, missing field, empty match, non-finite value,
/// out-of-bounds value — returns `Err` so a bench that stops reporting
/// a number reads as a regression rather than a pass.
fn evaluate_check(check: &Check, artifact: &str) -> Result<(), String> {
    let value =
        parse_json(artifact).map_err(|e| format!("{}: unparseable JSON: {e}", check.file))?;
    let selected = select(&value, &check.field)
        .map_err(|e| format!("{}: `{}`: {e}", check.file, check.field))?;
    if selected.is_empty() {
        return Err(format!("{}: `{}` matched no values", check.file, check.field));
    }
    for (i, &x) in selected.iter().enumerate() {
        if !x.is_finite() {
            return Err(format!(
                "{}: `{}`[{i}] is non-finite ({x})",
                check.file, check.field
            ));
        }
        if let Some(min) = check.min {
            if x < min {
                return Err(format!(
                    "{}: `{}`[{i}] = {x} below minimum {min}",
                    check.file, check.field
                ));
            }
        }
        if let Some(max) = check.max {
            if x > max {
                return Err(format!(
                    "{}: `{}`[{i}] = {x} above maximum {max}",
                    check.file, check.field
                ));
            }
        }
    }
    Ok(())
}

fn parse_thresholds(src: &str) -> Result<Vec<Check>, String> {
    let root = parse_json(src).map_err(|e| format!("thresholds: unparseable JSON: {e}"))?;
    let Some(Json::Arr(entries)) = root.get("checks") else {
        return Err("thresholds: missing `checks` array".to_string());
    };
    let mut checks = Vec::new();
    for entry in entries {
        let field_str = |key: &str| -> Result<String, String> {
            match entry.get(key) {
                Some(Json::Str(s)) => Ok(s.clone()),
                _ => Err(format!("thresholds: check missing string `{key}`")),
            }
        };
        let bound = |key: &str| -> Result<Option<f64>, String> {
            match entry.get(key) {
                Some(Json::Num(x)) if x.is_finite() => Ok(Some(*x)),
                Some(_) => Err(format!("thresholds: `{key}` must be a finite number")),
                None => Ok(None),
            }
        };
        let check = Check {
            file: field_str("file")?,
            field: field_str("field")?,
            min: bound("min")?,
            max: bound("max")?,
        };
        if check.min.is_none() && check.max.is_none() {
            return Err(format!(
                "thresholds: check on {}:`{}` has neither min nor max",
                check.file, check.field
            ));
        }
        checks.push(check);
    }
    if checks.is_empty() {
        return Err("thresholds: empty `checks` array".to_string());
    }
    Ok(checks)
}

/// Run every check; the loader is injected so tests can gate in-memory
/// artifacts. A missing artifact file is itself a gate failure.
fn run_gate<F>(checks: &[Check], load: F) -> Vec<String>
where
    F: Fn(&str) -> Option<String>,
{
    let mut failures = Vec::new();
    for check in checks {
        match load(&check.file) {
            None => failures.push(format!("{}: artifact missing", check.file)),
            Some(artifact) => {
                if let Err(msg) = evaluate_check(check, &artifact) {
                    failures.push(msg);
                }
            }
        }
    }
    failures
}

fn bench_gate_command(args: &[String]) -> ExitCode {
    let dir = args.first().map(String::as_str).unwrap_or(".");
    let thresholds_path =
        args.get(1).map(String::as_str).unwrap_or("benches/thresholds.json");
    let thresholds_src = match fs::read_to_string(thresholds_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("xtask bench-gate: cannot read {thresholds_path}: {e}");
            return ExitCode::from(2);
        }
    };
    let checks = match parse_thresholds(&thresholds_src) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("xtask bench-gate: {e}");
            return ExitCode::from(2);
        }
    };
    let dir = PathBuf::from(dir);
    let failures = run_gate(&checks, |file| fs::read_to_string(dir.join(file)).ok());
    for f in &failures {
        println!("[gate] {f}");
    }
    println!(
        "xtask bench-gate: {} check(s), {} failure(s)",
        checks.len(),
        failures.len()
    );
    if failures.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn lint_command() -> ExitCode {
    let src_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the cargo root")
        .join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files);
    files.sort();
    let (mut strict_n, mut warn_n, mut checked) = (0usize, 0usize, 0usize);
    for path in &files {
        let Ok(src) = fs::read_to_string(path) else {
            eprintln!("xtask lint: unreadable file {}", path.display());
            return ExitCode::from(2);
        };
        let rel: String = path
            .strip_prefix(&src_root)
            .expect("walked file under src root")
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        checked += 1;
        for f in lint_source(&rel, &src) {
            let sev = if f.strict { "error" } else { "warn " };
            println!("[{sev}] src/{rel}:{} {}: {}", f.line, f.rule, f.msg);
            if f.strict {
                strict_n += 1;
            } else {
                warn_n += 1;
            }
        }
    }
    println!(
        "xtask lint: {checked} files, {strict_n} contract violation(s), {warn_n} advisory warning(s)"
    );
    if strict_n > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn print_usage() {
    eprintln!(
        "usage: cargo xtask <command>\n\n\
         commands:\n  \
         lint        check the determinism / zero-alloc / panic-freedom contracts\n  \
         bench-gate  [artifacts-dir] [thresholds.json] — gate BENCH_*.json\n              \
         artifacts against committed regression thresholds\n  \
         help        this message"
    );
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        None | Some("lint") => lint_command(),
        Some("bench-gate") => bench_gate_command(&args[1..]),
        Some("help") | Some("--help") => {
            print_usage();
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("xtask: unknown command `{other}`");
            print_usage();
            ExitCode::from(2)
        }
    }
}

// ---------------------------------------------------------------------
// Seeded-violation tests: every rule must demonstrably fire on a
// violation and stay quiet on the annotated / out-of-scope variant.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // -- scrubber -----------------------------------------------------

    #[test]
    fn scrub_blanks_comments_and_strings_but_keeps_lines() {
        let src = "let a = 1; // has .unwrap( in a comment\nlet b = \".unwrap(\";\n";
        let s = scrub(src);
        assert_eq!(s.lines().count(), src.lines().count());
        assert!(!s.contains(".unwrap("));
        assert!(s.contains("let a = 1;"));
        assert!(s.contains("let b = \"")); // delimiters survive
    }

    #[test]
    fn scrub_handles_raw_strings_nested_comments_chars_and_lifetimes() {
        let src = r##"let r = r#"HashMap "quoted" inside"#;
        /* outer /* nested HashMap */ still comment */
        let c: char = '{';
        fn life<'a>(x: &'a str) -> &'a str { x }"##;
        let s = scrub(src);
        assert!(!s.contains("HashMap"));
        assert!(s.contains("fn life<'a>"), "lifetimes must survive verbatim");
        // The char-literal '{' is blanked, so braces stay balanced.
        let opens = s.matches('{').count();
        let closes = s.matches('}').count();
        assert_eq!(opens, closes, "scrubbed text must be brace-balanced:\n{s}");
    }

    // -- R1: nondet-map ----------------------------------------------

    const R1_BAD: &str = "use std::collections::HashMap;\n\
                          pub fn f() -> HashMap<u32, f64> { HashMap::new() }\n";

    #[test]
    fn nondet_map_fires_in_numeric_modules() {
        let f = lint_source("ftfi/foo.rs", R1_BAD);
        assert!(rules(&f).contains(&"nondet-map"), "{f:?}");
        assert!(f.iter().all(|x| x.strict));
    }

    #[test]
    fn nondet_map_ignores_non_numeric_modules_and_tests() {
        assert!(rules(&lint_source("coordinator/foo.rs", R1_BAD))
            .iter()
            .all(|r| *r != "nondet-map"));
        let in_test = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n\
                       fn f() { let _m: HashMap<u32, u32> = HashMap::new(); }\n}\n";
        assert!(lint_source("tree/foo.rs", in_test).is_empty());
    }

    #[test]
    fn nondet_map_respects_allow_annotation() {
        let src = "// lint: allow(nondet-map) — scratch map, drained sorted below.\n\
                   pub fn f() { let _m = std::collections::HashMap::<u32, u32>::new(); }\n";
        assert!(lint_source("graph/foo.rs", src).is_empty());
    }

    #[test]
    fn nondet_map_not_fooled_by_comments_or_strings() {
        let src = "// HashMap would be wrong here\npub fn f() -> &'static str { \"HashMap\" }\n";
        assert!(lint_source("linalg/foo.rs", src).is_empty());
    }

    // -- R2: alloc-in-hot-path ---------------------------------------

    #[test]
    fn alloc_fires_inside_into_fns_and_manifest_fns() {
        let src = "pub fn frob_into(out: &mut [f64]) {\n    let v = Vec::new();\n}\n";
        let f = lint_source("ftfi/foo.rs", src);
        assert_eq!(rules(&f), vec!["alloc-in-hot-path"], "{f:?}");
        let src = "fn integrate_ws(&self) {\n    let v = vec![0.0; 4];\n}\n";
        assert!(rules(&lint_source("tree/foo.rs", src)).contains(&"alloc-in-hot-path"));
    }

    #[test]
    fn alloc_ignores_cold_fns_and_nested_helpers() {
        let src = "pub fn frob(out: &mut [f64]) {\n    let v = Vec::new();\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
        // Innermost-fn attribution: a nested plain helper inside a hot
        // fn is its own (cold) extent.
        let src = "pub fn frob_into(out: &mut [f64]) {\n\
                   \x20   fn helper() -> Vec<f64> {\n\
                   \x20       Vec::new()\n\
                   \x20   }\n\
                   \x20   helper();\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
    }

    #[test]
    fn alloc_respects_cold_path_annotation() {
        let src = "pub fn frob_into(out: &mut [f64]) -> Result<(), String> {\n\
                   \x20   // lint: allow(alloc-in-hot-path) — cold error path.\n\
                   \x20   Err(format!(\"bad\"))\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
    }

    // -- R3: unchecked-panic -----------------------------------------

    #[test]
    fn unchecked_panic_is_strict_in_burned_down_modules() {
        let src = "pub fn f(v: &[u32]) -> u32 {\n    *v.iter().max().unwrap()\n}\n";
        let f = lint_source("ot/foo.rs", src);
        assert_eq!(rules(&f), vec!["unchecked-panic"]);
        assert!(f[0].strict);
        // The serving wire protocol and the fault injector sit on the
        // failure path by definition: a panic there takes down exactly
        // the machinery meant to contain failures, so both are pinned
        // strict (via the coordinator/ prefix) on purpose.
        for rel in ["coordinator/protocol.rs", "coordinator/faults.rs"] {
            assert!(panic_strict(rel), "{rel} must stay panic-strict");
            let f = lint_source(rel, src);
            assert_eq!(rules(&f), vec!["unchecked-panic"]);
            assert!(f[0].strict, "{rel} finding must be strict");
        }
        // …and advisory elsewhere.
        let f = lint_source("ml/foo.rs", src);
        assert_eq!(rules(&f), vec!["unchecked-panic"]);
        assert!(!f[0].strict);
    }

    #[test]
    fn unchecked_panic_skips_debug_asserts_unwrap_or_and_exempt_files() {
        let src = "pub fn f(a: usize, v: Option<u32>) -> u32 {\n\
                   \x20   debug_assert!(a > 0);\n\
                   \x20   debug_assert_eq!(a, a);\n\
                   \x20   v.unwrap_or(0)\n}\n";
        assert!(lint_source("coordinator/foo.rs", src).is_empty());
        let src = "pub fn f(a: usize) { assert!(a > 0); }\n";
        assert!(lint_source("tree/invariants.rs", src).is_empty());
        assert!(lint_source("bench_util.rs", src).is_empty());
    }

    #[test]
    fn unchecked_panic_respects_infallible_annotation() {
        let src = "pub fn f(v: &[u32]) -> u32 {\n\
                   \x20   // lint: infallible because the caller checked non-emptiness.\n\
                   \x20   *v.iter().max().unwrap()\n}\n";
        assert!(lint_source("ot/foo.rs", src).is_empty());
    }

    #[test]
    fn suppression_window_is_bounded() {
        // A directive more than SUPPRESS_WINDOW lines above must NOT
        // reach the finding.
        let src = "// lint: infallible because of reasons far away.\n\n\n\n\n\n\n\
                   pub fn f(v: &[u32]) -> u32 { *v.iter().max().unwrap() }\n";
        let f = lint_source("ot/foo.rs", src);
        assert_eq!(rules(&f), vec!["unchecked-panic"]);
    }

    // -- R4: unordered-float-reduction -------------------------------

    #[test]
    fn unordered_reduction_fires_on_hashed_sources_only() {
        let src = "pub fn f() -> f64 {\n\
                   \x20   let m: std::collections::HashMap<u32, f64> = Default::default();\n\
                   \x20   m.values().sum()\n}\n";
        let f = lint_source("coordinator/foo.rs", src);
        assert!(rules(&f).contains(&"unordered-float-reduction"), "{f:?}");
        let src = "pub fn f(v: &[f64]) -> f64 { v.iter().sum() }\n";
        assert!(lint_source("coordinator/foo.rs", src).is_empty());
    }

    #[test]
    fn unordered_reduction_respects_allow_annotation() {
        let src = "pub fn f() -> f64 {\n\
                   \x20   let m: std::collections::HashMap<u32, f64> = Default::default();\n\
                   \x20   // lint: allow(unordered-float-reduction) — counts, not floats.\n\
                   \x20   m.values().sum()\n}\n";
        let f = lint_source("coordinator/foo.rs", src);
        assert!(!rules(&f).contains(&"unordered-float-reduction"), "{f:?}");
    }

    // -- structure helpers -------------------------------------------

    #[test]
    fn fn_extents_track_nesting_and_skip_fn_pointer_types() {
        let src = "fn outer() {\n    fn inner() {}\n}\ntype F = fn(usize) -> u8;\nfn last() {}\n";
        let e = fn_extents(&scrub(src));
        let names: Vec<&str> = e.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner", "last"]);
        assert_eq!((e[0].start, e[0].end), (1, 3));
        assert_eq!((e[1].start, e[1].end), (2, 2));
    }

    #[test]
    fn test_spans_cover_cfg_test_mods_and_test_fns() {
        let src = "fn live() {}\n#[cfg(all(test, not(loom)))]\nmod tests {\n    fn t() {}\n}\n";
        let spans = test_spans(&scrub(src));
        assert_eq!(spans, vec![(2, 5)]);
        let src = "#[cfg(feature = \"pjrt\")]\nfn gated() {}\n";
        assert!(test_spans(&scrub(src)).is_empty(), "a non-test cfg is not a test span");
    }

    // -- R5: mixed-precision-cast ------------------------------------

    const R5_BAD: &str = "pub fn f(x: f64) -> f64 {\n    (x as f32) as f64\n}\n";

    #[test]
    fn mixed_precision_cast_fires_in_numeric_core() {
        let f = lint_source("ftfi/foo.rs", R5_BAD);
        assert!(rules(&f).contains(&"mixed-precision-cast"), "{f:?}");
        assert!(f.iter().all(|x| x.strict));
        assert!(rules(&lint_source("tree/foo.rs", "fn g(n: usize) -> f64 { n as f64 }\n"))
            .contains(&"mixed-precision-cast"));
    }

    #[test]
    fn mixed_precision_cast_exempts_lane_module_and_other_dirs() {
        // linalg/lanes.rs is where the tier casts are supposed to live.
        assert!(lint_source("linalg/lanes.rs", R5_BAD).is_empty());
        // Outside the numeric core the rule does not apply at all.
        assert!(lint_source("coordinator/foo.rs", R5_BAD).is_empty());
        assert!(lint_source("ml/foo.rs", R5_BAD).is_empty());
    }

    #[test]
    fn mixed_precision_cast_respects_allow_annotation_and_tests() {
        let src = "pub fn f(n: usize) -> f64 {\n\
                   \x20   // lint: allow(mixed-precision-cast) — index to coordinate.\n\
                   \x20   n as f64\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
        let in_test =
            "#[cfg(test)]\nmod tests {\n    fn t(n: usize) -> f64 { n as f64 }\n}\n";
        assert!(lint_source("linalg/foo.rs", in_test).is_empty());
        // Comments and strings are scrubbed before matching.
        let doc = "/// Binomial coefficient as f64.\npub fn f() {}\n";
        assert!(lint_source("ftfi/foo.rs", doc).is_empty());
    }

    // -- bench-gate: JSON parser + selector --------------------------

    #[test]
    fn json_parser_handles_bench_dialect() {
        let src = "{\"bench\": \"x\", \"quick\": true, \"rel_err\": 1.234e-10,\n\
                   \"results\": [{\"speedup\": 2.5}, {\"speedup\": -0.5}], \"pad\": null}";
        let v = parse_json(src).unwrap();
        assert_eq!(v.get("bench"), Some(&Json::Str("x".to_string())));
        assert_eq!(v.get("quick"), Some(&Json::Bool(true)));
        assert_eq!(v.get("rel_err"), Some(&Json::Num(1.234e-10)));
        assert_eq!(select(&v, "results[*].speedup").unwrap(), vec![2.5, -0.5]);
        assert_eq!(select(&v, "results[0].speedup").unwrap(), vec![2.5]);
        assert_eq!(select(&v, "results[last].speedup").unwrap(), vec![-0.5]);
        // Bare NaN (what format! prints for f64::NAN) must parse, so
        // the gate — not the parser — rejects it.
        let v = parse_json("{\"x\": NaN, \"y\": -inf}").unwrap();
        assert!(matches!(v.get("x"), Some(Json::Num(x)) if x.is_nan()));
        assert!(matches!(v.get("y"), Some(Json::Num(x)) if *x == f64::NEG_INFINITY));
        assert!(parse_json("{\"x\": }").is_err());
        assert!(parse_json("{\"x\": 1} trailing").is_err());
    }

    #[test]
    fn selector_errors_on_missing_structure() {
        let v = parse_json("{\"results\": [{\"speedup\": 1.0}]}").unwrap();
        assert!(select(&v, "results[*].missing").is_err());
        assert!(select(&v, "absent[*].speedup").is_err());
        assert!(select(&v, "results[7].speedup").is_err());
        let empty = parse_json("{\"results\": []}").unwrap();
        assert!(select(&empty, "results[last].speedup").is_err());
        assert_eq!(select(&empty, "results[*].speedup").unwrap(), Vec::<f64>::new());
    }

    // -- bench-gate: evaluation --------------------------------------

    const GOOD_BENCH: &str = "{\"bench\": \"hotpath_alloc\", \"results\": [\n\
        {\"speedup\": 1.8, \"allocs_workspace\": 0},\n\
        {\"speedup\": 2.4, \"allocs_workspace\": 0}]}";

    #[test]
    fn gate_passes_on_good_artifact() {
        let speedup = Check {
            file: "BENCH_hotpath.json".to_string(),
            field: "results[*].speedup".to_string(),
            min: Some(1.0),
            max: None,
        };
        let allocs = Check {
            file: "BENCH_hotpath.json".to_string(),
            field: "results[*].allocs_workspace".to_string(),
            min: None,
            max: Some(0.0),
        };
        assert!(evaluate_check(&speedup, GOOD_BENCH).is_ok());
        assert!(evaluate_check(&allocs, GOOD_BENCH).is_ok());
    }

    #[test]
    fn gate_trips_on_seeded_regression() {
        // A speedup below the committed floor is the canonical seeded
        // regression: the gate must fail, not warn.
        let check = Check {
            file: "BENCH_hotpath.json".to_string(),
            field: "results[*].speedup".to_string(),
            min: Some(2.0),
            max: None,
        };
        let err = evaluate_check(&check, GOOD_BENCH).unwrap_err();
        assert!(err.contains("below minimum"), "{err}");
        // …and an allocation creeping back in trips the max bound.
        let regressed = "{\"results\": [{\"allocs_workspace\": 3}]}";
        let check = Check {
            file: "BENCH_hotpath.json".to_string(),
            field: "results[*].allocs_workspace".to_string(),
            min: None,
            max: Some(0.0),
        };
        assert!(evaluate_check(&check, regressed).unwrap_err().contains("above maximum"));
    }

    #[test]
    fn gate_fails_on_missing_field_nan_and_empty_match() {
        let check = |field: &str| Check {
            file: "b.json".to_string(),
            field: field.to_string(),
            min: Some(0.0),
            max: None,
        };
        assert!(evaluate_check(&check("results[*].speedup"), "{\"results\": [{}]}").is_err());
        assert!(evaluate_check(&check("speedup"), "{\"speedup\": NaN}")
            .unwrap_err()
            .contains("non-finite"));
        assert!(evaluate_check(&check("results[*].speedup"), "{\"results\": []}")
            .unwrap_err()
            .contains("matched no values"));
        assert!(evaluate_check(&check("speedup"), "not json at all").is_err());
    }

    #[test]
    fn gate_fails_on_missing_artifact_file() {
        let checks = vec![Check {
            file: "BENCH_gone.json".to_string(),
            field: "results[*].speedup".to_string(),
            min: Some(1.0),
            max: None,
        }];
        let failures = run_gate(&checks, |_| None);
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("artifact missing"));
        // Injected in-memory artifact: same checks, good data, no failures.
        let failures = run_gate(&checks, |_| {
            Some("{\"results\": [{\"speedup\": 1.5}]}".to_string())
        });
        assert!(failures.is_empty(), "{failures:?}");
    }

    #[test]
    fn thresholds_parser_rejects_malformed_entries() {
        let good = "{\"checks\": [\
            {\"file\": \"BENCH_x.json\", \"field\": \"results[*].speedup\", \"min\": 1.0}]}";
        let checks = parse_thresholds(good).unwrap();
        assert_eq!(checks.len(), 1);
        assert_eq!(checks[0].min, Some(1.0));
        assert!(parse_thresholds("{\"checks\": []}").is_err());
        assert!(parse_thresholds("{}").is_err());
        // A check with neither bound can never fail — reject it.
        let unbounded =
            "{\"checks\": [{\"file\": \"a.json\", \"field\": \"results[*].speedup\"}]}";
        assert!(parse_thresholds(unbounded).is_err());
    }
}
