//! `cargo xtask` — repo automation as plain Rust (no shell, no deps).
//!
//! The one command that matters for CI is `cargo xtask lint`: a
//! contract linter that machine-checks the determinism, zero-alloc and
//! panic-freedom conventions DESIGN.md promises, on the real source
//! tree. It is deliberately token/structure-based (a scrubbing lexer
//! plus brace matching, not a full parser): cheap, dependency-free and
//! precise enough once comments/strings are blanked out.
//!
//! Rules (see DESIGN.md "Verification & static analysis"):
//!
//! * `nondet-map` — `HashMap`/`HashSet` in the numeric modules
//!   (`ftfi/`, `tree/`, `linalg/`, `ot/`, `graph/`). Iteration order of
//!   hashed containers is seeded per process, and PR 6 turned exactly
//!   that into a cross-process nondeterminism bug twice; numeric code
//!   uses `BTreeMap`/`BTreeSet` or sorted `Vec`s instead.
//! * `alloc-in-hot-path` — allocation-capable calls inside the
//!   zero-alloc contract surface: any `fn` whose name ends in `_into`
//!   plus the hot-path manifest below. Cold validation/error arms are
//!   annotated in place.
//! * `unchecked-panic` — `.unwrap(` / `.expect(` / `panic!` /
//!   `assert!`-family in non-test library code. Strict (CI-failing) in
//!   the burned-down modules; advisory elsewhere; `debug_assert*` is
//!   always fine (that is what the invariants layer is made of).
//! * `unordered-float-reduction` — float reductions (`.sum`/`.fold`/
//!   `.product`) over a variable declared as a hashed container: order
//!   nondeterminism straight into a float accumulator.
//!
//! Suppression: a `// lint: allow(<rule>) — reason` or
//! `// lint: infallible because <proof>` comment on the offending line
//! or up to [`SUPPRESS_WINDOW`] lines above it. The reason is part of
//! the grammar on purpose: every allowlisted site carries its own
//! justification in the diff.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// A directive covers findings on `[directive_line, directive_line + SUPPRESS_WINDOW]`,
/// so a multi-line justification comment still reaches the code below it.
const SUPPRESS_WINDOW: usize = 5;

/// Hot-path functions under the zero-alloc contract that do not carry
/// the `_into` suffix (the recursive workspace walkers and the pooled
/// entry points), pinned by `tests/hotpath_alloc.rs`.
const HOT_PATH_MANIFEST: [&str; 4] = [
    "integrate_ws",
    "integrate_ws_delta",
    "integrate_prepared_into_pooled",
    "integrate_delta_prepared_into_pooled",
];

/// Tokens that can allocate. `checkout_workspace`/`checkout_scratch`
/// are deliberately NOT tokens: growing the arena stock is the defined
/// warm-up, and the counting-allocator test pins the warmed steady
/// state.
const ALLOC_TOKENS: [&str; 12] = [
    "Vec::new(",
    "vec![",
    ".to_vec(",
    ".collect(",
    ".clone(",
    ".cloned(",
    "format!(",
    ".to_string(",
    "String::new(",
    "Box::new(",
    ".to_owned(",
    "with_capacity(",
];

/// Numeric modules where hashed containers are banned outright.
const NONDET_MAP_DIRS: [&str; 5] = ["ftfi/", "tree/", "linalg/", "ot/", "graph/"];

/// Modules where `unchecked-panic` fails CI (the completed burn-down
/// surface: fallible APIs exist, every remaining site is annotated).
fn panic_strict(rel: &str) -> bool {
    rel == "ftfi/vandermonde.rs"
        || rel.starts_with("ot/")
        || rel.starts_with("coordinator/")
        || rel == "runtime/pool.rs"
}

/// Modules exempt from `unchecked-panic` entirely: the invariants layer
/// IS assertions by design, and bench_util's counting allocator aborts
/// on misuse on purpose.
fn panic_exempt(rel: &str) -> bool {
    rel == "tree/invariants.rs" || rel == "bench_util.rs"
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Finding {
    rule: &'static str,
    line: usize,
    strict: bool,
    msg: String,
}

// ---------------------------------------------------------------------
// Scrubbing lexer
// ---------------------------------------------------------------------

/// Blank comments and string/char-literal contents with spaces,
/// preserving newlines (and therefore line numbers) exactly. Handles
/// line comments, nested block comments, escapes, raw strings
/// (`r"…"` / `r#"…"#` / `br#"…"#`) and char-literal vs lifetime
/// disambiguation. String delimiters are kept so call tokens like
/// `.expect(` stay visible while their payload does not.
fn scrub(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let blank = |out: &mut String, c: char| out.push(if c == '\n' { '\n' } else { ' ' });
    while i < b.len() {
        let c = b[i];
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and byte-raw) strings: r"…", r#"…"#, br##"…"##.
        if (c == 'r' || c == 'b') && !prev_is_ident(&b, i) {
            let mut j = i;
            if b[j] == 'b' && b.get(j + 1) == Some(&'r') {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while b.get(k) == Some(&'#') {
                    hashes += 1;
                    k += 1;
                }
                if b.get(k) == Some(&'"') {
                    for &p in &b[i..=k] {
                        out.push(p);
                    }
                    i = k + 1;
                    while i < b.len() {
                        if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                            out.push('"');
                            for _ in 0..hashes {
                                out.push('#');
                            }
                            i += 1 + hashes;
                            break;
                        }
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                    continue;
                }
            }
        }
        if c == '"' {
            out.push('"');
            i += 1;
            while i < b.len() {
                if b[i] == '\\' && i + 1 < b.len() {
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        if c == '\'' {
            if b.get(i + 1) == Some(&'\\') {
                // Escaped char literal: '\n', '\u{7f}', …
                out.push('\'');
                i += 1;
                while i < b.len() && b[i] != '\'' {
                    if b[i] == '\\' && i + 1 < b.len() {
                        out.push_str("  ");
                        i += 2;
                    } else {
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                }
                if i < b.len() {
                    out.push('\'');
                    i += 1;
                }
            } else if b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\'') {
                // Plain char literal 'x'.
                out.push_str("' '");
                i += 3;
            } else {
                // Lifetime: keep as-is.
                out.push('\'');
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

// ---------------------------------------------------------------------
// Structure: test spans, fn extents, directives
// ---------------------------------------------------------------------

/// Inclusive 1-indexed line spans of `#[cfg(…test…)]` / `#[test]`
/// items (computed on scrubbed text so braces in strings cannot
/// confuse the matcher).
fn test_spans(scrubbed: &str) -> Vec<(usize, usize)> {
    let b: Vec<char> = scrubbed.chars().collect();
    let line_of = line_index(&b);
    let mut spans = Vec::new();
    let mut i = 0;
    while i + 1 < b.len() {
        if !(b[i] == '#' && b[i + 1] == '[') {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut content = String::new();
        while j < b.len() && depth > 0 {
            match b[j] {
                '[' => depth += 1,
                ']' => depth -= 1,
                c => content.push(c),
            }
            j += 1;
        }
        let is_test_attr = {
            let t = content.trim();
            t == "test" || (t.starts_with("cfg") && has_word(&content, "test"))
        };
        if !is_test_attr {
            i = j;
            continue;
        }
        // Item extent: forward to the first `{` (brace-match) or `;`.
        let mut k = j;
        while k < b.len() && b[k] != '{' && b[k] != ';' {
            k += 1;
        }
        let end = if k < b.len() && b[k] == '{' {
            let mut d = 1usize;
            let mut m = k + 1;
            while m < b.len() && d > 0 {
                match b[m] {
                    '{' => d += 1,
                    '}' => d -= 1,
                    _ => {}
                }
                m += 1;
            }
            m.saturating_sub(1)
        } else {
            k.min(b.len().saturating_sub(1))
        };
        spans.push((line_of[attr_start], line_of[end.min(line_of.len() - 1)]));
        i = j;
    }
    spans
}

#[derive(Debug)]
struct FnExtent {
    name: String,
    start: usize,
    end: usize,
}

/// Extents (inclusive 1-indexed line ranges) of every `fn` item, for
/// innermost-function attribution of hot-path findings. Closures do
/// not open a new extent — a closure inside a `_into` fn is still on
/// the hot path; a nested helper `fn` is not.
fn fn_extents(scrubbed: &str) -> Vec<FnExtent> {
    let b: Vec<char> = scrubbed.chars().collect();
    let line_of = line_index(&b);
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < b.len() {
        let word_fn = b[i] == 'f'
            && b[i + 1] == 'n'
            && !prev_is_ident(&b, i)
            && b.get(i + 2).map_or(true, |c| !(c.is_alphanumeric() || *c == '_'));
        if !word_fn {
            i += 1;
            continue;
        }
        let start_line = line_of[i];
        let mut j = i + 2;
        while j < b.len() && b[j].is_whitespace() {
            j += 1;
        }
        let mut name = String::new();
        while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
            name.push(b[j]);
            j += 1;
        }
        if name.is_empty() {
            // `fn(..)` pointer type, not an item.
            i = j.max(i + 2);
            continue;
        }
        // Signature → first `{` (body) or `;` (trait declaration).
        let mut k = j;
        while k < b.len() && b[k] != '{' && b[k] != ';' {
            k += 1;
        }
        if k >= b.len() || b[k] == ';' {
            i = k.min(b.len());
            continue;
        }
        let mut d = 1usize;
        let mut m = k + 1;
        while m < b.len() && d > 0 {
            match b[m] {
                '{' => d += 1,
                '}' => d -= 1,
                _ => {}
            }
            m += 1;
        }
        let end_line = line_of[m.saturating_sub(1).min(line_of.len() - 1)];
        out.push(FnExtent { name, start: start_line, end: end_line });
        i = j;
    }
    out
}

/// For every char index, the 1-indexed line it sits on.
fn line_index(b: &[char]) -> Vec<usize> {
    let mut out = Vec::with_capacity(b.len());
    let mut line = 1usize;
    for &c in b {
        out.push(line);
        if c == '\n' {
            line += 1;
        }
    }
    if out.is_empty() {
        out.push(1);
    }
    out
}

#[derive(Debug)]
struct Directive {
    line: usize,
    rule: String,
}

/// `// lint:` directives, collected from the RAW source (the scrubber
/// blanks them). `infallible` is shorthand for `allow(unchecked-panic)`.
fn collect_directives(src: &str) -> Vec<Directive> {
    let mut out = Vec::new();
    for (idx, line) in src.lines().enumerate() {
        let Some(pos) = line.find("// lint:") else { continue };
        let rest = line[pos + "// lint:".len()..].trim_start();
        let rule = if rest.starts_with("infallible") {
            "unchecked-panic".to_string()
        } else if let Some(a) = rest.find("allow(") {
            rest[a + "allow(".len()..].split(')').next().unwrap_or("").trim().to_string()
        } else {
            continue;
        };
        out.push(Directive { line: idx + 1, rule });
    }
    out
}

fn suppressed(directives: &[Directive], rule: &str, line: usize) -> bool {
    directives
        .iter()
        .any(|d| d.rule == rule && d.line <= line && line <= d.line + SUPPRESS_WINDOW)
}

// ---------------------------------------------------------------------
// Token helpers
// ---------------------------------------------------------------------

/// Whole-word occurrence (non-identifier chars on both sides).
fn has_word(hay: &str, word: &str) -> bool {
    let hb = hay.as_bytes();
    let mut start = 0;
    while let Some(p) = hay[start..].find(word) {
        let abs = start + p;
        let left_ok = abs == 0 || !(hb[abs - 1].is_ascii_alphanumeric() || hb[abs - 1] == b'_');
        let r = abs + word.len();
        let right_ok = r >= hb.len() || !(hb[r].is_ascii_alphanumeric() || hb[r] == b'_');
        if left_ok && right_ok {
            return true;
        }
        start = abs + word.len();
    }
    false
}

/// First panic-capable token on the line, if any. `debug_assert*` never
/// matches (the `assert!` family is checked with a left word boundary),
/// and `.unwrap_or*` / `.expect_err(` never match the `(`-anchored
/// method tokens.
fn panic_token(line: &str) -> Option<&'static str> {
    for t in [".unwrap(", ".expect("] {
        if line.contains(t) {
            return Some(t);
        }
    }
    let lb = line.as_bytes();
    for t in ["panic!", "assert!", "assert_eq!", "assert_ne!"] {
        let mut start = 0;
        while let Some(p) = line[start..].find(t) {
            let abs = start + p;
            let left_ok =
                abs == 0 || !(lb[abs - 1].is_ascii_alphanumeric() || lb[abs - 1] == b'_');
            if left_ok {
                return Some(t);
            }
            start = abs + t.len();
        }
    }
    None
}

// ---------------------------------------------------------------------
// The linter core
// ---------------------------------------------------------------------

/// Lint one file. `rel` is the path relative to `src/` with `/`
/// separators (e.g. `"tree/integrator_tree.rs"`).
fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let scrubbed = scrub(src);
    let directives = collect_directives(src);
    let tests = test_spans(&scrubbed);
    let fns = fn_extents(&scrubbed);
    let in_test = |line: usize| tests.iter().any(|&(s, e)| s <= line && line <= e);
    let innermost = |line: usize| {
        fns.iter()
            .filter(|f| f.start <= line && line <= f.end)
            .max_by_key(|f| f.start)
    };
    let hot = |name: &str| name.ends_with("_into") || HOT_PATH_MANIFEST.contains(&name);

    let numeric = NONDET_MAP_DIRS.iter().any(|d| rel.starts_with(*d));
    let r3_strict = panic_strict(rel);
    let r3_exempt = panic_exempt(rel);

    // R4 preparation: variables declared with a hashed-container type.
    let mut hashed_vars: Vec<String> = Vec::new();
    for line in scrubbed.lines() {
        if (line.contains("HashMap") || line.contains("HashSet")) && has_word(line, "let") {
            let after = line.split_once("let ").map(|(_, a)| a).unwrap_or("");
            let after = after.strip_prefix("mut ").unwrap_or(after);
            let name: String = after
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                hashed_vars.push(name);
            }
        }
    }

    let mut findings = Vec::new();
    for (idx, line) in scrubbed.lines().enumerate() {
        let lno = idx + 1;
        if in_test(lno) {
            continue;
        }
        // R1: nondeterministic containers in numeric modules.
        if numeric && (has_word(line, "HashMap") || has_word(line, "HashSet")) {
            if !suppressed(&directives, "nondet-map", lno) {
                findings.push(Finding {
                    rule: "nondet-map",
                    line: lno,
                    strict: true,
                    msg: "hashed container in a numeric module (iteration order is \
                          process-seeded; use BTreeMap/BTreeSet or a sorted Vec)"
                        .to_string(),
                });
            }
        }
        // R2: allocation inside the zero-alloc contract surface.
        if let Some(f) = innermost(lno) {
            if hot(&f.name) {
                for t in ALLOC_TOKENS {
                    if line.contains(t) && !suppressed(&directives, "alloc-in-hot-path", lno) {
                        findings.push(Finding {
                            rule: "alloc-in-hot-path",
                            line: lno,
                            strict: true,
                            msg: format!(
                                "`{t}` inside hot-path fn `{}` (zero-alloc contract; annotate \
                                 cold error arms with `// lint: allow(alloc-in-hot-path)`)",
                                f.name
                            ),
                        });
                        break;
                    }
                }
            }
        }
        // R3: unchecked panics in library code.
        if !r3_exempt {
            if let Some(t) = panic_token(line) {
                if !suppressed(&directives, "unchecked-panic", lno) {
                    findings.push(Finding {
                        rule: "unchecked-panic",
                        line: lno,
                        strict: r3_strict,
                        msg: format!(
                            "`{t}` in non-test library code (return FtfiError/ServerError, or \
                             justify with `// lint: infallible because …`)"
                        ),
                    });
                }
            }
        }
        // R4: float reduction over a hashed container.
        let reduces =
            line.contains(".sum(") || line.contains(".fold(") || line.contains(".product(");
        if reduces {
            let over_hashed = hashed_vars.iter().any(|v| {
                let mut s = 0;
                let needle = format!("{v}.");
                while let Some(p) = line[s..].find(&needle) {
                    let abs = s + p;
                    let lb = line.as_bytes();
                    if abs == 0 || !(lb[abs - 1].is_ascii_alphanumeric() || lb[abs - 1] == b'_') {
                        return true;
                    }
                    s = abs + needle.len();
                }
                false
            });
            if over_hashed && !suppressed(&directives, "unordered-float-reduction", lno) {
                findings.push(Finding {
                    rule: "unordered-float-reduction",
                    line: lno,
                    strict: true,
                    msg: "reduction over a hashed container (iteration order is nondeterministic \
                          and float addition is not associative)"
                        .to_string(),
                });
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn lint_command() -> ExitCode {
    let src_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the cargo root")
        .join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files);
    files.sort();
    let (mut strict_n, mut warn_n, mut checked) = (0usize, 0usize, 0usize);
    for path in &files {
        let Ok(src) = fs::read_to_string(path) else {
            eprintln!("xtask lint: unreadable file {}", path.display());
            return ExitCode::from(2);
        };
        let rel: String = path
            .strip_prefix(&src_root)
            .expect("walked file under src root")
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        checked += 1;
        for f in lint_source(&rel, &src) {
            let sev = if f.strict { "error" } else { "warn " };
            println!("[{sev}] src/{rel}:{} {}: {}", f.line, f.rule, f.msg);
            if f.strict {
                strict_n += 1;
            } else {
                warn_n += 1;
            }
        }
    }
    println!(
        "xtask lint: {checked} files, {strict_n} contract violation(s), {warn_n} advisory warning(s)"
    );
    if strict_n > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn print_usage() {
    eprintln!(
        "usage: cargo xtask <command>\n\n\
         commands:\n  \
         lint    check the determinism / zero-alloc / panic-freedom contracts\n  \
         help    this message"
    );
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        None | Some("lint") => lint_command(),
        Some("help") | Some("--help") => {
            print_usage();
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("xtask: unknown command `{other}`");
            print_usage();
            ExitCode::from(2)
        }
    }
}

// ---------------------------------------------------------------------
// Seeded-violation tests: every rule must demonstrably fire on a
// violation and stay quiet on the annotated / out-of-scope variant.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // -- scrubber -----------------------------------------------------

    #[test]
    fn scrub_blanks_comments_and_strings_but_keeps_lines() {
        let src = "let a = 1; // has .unwrap( in a comment\nlet b = \".unwrap(\";\n";
        let s = scrub(src);
        assert_eq!(s.lines().count(), src.lines().count());
        assert!(!s.contains(".unwrap("));
        assert!(s.contains("let a = 1;"));
        assert!(s.contains("let b = \"")); // delimiters survive
    }

    #[test]
    fn scrub_handles_raw_strings_nested_comments_chars_and_lifetimes() {
        let src = r##"let r = r#"HashMap "quoted" inside"#;
        /* outer /* nested HashMap */ still comment */
        let c: char = '{';
        fn life<'a>(x: &'a str) -> &'a str { x }"##;
        let s = scrub(src);
        assert!(!s.contains("HashMap"));
        assert!(s.contains("fn life<'a>"), "lifetimes must survive verbatim");
        // The char-literal '{' is blanked, so braces stay balanced.
        let opens = s.matches('{').count();
        let closes = s.matches('}').count();
        assert_eq!(opens, closes, "scrubbed text must be brace-balanced:\n{s}");
    }

    // -- R1: nondet-map ----------------------------------------------

    const R1_BAD: &str = "use std::collections::HashMap;\n\
                          pub fn f() -> HashMap<u32, f64> { HashMap::new() }\n";

    #[test]
    fn nondet_map_fires_in_numeric_modules() {
        let f = lint_source("ftfi/foo.rs", R1_BAD);
        assert!(rules(&f).contains(&"nondet-map"), "{f:?}");
        assert!(f.iter().all(|x| x.strict));
    }

    #[test]
    fn nondet_map_ignores_non_numeric_modules_and_tests() {
        assert!(rules(&lint_source("coordinator/foo.rs", R1_BAD))
            .iter()
            .all(|r| *r != "nondet-map"));
        let in_test = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n\
                       fn f() { let _m: HashMap<u32, u32> = HashMap::new(); }\n}\n";
        assert!(lint_source("tree/foo.rs", in_test).is_empty());
    }

    #[test]
    fn nondet_map_respects_allow_annotation() {
        let src = "// lint: allow(nondet-map) — scratch map, drained sorted below.\n\
                   pub fn f() { let _m = std::collections::HashMap::<u32, u32>::new(); }\n";
        assert!(lint_source("graph/foo.rs", src).is_empty());
    }

    #[test]
    fn nondet_map_not_fooled_by_comments_or_strings() {
        let src = "// HashMap would be wrong here\npub fn f() -> &'static str { \"HashMap\" }\n";
        assert!(lint_source("linalg/foo.rs", src).is_empty());
    }

    // -- R2: alloc-in-hot-path ---------------------------------------

    #[test]
    fn alloc_fires_inside_into_fns_and_manifest_fns() {
        let src = "pub fn frob_into(out: &mut [f64]) {\n    let v = Vec::new();\n}\n";
        let f = lint_source("ftfi/foo.rs", src);
        assert_eq!(rules(&f), vec!["alloc-in-hot-path"], "{f:?}");
        let src = "fn integrate_ws(&self) {\n    let v = vec![0.0; 4];\n}\n";
        assert!(rules(&lint_source("tree/foo.rs", src)).contains(&"alloc-in-hot-path"));
    }

    #[test]
    fn alloc_ignores_cold_fns_and_nested_helpers() {
        let src = "pub fn frob(out: &mut [f64]) {\n    let v = Vec::new();\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
        // Innermost-fn attribution: a nested plain helper inside a hot
        // fn is its own (cold) extent.
        let src = "pub fn frob_into(out: &mut [f64]) {\n\
                   \x20   fn helper() -> Vec<f64> {\n\
                   \x20       Vec::new()\n\
                   \x20   }\n\
                   \x20   helper();\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
    }

    #[test]
    fn alloc_respects_cold_path_annotation() {
        let src = "pub fn frob_into(out: &mut [f64]) -> Result<(), String> {\n\
                   \x20   // lint: allow(alloc-in-hot-path) — cold error path.\n\
                   \x20   Err(format!(\"bad\"))\n}\n";
        assert!(lint_source("ftfi/foo.rs", src).is_empty());
    }

    // -- R3: unchecked-panic -----------------------------------------

    #[test]
    fn unchecked_panic_is_strict_in_burned_down_modules() {
        let src = "pub fn f(v: &[u32]) -> u32 {\n    *v.iter().max().unwrap()\n}\n";
        let f = lint_source("ot/foo.rs", src);
        assert_eq!(rules(&f), vec!["unchecked-panic"]);
        assert!(f[0].strict);
        // …and advisory elsewhere.
        let f = lint_source("ml/foo.rs", src);
        assert_eq!(rules(&f), vec!["unchecked-panic"]);
        assert!(!f[0].strict);
    }

    #[test]
    fn unchecked_panic_skips_debug_asserts_unwrap_or_and_exempt_files() {
        let src = "pub fn f(a: usize, v: Option<u32>) -> u32 {\n\
                   \x20   debug_assert!(a > 0);\n\
                   \x20   debug_assert_eq!(a, a);\n\
                   \x20   v.unwrap_or(0)\n}\n";
        assert!(lint_source("coordinator/foo.rs", src).is_empty());
        let src = "pub fn f(a: usize) { assert!(a > 0); }\n";
        assert!(lint_source("tree/invariants.rs", src).is_empty());
        assert!(lint_source("bench_util.rs", src).is_empty());
    }

    #[test]
    fn unchecked_panic_respects_infallible_annotation() {
        let src = "pub fn f(v: &[u32]) -> u32 {\n\
                   \x20   // lint: infallible because the caller checked non-emptiness.\n\
                   \x20   *v.iter().max().unwrap()\n}\n";
        assert!(lint_source("ot/foo.rs", src).is_empty());
    }

    #[test]
    fn suppression_window_is_bounded() {
        // A directive more than SUPPRESS_WINDOW lines above must NOT
        // reach the finding.
        let src = "// lint: infallible because of reasons far away.\n\n\n\n\n\n\n\
                   pub fn f(v: &[u32]) -> u32 { *v.iter().max().unwrap() }\n";
        let f = lint_source("ot/foo.rs", src);
        assert_eq!(rules(&f), vec!["unchecked-panic"]);
    }

    // -- R4: unordered-float-reduction -------------------------------

    #[test]
    fn unordered_reduction_fires_on_hashed_sources_only() {
        let src = "pub fn f() -> f64 {\n\
                   \x20   let m: std::collections::HashMap<u32, f64> = Default::default();\n\
                   \x20   m.values().sum()\n}\n";
        let f = lint_source("coordinator/foo.rs", src);
        assert!(rules(&f).contains(&"unordered-float-reduction"), "{f:?}");
        let src = "pub fn f(v: &[f64]) -> f64 { v.iter().sum() }\n";
        assert!(lint_source("coordinator/foo.rs", src).is_empty());
    }

    #[test]
    fn unordered_reduction_respects_allow_annotation() {
        let src = "pub fn f() -> f64 {\n\
                   \x20   let m: std::collections::HashMap<u32, f64> = Default::default();\n\
                   \x20   // lint: allow(unordered-float-reduction) — counts, not floats.\n\
                   \x20   m.values().sum()\n}\n";
        let f = lint_source("coordinator/foo.rs", src);
        assert!(!rules(&f).contains(&"unordered-float-reduction"), "{f:?}");
    }

    // -- structure helpers -------------------------------------------

    #[test]
    fn fn_extents_track_nesting_and_skip_fn_pointer_types() {
        let src = "fn outer() {\n    fn inner() {}\n}\ntype F = fn(usize) -> u8;\nfn last() {}\n";
        let e = fn_extents(&scrub(src));
        let names: Vec<&str> = e.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner", "last"]);
        assert_eq!((e[0].start, e[0].end), (1, 3));
        assert_eq!((e[1].start, e[1].end), (2, 2));
    }

    #[test]
    fn test_spans_cover_cfg_test_mods_and_test_fns() {
        let src = "fn live() {}\n#[cfg(all(test, not(loom)))]\nmod tests {\n    fn t() {}\n}\n";
        let spans = test_spans(&scrub(src));
        assert_eq!(spans, vec![(2, 5)]);
        let src = "#[cfg(feature = \"pjrt\")]\nfn gated() {}\n";
        assert!(test_spans(&scrub(src)).is_empty(), "a non-test cfg is not a test span");
    }
}
