//! Property-based equivalence suite: FTFI must agree with the brute-force
//! integrator across randomized trees, fields, function classes, leaf
//! thresholds and forced strategies. The offline environment has no
//! proptest crate, so this uses seeded random sweeps (large case counts,
//! deterministic seeds — failures print the seed for replay).

use ftfi::ftfi::brute::{btfi, btfi_streaming};
use ftfi::ftfi::cordial::{cross_apply, cross_apply_dense, CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{random_rational_tree, random_tree};
use ftfi::graph::{generators, mst::minimum_spanning_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::tree::Tree;
use ftfi::TreeFieldIntegrator;

fn f_pool(rng: &mut Pcg) -> Vec<(FDist, f64)> {
    vec![
        (FDist::Identity, 1e-9),
        (FDist::Polynomial(vec![rng.normal(), rng.normal(), rng.normal() * 0.3]), 1e-8),
        (FDist::Exponential { lambda: rng.uniform_in(-1.0, -0.1), scale: 1.0 }, 1e-9),
        (
            FDist::PolyExp {
                coeffs: vec![1.0, rng.uniform_in(-0.5, 0.5)],
                lambda: rng.uniform_in(-0.8, -0.1),
            },
            1e-9,
        ),
        (
            FDist::Trig {
                omega: rng.uniform_in(0.2, 1.5),
                phase: rng.uniform_in(0.0, 1.0),
                scale: 1.0,
            },
            1e-9,
        ),
        (FDist::inverse_quadratic(rng.uniform_in(0.1, 2.0)), 1e-6),
        (
            FDist::ExpOverLinear { lambda: rng.uniform_in(-0.5, 0.0), c: rng.uniform_in(0.5, 2.0) },
            1e-6,
        ),
        (FDist::gaussian(rng.uniform_in(0.05, 0.5)), 1e-6),
    ]
}

/// Property: FTFI(tree, f, X) == BTFI(tree, f, X) for random everything.
#[test]
fn property_ftfi_equals_brute_random_sweep() {
    for case in 0..40u64 {
        let mut rng = Pcg::seed(1000 + case);
        let n = rng.range(2, 300);
        let d = rng.range(1, 4);
        let tree = random_tree(n, 0.05, 1.0, &mut rng);
        let x = Matrix::randn(n, d, &mut rng);
        let t = [2usize, 8, 48][rng.below(3)];
        for (f, tol) in f_pool(&mut rng) {
            let tfi = TreeFieldIntegrator::with_options(&tree, t, CrossPolicy::default());
            let got = tfi.integrate(&f, &x);
            let want = btfi(&tree, &f, &x);
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < tol, "case {case} n={n} d={d} t={t} {f:?}: rel {rel}");
        }
    }
}

/// Property: lattice trees make *any* f exact through the Hankel path.
#[test]
fn property_lattice_trees_any_f() {
    for case in 0..15u64 {
        let mut rng = Pcg::seed(2000 + case);
        let n = rng.range(20, 400);
        let p = rng.range(1, 6) as u32;
        let q = rng.range(1, 5) as u32;
        let tree = random_rational_tree(n, p, q, &mut rng);
        let freq = rng.uniform_in(0.1, 0.9);
        let f = FDist::Custom(std::sync::Arc::new(move |x: f64| {
            (freq * x).sin() / (1.0 + 0.2 * x)
        }));
        let x = Matrix::randn(n, 2, &mut rng);
        let tfi = TreeFieldIntegrator::new(&tree);
        let got = tfi.integrate(&f, &x);
        let want = btfi(&tree, &f, &x);
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-7, "case {case} n={n} p={p} q={q}: rel {rel}");
    }
}

/// Property: linearity — integrate(aX + bY) = a·integrate(X) + b·integrate(Y).
#[test]
fn property_linearity() {
    for case in 0..10u64 {
        let mut rng = Pcg::seed(3000 + case);
        let n = rng.range(10, 200);
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::new(&tree);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let x = Matrix::randn(n, 2, &mut rng);
        let y = Matrix::randn(n, 2, &mut rng);
        let (a, b) = (rng.normal(), rng.normal());
        let mut combo = x.clone();
        combo.scale(a);
        combo.axpy(b, &y);
        let lhs = tfi.integrate(&f, &combo);
        let mut rhs = tfi.integrate(&f, &x);
        rhs.scale(a);
        rhs.axpy(b, &tfi.integrate(&f, &y));
        assert!(lhs.frobenius_diff(&rhs) / (1.0 + rhs.frobenius()) < 1e-9, "case {case}");
    }
}

/// Property: symmetry — for symmetric M_f, xᵀ·(M·y) == yᵀ·(M·x).
#[test]
fn property_operator_symmetry() {
    for case in 0..10u64 {
        let mut rng = Pcg::seed(4000 + case);
        let n = rng.range(10, 150);
        let tree = random_tree(n, 0.2, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::new(&tree);
        let f = FDist::inverse_quadratic(0.7);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let my = tfi.integrate_vec(&f, &y);
        let mx = tfi.integrate_vec(&f, &x);
        let lhs: f64 = x.iter().zip(&my).map(|(a, b)| a * b).sum();
        let rhs: f64 = y.iter().zip(&mx).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-6 * (1.0 + lhs.abs()), "case {case}: {lhs} vs {rhs}");
    }
}

/// Property: every forced strategy that applies must agree with dense.
#[test]
fn property_forced_strategies_agree() {
    for case in 0..12u64 {
        let mut rng = Pcg::seed(5000 + case);
        let a = rng.range(20, 120);
        let b = rng.range(20, 120);
        let d = rng.range(1, 4);
        let v = Matrix::randn(b, d, &mut rng);
        // Lattice-valued points so every strategy is applicable.
        let xs: Vec<f64> = (0..a).map(|_| rng.below(40) as f64 * 0.25).collect();
        let ys: Vec<f64> = (0..b).map(|_| rng.below(40) as f64 * 0.25).collect();
        let cases: Vec<(FDist, Vec<Strategy>, f64)> = vec![
            (
                FDist::Exponential { lambda: -0.3, scale: 1.0 },
                vec![Strategy::Separable, Strategy::Lattice],
                1e-8,
            ),
            (
                FDist::inverse_quadratic(0.4),
                vec![Strategy::Lattice, Strategy::Chebyshev, Strategy::RationalSum],
                1e-6,
            ),
            (
                FDist::gaussian(0.2),
                vec![Strategy::Lattice, Strategy::Chebyshev, Strategy::Vandermonde],
                1e-6,
            ),
        ];
        for (f, strategies, tol) in cases {
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            for s in strategies {
                let policy = CrossPolicy { force: Some(s), ..Default::default() };
                let got = cross_apply(&f, &xs, &ys, &v, &policy);
                let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
                assert!(rel < tol, "case {case} {f:?} {s:?}: rel {rel}");
            }
        }
    }
}

/// Property: streaming and materialised brute agree (baseline sanity).
#[test]
fn property_brute_variants_agree() {
    for case in 0..8u64 {
        let mut rng = Pcg::seed(6000 + case);
        let n = rng.range(5, 120);
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let f = FDist::Polynomial(vec![0.5, 1.0]);
        let x = Matrix::randn(n, 2, &mut rng);
        let a = btfi(&tree, &f, &x);
        let b = btfi_streaming(&tree, &f, &x);
        assert!(a.max_abs_diff(&b) < 1e-9, "case {case}");
    }
}

/// Property: MST distances dominate graph distances; the graph pipeline
/// equals BTFI on its MST.
#[test]
fn property_graph_pipeline_consistency() {
    for case in 0..8u64 {
        let mut rng = Pcg::seed(7000 + case);
        let n = rng.range(20, 150);
        let g = generators::path_plus_random_edges(n, n / 3, &mut rng);
        let tree = minimum_spanning_tree(&g);
        for _ in 0..10 {
            let u = rng.below(n);
            let d_tree: Vec<f64> = tree.distances_from(u);
            let d_graph = ftfi::graph::shortest_path::dijkstra(&g, u);
            for v in 0..n {
                assert!(d_tree[v] + 1e-9 >= d_graph[v], "case {case}: ({u},{v})");
            }
        }
        let gfi = ftfi::GraphFieldIntegrator::new(&g);
        let x = Matrix::randn(n, 1, &mut rng);
        let f = FDist::Exponential { lambda: -0.6, scale: 1.0 };
        let got = gfi.integrate(&f, &x);
        let want = btfi(gfi.tree(), &f, &x);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-9);
    }
}

/// Regression: pathological tree shapes (paths, stars, caterpillars).
#[test]
fn pathological_tree_shapes() {
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
    let mut rng = Pcg::seed(8000);
    let path = Tree::path(&vec![0.3; 499]);
    let star_edges: Vec<(u32, u32, f64)> = (1..400).map(|v| (0, v, 1.0)).collect();
    let star = Tree::from_edges(400, &star_edges);
    // Caterpillar: path with a leaf hanging off every spine vertex.
    let mut cat_edges = Vec::new();
    for i in 0..200u32 {
        if i > 0 {
            cat_edges.push((i - 1, i, 0.7));
        }
        cat_edges.push((i, 200 + i, 0.2));
    }
    let caterpillar = Tree::from_edges(400, &cat_edges);
    for (name, tree) in [("path", path), ("star", star), ("caterpillar", caterpillar)] {
        let x = Matrix::randn(tree.n(), 2, &mut rng);
        let tfi = TreeFieldIntegrator::new(&tree);
        let got = tfi.integrate(&f, &x);
        let want = btfi(&tree, &f, &x);
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-9, "{name}: rel {rel}");
    }
}
