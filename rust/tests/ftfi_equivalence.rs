//! Property-based equivalence suite: FTFI must agree with the brute-force
//! integrator across randomized trees, fields, function classes, leaf
//! thresholds and forced strategies. The offline environment has no
//! proptest crate, so this uses seeded random sweeps (large case counts,
//! deterministic seeds — failures print the seed for replay).

use ftfi::ftfi::brute::{btfi, btfi_streaming};
use ftfi::ftfi::cordial::{cross_apply, cross_apply_dense, CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{random_rational_tree, random_tree};
use ftfi::graph::{generators, mst::minimum_spanning_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::tree::Tree;
use ftfi::{FtfiError, TreeFieldIntegrator};

fn f_pool(rng: &mut Pcg) -> Vec<(FDist, f64)> {
    vec![
        (FDist::Identity, 1e-9),
        (FDist::Polynomial(vec![rng.normal(), rng.normal(), rng.normal() * 0.3]), 1e-8),
        (FDist::Exponential { lambda: rng.uniform_in(-1.0, -0.1), scale: 1.0 }, 1e-9),
        (
            FDist::PolyExp {
                coeffs: vec![1.0, rng.uniform_in(-0.5, 0.5)],
                lambda: rng.uniform_in(-0.8, -0.1),
            },
            1e-9,
        ),
        (
            FDist::Trig {
                omega: rng.uniform_in(0.2, 1.5),
                phase: rng.uniform_in(0.0, 1.0),
                scale: 1.0,
            },
            1e-9,
        ),
        (FDist::inverse_quadratic(rng.uniform_in(0.1, 2.0)), 1e-6),
        (
            FDist::ExpOverLinear { lambda: rng.uniform_in(-0.5, 0.0), c: rng.uniform_in(0.5, 2.0) },
            1e-6,
        ),
        (FDist::gaussian(rng.uniform_in(0.05, 0.5)), 1e-6),
    ]
}

/// Property: FTFI(tree, f, X) == BTFI(tree, f, X) for random everything.
#[test]
fn property_ftfi_equals_brute_random_sweep() {
    for case in 0..40u64 {
        let mut rng = Pcg::seed(1000 + case);
        let n = rng.range(2, 300);
        let d = rng.range(1, 4);
        let tree = random_tree(n, 0.05, 1.0, &mut rng);
        let x = Matrix::randn(n, d, &mut rng);
        let t = [2usize, 8, 48][rng.below(3)];
        for (f, tol) in f_pool(&mut rng) {
            let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(t).build().unwrap();
            let got = tfi.try_integrate(&f, &x).unwrap();
            let want = btfi(&tree, &f, &x);
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < tol, "case {case} n={n} d={d} t={t} {f:?}: rel {rel}");
        }
    }
}

/// Property: lattice trees make *any* f exact through the Hankel path.
#[test]
fn property_lattice_trees_any_f() {
    for case in 0..15u64 {
        let mut rng = Pcg::seed(2000 + case);
        let n = rng.range(20, 400);
        let p = rng.range(1, 6) as u32;
        let q = rng.range(1, 5) as u32;
        let tree = random_rational_tree(n, p, q, &mut rng);
        let freq = rng.uniform_in(0.1, 0.9);
        let f = FDist::Custom(std::sync::Arc::new(move |x: f64| {
            (freq * x).sin() / (1.0 + 0.2 * x)
        }));
        let x = Matrix::randn(n, 2, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let got = tfi.try_integrate(&f, &x).unwrap();
        let want = btfi(&tree, &f, &x);
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-7, "case {case} n={n} p={p} q={q}: rel {rel}");
    }
}

/// Property: linearity — integrate(aX + bY) = a·integrate(X) + b·integrate(Y).
#[test]
fn property_linearity() {
    for case in 0..10u64 {
        let mut rng = Pcg::seed(3000 + case);
        let n = rng.range(10, 200);
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let x = Matrix::randn(n, 2, &mut rng);
        let y = Matrix::randn(n, 2, &mut rng);
        let (a, b) = (rng.normal(), rng.normal());
        let mut combo = x.clone();
        combo.scale(a);
        combo.axpy(b, &y);
        let lhs = tfi.try_integrate(&f, &combo).unwrap();
        let mut rhs = tfi.try_integrate(&f, &x).unwrap();
        rhs.scale(a);
        rhs.axpy(b, &tfi.try_integrate(&f, &y).unwrap());
        assert!(lhs.frobenius_diff(&rhs) / (1.0 + rhs.frobenius()) < 1e-9, "case {case}");
    }
}

/// Property: symmetry — for symmetric M_f, xᵀ·(M·y) == yᵀ·(M·x).
#[test]
fn property_operator_symmetry() {
    for case in 0..10u64 {
        let mut rng = Pcg::seed(4000 + case);
        let n = rng.range(10, 150);
        let tree = random_tree(n, 0.2, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let f = FDist::inverse_quadratic(0.7);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let my = tfi.try_integrate_vec(&f, &y).unwrap();
        let mx = tfi.try_integrate_vec(&f, &x).unwrap();
        let lhs: f64 = x.iter().zip(&my).map(|(a, b)| a * b).sum();
        let rhs: f64 = y.iter().zip(&mx).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-6 * (1.0 + lhs.abs()), "case {case}: {lhs} vs {rhs}");
    }
}

/// Property: every forced strategy that applies must agree with dense.
#[test]
fn property_forced_strategies_agree() {
    for case in 0..12u64 {
        let mut rng = Pcg::seed(5000 + case);
        let a = rng.range(20, 120);
        let b = rng.range(20, 120);
        let d = rng.range(1, 4);
        let v = Matrix::randn(b, d, &mut rng);
        // Lattice-valued points so every strategy is applicable.
        let xs: Vec<f64> = (0..a).map(|_| rng.below(40) as f64 * 0.25).collect();
        let ys: Vec<f64> = (0..b).map(|_| rng.below(40) as f64 * 0.25).collect();
        let cases: Vec<(FDist, Vec<Strategy>, f64)> = vec![
            (
                FDist::Exponential { lambda: -0.3, scale: 1.0 },
                vec![Strategy::Separable, Strategy::Lattice],
                1e-8,
            ),
            (
                FDist::inverse_quadratic(0.4),
                vec![Strategy::Lattice, Strategy::Chebyshev, Strategy::RationalSum],
                1e-6,
            ),
            (
                FDist::gaussian(0.2),
                vec![Strategy::Lattice, Strategy::Chebyshev, Strategy::Vandermonde],
                1e-6,
            ),
        ];
        for (f, strategies, tol) in cases {
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            for s in strategies {
                let policy = CrossPolicy { force: Some(s), ..Default::default() };
                let got = cross_apply(&f, &xs, &ys, &v, &policy);
                let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
                assert!(rel < tol, "case {case} {f:?} {s:?}: rel {rel}");
            }
        }
    }
}

/// Property: streaming and materialised brute agree (baseline sanity).
#[test]
fn property_brute_variants_agree() {
    for case in 0..8u64 {
        let mut rng = Pcg::seed(6000 + case);
        let n = rng.range(5, 120);
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let f = FDist::Polynomial(vec![0.5, 1.0]);
        let x = Matrix::randn(n, 2, &mut rng);
        let a = btfi(&tree, &f, &x);
        let b = btfi_streaming(&tree, &f, &x);
        assert!(a.max_abs_diff(&b) < 1e-9, "case {case}");
    }
}

/// Property: MST distances dominate graph distances; the graph pipeline
/// equals BTFI on its MST.
#[test]
fn property_graph_pipeline_consistency() {
    for case in 0..8u64 {
        let mut rng = Pcg::seed(7000 + case);
        let n = rng.range(20, 150);
        let g = generators::path_plus_random_edges(n, n / 3, &mut rng);
        let tree = minimum_spanning_tree(&g);
        for _ in 0..10 {
            let u = rng.below(n);
            let d_tree: Vec<f64> = tree.distances_from(u);
            let d_graph = ftfi::graph::shortest_path::dijkstra(&g, u);
            for v in 0..n {
                assert!(d_tree[v] + 1e-9 >= d_graph[v], "case {case}: ({u},{v})");
            }
        }
        let gfi = ftfi::GraphFieldIntegrator::try_new(&g).unwrap();
        let x = Matrix::randn(n, 1, &mut rng);
        let f = FDist::Exponential { lambda: -0.6, scale: 1.0 };
        let got = gfi.try_integrate(&f, &x).unwrap();
        let want = btfi(gfi.tree(), &f, &x);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-9);
    }
}

/// Regression: pathological tree shapes (paths, stars, caterpillars).
#[test]
fn pathological_tree_shapes() {
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
    let mut rng = Pcg::seed(8000);
    let path = Tree::path(&vec![0.3; 499]);
    let star_edges: Vec<(u32, u32, f64)> = (1..400).map(|v| (0, v, 1.0)).collect();
    let star = Tree::from_edges(400, &star_edges);
    // Caterpillar: path with a leaf hanging off every spine vertex.
    let mut cat_edges = Vec::new();
    for i in 0..200u32 {
        if i > 0 {
            cat_edges.push((i - 1, i, 0.7));
        }
        cat_edges.push((i, 200 + i, 0.2));
    }
    let caterpillar = Tree::from_edges(400, &cat_edges);
    for (name, tree) in [("path", path), ("star", star), ("caterpillar", caterpillar)] {
        let x = Matrix::randn(tree.n(), 2, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let got = tfi.try_integrate(&f, &x).unwrap();
        let want = btfi(&tree, &f, &x);
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-9, "{name}: rel {rel}");
    }
}

/// Satellite sweep: every *applicable* strategy, forced through
/// `CrossPolicy::force` at the full-integrator level, must agree with
/// the forced-Dense ground truth on a rational-weight tree (rational
/// weights make the Lattice/Vandermonde paths applicable). Inapplicable
/// (f, strategy) combos surface as `StrategyInapplicable` and are
/// skipped by definition; the test pins a minimum applicable count so
/// the sweep cannot silently degenerate.
#[test]
fn strategy_equivalence_sweep_all_fdist_variants() {
    use std::sync::Arc;
    let mut rng = Pcg::seed(9000);
    let tree = random_rational_tree(160, 3, 4, &mut rng);
    let x = Matrix::randn(160, 2, &mut rng);
    let fs: Vec<FDist> = vec![
        FDist::Identity,
        FDist::Polynomial(vec![0.4, 1.0, -0.05]),
        FDist::Exponential { lambda: -0.3, scale: 1.2 },
        FDist::PolyExp { coeffs: vec![1.0, 0.3], lambda: -0.4 },
        FDist::Trig { omega: 0.6, phase: 0.3, scale: 1.0 },
        FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.5] },
        FDist::ExpOverLinear { lambda: -0.2, c: 1.5 },
        FDist::ExpQuadratic { u: -0.05, v: 0.02, w: 0.1 },
        FDist::Custom(Arc::new(|x: f64| (0.4 * x).sin() / (1.0 + 0.3 * x))),
    ];
    let all = [
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for f in &fs {
        // Ground truth: everything forced through the dense multiplier,
        // itself pinned against the brute-force oracle.
        let dense = TreeFieldIntegrator::builder(&tree)
            .leaf_threshold(8)
            .policy(CrossPolicy { force: Some(Strategy::Dense), ..Default::default() })
            .build()
            .unwrap();
        let want = dense.try_integrate(f, &x).unwrap();
        let brute = btfi(&tree, f, &x);
        assert!(
            want.frobenius_diff(&brute) / (1.0 + brute.frobenius()) < 1e-9,
            "{f:?}: dense path diverged from brute oracle"
        );
        for &s in &all {
            let policy =
                CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
            let tfi = TreeFieldIntegrator::builder(&tree)
                .leaf_threshold(8)
                .policy(policy)
                .build()
                .unwrap();
            match tfi.prepare(f) {
                Err(FtfiError::StrategyInapplicable { .. }) => continue,
                Err(e) => panic!("{f:?} forced {s:?}: unexpected error {e}"),
                Ok(prepared) => {
                    applicable += 1;
                    let got = prepared.integrate(&x).unwrap();
                    // Exact strategies (separable decompositions, the
                    // lattice FFT) hold to 1e-9; Chebyshev/Vandermonde
                    // are spectrally accurate to the probe tolerance,
                    // and the RationalSum / Cauchy LDR paths are exact
                    // in exact arithmetic but shed digits in f64
                    // (DESIGN.md, Numerics).
                    let tol = match s {
                        Strategy::RationalSum | Strategy::Cauchy => 5e-6,
                        Strategy::Chebyshev | Strategy::Vandermonde => 1e-6,
                        _ => 1e-9,
                    };
                    let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
                    assert!(rel < tol, "{f:?} forced {s:?}: rel {rel}");
                    // The re-planning path must match the prepared path.
                    let got2 = tfi.try_integrate(f, &x).unwrap();
                    let drift = got2.frobenius_diff(&got) / (1.0 + got.frobenius());
                    assert!(drift < 1e-12, "{f:?} forced {s:?}: drift {drift}");
                }
            }
        }
    }
    // Separable (5) + Lattice (9) + RationalSum + Cauchy + Vandermonde
    // alone give 17 applicable combos; Chebyshev adds more. Pin a floor
    // so the sweep cannot silently degenerate into skipping everything.
    assert!(applicable >= 17, "only {applicable} (f, strategy) combos were applicable");
}

/// Satellite error paths: malformed input yields the right `FtfiError`
/// variant instead of a panic, on every public surface.
#[test]
fn error_paths_return_typed_errors() {
    // Disconnected graph.
    let g = ftfi::Graph::from_edges(
        6,
        &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 1.0), (4, 5, 1.0)],
    );
    assert!(matches!(
        ftfi::GraphFieldIntegrator::try_new(&g),
        Err(FtfiError::DisconnectedGraph)
    ));

    // Shape mismatch through both integrate paths.
    let mut rng = Pcg::seed(42);
    let tree = random_tree(60, 0.1, 1.0, &mut rng);
    let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
    let prepared = tfi.prepare(&f).unwrap();
    let bad = Matrix::zeros(59, 2);
    assert!(matches!(
        prepared.integrate(&bad),
        Err(FtfiError::ShapeMismatch { expected: 60, got: 59 })
    ));
    assert!(matches!(
        tfi.try_integrate(&f, &bad),
        Err(FtfiError::ShapeMismatch { expected: 60, got: 59 })
    ));

    // Inapplicable forced strategy: Lattice on an irrational-weight tree.
    let forced =
        CrossPolicy { force: Some(Strategy::Lattice), dense_cutoff: 0, ..Default::default() };
    let tfi = TreeFieldIntegrator::builder(&tree)
        .leaf_threshold(4)
        .policy(forced)
        .build()
        .unwrap();
    let err = tfi.prepare(&f).err().expect("lattice must be inapplicable");
    assert!(matches!(
        err,
        FtfiError::StrategyInapplicable { strategy: Strategy::Lattice, .. }
    ));
    // …and the re-planning path reports the same typed error.
    let x = Matrix::randn(60, 1, &mut rng);
    assert!(matches!(
        tfi.try_integrate(&f, &x),
        Err(FtfiError::StrategyInapplicable { strategy: Strategy::Lattice, .. })
    ));

    // Forced Separable on a non-separable f.
    let forced = CrossPolicy {
        force: Some(Strategy::Separable),
        dense_cutoff: 0,
        ..Default::default()
    };
    let tfi = TreeFieldIntegrator::builder(&tree)
        .leaf_threshold(4)
        .policy(forced)
        .build()
        .unwrap();
    let err = tfi
        .prepare(&FDist::inverse_quadratic(0.5))
        .err()
        .expect("separable must be inapplicable");
    assert!(matches!(
        err,
        FtfiError::StrategyInapplicable { strategy: Strategy::Separable, .. }
    ));
}

/// Acceptance (multi-threaded engine): `threads = 1` and `threads = 4`
/// produce **bit-identical** outputs — the parallel engine never
/// reorders a floating-point reduction — across the re-planning,
/// prepared, batch and graph (MST-metric) paths. CI runs the whole
/// suite under `FTFI_THREADS ∈ {1, 4}`; the explicit `.threads(..)`
/// knobs below pin both engines regardless of the environment.
#[test]
fn threads_serial_and_parallel_are_bit_identical() {
    let mut rng = Pcg::seed(12000);
    // Rational weights keep the lattice path applicable for any f; n is
    // comfortably above the recursion's fork cutoff (512) so the
    // parallel engine actually engages (pinned via `par_forks`).
    let tree = random_rational_tree(1200, 3, 4, &mut rng);
    let x = Matrix::randn(1200, 2, &mut rng);
    let fs: Vec<FDist> = vec![
        FDist::Identity,
        FDist::Exponential { lambda: -0.3, scale: 1.0 },
        FDist::inverse_quadratic(0.5),
        FDist::gaussian(0.05),
        FDist::Custom(std::sync::Arc::new(|t: f64| (0.3 * t).sin() / (1.0 + 0.2 * t))),
    ];
    for f in &fs {
        let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
        let a = serial.try_integrate(f, &x).unwrap();
        let b = par.try_integrate(f, &x).unwrap();
        assert!(a == b, "{f:?}: re-planning path must be bit-identical");
        let ps = serial.prepare(f).unwrap();
        let pp = par.prepare(f).unwrap();
        let a = ps.integrate(&x).unwrap();
        let b = pp.integrate(&x).unwrap();
        assert!(a == b, "{f:?}: prepared path must be bit-identical");
        assert!(par.stats().par_forks > 0, "{f:?}: the parallel engine never forked");
    }

    // Batch axis: a parallel `integrate_batch` equals one-by-one serial
    // integration, in order.
    let f = FDist::inverse_quadratic(0.5);
    let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
    let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
    let ps = serial.prepare(&f).unwrap();
    let pp = par.prepare(&f).unwrap();
    let fields: Vec<Matrix> = (0..6).map(|_| Matrix::randn(1200, 2, &mut rng)).collect();
    let refs: Vec<&Matrix> = fields.iter().collect();
    let batch = pp.integrate_batch(&refs).unwrap();
    for (x_i, got) in fields.iter().zip(&batch) {
        let want = ps.integrate(x_i).unwrap();
        assert!(*got == want, "batch output must be bit-identical to serial");
    }

    // Graph (MST-metric) integrators.
    let g = generators::path_plus_random_edges(900, 450, &mut rng);
    let gs = ftfi::GraphFieldIntegrator::builder(&g).threads(1).build().unwrap();
    let gp = ftfi::GraphFieldIntegrator::builder(&g).threads(4).build().unwrap();
    let xg = Matrix::randn(900, 2, &mut rng);
    let fg = FDist::Exponential { lambda: -0.4, scale: 1.0 };
    let a = gs.try_integrate(&fg, &xg).unwrap();
    let b = gp.try_integrate(&fg, &xg).unwrap();
    assert!(a == b, "graph integrator must be bit-identical across thread counts");
}

/// Forced-strategy sweep under the thread matrix: every applicable
/// `(f, strategy)` combo is bit-identical at `threads = 1` vs
/// `threads = 4`, and applicability itself does not depend on the
/// thread count.
#[test]
fn threads_bit_identical_across_forced_strategies() {
    let mut rng = Pcg::seed(12100);
    let tree = random_rational_tree(700, 3, 4, &mut rng);
    let x = Matrix::randn(700, 2, &mut rng);
    let fs: Vec<FDist> = vec![
        FDist::Exponential { lambda: -0.3, scale: 1.0 },
        FDist::inverse_quadratic(0.4),
        FDist::gaussian(0.1),
        FDist::ExpOverLinear { lambda: -0.2, c: 1.5 },
    ];
    let all = [
        Strategy::Dense,
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for f in &fs {
        for &s in &all {
            let policy =
                CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
            let serial = TreeFieldIntegrator::builder(&tree)
                .threads(1)
                .policy(policy.clone())
                .build()
                .unwrap();
            let par = TreeFieldIntegrator::builder(&tree)
                .threads(4)
                .policy(policy)
                .build()
                .unwrap();
            let (ps, pp) = match (serial.prepare(f), par.prepare(f)) {
                (Ok(a), Ok(b)) => (a, b),
                (
                    Err(FtfiError::StrategyInapplicable { .. }),
                    Err(FtfiError::StrategyInapplicable { .. }),
                ) => continue,
                (a, b) => panic!(
                    "{f:?} forced {s:?}: applicability diverged across thread counts \
                     (serial ok={}, parallel ok={})",
                    a.is_ok(),
                    b.is_ok()
                ),
            };
            applicable += 1;
            let a = ps.integrate(&x).unwrap();
            let b = pp.integrate(&x).unwrap();
            assert!(a == b, "{f:?} forced {s:?}: outputs must be bit-identical");
        }
    }
    assert!(applicable >= 10, "only {applicable} (f, strategy) combos were applicable");
}

/// Tentpole acceptance (PR 4): the zero-allocation workspace hot path
/// is **bit-identical** to the legacy (pre-workspace) prepared path for
/// every applicable forced `Strategy` × `FDist` combo, at threads 1 and
/// 4, including the `integrate_into` surface. The nested-dissection
/// permutation and the arena-backed kernels change *where* rows live,
/// never the value or order of any floating-point reduction.
#[test]
fn workspace_prepared_path_is_bit_identical_to_legacy() {
    let mut rng = Pcg::seed(13000);
    // Rational weights keep the Lattice/Vandermonde paths applicable.
    let tree = random_rational_tree(700, 3, 4, &mut rng);
    let x = Matrix::randn(700, 2, &mut rng);
    let fs: Vec<FDist> = vec![
        FDist::Exponential { lambda: -0.3, scale: 1.0 },
        FDist::inverse_quadratic(0.4),
        FDist::gaussian(0.1),
        FDist::ExpOverLinear { lambda: -0.2, c: 1.5 },
        FDist::Custom(std::sync::Arc::new(|t: f64| (0.3 * t).sin() / (1.0 + 0.2 * t))),
    ];
    let all = [
        Strategy::Dense,
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for f in &fs {
        for &s in &all {
            let policy =
                CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
            for threads in [1usize, 4] {
                let tfi = TreeFieldIntegrator::builder(&tree)
                    .threads(threads)
                    .policy(policy.clone())
                    .build()
                    .unwrap();
                let plans = match tfi.prepare_plans(f, 2) {
                    Err(FtfiError::StrategyInapplicable { .. }) => continue,
                    Err(e) => panic!("{f:?} forced {s:?}: unexpected error {e}"),
                    Ok(p) => p,
                };
                applicable += 1;
                let want = tfi.integrate_prepared_legacy(&x, &plans).unwrap();
                let got = tfi.integrate_prepared(&x, &plans).unwrap();
                assert!(
                    got == want,
                    "{f:?} forced {s:?} threads={threads}: workspace path != legacy"
                );
                let mut into = Matrix::zeros(700, 2);
                tfi.integrate_prepared_into(&x, &plans, &mut into).unwrap();
                assert!(
                    into == want,
                    "{f:?} forced {s:?} threads={threads}: integrate_into != legacy"
                );
            }
        }
    }
    assert!(applicable >= 24, "only {applicable} (f, strategy, threads) combos applicable");
}

/// The workspace hot path stays bit-identical to the legacy reference
/// through the higher-level serving surfaces: the graph (MST-metric)
/// prepared handle, the prepared batch axis, and the tree-ensemble
/// average (whose re-planning path runs the legacy arithmetic).
#[test]
fn workspace_path_bit_identical_through_graph_batch_and_ensemble() {
    use ftfi::ftfi::ensemble::EnsembleMethod;
    use ftfi::EnsembleFieldIntegrator;
    let mut rng = Pcg::seed(13100);

    // Graph (MST-metric) path, threads 1 and 4.
    let g = generators::path_plus_random_edges(600, 300, &mut rng);
    let xg = Matrix::randn(600, 2, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    for threads in [1usize, 4] {
        let gfi = ftfi::GraphFieldIntegrator::builder(&g).threads(threads).build().unwrap();
        let tfi = gfi.tree_integrator();
        let plans = tfi.prepare_plans(&f, 1).unwrap();
        let want = tfi.integrate_prepared_legacy(&xg, &plans).unwrap();
        let prepared = gfi.prepare(&f).unwrap();
        let got = prepared.integrate(&xg).unwrap();
        assert!(got == want, "threads={threads}: graph prepared path != legacy");
    }

    // Batch axis: every fused field equals its legacy single-field run.
    let tfi = TreeFieldIntegrator::builder(&minimum_spanning_tree(&g)).threads(4).build().unwrap();
    let plans = tfi.prepare_plans(&f, 2).unwrap();
    let prepared = tfi.prepare_with_channels(&f, 2).unwrap();
    let fields: Vec<Matrix> = (0..5).map(|_| Matrix::randn(600, 2, &mut rng)).collect();
    let refs: Vec<&Matrix> = fields.iter().collect();
    let batch = prepared.integrate_batch(&refs).unwrap();
    for (x_i, got) in fields.iter().zip(&batch) {
        let want = tfi.integrate_prepared_legacy(x_i, &plans).unwrap();
        assert!(*got == want, "batch output must be bit-identical to the legacy path");
    }

    // Ensemble: the prepared (workspace) average equals the re-planning
    // average, whose per-tree arithmetic is the legacy reduction order.
    let xe = Matrix::randn(300, 2, &mut rng);
    let ge = generators::path_plus_random_edges(300, 150, &mut rng);
    for threads in [1usize, 4] {
        let ens = EnsembleFieldIntegrator::builder(&ge)
            .trees(3)
            .seed(42)
            .method(EnsembleMethod::Frt)
            .threads(threads)
            .build()
            .unwrap();
        let fe = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let prepared = ens.prepare(&fe).unwrap();
        let got = prepared.integrate(&xe).unwrap();
        let want = ens.try_integrate(&fe, &xe).unwrap();
        assert!(got == want, "threads={threads}: ensemble prepared path != re-planning");
    }
}

/// Acceptance: `prepare(&f)` builds every plan exactly once; k repeated
/// `integrate` calls reuse them (the `plan_builds` counter in `ItStats`
/// does not move) and stay correct against the brute oracle.
#[test]
fn prepare_builds_plans_once_and_reuses_them() {
    let mut rng = Pcg::seed(77);
    let tree = random_tree(400, 0.1, 1.0, &mut rng);
    let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
    let f = FDist::inverse_quadratic(0.6);
    let base = tfi.stats().plan_builds;
    let prepared = tfi.prepare(&f).unwrap();
    let after = tfi.stats().plan_builds;
    assert_eq!(after - base, prepared.plans_built());
    assert!(prepared.plans_built() > 0);
    let xs: Vec<Matrix> = (0..6).map(|_| Matrix::randn(400, 2, &mut rng)).collect();
    for x in &xs {
        let got = prepared.integrate(x).unwrap();
        let want = btfi(&tree, &f, x);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }
    assert_eq!(tfi.stats().plan_builds, after, "prepared integrations must not re-plan");
    let refs: Vec<&Matrix> = xs.iter().collect();
    let batch = prepared.integrate_batch(&refs).unwrap();
    assert_eq!(batch.len(), xs.len());
    assert_eq!(tfi.stats().plan_builds, after, "integrate_batch must not re-plan");
}
