//! Chaos harness for the fault-tolerant serving stack: randomized,
//! seeded fault schedules driven through the real server (and the real
//! TCP front-end) asserting the two robustness invariants of
//! DESIGN.md "Serving robustness":
//!
//! 1. **Exactly one response** — every accepted request resolves with
//!    exactly one outcome (a typed response or a typed error); lost
//!    responses surface as `ServerError::Timeout`, never as a hang.
//! 2. **Session-state integrity** — after any fault schedule, replaying
//!    only the requests that actually executed into a freshly-built
//!    oracle reproduces every response and the final per-session
//!    outputs bit-identically.
//!
//! Every assertion carries a `REPRO:` message with the schedule seed
//! and worker count, so a failure replays deterministically.

use ftfi::coordinator::protocol::{self, StreamRequest, StreamResponse};
use ftfi::coordinator::{
    BatchExecutor, BatcherConfig, FaultPlan, Faults, FaultyExecutor, InferenceServer,
    ServerError, StreamingFieldExecutor, TcpFront,
};
use ftfi::ftfi::TreeFieldIntegrator;
use ftfi::graph::generators;
use ftfi::ml::rng::Pcg;
use ftfi::{FDist, Tree};
use std::sync::Arc;
use std::time::Duration;

/// Vertex count of every chaos tree: small enough that 200 schedules
/// stay fast, large enough that updates and replans do real work.
const N: usize = 24;

fn build_exec(tree: &Tree) -> StreamingFieldExecutor {
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
    let tfi = TreeFieldIntegrator::builder(tree).threads(1).build().unwrap();
    StreamingFieldExecutor::new(tfi, &f, 1, 4, 3, 4).unwrap().with_max_pending(4)
}

fn set_req(session: u32, rng: &mut Pcg) -> StreamRequest {
    StreamRequest::Set {
        session,
        rows: N as u32,
        channels: 1,
        values: (0..N).map(|_| rng.normal() as f32).collect(),
    }
}

fn update_req(session: u32, rng: &mut Pcg) -> StreamRequest {
    let k = 1 + rng.below(3);
    let start = rng.below(N);
    StreamRequest::Update {
        session,
        rows: (0..k).map(|j| ((start + j) % N) as u32).collect(),
        channels: 1,
        values: (0..k).map(|_| rng.normal() as f32).collect(),
    }
}

/// A seeded mixed request script: opens three sessions, then streams
/// updates, replans, leases, closes, re-sets (including a fourth
/// session id, so LRU eviction fires) and deliberately invalid rows
/// (so typed `Error` responses replay too).
fn make_script(seed: u64, edges: &[(u32, u32, f64)]) -> Vec<StreamRequest> {
    let mut rng = Pcg::new(seed, 0x5C21);
    let mut reqs = Vec::new();
    for s in 0..3u32 {
        reqs.push(set_req(s, &mut rng));
    }
    for _ in 0..30 {
        let session = rng.below(4) as u32;
        reqs.push(match rng.below(10) {
            0 => set_req(session, &mut rng),
            1..=5 => update_req(session, &mut rng),
            6 => {
                let (u, v, w) = edges[rng.below(edges.len())];
                let scale = if rng.bool(0.5) { 1.5 } else { 0.75 };
                StreamRequest::ReplanEdge { session, u, v, w: w * scale }
            }
            7 => StreamRequest::Lease { session },
            8 => StreamRequest::Close { session },
            _ => StreamRequest::Update {
                session,
                rows: vec![999],
                channels: 1,
                values: vec![1.0],
            },
        });
    }
    reqs
}

/// One schedule: serialized submit→wait traffic through a real server
/// whose workers wrap the shared executor in a seeded [`FaultyExecutor`]
/// (request corruption, injected latency, worker panics). Serialization
/// makes the fault schedule — and therefore the executed subsequence —
/// deterministic, which is what lets the oracle replay bit-identically.
fn run_schedule(seed: u64, workers: usize) {
    let repro = format!("REPRO: serving_faults schedule seed={seed} workers={workers}");
    let mut tree_rng = Pcg::seed(seed);
    let tree = generators::random_tree(N, 0.2, 1.0, &mut tree_rng);
    let live = Arc::new(build_exec(&tree));
    let oracle = build_exec(&tree);
    let plan = FaultPlan {
        seed,
        corrupt_frame: 0.15,
        latency: 0.05,
        latency_ms: 1,
        panic_worker: 0.05,
        ..FaultPlan::default()
    };
    let faults = Faults::new(&plan).expect("plan is on");
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..workers)
        .map(|_| {
            let exec = Arc::clone(&live);
            let faults = Arc::clone(&faults);
            Box::new(move || {
                Box::new(FaultyExecutor::new(exec, faults)) as Box<dyn BatchExecutor>
            }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
        })
        .collect();
    let server = InferenceServer::start(
        factories,
        BatcherConfig {
            batch_size: 4,
            batch_timeout: Duration::from_millis(1),
            shed_after: None,
        },
        64,
    );

    let script = make_script(seed, tree.edges());
    let mut outcomes: Vec<Option<StreamResponse>> = Vec::with_capacity(script.len());
    let (mut corrupted, mut panicked) = (0u64, 0u64);
    for (i, req) in script.iter().enumerate() {
        let words = protocol::request_words(req, i as u64);
        let handle = server
            .submit_blocking(words)
            .unwrap_or_else(|e| panic!("submit failed: {e}; {repro}"));
        match handle.wait_timeout(Duration::from_secs(30)) {
            Ok(words) => {
                let (id, resp) = protocol::response_from_words(&words)
                    .unwrap_or_else(|e| panic!("undecodable response: {e}; {repro}"));
                assert_eq!(id, i as u64, "response must echo the request id; {repro}");
                outcomes.push(Some(resp));
            }
            Err(ServerError::Protocol(_)) => {
                corrupted += 1;
                outcomes.push(None);
            }
            Err(ServerError::Exec(e)) if e.starts_with("worker panic") => {
                panicked += 1;
                outcomes.push(None);
            }
            Err(ServerError::Timeout) => {
                panic!("request {i} lost its response (exactly-one violated); {repro}")
            }
            Err(e) => panic!("request {i} unexpected error: {e}; {repro}"),
        }
    }
    server.shutdown();

    // Every failure must be explained by an injected fault, exactly.
    let c = faults.counters();
    assert_eq!(corrupted, c.frames_corrupted, "unexplained decode failures; {repro}");
    assert_eq!(panicked, c.panics_injected, "unexplained worker panics; {repro}");

    // Replaying the executed subsequence into a fresh oracle reproduces
    // every response bit-identically (corrupted and panicked requests
    // never touched session state, so they are skipped).
    for (req, outcome) in script.iter().zip(&outcomes) {
        if let Some(live_resp) = outcome {
            let oracle_resp = oracle.execute_request(req);
            assert_eq!(&oracle_resp, live_resp, "response diverged from the oracle; {repro}");
        }
    }
    // Post-fault session state matches the rebuilt oracle bit-exactly.
    for s in 0..4u32 {
        let probe = StreamRequest::Lease { session: s };
        assert_eq!(
            live.execute_request(&probe),
            oracle.execute_request(&probe),
            "session {s} state diverged from the rebuilt oracle; {repro}"
        );
    }
}

/// 100 seeds × worker counts {1, 4} = 200 randomized fault schedules.
/// Injected worker panics are expected here, so the global panic hook
/// is silenced for the duration (assertion payloads still surface
/// through the harness).
#[test]
fn two_hundred_fault_schedules_keep_every_invariant() {
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let result = std::panic::catch_unwind(|| {
        for seed in 0..100u64 {
            for workers in [1usize, 4] {
                run_schedule(seed, workers);
            }
        }
    });
    std::panic::set_hook(prev_hook);
    if let Err(payload) = result {
        std::panic::resume_unwind(payload);
    }
}

/// Response-path faults over the real TCP front: every missing response
/// is explained by the drop counter and every extra one by the
/// duplicate counter — `lost_unexplained` is zero by construction.
#[test]
fn tcp_response_faults_are_fully_explained_by_the_ledger() {
    let mut rng = Pcg::seed(77);
    let tree = generators::random_tree(N, 0.2, 1.0, &mut rng);
    let exec = Arc::new(build_exec(&tree));
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = vec![Box::new({
        let exec = Arc::clone(&exec);
        move || Box::new(exec) as Box<dyn BatchExecutor>
    })];
    let server = Arc::new(InferenceServer::start(
        factories,
        BatcherConfig {
            batch_size: 4,
            batch_timeout: Duration::from_millis(1),
            shed_after: None,
        },
        64,
    ));
    let plan = FaultPlan {
        seed: 77,
        drop_response: 0.15,
        duplicate_response: 0.15,
        ..FaultPlan::default()
    };
    let faults = Faults::new(&plan).expect("plan is on");
    let front =
        TcpFront::start(Arc::clone(&server), Some(Arc::clone(&faults)), "127.0.0.1:0").unwrap();

    let mut conn = std::net::TcpStream::connect(front.local_addr()).unwrap();
    let mut rd = std::io::BufReader::new(conn.try_clone().unwrap());
    let mut script_rng = Pcg::new(77, 0xC11E);
    let total = 61u64;
    protocol::write_frame(&mut conn, &protocol::encode_request(&set_req(0, &mut script_rng), 0))
        .unwrap();
    for id in 1..total {
        let req = if script_rng.bool(0.5) {
            update_req(0, &mut script_rng)
        } else {
            StreamRequest::Lease { session: 0 }
        };
        protocol::write_frame(&mut conn, &protocol::encode_request(&req, id)).unwrap();
    }
    // Half-close: the handler drains every pipelined frame, answers
    // each (minus drops, plus duplicates), then hits clean EOF.
    conn.shutdown(std::net::Shutdown::Write).unwrap();

    let mut counts = std::collections::BTreeMap::<u64, u64>::new();
    let mut received = 0u64;
    while let Some(payload) = protocol::read_frame(&mut rd).unwrap() {
        let (id, resp) = protocol::decode_response(&payload).unwrap();
        assert!(id < total, "unknown response id {id}");
        assert!(matches!(resp, StreamResponse::Output { .. }), "got {resp:?}");
        *counts.entry(id).or_insert(0) += 1;
        received += 1;
    }
    let c = faults.counters();
    let unique = counts.len() as u64;
    let dupes: u64 = counts.values().map(|&n| n - 1).sum();
    assert!(counts.values().all(|&n| n <= 2), "a response is sent at most twice");
    assert_eq!(unique + c.responses_dropped, total, "losses beyond the drop counter");
    assert_eq!(dupes, c.responses_duplicated, "extras beyond the duplicate counter");
    assert_eq!(received, total - c.responses_dropped + c.responses_duplicated);
    front.stop();
}

/// A client that tears its connection down mid-frame must not take the
/// front-end with it: the next connection still round-trips.
#[test]
fn disconnect_mid_frame_leaves_the_server_serving() {
    use std::io::Write;
    let mut rng = Pcg::seed(9);
    let tree = generators::random_tree(N, 0.2, 1.0, &mut rng);
    let exec = Arc::new(build_exec(&tree));
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = vec![Box::new({
        let exec = Arc::clone(&exec);
        move || Box::new(exec) as Box<dyn BatchExecutor>
    })];
    let server = Arc::new(InferenceServer::start(
        factories,
        BatcherConfig {
            batch_size: 4,
            batch_timeout: Duration::from_millis(1),
            shed_after: None,
        },
        64,
    ));
    let front = TcpFront::start(Arc::clone(&server), None, "127.0.0.1:0").unwrap();

    // A torn frame: the length prefix promises more bytes than arrive.
    let mut conn = std::net::TcpStream::connect(front.local_addr()).unwrap();
    let payload = protocol::encode_request(&set_req(0, &mut rng), 1);
    conn.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    conn.write_all(&payload[..payload.len() / 2]).unwrap();
    drop(conn);

    // A fresh connection still serves end to end.
    let mut conn2 = std::net::TcpStream::connect(front.local_addr()).unwrap();
    let mut rd = std::io::BufReader::new(conn2.try_clone().unwrap());
    protocol::write_frame(&mut conn2, &protocol::encode_request(&set_req(0, &mut rng), 2))
        .unwrap();
    let resp = protocol::read_frame(&mut rd).unwrap().expect("response frame");
    let (id, resp) = protocol::decode_response(&resp).unwrap();
    assert_eq!(id, 2);
    assert!(matches!(resp, StreamResponse::Output { .. }), "got {resp:?}");
    front.stop();
}
