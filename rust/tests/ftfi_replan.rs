//! Replan / rebuild equivalence harness for the dynamic-metric edge
//! re-plan subsystem (`TreeFieldIntegrator::replan_edge` and its
//! prepared twin `replan_edge_prepared`, plus the shared streaming
//! surface `StreamingIntegrator::update_edge`).
//!
//! The separator hierarchy is weight-*independent* (centroids and the
//! component grouping use only subtree sizes and adjacency order), so
//! an in-place re-plan yields a tree and plan handle structurally
//! identical to a from-scratch rebuild on the mutated weights — same
//! pivots, same vertex orders, same slot layout. The harness pins the
//! consequence across seeded replan sequences interleaved with
//! integrations, for every applicable forced `Strategy` × the `FDist`
//! classes × threads ∈ {1, 4}:
//!
//! **ULP budget.** Replan and rebuild retabulate the same distance
//! tables with the same deterministic kernels, so the exactly-planned
//! classes (`Dense`/`Separable`/`Lattice`, and the default policy's
//! routing) must match the rebuild **bit for bit**. The LDR coefficient
//! pipelines are held to the per-strategy relative-Frobenius floors of
//! `tests/ftfi_property.rs` as stated headroom — `RationalSum`/`Cauchy`
//! 5e-6, `Chebyshev`/`Vandermonde` 1e-8 — though they too are observed
//! bit-identical in practice.
//!
//! The walk itself is a single root-to-leaf separator path, so
//! `nodes_visited` is held to `5·⌈log₂ n⌉ + 2` per replan.
//!
//! No proptest offline: seeded sweeps, every assertion leading with
//! `REPRO seed=…` so `Pcg::seed(seed)` replays the exact case.

use ftfi::ftfi::cordial::{CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{random_rational_tree, random_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::tree::Tree;
use ftfi::{FtfiError, ReplanStats, SharedPlans, StreamingIntegrator, TreeFieldIntegrator};
use std::sync::Arc;

/// The size ladder of `tests/ftfi_property.rs`: singleton, single edge,
/// one leaf, a few IT levels, above the batch-axis cutoff (odd).
const SIZES: [usize; 5] = [1, 2, 17, 64, 257];

/// Replans per (tree, f, strategy) combo in the sequence sweeps.
const STEPS: usize = 3;

/// Per-class replan-vs-rebuild budgets. `None` means exactly planned:
/// the re-planned handle must reproduce the rebuild bit for bit.
fn strategy_budget(s: Strategy) -> Option<f64> {
    match s {
        Strategy::RationalSum | Strategy::Cauchy => Some(5e-6),
        Strategy::Chebyshev | Strategy::Vandermonde => Some(1e-8),
        _ => None,
    }
}

/// Per-class `FDist` representatives (mirrors `ftfi_property.rs`).
fn f_cases(rng: &mut Pcg) -> Vec<FDist> {
    vec![
        FDist::Identity,
        FDist::Polynomial(vec![rng.normal(), rng.normal(), rng.normal() * 0.3]),
        FDist::Exponential { lambda: rng.uniform_in(-1.0, -0.1), scale: 1.0 },
        FDist::Trig {
            omega: rng.uniform_in(0.2, 1.5),
            phase: rng.uniform_in(0.0, 1.0),
            scale: 1.0,
        },
        FDist::inverse_quadratic(rng.uniform_in(0.1, 2.0)),
        FDist::ExpOverLinear { lambda: rng.uniform_in(-0.5, 0.0), c: rng.uniform_in(0.5, 2.0) },
        FDist::gaussian(rng.uniform_in(0.05, 0.5)),
        FDist::Custom(std::sync::Arc::new(|x: f64| (0.4 * x).sin() / (1.0 + 0.3 * x))),
    ]
}

fn rel_err(got: &Matrix, want: &Matrix) -> f64 {
    got.frobenius_diff(want) / (1.0 + want.frobenius())
}

/// The per-replan invalidation-walk ceiling: one root-to-leaf separator
/// path with generous headroom, `5·⌈log₂ n⌉ + 2`.
fn visit_budget(n: usize) -> usize {
    if n <= 1 {
        2
    } else {
        5 * (usize::BITS - (n - 1).leading_zeros()) as usize + 2
    }
}

/// From-scratch oracle: build + prepare on the mutated tree with the
/// same knobs and integrate the same field.
fn rebuild_integrate(
    tree: &Tree,
    policy: &CrossPolicy,
    f: &FDist,
    d: usize,
    threads: usize,
    x: &Matrix,
) -> Matrix {
    let tfi = TreeFieldIntegrator::builder(tree)
        .leaf_threshold(8)
        .policy(policy.clone())
        .threads(threads)
        .build()
        .unwrap();
    let plans = tfi.prepare_plans(f, d).unwrap();
    tfi.integrate_prepared(x, &plans).unwrap()
}

/// Drive a seeded replan sequence through one prepared handle,
/// mirroring every committed weight change on a plain [`Tree`] copy and
/// comparing a prepared integration against the rebuild oracle after
/// each step. Returns `false` when the forced strategy was inapplicable
/// at prepare time (combo skipped).
#[allow(clippy::too_many_arguments)]
fn run_sequence(
    tree0: &Tree,
    policy: CrossPolicy,
    f: &FDist,
    d: usize,
    threads: usize,
    budget: Option<f64>,
    rational_weights: bool,
    rng: &mut Pcg,
    label: &str,
) -> bool {
    let mut tfi = TreeFieldIntegrator::builder(tree0)
        .leaf_threshold(8)
        .policy(policy.clone())
        .threads(threads)
        .build()
        .unwrap();
    let mut plans = match tfi.prepare_plans(f, d) {
        Err(FtfiError::StrategyInapplicable { .. }) => return false,
        Err(e) => panic!("{label}: unexpected {e}"),
        Ok(p) => p,
    };
    let compare = |got: &Matrix, want: &Matrix, ctx: String| match budget {
        None => assert!(
            got == want,
            "{ctx}: re-planned handle must be bit-identical to a from-scratch rebuild"
        ),
        Some(tol) => {
            let rel = rel_err(got, want);
            assert!(rel < tol, "{ctx}: replan-vs-rebuild rel {rel} > {tol}");
        }
    };
    let mut cur = tree0.clone();
    let x = Matrix::randn(tree0.n(), d, rng);
    for step in 0..STEPS {
        if cur.edges().is_empty() {
            break; // n ∈ {0, 1}: nothing to re-plan (covered separately).
        }
        let (eu, ev, old) = cur.edges()[rng.below(cur.edges().len())];
        let (u, v) = (eu as usize, ev as usize);
        let w = if rational_weights {
            // Stay on the rational grid of `random_rational_tree` so the
            // lattice / rational-sum strategies usually stay applicable.
            (1 + rng.below(8)) as f64 / 4.0
        } else {
            old * if rng.below(2) == 0 { rng.uniform_in(0.45, 0.9) } else { rng.uniform_in(1.1, 1.9) }
        };
        let st = match tfi.replan_edge_prepared(u, v, w, &mut plans) {
            // A forced strategy can be inapplicable to the *new* distance
            // tables; the two-phase commit must then leave everything
            // untouched — the handle keeps serving the old weights.
            Err(FtfiError::StrategyInapplicable { .. }) => {
                let still = tfi.integrate_prepared(&x, &plans).unwrap();
                let oracle = rebuild_integrate(&cur, &policy, f, d, threads, &x);
                compare(&still, &oracle, format!("{label} step={step} (rejected replan)"));
                continue;
            }
            Err(e) => panic!("{label} step={step}: unexpected {e}"),
            Ok(st) => st,
        };
        assert!(
            st.nodes_visited <= visit_budget(cur.n()),
            "{label} step={step}: replan visited {} nodes, budget {}",
            st.nodes_visited,
            visit_budget(cur.n())
        );
        if w == old {
            assert_eq!(
                st,
                ReplanStats::default(),
                "{label} step={step}: a same-weight replan must be a stat-free no-op"
            );
        } else {
            assert!(st.changed, "{label} step={step}: a weight change must report changed");
            assert_eq!(
                cur.set_edge_weight(u, v, w),
                Some(old),
                "{label} step={step}: mirror tree rejected the same edge"
            );
        }
        let got = tfi.integrate_prepared(&x, &plans).unwrap();
        let want = rebuild_integrate(&cur, &policy, f, d, threads, &x);
        compare(&got, &want, format!("{label} step={step}"));
    }
    true
}

/// Property: under the default policy, a re-planned handle reproduces
/// the from-scratch rebuild **bit for bit** on every ladder size, every
/// function class, threads ∈ {1, 4}.
#[test]
fn property_replan_sequences_are_bit_identical_to_rebuild_default_policy() {
    for &n in &SIZES {
        for &threads in &[1usize, 4] {
            let seed = 910_000 + (n as u64) * 10 + threads as u64;
            let mut rng = Pcg::seed(seed);
            let d = 1 + rng.below(3);
            let tree = random_tree(n, 0.05, 1.0, &mut rng);
            for f in f_cases(&mut rng) {
                let label = format!("REPRO seed={seed} n={n} d={d} threads={threads} {f:?}");
                run_sequence(
                    &tree,
                    CrossPolicy::default(),
                    &f,
                    d,
                    threads,
                    None,
                    false,
                    &mut rng,
                    &label,
                );
            }
        }
    }
}

/// Property: every *applicable* forced strategy tracks the rebuild
/// oracle through replan sequences on rational-weight trees, within its
/// stated budget (bit-identical for the exactly-planned classes), for
/// threads ∈ {1, 4}. Inapplicable combos surface as the typed
/// `StrategyInapplicable` and are skipped; a floor pins the sweep
/// cannot degenerate into skipping everything.
#[test]
fn property_replan_matches_rebuild_for_every_applicable_forced_strategy() {
    let all = [
        Strategy::Dense,
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for &n in &SIZES {
        for &threads in &[1usize, 4] {
            let seed = 920_000 + (n as u64) * 10 + threads as u64;
            let mut rng = Pcg::seed(seed);
            let tree = random_rational_tree(n, 3, 4, &mut rng);
            let d = 1 + rng.below(3);
            for f in f_cases(&mut rng) {
                for &s in &all {
                    let policy =
                        CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
                    let label = format!(
                        "REPRO seed={seed} n={n} d={d} threads={threads} {f:?} forced {s:?}"
                    );
                    if run_sequence(
                        &tree,
                        policy,
                        &f,
                        d,
                        threads,
                        strategy_budget(s),
                        true,
                        &mut rng,
                        &label,
                    ) {
                        applicable += 1;
                    }
                }
            }
        }
    }
    assert!(applicable >= 100, "only {applicable} (f, strategy) combos were applicable");
}

/// Threads must not change replanned outputs: two handles prepared
/// under different pool widths, fed the identical replan sequence, stay
/// bit-identical (and report identical [`ReplanStats`]).
#[test]
fn replanned_outputs_are_bit_identical_across_thread_counts() {
    let seed = 930_001u64;
    let mut rng = Pcg::seed(seed);
    // n above the fork cutoff so the recursion actually forks.
    let n = 1100;
    let tree = random_tree(n, 0.1, 1.0, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    let mut serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
    let mut par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
    let mut plans_s = serial.prepare_plans(&f, 2).unwrap();
    let mut plans_p = par.prepare_plans(&f, 2).unwrap();
    let x = Matrix::randn(n, 2, &mut rng);
    let mut cur = tree.clone();
    for step in 0..6 {
        let (eu, ev, old) = cur.edges()[rng.below(cur.edges().len())];
        let (u, v) = (eu as usize, ev as usize);
        let w = old * rng.uniform_in(1.1, 1.9);
        let a = serial.replan_edge_prepared(u, v, w, &mut plans_s).unwrap();
        let b = par.replan_edge_prepared(u, v, w, &mut plans_p).unwrap();
        assert_eq!(a, b, "REPRO seed={seed} step={step}: replan stats diverged across threads");
        cur.set_edge_weight(u, v, w).unwrap();
        let ya = serial.integrate_prepared(&x, &plans_s).unwrap();
        let yb = par.integrate_prepared(&x, &plans_p).unwrap();
        assert!(
            ya == yb,
            "REPRO seed={seed} step={step}: replanned output must be bit-identical across threads"
        );
    }
}

/// Degenerates: a singleton tree rejects every replan; the n = 2 single
/// edge can be re-planned over and over (including the same-weight
/// no-op, which must rebuild zero plans and leave every counter
/// frozen); hammering one fixed edge through a weight sequence keeps
/// tracking the rebuild bit for bit.
#[test]
fn degenerate_trees_repeated_edges_and_noop_replans() {
    let seed = 940_001u64;
    let mut rng = Pcg::seed(seed);

    // n = 1: no edges — every replan is a typed rejection and the
    // handle keeps serving.
    let t1 = random_tree(1, 0.1, 1.0, &mut rng);
    let mut tfi = TreeFieldIntegrator::builder(&t1).build().unwrap();
    let mut plans = tfi.prepare_plans(&FDist::Identity, 1).unwrap();
    for (u, v) in [(0usize, 0usize), (0, 1), (5, 0)] {
        match tfi.replan_edge_prepared(u, v, 1.0, &mut plans) {
            Err(FtfiError::InvalidInput(_)) => {}
            other => panic!(
                "REPRO seed={seed}: n=1 replan ({u}, {v}) must be InvalidInput, got {other:?}"
            ),
        }
    }
    let x1 = Matrix::randn(1, 1, &mut rng);
    tfi.integrate_prepared(&x1, &plans).unwrap();

    // n = 2: one edge, one leaf node. Repeated replans of the same edge
    // each visit exactly that leaf and rebuild zero cross plans.
    let t2 = random_tree(2, 0.5, 1.5, &mut rng);
    let mut tfi = TreeFieldIntegrator::builder(&t2).build().unwrap();
    let mut plans = tfi.prepare_plans(&FDist::gaussian(0.3), 2).unwrap();
    let mut cur = t2.clone();
    let x2 = Matrix::randn(2, 2, &mut rng);
    for step in 0..4 {
        let (eu, ev, old) = cur.edges()[0];
        let (u, v) = (eu as usize, ev as usize);
        let w = old * 1.25;
        let st = tfi.replan_edge_prepared(u, v, w, &mut plans).unwrap();
        assert!(
            st.changed && st.nodes_visited == 1 && st.leaves_rebuilt == 1 && st.plan_rebuilds == 0,
            "REPRO seed={seed} step={step}: n=2 replan must touch exactly the one leaf, got {st:?}"
        );
        cur.set_edge_weight(u, v, w).unwrap();
        let got = tfi.integrate_prepared(&x2, &plans).unwrap();
        let want = rebuild_integrate(&cur, &CrossPolicy::default(), &FDist::gaussian(0.3), 2, 1, &x2);
        assert!(got == want, "REPRO seed={seed} step={step}: n=2 replan diverged from rebuild");
    }
    // Same-weight no-op: nothing visited, nothing rebuilt, every
    // counter frozen, handle still current.
    let before = tfi.stats();
    let (eu, ev, old) = cur.edges()[0];
    let st = tfi.replan_edge_prepared(eu as usize, ev as usize, old, &mut plans).unwrap();
    assert_eq!(st, ReplanStats::default(), "REPRO seed={seed}: same-weight replan must be a no-op");
    let after = tfi.stats();
    assert_eq!(before.replan_nodes_visited, after.replan_nodes_visited);
    assert_eq!(before.replan_plan_rebuilds, after.replan_plan_rebuilds);
    assert_eq!(before.plan_builds, after.plan_builds);
    tfi.integrate_prepared(&x2, &plans).unwrap();

    // n = 33: hammer one fixed edge through a whole weight sequence.
    let t3 = random_tree(33, 0.2, 1.0, &mut rng);
    let mut tfi = TreeFieldIntegrator::builder(&t3).leaf_threshold(8).build().unwrap();
    let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
    let mut plans = tfi.prepare_plans(&f, 2).unwrap();
    let mut cur = t3.clone();
    let x3 = Matrix::randn(33, 2, &mut rng);
    let (eu, ev, w0) = t3.edges()[7];
    let (u, v) = (eu as usize, ev as usize);
    for (step, scale) in [0.5, 2.0, 0.25, 4.0, 0.5, 1.0].into_iter().enumerate() {
        let w = w0 * scale;
        let st = tfi.replan_edge_prepared(u, v, w, &mut plans).unwrap();
        assert!(st.changed, "REPRO seed={seed} step={step}: consecutive weights always differ");
        cur.set_edge_weight(u, v, w).unwrap();
        let got = tfi.integrate_prepared(&x3, &plans).unwrap();
        let want = rebuild_integrate(&cur, &CrossPolicy::default(), &f, 2, 1, &x3);
        assert!(
            got == want,
            "REPRO seed={seed} step={step}: repeated same-edge replan diverged from rebuild"
        );
    }
}

/// Malformed replans — out-of-range endpoints, a non-adjacent pair, a
/// self loop, non-finite / non-positive weights — return the typed
/// [`FtfiError::InvalidInput`] on both the raw and prepared surfaces
/// and leave the integrator, the handle and every counter untouched.
#[test]
fn validation_errors_are_typed_and_leave_the_integrator_untouched() {
    let seed = 950_001u64;
    let mut rng = Pcg::seed(seed);
    let n = 40;
    let tree = random_tree(n, 0.2, 1.0, &mut rng);
    let f = FDist::inverse_quadratic(0.7);
    let mut tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
    let mut plans = tfi.prepare_plans(&f, 2).unwrap();
    let x = Matrix::randn(n, 2, &mut rng);
    let baseline = tfi.integrate_prepared(&x, &plans).unwrap();
    let before = tfi.stats();
    let (eu, ev, _) = tree.edges()[0];
    let (u, v) = (eu as usize, ev as usize);
    let mut non_adj = None;
    'outer: for i in 0..n {
        for j in 0..n {
            if i != j && tree.edge_weight(i, j).is_none() {
                non_adj = Some((i, j));
                break 'outer;
            }
        }
    }
    let (na, nb) = non_adj.expect("a 40-vertex tree has non-adjacent pairs");
    let bad: [(usize, usize, f64, &str); 8] = [
        (n, 0, 1.0, "left endpoint out of range"),
        (0, n + 3, 1.0, "right endpoint out of range"),
        (na, nb, 1.0, "non-adjacent pair"),
        (u, u, 1.0, "self loop"),
        (u, v, f64::NAN, "NaN weight"),
        (u, v, f64::INFINITY, "infinite weight"),
        (u, v, -1.0, "negative weight"),
        (u, v, 0.0, "zero weight"),
    ];
    for &(bu, bv, bw, what) in &bad {
        match tfi.replan_edge(bu, bv, bw) {
            Err(FtfiError::InvalidInput(_)) => {}
            other => panic!("REPRO seed={seed}: raw replan with {what} must be InvalidInput, got {other:?}"),
        }
        match tfi.replan_edge_prepared(bu, bv, bw, &mut plans) {
            Err(FtfiError::InvalidInput(_)) => {}
            other => panic!(
                "REPRO seed={seed}: prepared replan with {what} must be InvalidInput, got {other:?}"
            ),
        }
        let still = tfi.integrate_prepared(&x, &plans).unwrap();
        assert!(
            still == baseline,
            "REPRO seed={seed}: a rejected replan ({what}) must leave the output bit-unchanged"
        );
    }
    let after = tfi.stats();
    assert_eq!(before.replan_nodes_visited, after.replan_nodes_visited);
    assert_eq!(before.replan_plan_rebuilds, after.replan_plan_rebuilds);
    assert_eq!(before.plan_builds, after.plan_builds);
}

/// A raw replan (without the prepared twin) invalidates outstanding
/// handles: their next use is the typed staleness error, and a freshly
/// prepared handle matches the rebuild oracle bit for bit.
#[test]
fn raw_replans_invalidate_prepared_handles_with_a_typed_staleness_error() {
    let seed = 960_001u64;
    let mut rng = Pcg::seed(seed);
    let n = 50;
    let tree = random_tree(n, 0.2, 1.0, &mut rng);
    let f = FDist::gaussian(0.2);
    let mut tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
    let mut plans = tfi.prepare_plans(&f, 2).unwrap();
    let x = Matrix::randn(n, 2, &mut rng);
    let (eu, ev, old) = tree.edges()[3];
    let (u, v) = (eu as usize, ev as usize);
    let st = tfi.replan_edge(u, v, old * 1.5).unwrap();
    assert!(st.changed);
    for err in [
        tfi.integrate_prepared(&x, &plans).map(|_| ()).unwrap_err(),
        tfi.replan_edge_prepared(u, v, old * 2.0, &mut plans).map(|_| ()).unwrap_err(),
    ] {
        match err {
            FtfiError::InvalidInput(msg) => assert!(
                msg.contains("stale"),
                "REPRO seed={seed}: staleness error must say so, got: {msg}"
            ),
            other => panic!("REPRO seed={seed}: expected InvalidInput, got {other:?}"),
        }
    }
    let mut cur = tree.clone();
    cur.set_edge_weight(u, v, old * 1.5).unwrap();
    let plans2 = tfi.prepare_plans(&f, 2).unwrap();
    let got = tfi.integrate_prepared(&x, &plans2).unwrap();
    let want = rebuild_integrate(&cur, &CrossPolicy::default(), &f, 2, 1, &x);
    assert!(got == want, "REPRO seed={seed}: re-prepared handle must match the rebuild");
}

/// Streaming surface: `update_edge` re-plans the shared metric and
/// refreshes the session bit-exactly — after every step the session
/// output equals a cold integrator built from scratch on the mutated
/// tree, and the replan counters aggregate into the session's
/// `stats()`.
#[test]
fn streaming_update_edge_tracks_a_rebuilt_session_bit_for_bit() {
    let seed = 970_001u64;
    let mut rng = Pcg::seed(seed);
    let n = 120;
    let tree = random_tree(n, 0.1, 1.0, &mut rng);
    let f = FDist::ExpOverLinear { lambda: -0.3, c: 1.0 };
    let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
    let plans = tfi.prepare_plans(&f, 2).unwrap();
    let shared = Arc::new(SharedPlans::new(tfi, plans));
    let field = Matrix::randn(n, 2, &mut rng);
    let mut session = StreamingIntegrator::new(Arc::clone(&shared), field, 5).unwrap();
    let mut cur = tree.clone();
    let mut total_visits = 0usize;
    for step in 0..6 {
        let (eu, ev, old) = cur.edges()[rng.below(cur.edges().len())];
        let (u, v) = (eu as usize, ev as usize);
        let w = old * rng.uniform_in(1.1, 1.9);
        let st = session.update_edge(u, v, w).unwrap();
        assert!(st.changed, "REPRO seed={seed} step={step}: weight change must commit");
        assert!(
            st.nodes_visited <= visit_budget(n),
            "REPRO seed={seed} step={step}: visited {} nodes, budget {}",
            st.nodes_visited,
            visit_budget(n)
        );
        total_visits += st.nodes_visited;
        cur.set_edge_weight(u, v, w).unwrap();
        let want = rebuild_integrate(&cur, &CrossPolicy::default(), &f, 2, 1, session.field());
        assert!(
            *session.output() == want,
            "REPRO seed={seed} step={step}: session must refresh bit-exactly after a replan"
        );
    }
    assert_eq!(shared.epoch(), 6, "every committed replan bumps the shared epoch once");
    assert_eq!(session.stats().replan_nodes_visited, total_visits);
}

/// The O(log n) claim at serving scale: on n = 2048 every replan visits
/// at most `5·⌈log₂ n⌉ + 2` nodes, the per-replan stats aggregate
/// exactly into the lifetime counter, and the handle still matches the
/// rebuild bit for bit at the end of the sequence.
#[test]
fn replan_visits_are_logarithmic_and_aggregate_into_stats() {
    let seed = 980_001u64;
    let mut rng = Pcg::seed(seed);
    let n = 2048;
    let tree = random_tree(n, 0.1, 1.0, &mut rng);
    let f = FDist::Exponential { lambda: -0.25, scale: 1.0 };
    let mut tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
    let mut plans = tfi.prepare_plans(&f, 2).unwrap();
    let mut cur = tree.clone();
    let mut total = 0usize;
    for step in 0..12 {
        let (eu, ev, old) = cur.edges()[rng.below(cur.edges().len())];
        let (u, v) = (eu as usize, ev as usize);
        let w = old * rng.uniform_in(1.1, 1.9);
        let st = tfi.replan_edge_prepared(u, v, w, &mut plans).unwrap();
        assert!(st.changed);
        assert!(
            (1..=visit_budget(n)).contains(&st.nodes_visited),
            "REPRO seed={seed} step={step}: visited {} nodes, budget {}",
            st.nodes_visited,
            visit_budget(n)
        );
        total += st.nodes_visited;
        cur.set_edge_weight(u, v, w).unwrap();
    }
    assert_eq!(tfi.stats().replan_nodes_visited, total);
    let x = Matrix::randn(n, 2, &mut rng);
    let got = tfi.integrate_prepared(&x, &plans).unwrap();
    let oracle = TreeFieldIntegrator::builder(&cur).build().unwrap();
    let oracle_plans = oracle.prepare_plans(&f, 2).unwrap();
    let want = oracle.integrate_prepared(&x, &oracle_plans).unwrap();
    assert!(got == want, "REPRO seed={seed}: 12-replan handle must still match a rebuild");
}
