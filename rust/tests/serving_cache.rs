//! Session-churn equivalence harness for the multi-graph prepared-plan
//! cache and fused delta batching (DESIGN.md "Multi-graph cache &
//! update fusion").
//!
//! Randomized, seeded schedules of open-graph / set / update / replan /
//! close / evict traffic over G graphs × S sessions are driven through
//! [`StreamingFieldExecutor::execute_each`] in batch windows, and three
//! invariants are pinned:
//!
//! 1. **Fusion is invisible** — a fused executor and an unfused one fed
//!    the *identical* window sequence agree bit-for-bit: on every
//!    response except the non-final members of a fused update run
//!    (which by contract carry the post-run output), and on every
//!    session's full lease state after every window.
//! 2. **The cache is invisible** — a session that resolved its graph
//!    through the plan cache (hits, misses, migrations and all) ends
//!    bit-identical to a replay into a freshly-built executor whose
//!    *default* graph is that session's graph (no cache involved).
//! 3. **Eviction never poisons in-flight sessions** — under a
//!    one-entry cache thrashed by competing opens, sessions holding
//!    evicted entries keep serving, and their outputs still match the
//!    fresh-built oracle.
//!
//! Every assertion carries a `REPRO:` message with the schedule seed
//! and thread count, so a failure replays deterministically.

use ftfi::config::CacheConfig;
use ftfi::coordinator::protocol::{self, StreamRequest, StreamResponse};
use ftfi::coordinator::{BatchExecutor, MetricsRegistry, StreamingFieldExecutor};
use ftfi::ftfi::TreeFieldIntegrator;
use ftfi::graph::generators;
use ftfi::ml::rng::Pcg;
use ftfi::{FDist, Tree};
use std::collections::BTreeMap;
use std::sync::Arc;

/// `G` same-sized trees; graph 0 is the executor's default, the rest
/// resolve through `OpenGraph` and the plan cache.
fn graphs_for(n: usize, g: usize, seed: u64) -> Vec<Tree> {
    (0..g)
        .map(|gi| {
            let mut rng = Pcg::seed(seed ^ (0xC0DE + gi as u64));
            generators::random_tree(n, 0.2, 1.0, &mut rng)
        })
        .collect()
}

fn build_exec(
    tree: &Tree,
    threads: usize,
    refresh_every: usize,
    capacity: usize,
    max_graphs: usize,
    fuse: bool,
    metrics: &Arc<MetricsRegistry>,
) -> StreamingFieldExecutor {
    let f = FDist::Exponential { lambda: -0.45, scale: 1.0 };
    let tfi = TreeFieldIntegrator::builder(tree).threads(threads).build().unwrap();
    StreamingFieldExecutor::new(tfi, &f, 1, refresh_every, capacity, 16)
        .unwrap()
        .with_cache(CacheConfig { max_graphs, max_bytes_mb: 0, fuse_updates: fuse })
        .with_metrics(Arc::clone(metrics))
}

fn set_req(session: u32, n: usize, rng: &mut Pcg) -> StreamRequest {
    StreamRequest::Set {
        session,
        rows: n as u32,
        channels: 1,
        values: (0..n).map(|_| rng.normal() as f32).collect(),
    }
}

fn update_req(session: u32, n: usize, rng: &mut Pcg) -> StreamRequest {
    // Duplicate rows are allowed: staging telescopes per-row deltas.
    let k = 1 + rng.below(4);
    StreamRequest::Update {
        session,
        rows: (0..k).map(|_| rng.below(n) as u32).collect(),
        channels: 1,
        values: (0..k).map(|_| rng.normal() as f32).collect(),
    }
}

fn open_req(session: u32, tree: &Tree) -> StreamRequest {
    StreamRequest::OpenGraph {
        session,
        n: tree.n() as u32,
        edges: tree.edges().to_vec(),
    }
}

/// Drive one batch window through `execute_each`, decoding every typed
/// response. Request ids are globally sequential so both executors in a
/// comparison see identical frames.
fn run_window(
    exec: &StreamingFieldExecutor,
    window: &[StreamRequest],
    next_id: &mut u64,
    repro: &str,
) -> Vec<(u64, StreamResponse)> {
    let words: Vec<Vec<f32>> = window
        .iter()
        .map(|r| {
            let id = *next_id;
            *next_id += 1;
            protocol::request_words(r, id)
        })
        .collect();
    exec.execute_each(&words)
        .iter()
        .map(|res| match res {
            Ok(out) => protocol::response_from_words(out)
                .unwrap_or_else(|e| panic!("undecodable response: {e}; {repro}")),
            Err(e) => panic!("well-formed frame failed to decode: {e}; {repro}"),
        })
        .collect()
}

/// Bit-exact response comparison: float payloads are compared by their
/// bit patterns (so `-0.0` vs `0.0` or a NaN sneak-in still fails).
fn assert_resp_bits_eq(a: &StreamResponse, b: &StreamResponse, what: &str, repro: &str) {
    if let (
        StreamResponse::Output { session: sa, rows: ra, channels: ca, values: va },
        StreamResponse::Output { session: sb, rows: rb, channels: cb, values: vb },
    ) = (a, b)
    {
        assert_eq!((sa, ra, ca), (sb, rb, cb), "{what}: output shape diverged; {repro}");
        let ba: Vec<u32> = va.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = vb.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "{what}: output bits diverged; {repro}");
    } else {
        assert_eq!(a, b, "{what}: responses diverged; {repro}");
    }
}

/// Which indices of a window are comparable between a fused and an
/// unfused run: everything except the non-final members of each maximal
/// same-session update run (those carry the post-run output when
/// fused, a progressive output when not — by documented contract).
fn comparable_mask(window: &[StreamRequest]) -> Vec<bool> {
    let mut cmp = vec![true; window.len()];
    let mut pending: BTreeMap<u32, usize> = BTreeMap::new();
    for (i, r) in window.iter().enumerate() {
        let s = r.session();
        if matches!(r, StreamRequest::Update { .. }) {
            if let Some(prev) = pending.insert(s, i) {
                cmp[prev] = false;
            }
        } else {
            pending.remove(&s);
        }
    }
    cmp
}

/// A seeded churn schedule: an admission window (every session opens
/// its home graph and seeds a field), then `len` windows mixing naked
/// re-opens (live migration / pending rebinds), re-sets, updates,
/// leases, closes — plus solo replan windows, kept solo so the epoch
/// every window observes is deterministic under parallel chains.
#[allow(clippy::too_many_arguments)]
fn make_windows(
    seed: u64,
    n: usize,
    graphs: &[Tree],
    sessions: u32,
    len: usize,
    with_close: bool,
    with_replan: bool,
) -> Vec<Vec<StreamRequest>> {
    let mut rng = Pcg::new(seed, 0x51ED);
    let mut windows = Vec::new();
    let mut first = Vec::new();
    for s in 0..sessions {
        let gi = s as usize % graphs.len();
        if gi > 0 {
            first.push(open_req(s, &graphs[gi]));
        }
        first.push(set_req(s, n, &mut rng));
    }
    windows.push(first);
    for _ in 0..len {
        if with_replan && rng.below(5) == 0 {
            let s = rng.below(sessions as usize) as u32;
            let g = &graphs[rng.below(graphs.len())];
            let (u, v, w) = g.edges()[rng.below(g.edges().len())];
            let scale = if rng.bool(0.5) { 1.3 } else { 0.7 };
            windows.push(vec![StreamRequest::ReplanEdge { session: s, u, v, w: w * scale }]);
            continue;
        }
        let size = 1 + rng.below(6);
        let mut w = Vec::new();
        for _ in 0..size {
            let s = rng.below(sessions as usize) as u32;
            w.push(match rng.below(12) {
                0 => set_req(s, n, &mut rng),
                1 => open_req(s, &graphs[rng.below(graphs.len())]),
                2 => StreamRequest::Lease { session: s },
                3 if with_close => StreamRequest::Close { session: s },
                _ => update_req(s, n, &mut rng),
            });
        }
        windows.push(w);
    }
    windows
}

/// One churn schedule, fused vs unfused, window for window.
fn run_fusion_schedule(seed: u64, threads: usize, n: usize, sessions: u32, capacity: usize) -> u64 {
    let repro = format!("REPRO: serving_cache fusion schedule seed={seed} threads={threads}");
    let graphs = graphs_for(n, 4, seed);
    let fused_metrics = Arc::new(MetricsRegistry::new());
    let plain_metrics = Arc::new(MetricsRegistry::new());
    let fused = build_exec(&graphs[0], threads, 3, capacity, 8, true, &fused_metrics);
    let plain = build_exec(&graphs[0], threads, 3, capacity, 8, false, &plain_metrics);
    let windows = make_windows(seed, n, &graphs, sessions, 10, true, true);

    let (mut id_a, mut id_b) = (0u64, 0u64);
    for (wi, window) in windows.iter().enumerate() {
        let got_fused = run_window(&fused, window, &mut id_a, &repro);
        let got_plain = run_window(&plain, window, &mut id_b, &repro);
        let cmp = comparable_mask(window);
        for (i, ((ida, ra), (idb, rb))) in got_fused.iter().zip(&got_plain).enumerate() {
            assert_eq!(ida, idb, "request ids desynced; {repro}");
            if cmp[i] {
                assert_resp_bits_eq(ra, rb, &format!("window {wi} response {i}"), &repro);
            }
        }
        // Full session state after every window, bit for bit.
        for s in 0..sessions {
            let probe = StreamRequest::Lease { session: s };
            assert_resp_bits_eq(
                &fused.execute_request(&probe),
                &plain.execute_request(&probe),
                &format!("window {wi} lease of session {s}"),
                &repro,
            );
        }
    }
    let (fa, fb) = (fused_metrics.snapshot(), plain_metrics.snapshot());
    if threads == 1 {
        // Serial windows resolve cache traffic in identical order.
        assert_eq!(fa.cache_hits, fb.cache_hits, "cache hits diverged; {repro}");
        assert_eq!(fa.cache_misses, fb.cache_misses, "cache misses diverged; {repro}");
        assert_eq!(fa.cache_evictions, fb.cache_evictions, "cache evictions diverged; {repro}");
    }
    assert_eq!(fb.fused_updates, 0, "the unfused executor must not fuse; {repro}");
    fa.fused_updates
}

/// The main harness: serial schedules with session-slot eviction
/// pressure (capacity < sessions) plus parallel-chain schedules on a
/// graph large enough to cross the fan-out cutoff. Fused runs must
/// actually fuse somewhere across the sweep, or the harness is
/// vacuous.
#[test]
fn churn_schedules_fused_matches_unfused_bit_for_bit() {
    let mut total_fused = 0u64;
    for seed in 0..30u64 {
        total_fused += run_fusion_schedule(seed, 1, 24, 6, 4);
    }
    for seed in 100..108u64 {
        // n = 256 ≥ PAR_MAP_MIN_N: chains genuinely fan out. Session
        // capacity covers every session — LRU victim choice under
        // racing clock stamps is the one schedule-level nondeterminism,
        // so slot eviction stays a serial-schedule concern.
        total_fused += run_fusion_schedule(seed, 4, 256, 5, 8);
    }
    assert!(total_fused > 0, "REPRO: no schedule ever fused an update run — harness is vacuous");
}

/// Replay log for the fresh-built-oracle pin: the session's home graph
/// plus every state-changing request since its last `Set`.
struct SessionLog {
    graph: usize,
    requests: Vec<StreamRequest>,
}

/// Schedule generator for the oracle pin: rebinds are always an
/// `OpenGraph` immediately followed by a `Set` for the same session, so
/// each session's state is fully determined by (home graph, last `Set`,
/// subsequent updates) — the exact subsequence the oracle replays. No
/// replans and no closes: every logged request must have executed.
fn make_pinnable_windows(
    seed: u64,
    n: usize,
    graphs: &[Tree],
    sessions: u32,
    len: usize,
) -> (Vec<Vec<StreamRequest>>, Vec<SessionLog>) {
    let mut rng = Pcg::new(seed, 0x0A0C);
    let mut windows = Vec::new();
    let mut logs: Vec<SessionLog> = (0..sessions)
        .map(|s| SessionLog { graph: s as usize % graphs.len(), requests: Vec::new() })
        .collect();
    let mut first = Vec::new();
    for s in 0..sessions {
        let gi = logs[s as usize].graph;
        if gi > 0 {
            first.push(open_req(s, &graphs[gi]));
        }
        let set = set_req(s, n, &mut rng);
        logs[s as usize].requests.push(set.clone());
        first.push(set);
    }
    windows.push(first);
    for _ in 0..len {
        let size = 1 + rng.below(5);
        let mut w = Vec::new();
        for _ in 0..size {
            let s = rng.below(sessions as usize) as u32;
            let log = &mut logs[s as usize];
            match rng.below(10) {
                0 => {
                    // Rebind: open + set as an adjacent pair. The log
                    // restarts — state before a `Set` is overwritten.
                    let gi = rng.below(graphs.len());
                    if gi > 0 {
                        w.push(open_req(s, &graphs[gi]));
                    }
                    let set = set_req(s, n, &mut rng);
                    log.graph = gi;
                    log.requests.clear();
                    log.requests.push(set.clone());
                    w.push(set);
                }
                1 => w.push(StreamRequest::Lease { session: s }),
                _ => {
                    let u = update_req(s, n, &mut rng);
                    log.requests.push(u.clone());
                    w.push(u);
                }
            }
        }
        windows.push(w);
    }
    (windows, logs)
}

/// Replay a session's log into a fresh executor whose *default* graph
/// is the session's graph — no `OpenGraph`, no cache — and return its
/// final lease.
fn fresh_oracle_lease(
    tree: &Tree,
    threads: usize,
    session: u32,
    log: &[StreamRequest],
    repro: &str,
) -> StreamResponse {
    let metrics = Arc::new(MetricsRegistry::new());
    let oracle = build_exec(tree, threads, 3, 1, 8, false, &metrics);
    for req in log {
        let resp = oracle.execute_request(req);
        assert!(
            matches!(resp, StreamResponse::Output { .. }),
            "oracle replay rejected a logged request: {resp:?}; {repro}"
        );
    }
    oracle.execute_request(&StreamRequest::Lease { session })
}

/// Invariant 2: cached, migrated, fused serving pins bit-exactly to a
/// per-graph fresh-built oracle.
#[test]
fn cached_sessions_match_a_fresh_built_per_graph_oracle() {
    for (seed, threads, n) in [(7u64, 1usize, 24usize), (8, 1, 24), (9, 4, 256)] {
        let repro = format!("REPRO: serving_cache oracle pin seed={seed} threads={threads}");
        let sessions = 4u32;
        let graphs = graphs_for(n, 3, seed);
        let metrics = Arc::new(MetricsRegistry::new());
        let live = build_exec(&graphs[0], threads, 3, 8, 8, true, &metrics);
        let (windows, logs) = make_pinnable_windows(seed, n, &graphs, sessions, 8);
        let mut next_id = 0u64;
        for window in &windows {
            run_window(&live, window, &mut next_id, &repro);
        }
        for (s, log) in logs.iter().enumerate() {
            let live_lease = live.execute_request(&StreamRequest::Lease { session: s as u32 });
            let oracle_lease =
                fresh_oracle_lease(&graphs[log.graph], threads, s as u32, &log.requests, &repro);
            assert_resp_bits_eq(
                &live_lease,
                &oracle_lease,
                &format!("session {s} (graph {})", log.graph),
                &repro,
            );
        }
        let snap = metrics.snapshot();
        assert!(snap.cache_misses >= 2, "both non-default graphs must have been built; {repro}");
    }
}

/// Invariant 3: a one-entry cache thrashed by competing opens keeps
/// every in-flight session serving, and their state still pins to the
/// fresh-built oracle — eviction only drops the cache's reference.
#[test]
fn eviction_thrash_never_poisons_in_flight_sessions() {
    let (seed, threads, n) = (66u64, 1usize, 24usize);
    let repro = format!("REPRO: serving_cache eviction thrash seed={seed}");
    let graphs = graphs_for(n, 3, seed);
    let metrics = Arc::new(MetricsRegistry::new());
    let live = build_exec(&graphs[0], threads, 3, 8, 1, true, &metrics);
    let mut rng = Pcg::new(seed, 0xE71C);
    let mut next_id = 0u64;

    // Sessions 1 and 2 live on graphs 1 and 2; opening the second
    // evicts the first's entry from the one-slot cache immediately.
    let mut logs: Vec<SessionLog> = Vec::new();
    for s in 1..=2u32 {
        let set = set_req(s, n, &mut rng);
        run_window(
            &live,
            &[open_req(s, &graphs[s as usize]), set.clone()],
            &mut next_id,
            &repro,
        );
        logs.push(SessionLog { graph: s as usize, requests: vec![set] });
    }
    for round in 0..6 {
        // Session 3 churns the cache: re-open graph 1 then graph 2,
        // forcing an eviction (and a rebuild miss) every round.
        let churn_graph = 1 + round % 2;
        run_window(
            &live,
            &[open_req(3, &graphs[churn_graph]), set_req(3, n, &mut rng)],
            &mut next_id,
            &repro,
        );
        let mut window = Vec::new();
        for s in 1..=2u32 {
            let u = update_req(s, n, &mut rng);
            logs[s as usize - 1].requests.push(u.clone());
            window.push(u);
        }
        for (i, (_, resp)) in run_window(&live, &window, &mut next_id, &repro).iter().enumerate() {
            assert!(
                matches!(resp, StreamResponse::Output { .. }),
                "round {round}: in-flight session {} stopped serving: {resp:?}; {repro}",
                i + 1
            );
        }
    }
    for (s, log) in logs.iter().enumerate() {
        let session = s as u32 + 1;
        assert_resp_bits_eq(
            &live.execute_request(&StreamRequest::Lease { session }),
            &fresh_oracle_lease(&graphs[log.graph], threads, session, &log.requests, &repro),
            &format!("thrashed session {session}"),
            &repro,
        );
    }
    let snap = metrics.snapshot();
    assert_eq!(live.plan_cache().graphs(), 1, "cache must hold exactly its budget; {repro}");
    assert!(snap.cache_evictions >= 5, "churn must actually evict; {repro}");
    assert!(snap.cache_misses >= 6, "every re-open of an evicted graph rebuilds; {repro}");
}
