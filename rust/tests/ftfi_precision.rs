//! Mixed-precision serving tier: ULP-budget sweep of the opt-in f32
//! tier (f32 products, f64 accumulation — `linalg::lanes`) against the
//! f64 oracle, across every applicable forced strategy × f-distance ×
//! thread count; plus streaming-session drift, in-tier bit-identity
//! contracts, and the backend rejection surface.
//!
//! Budget convention: budgets are *relative Frobenius* errors stated in
//! units of `ULP_F32 = f32::EPSILON as f64` (one f32 ulp at 1.0,
//! ≈ 1.19e-7). The f32 tier rounds each product once (accumulation
//! stays f64), so error scales with the number of products per output
//! and the conditioning of the strategy's basis:
//!
//! | strategy                | budget (× ULP_F32) | why                              |
//! |-------------------------|--------------------|----------------------------------|
//! | Dense/Separable/Lattice | 1024               | one rounded product per term     |
//! | Chebyshev / Vandermonde | 4096               | spectral-coefficient amplification|
//! | RationalSum / Cauchy    | 65536              | ill-conditioned rational basis   |
//!
//! (The same constants are tabulated in DESIGN.md "SIMD lanes &
//! precision tiers".)

use std::sync::Arc;

use ftfi::ftfi::cordial::{CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{self, random_rational_tree, random_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::{
    EnsembleFieldIntegrator, FtfiError, GraphFieldIntegrator, Precision, SharedPlans,
    StreamingIntegrator, TreeFieldIntegrator,
};

/// One f32 ulp at 1.0, as the f64 the comparisons run in.
const ULP_F32: f64 = f32::EPSILON as f64;

/// Per-strategy relative-error budget for the f32 tier vs the f64
/// oracle (same strategy, same plans — only the tier differs, so the
/// budget is pure rounding × basis conditioning; see module doc).
fn tier_budget(s: Strategy) -> f64 {
    match s {
        Strategy::RationalSum | Strategy::Cauchy => 65536.0 * ULP_F32,
        Strategy::Chebyshev | Strategy::Vandermonde => 4096.0 * ULP_F32,
        _ => 1024.0 * ULP_F32,
    }
}

fn rel_err(got: &Matrix, want: &Matrix) -> f64 {
    got.frobenius_diff(want) / (1.0 + want.frobenius())
}

/// The tentpole sweep: every applicable forced strategy × f-distance ×
/// threads ∈ {1, 4}. For each case the f32-tier prepared integration
/// must (a) stay inside its stated budget against the f64-tier oracle
/// with the same forced strategy, and (b) be bit-identical across
/// thread counts — the determinism contract holds per tier. A minimum
/// applicable-pair count pins the sweep against silent degeneration.
#[test]
fn f32_tier_ulp_budget_sweep_forced_strategies() {
    let mut rng = Pcg::seed(7100);
    // Rational edge weights keep the Lattice / Vandermonde paths
    // applicable, mirroring the equivalence sweep.
    let tree = random_rational_tree(160, 3, 4, &mut rng);
    let x = Matrix::randn(160, 2, &mut rng);
    let fs: Vec<FDist> = vec![
        FDist::Identity,
        FDist::Polynomial(vec![0.4, 1.0, -0.05]),
        FDist::Exponential { lambda: -0.3, scale: 1.2 },
        FDist::Trig { omega: 0.6, phase: 0.3, scale: 1.0 },
        FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.5] },
        FDist::ExpQuadratic { u: -0.05, v: 0.02, w: 0.1 },
    ];
    let all = [
        Strategy::Dense,
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for f in &fs {
        for &s in &all {
            let build = |prec: Precision, threads: usize| {
                TreeFieldIntegrator::builder(&tree)
                    .leaf_threshold(8)
                    .policy(CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() })
                    .threads(threads)
                    .precision(prec)
                    .build()
                    .unwrap()
            };
            let oracle = build(Precision::F64, 1);
            let want = match oracle.prepare(f) {
                Err(FtfiError::StrategyInapplicable { .. }) => continue,
                Err(e) => panic!("{f:?} forced {s:?}: unexpected error {e}"),
                Ok(prepared) => prepared.integrate(&x).unwrap(),
            };
            applicable += 1;
            // Planning is tier-independent, so the fast tier must be
            // applicable whenever the oracle is.
            let fast1 = build(Precision::F32, 1);
            let got1 = fast1.prepare(f).expect("tier must not change applicability");
            let got1 = got1.integrate(&x).unwrap();
            let fast4 = build(Precision::F32, 4);
            let got4 = fast4.prepare(f).unwrap().integrate(&x).unwrap();
            assert!(
                got1 == got4,
                "{f:?} forced {s:?}: f32 tier must be bit-identical across thread counts"
            );
            let rel = rel_err(&got1, &want);
            let budget = tier_budget(s);
            assert!(
                rel < budget,
                "{f:?} forced {s:?}: f32-tier rel err {rel:.3e} exceeds budget {budget:.3e} \
                 ({:.0} ULP_F32)",
                budget / ULP_F32
            );
        }
    }
    assert!(applicable >= 12, "sweep degenerated: only {applicable} applicable (f, strategy) pairs");
}

/// The fast tier must actually engage: on a generic workload its output
/// differs bitwise from the f64 tier (while staying inside budget). A
/// tier that silently no-ops would pass every budget test — this pins
/// the other direction.
#[test]
fn f32_tier_actually_differs_from_f64_tier() {
    let mut rng = Pcg::seed(7200);
    let tree = random_tree(220, 0.1, 1.0, &mut rng);
    let x = Matrix::randn(220, 4, &mut rng);
    let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
    let f64_out = TreeFieldIntegrator::builder(&tree)
        .build()
        .unwrap()
        .try_integrate(&f, &x)
        .unwrap();
    let f32_out = TreeFieldIntegrator::builder(&tree)
        .precision(Precision::F32)
        .build()
        .unwrap()
        .try_integrate(&f, &x)
        .unwrap();
    assert!(
        f32_out != f64_out,
        "f32 tier produced bit-identical output — the tier is not reaching the kernels"
    );
    let rel = rel_err(&f32_out, &f64_out);
    assert!(rel < 1024.0 * ULP_F32, "f32 tier drifted to rel {rel:.3e}");
}

/// In-tier delta consistency: at the f32 tier, the k = n degenerate
/// delta must stay bit-identical to a plain prepared integration of the
/// delta field — the same contract the f64 tier pins in the delta
/// ablation. Both paths run the same tier, so bit-identity survives.
#[test]
fn f32_tier_full_rows_delta_is_bit_identical_in_tier() {
    let mut rng = Pcg::seed(7300);
    let n = 200;
    let d = 2;
    let tree = random_tree(n, 0.1, 1.0, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    let tfi = TreeFieldIntegrator::builder(&tree)
        .threads(1)
        .precision(Precision::F32)
        .build()
        .unwrap();
    let plans = tfi.prepare_plans(&f, d).unwrap();
    let dx = Matrix::randn(n, d, &mut rng);
    let rows: Vec<u32> = (0..n as u32).collect();
    let dout = tfi.integrate_delta_prepared(&rows, &dx, &plans).unwrap();
    let want = tfi.integrate_prepared(&dx, &plans).unwrap();
    assert!(dout == want, "k=n delta must be bit-identical to integrate(Δ) within the f32 tier");
}

/// Streaming drift: run the same update stream through an f64-tier and
/// an f32-tier session. Row assignments are exact in any tier, so the
/// fields stay bitwise equal; at every refresh boundary the f32 session
/// must (a) restore the f64-tier refresh state within the serving
/// budget and (b) match its own tier's cold recompute bit-exactly (the
/// bit-exact-refresh drift policy, per tier).
#[test]
fn streaming_refresh_restores_f64_refresh_state_within_budget() {
    let mut rng = Pcg::seed(7400);
    let n = 300;
    let d = 3;
    let tree = random_tree(n, 0.1, 1.0, &mut rng);
    let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
    let field = Matrix::randn(n, d, &mut rng);
    let refresh_every = 4;
    let make = |prec: Precision| {
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).precision(prec).build().unwrap();
        let plans = tfi.prepare_plans(&f, d).unwrap();
        Arc::new(SharedPlans::new(tfi, plans))
    };
    let shared64 = make(Precision::F64);
    let shared32 = make(Precision::F32);
    let mut s64 =
        StreamingIntegrator::new(Arc::clone(&shared64), field.clone(), refresh_every).unwrap();
    let mut s32 =
        StreamingIntegrator::new(Arc::clone(&shared32), field.clone(), refresh_every).unwrap();
    for round in 1..=3 {
        for _ in 0..refresh_every {
            let k = 1 + rng.below(8);
            let rows: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
            let vals = Matrix::randn(k, d, &mut rng);
            s64.apply_update(&rows, &vals).unwrap();
            s32.apply_update(&rows, &vals).unwrap();
        }
        // The refresh_every-th update just recomputed both sessions
        // from their (bitwise equal) fields.
        assert!(s32.field() == s64.field(), "round {round}: fields must stay bitwise equal");
        let rel = rel_err(s32.output(), s64.output());
        assert!(
            rel < 1024.0 * ULP_F32,
            "round {round}: post-refresh f32 state drifted to rel {rel:.3e} from the f64 tier"
        );
        let cold = shared32
            .with(|tfi, plans| tfi.integrate_prepared(s32.field(), plans))
            .unwrap()
            .unwrap();
        assert!(
            *s32.output() == cold,
            "round {round}: f32-tier refresh must be bit-exact within its own tier"
        );
    }
}

/// The fast tier is a tree-backend feature: the graph and ensemble
/// builders accept `.precision(..)` for uniformity but reject anything
/// other than the f64 tier at `build()` with `InvalidInput`.
#[test]
fn fast_tier_rejected_on_graph_and_ensemble_backends() {
    let mut rng = Pcg::seed(7500);
    let g = generators::path_plus_random_edges(60, 30, &mut rng);
    match GraphFieldIntegrator::builder(&g).precision(Precision::F32).build() {
        Err(FtfiError::InvalidInput(msg)) => {
            assert!(msg.contains("f64"), "rejection must name the supported tier: {msg}")
        }
        Err(e) => panic!("graph backend: wrong error kind for the f32 tier: {e}"),
        Ok(_) => panic!("graph backend must reject the f32 tier"),
    }
    match EnsembleFieldIntegrator::builder(&g).trees(2).seed(7).precision(Precision::F32).build() {
        Err(FtfiError::InvalidInput(msg)) => {
            assert!(msg.contains("f64"), "rejection must name the supported tier: {msg}")
        }
        Err(e) => panic!("ensemble backend: wrong error kind for the f32 tier: {e}"),
        Ok(_) => panic!("ensemble backend must reject the f32 tier"),
    }
    // The default tier stays accepted on both.
    assert!(GraphFieldIntegrator::builder(&g).precision(Precision::F64).build().is_ok());
    assert!(EnsembleFieldIntegrator::builder(&g)
        .trees(2)
        .seed(7)
        .precision(Precision::F64)
        .build()
        .is_ok());
}

/// Accessor round-trip: the tier set on the builder is visible on the
/// integrator and on every prepared handle derived from it.
#[test]
fn precision_threads_through_builder_and_prepared_handles() {
    let mut rng = Pcg::seed(7600);
    let tree = random_tree(50, 0.1, 1.0, &mut rng);
    let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
    let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
    assert_eq!(tfi.precision(), Precision::F64, "f64 is the default tier");
    let tfi = TreeFieldIntegrator::builder(&tree).precision(Precision::F32).build().unwrap();
    assert_eq!(tfi.precision(), Precision::F32);
    let prepared = tfi.prepare(&f).unwrap();
    assert_eq!(prepared.precision(), Precision::F32);
    assert_eq!(Precision::parse("f32"), Some(Precision::F32));
    assert_eq!(Precision::parse("f64"), Some(Precision::F64));
    assert_eq!(Precision::parse("f16"), None);
    assert_eq!(Precision::F32.as_str(), "f32");
}
