//! Integration tests over the AOT → PJRT boundary: require the artifacts
//! built by `make artifacts` (skipped with a clear message otherwise) and
//! exercise the full python-compiled / rust-executed stack.
//!
//! This target is gated behind the `pjrt` cargo feature (see Cargo.toml)
//! — run with `cargo test --features pjrt --test runtime_integration`.

use ftfi::ml::rng::Pcg;
use ftfi::ml::shapes;
use ftfi::runtime::topvit::{TopVit, TRAIN_BATCH};
use ftfi::runtime::{Runtime, TensorF32};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("sanity_matmul.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        None
    }
}

#[test]
fn sanity_matmul_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    assert!(!rt.platform().is_empty());
    let exe = rt.load_hlo_text(dir.join("sanity_matmul.hlo.txt")).expect("load sanity");
    let x = TensorF32::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = TensorF32::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
    let out = exe.run(&[x, y]).expect("run");
    assert_eq!(out.len(), 1);
    // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert_eq!(out[0].data, vec![5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn topvit_forward_shapes_and_determinism() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = TopVit::load(&rt, &dir, "topvit_init_masked.bin", &[1, 8], false).unwrap();
    let mut rng = Pcg::seed(7);
    let img: Vec<f32> = (0..32 * 32).map(|_| rng.normal() as f32).collect();
    let logits = model.forward(1, &img).unwrap();
    assert_eq!(logits.shape, vec![1, 8]);
    assert!(logits.data.iter().all(|v| v.is_finite()));
    // Determinism across calls.
    let logits2 = model.forward(1, &img).unwrap();
    assert_eq!(logits.data, logits2.data);
    // Batch-8 consistency with batch-1 on the first row.
    let mut batch = img.clone();
    for _ in 0..7 {
        batch.extend((0..32 * 32).map(|_| rng.normal() as f32));
    }
    let l8 = model.forward(8, &batch).unwrap();
    assert_eq!(l8.shape, vec![8, 8]);
    for (a, b) in logits.data.iter().zip(&l8.data[..8]) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn topvit_masked_and_unmasked_differ() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let masked = TopVit::load(&rt, &dir, "topvit_init_masked.bin", &[1], false).unwrap();
    let unmasked = TopVit::load(&rt, &dir, "topvit_init_unmasked.bin", &[1], false).unwrap();
    // Same weights except the 3 mask parameters per layer.
    assert!(!masked.mask_params().is_empty());
    for (name, vals) in unmasked.mask_params() {
        assert!(vals.iter().all(|&v| v == 0.0), "{name} not zeroed");
    }
    let mut rng = Pcg::seed(9);
    let img: Vec<f32> = (0..32 * 32).map(|_| rng.normal() as f32).collect();
    let a = masked.forward(1, &img).unwrap();
    let b = unmasked.forward(1, &img).unwrap();
    let diff: f32 =
        a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
    assert!(diff > 1e-5, "mask parameters had no effect: {diff}");
}

#[test]
fn topvit_train_step_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut model = TopVit::load(&rt, &dir, "topvit_init_masked.bin", &[], true).unwrap();
    let mut rng = Pcg::seed(11);
    let data = shapes::dataset(8, &mut rng); // 64 examples
    let (images, labels) = shapes::pack_batch(&data, 0, TRAIN_BATCH);
    let first = model.train_step(&images, &labels, 0.01).unwrap();
    let mut last = first;
    for _ in 0..15 {
        last = model.train_step(&images, &labels, 0.01).unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(
        last < first,
        "loss did not decrease on a fixed batch: {first} -> {last}"
    );
}

#[test]
fn topvit_training_moves_mask_params() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut model = TopVit::load(&rt, &dir, "topvit_init_masked.bin", &[], true).unwrap();
    let before = model.mask_params();
    let mut rng = Pcg::seed(12);
    let data = shapes::dataset(4, &mut rng);
    for step in 0..5 {
        let (images, labels) = shapes::pack_batch(&data, step * TRAIN_BATCH, TRAIN_BATCH);
        model.train_step(&images, &labels, 0.01).unwrap();
    }
    let after = model.mask_params();
    let moved = before
        .iter()
        .zip(&after)
        .any(|((_, a), (_, b))| a.iter().zip(b).any(|(x, y)| (x - y).abs() > 1e-7));
    assert!(moved, "the 3 learnable RPE parameters never moved");
}
