//! Loom model-checking of the engine's hand-rolled concurrency: the
//! [`WorkPool`] helper-token protocol, the [`ArenaPool`] checkout/return
//! protocol and the streaming session-table set-vs-update race.
//!
//! The whole file is gated on `--cfg loom`: the offline build (no loom
//! in the dependency tree) compiles it to an empty test binary, while
//! the CI `loom` job adds the dependency (`cargo add loom`) and runs
//!
//! ```text
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --test loom_models --release
//! ```
//!
//! Under that cfg the crate's `crate::sync` shim resolves every Mutex,
//! atomic and scoped spawn these components use to loom equivalents, so
//! the models below exhaustively explore the interleavings of the REAL
//! shipped code, not of a copy that can drift.
#![cfg(loom)]

use ftfi::runtime::pool::WorkPool;
use ftfi::sync::atomic::{AtomicUsize, Ordering};
use ftfi::sync::{ArenaPool, Mutex};
use std::sync::Arc;

/// `join` returns `(a(), b())` positionally and hands its helper token
/// back, for every interleaving of the fork, the helper body and the
/// join — the foundation of the bit-identical-across-thread-counts
/// contract.
#[test]
fn join_is_ordered_and_returns_its_token() {
    loom::model(|| {
        let pool = WorkPool::new(2);
        let (a, b) = pool.join(|| 1u64, || 2u64);
        assert_eq!((a, b), (1, 2), "join must assemble results positionally");
        // The helper token must be back regardless of which side ran
        // where: a later join must still be able to fork.
        let (c, d) = pool.join(|| 3u64, || 4u64);
        assert_eq!((c, d), (3, 4));
    });
}

/// Nested joins under token exhaustion: with a single helper token the
/// inner joins race for it, the losers degrade to inline execution, and
/// no interleaving loses a token or a result.
#[test]
fn nested_join_degrades_inline_when_saturated() {
    loom::model(|| {
        let pool = WorkPool::new(2);
        let (left, right) = pool.join(
            || {
                let (a, b) = pool.join(|| 1u64, || 2u64);
                a + b
            },
            || {
                let (a, b) = pool.join(|| 10u64, || 20u64);
                a + b
            },
        );
        assert_eq!((left, right), (3, 30));
        // All tokens restored: a fresh join can fork again.
        let (a, b) = pool.join(|| 7u64, || 8u64);
        assert_eq!((a, b), (7, 8));
    });
}

/// `map` writes every result into its input slot through the atomic
/// cursor: for every schedule of caller and helper the output equals
/// the serial map, each index is produced exactly once, and the helper
/// tokens come back.
#[test]
fn map_distributes_every_index_exactly_once() {
    loom::model(|| {
        let pool = WorkPool::new(2);
        let items: Vec<u64> = vec![5, 6, 7];
        let hits = AtomicUsize::new(0);
        let out = pool.map(&items, |i, &v| {
            hits.fetch_add(1, Ordering::Relaxed);
            v * 10 + i as u64
        });
        assert_eq!(out, vec![50, 61, 72], "map must be order-preserving");
        assert_eq!(hits.load(Ordering::Relaxed), 3, "each item runs exactly once");
    });
}

/// Two threads driving one shared pool concurrently: the token counter
/// never admits more helpers than the budget, and both callers get
/// correct, positionally ordered results under every interleaving.
#[test]
fn concurrent_joins_share_the_token_budget_safely() {
    loom::model(|| {
        let pool = Arc::new(WorkPool::new(2));
        let p2 = Arc::clone(&pool);
        let other = loom::thread::spawn(move || {
            let (a, b) = p2.join(|| 100u64, || 200u64);
            assert_eq!((a, b), (100, 200));
        });
        let (a, b) = pool.join(|| 1u64, || 2u64);
        assert_eq!((a, b), (1, 2));
        other.join().expect("peer join thread");
        // Whoever won the token raced cleanly: it is back now.
        let (c, d) = pool.join(|| 3u64, || 4u64);
        assert_eq!((c, d), (3, 4));
    });
}

/// The arena checkout/return protocol: two threads contending for one
/// stocked arena never hand the same arena out twice, and every arena
/// (stocked or freshly made) is back in the stock at the end.
#[test]
fn arena_checkout_never_aliases_under_contention() {
    loom::model(|| {
        let pool: Arc<ArenaPool<u64>> = Arc::new(ArenaPool::new());
        pool.put_back(1);
        let p2 = Arc::clone(&pool);
        let peer = loom::thread::spawn(move || {
            let a = p2.checkout(|| 2);
            p2.put_back(a);
            a
        });
        let mine = pool.checkout(|| 2);
        pool.put_back(mine);
        let theirs = peer.join().expect("peer checkout thread");
        let idle = pool.idle();
        // Exactly two legal outcomes: the checkouts serialised (both saw
        // the one stocked arena, which is back alone at the end) or they
        // overlapped (one made a fresh arena, two are stocked now). A
        // broken lock handing the stocked arena out twice would leave
        // two *copies* of it — (1, 1) with idle == 2 — and must not
        // survive any interleaving.
        let serialised = mine == 1 && theirs == 1 && idle == 1;
        let overlapped = mine + theirs == 3 && idle == 2;
        assert!(
            serialised || overlapped,
            "illegal arena protocol outcome: mine={mine} theirs={theirs} idle={idle}"
        );
    });
}

/// Miniature model of the streaming executor's session table: a `set`
/// request (install/overwrite) racing an `update` request (mutate in
/// place) on the same occupied slot. Every interleaving must linearise:
/// update-then-set leaves the fresh session (100), set-then-update
/// leaves the fresh session with the update applied (101). A torn state
/// (the update landing on a half-installed session, or a lost update
/// with the old session still in place) must be unreachable.
#[test]
fn session_set_vs_update_race_linearises() {
    loom::model(|| {
        let slot: Arc<Mutex<Option<u64>>> = Arc::new(Mutex::new(Some(0)));
        let s2 = Arc::clone(&slot);
        let setter = loom::thread::spawn(move || {
            *s2.lock().expect("session slot") = Some(100);
        });
        {
            let mut guard = slot.lock().expect("session slot");
            if let Some(v) = guard.as_mut() {
                *v += 1;
            }
        }
        setter.join().expect("setter thread");
        let final_state = *slot.lock().expect("session slot");
        assert!(
            matches!(final_state, Some(100) | Some(101)),
            "non-linearisable session state: {final_state:?}"
        );
    });
}
