//! Embedding invariants for the random low-distortion tree embeddings
//! (`tree/frt.rs`, `tree/bartal.rs`) — the sampling layer under the
//! tree-ensemble integrator:
//!
//! - **domination**: the tree metric never undercuts the graph metric;
//! - **2-HST level structure**: edge weights decay geometrically along
//!   every root→leaf path (FRT halves exactly; Bartal never increases
//!   and is bounded by half the parent cluster's diameter);
//! - **lift/restrict round-trip**: exact (bitwise) with Steiner rows
//!   zeroed.

use ftfi::graph::shortest_path::all_pairs;
use ftfi::graph::{generators, Graph};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::tree::bartal::bartal_tree;
use ftfi::tree::frt::{frt_tree, TreeEmbedding};

type Embedder = fn(&Graph, &mut Pcg) -> TreeEmbedding;

fn embedders() -> Vec<(&'static str, Embedder)> {
    vec![("frt", frt_tree as Embedder), ("bartal", bartal_tree as Embedder)]
}

/// `(parent_edge_weight, child_edge_weight)` for every non-root edge
/// pair along the embedding tree, via BFS from the root (vertex 0 in
/// both constructions).
fn parent_child_edge_weights(emb: &TreeEmbedding) -> Vec<(f64, f64)> {
    let t = &emb.tree;
    let mut incoming = vec![f64::NAN; t.n()];
    let mut seen = vec![false; t.n()];
    let mut queue = std::collections::VecDeque::from([0usize]);
    seen[0] = true;
    let mut pairs = Vec::new();
    while let Some(v) = queue.pop_front() {
        for &(u, w) in t.neighbors(v) {
            if seen[u as usize] {
                continue;
            }
            seen[u as usize] = true;
            if !incoming[v].is_nan() {
                pairs.push((incoming[v], w));
            }
            incoming[u as usize] = w;
            queue.push_back(u as usize);
        }
    }
    assert!(seen.iter().all(|&s| s), "embedding tree must be connected");
    pairs
}

/// The tree metric dominates the graph metric on all sampled pairs, for
/// both embedding families, across several graphs and seeds.
#[test]
fn tree_metric_dominates_graph_metric() {
    for seed in 0..3u64 {
        let mut rng = Pcg::seed(40 + seed);
        let n = 35;
        let g = generators::erdos_renyi(n, 0.15, &mut rng);
        let d = all_pairs(&g);
        for (name, build) in embedders() {
            let emb = build(&g, &mut rng);
            for i in 0..n {
                for j in 0..n {
                    let dt = emb.distance(i, j);
                    let dg = d[i * n + j];
                    assert!(
                        dt + 1e-6 >= dg,
                        "{name} seed={seed} ({i},{j}): tree {dt} < graph {dg}"
                    );
                }
            }
        }
    }
}

/// FRT builds a 2-HST: every child edge is exactly half its parent edge
/// (the level radii are `β·2^level`, and the leaf hook is half the
/// bottom radius).
#[test]
fn frt_edge_weights_halve_along_every_path() {
    for seed in 0..3u64 {
        let mut rng = Pcg::seed(50 + seed);
        // Weights ≥ 0.5 keep every level radius far above the 1e-9
        // positivity clamp, so the halving is exact.
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let emb = frt_tree(&g, &mut rng);
        let pairs = parent_child_edge_weights(&emb);
        assert!(!pairs.is_empty(), "seed={seed}: tree must have ≥ 2 levels");
        for (wp, wc) in pairs {
            assert!(
                (wc - 0.5 * wp).abs() <= 1e-9 * (1.0 + wp),
                "seed={seed}: child edge {wc} is not half of parent edge {wp}"
            );
        }
    }
}

/// Bartal's low-diameter decomposition: edge weights never increase
/// along a root→leaf path (child clusters are subsets, so their
/// diameters — and hence their half-diameter hooks — cannot grow).
#[test]
fn bartal_edge_weights_never_increase_along_paths() {
    for seed in 0..3u64 {
        let mut rng = Pcg::seed(60 + seed);
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let emb = bartal_tree(&g, &mut rng);
        for (wp, wc) in parent_child_edge_weights(&emb) {
            assert!(
                wc <= wp + 1e-9,
                "seed={seed}: child edge {wc} grew past parent edge {wp}"
            );
        }
    }
}

/// `lift_field` / `restrict_field` round-trip exactly (bitwise), with
/// every Steiner row zeroed and every leaf row a copy of its source.
#[test]
fn lift_restrict_roundtrip_is_exact_with_steiner_zeroing() {
    for (name, build) in embedders() {
        let mut rng = Pcg::seed(70);
        let g = generators::path_plus_random_edges(25, 12, &mut rng);
        let emb = build(&g, &mut rng);
        assert_eq!(emb.n_original(), 25);
        assert_eq!(emb.n_steiner(), emb.tree.n() - 25);
        let x = Matrix::randn(25, 3, &mut rng);
        let lifted = emb.lift_field(&x);
        assert_eq!(lifted.rows(), emb.tree.n());
        assert_eq!(lifted.cols(), 3);
        let leaf_set: std::collections::HashSet<u32> = emb.leaf_of.iter().copied().collect();
        assert_eq!(leaf_set.len(), 25, "{name}: leaf slots must be distinct");
        for (v, &slot) in emb.leaf_of.iter().enumerate() {
            assert!((slot as usize) < emb.tree.n(), "{name}: leaf slot out of range");
            assert_eq!(lifted.row(slot as usize), x.row(v), "{name}: leaf row must copy");
        }
        for t in 0..emb.tree.n() as u32 {
            if !leaf_set.contains(&t) {
                assert!(
                    lifted.row(t as usize).iter().all(|&v| v == 0.0),
                    "{name}: Steiner row {t} must be zero"
                );
            }
        }
        let back = emb.restrict_field(&lifted);
        assert!(back == x, "{name}: restrict(lift(x)) must be bitwise x");
    }
}

/// Degenerate inputs: singleton and two-vertex graphs embed without
/// panicking and keep the invariants.
#[test]
fn degenerate_graphs_embed_cleanly() {
    for (name, build) in embedders() {
        let mut rng = Pcg::seed(80);
        let g1 = Graph::from_edges(1, &[]);
        let e1 = build(&g1, &mut rng);
        assert_eq!(e1.n_original(), 1, "{name}");
        assert_eq!(e1.distance(0, 0), 0.0, "{name}");
        let g2 = Graph::from_edges(2, &[(0, 1, 3.0)]);
        let e2 = build(&g2, &mut rng);
        assert!(e2.distance(0, 1) + 1e-9 >= 3.0, "{name}: must dominate the edge");
        let x = Matrix::randn(2, 1, &mut rng);
        let back = e2.restrict_field(&e2.lift_field(&x));
        assert!(back == x, "{name}: two-vertex round-trip");
    }
}
