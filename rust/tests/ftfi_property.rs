//! Property-based equivalence harness: FTFI vs the brute-force oracle
//! (`BruteForceIntegrator`) across the size ladder n ∈ {1, 2, 17, 64,
//! 257} — degenerate singletons, tiny trees, odd non-powers-of-two and
//! a size above every internal cutoff — with random multi-channel
//! fields and the full `FDist` × forced-`Strategy` sweep.
//!
//! The offline environment has no proptest crate, so this is a seeded
//! random sweep: every case derives from a deterministic seed, and
//! every assertion message leads with `REPRO seed=…` so a failure can
//! be replayed exactly (`Pcg::seed(seed)` regenerates the case).

use ftfi::ftfi::brute::{btfi_streaming, BruteForceIntegrator};
use ftfi::ftfi::cordial::{CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{path_plus_random_edges, random_rational_tree, random_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::{
    EnsembleFieldIntegrator, FieldIntegrator, FtfiError, GraphFieldIntegrator,
    TreeFieldIntegrator,
};

/// The size ladder: 1 (singleton), 2 (single edge), 17 (one leaf), 64
/// (a few IT levels), 257 (above the batch-axis cutoff, odd).
const SIZES: [usize; 5] = [1, 2, 17, 64, 257];

/// Randomly-parameterised representatives of every `FDist` class, with
/// the per-class tolerance of the default planning path (exact
/// separable/lattice classes at 1e-9; Chebyshev/LDR-planned smooth
/// classes at 1e-6 — see DESIGN.md, Numerics).
fn f_cases(rng: &mut Pcg) -> Vec<(FDist, f64)> {
    vec![
        (FDist::Identity, 1e-9),
        (FDist::Polynomial(vec![rng.normal(), rng.normal(), rng.normal() * 0.3]), 1e-8),
        (FDist::Exponential { lambda: rng.uniform_in(-1.0, -0.1), scale: 1.0 }, 1e-9),
        (
            FDist::PolyExp {
                coeffs: vec![1.0, rng.uniform_in(-0.5, 0.5)],
                lambda: rng.uniform_in(-0.8, -0.1),
            },
            1e-9,
        ),
        (
            FDist::Trig {
                omega: rng.uniform_in(0.2, 1.5),
                phase: rng.uniform_in(0.0, 1.0),
                scale: 1.0,
            },
            1e-9,
        ),
        (FDist::inverse_quadratic(rng.uniform_in(0.1, 2.0)), 1e-6),
        (
            FDist::ExpOverLinear { lambda: rng.uniform_in(-0.5, 0.0), c: rng.uniform_in(0.5, 2.0) },
            1e-6,
        ),
        (FDist::gaussian(rng.uniform_in(0.05, 0.5)), 1e-6),
        (FDist::Custom(std::sync::Arc::new(|x: f64| (0.4 * x).sin() / (1.0 + 0.3 * x))), 1e-6),
    ]
}

/// Strategy-specific floors (the LDR paths shed digits in f64).
fn strategy_tol(s: Strategy) -> f64 {
    match s {
        Strategy::RationalSum | Strategy::Cauchy => 5e-5,
        Strategy::Chebyshev | Strategy::Vandermonde => 5e-6,
        _ => 1e-9,
    }
}

fn rel_err(got: &Matrix, want: &Matrix) -> f64 {
    got.frobenius_diff(want) / (1.0 + want.frobenius())
}

/// Property: with the default policy, FTFI equals the brute oracle on
/// every ladder size, for every function class, for random
/// multi-channel fields and random leaf thresholds.
#[test]
fn property_default_policy_matches_brute_across_size_ladder() {
    for &n in &SIZES {
        for case in 0..4u64 {
            let seed = 100_000 + (n as u64) * 100 + case;
            let mut rng = Pcg::seed(seed);
            let d = 1 + rng.below(3);
            let tree = random_tree(n, 0.05, 1.0, &mut rng);
            let x = Matrix::randn(n, d, &mut rng);
            let t = [2usize, 8, 48][rng.below(3)];
            let brute = BruteForceIntegrator::from_tree(tree.clone());
            for (f, tol) in f_cases(&mut rng) {
                let tfi = TreeFieldIntegrator::builder(&tree)
                    .leaf_threshold(t)
                    .build()
                    .unwrap();
                let got = tfi.try_integrate(&f, &x).unwrap();
                let want = brute.integrate(&f, &x).unwrap();
                let rel = rel_err(&got, &want);
                assert!(
                    rel < tol,
                    "REPRO seed={seed} n={n} d={d} t={t} {f:?}: rel {rel}"
                );
            }
        }
    }
}

/// Property: every *applicable* forced strategy equals the brute oracle
/// on every ladder size. Rational-weight trees keep the lattice /
/// Vandermonde paths applicable; inapplicable `(f, strategy)` combos
/// surface as the typed `StrategyInapplicable` and are skipped by
/// definition. A floor on the applicable count pins that the sweep
/// cannot silently degenerate into skipping everything.
#[test]
fn property_every_applicable_forced_strategy_matches_brute() {
    let all = [
        Strategy::Dense,
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for &n in &SIZES {
        let seed = 200_000 + n as u64;
        let mut rng = Pcg::seed(seed);
        let tree = random_rational_tree(n, 3, 4, &mut rng);
        let d = 1 + rng.below(3);
        let x = Matrix::randn(n, d, &mut rng);
        let brute = BruteForceIntegrator::from_tree(tree.clone());
        for (f, base_tol) in f_cases(&mut rng) {
            let want = brute.integrate(&f, &x).unwrap();
            for &s in &all {
                let policy =
                    CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
                let tfi = TreeFieldIntegrator::builder(&tree)
                    .leaf_threshold(8)
                    .policy(policy)
                    .build()
                    .unwrap();
                match tfi.prepare(&f) {
                    Err(FtfiError::StrategyInapplicable { .. }) => continue,
                    Err(e) => {
                        panic!("REPRO seed={seed} n={n} {f:?} forced {s:?}: unexpected {e}")
                    }
                    Ok(prepared) => {
                        applicable += 1;
                        let got = prepared.integrate(&x).unwrap();
                        let tol = base_tol.max(strategy_tol(s));
                        let rel = rel_err(&got, &want);
                        assert!(
                            rel < tol,
                            "REPRO seed={seed} n={n} d={d} {f:?} forced {s:?}: rel {rel}"
                        );
                    }
                }
            }
        }
    }
    // Sizes 1/2 are leaf-only (every strategy vacuously applies: 9·7
    // combos each); the larger rational trees keep at least the
    // Dense/Lattice/Chebyshev columns live. Pin a conservative floor.
    assert!(applicable >= 60, "only {applicable} (f, strategy) combos were applicable");
}

/// Property: the graph pipelines agree with their oracles on every
/// ladder size — the MST route *exactly* (same tree metric), the
/// ensemble route against the member-order average of per-tree brute
/// integrals.
#[test]
fn property_graph_backends_match_their_oracles() {
    for &n in &SIZES {
        let seed = 300_000 + n as u64;
        let mut rng = Pcg::seed(seed);
        let g = if n >= 3 {
            // (n = 2 has no non-adjacent pairs for chord edges.)
            path_plus_random_edges(n, n / 2, &mut rng)
        } else {
            random_tree(n, 0.1, 1.0, &mut rng).to_graph()
        };
        let d = 1 + rng.below(3);
        let x = Matrix::randn(n, d, &mut rng);
        let f = FDist::Exponential { lambda: rng.uniform_in(-0.8, -0.2), scale: 1.0 };

        // Single-MST route: identical metric to brute-on-the-MST.
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let brute_mst = BruteForceIntegrator::from_tree(gfi.tree().clone());
        let got = gfi.try_integrate(&f, &x).unwrap();
        let want = brute_mst.integrate(&f, &x).unwrap();
        let rel = rel_err(&got, &want);
        assert!(rel < 1e-9, "REPRO seed={seed} n={n} d={d} MST route: rel {rel}");

        // Ensemble route: member-order average of brute per-tree
        // integrals (lift → streaming BTFI on the embedding tree —
        // O(N) memory, embedding trees carry many Steiner nodes —
        // → restrict).
        let ens =
            EnsembleFieldIntegrator::builder(&g).trees(3).seed(seed).build().unwrap();
        let mut want = Matrix::zeros(n, d);
        for i in 0..ens.trees() {
            let emb = ens.embedding(i);
            let lifted = emb.lift_field(&x);
            want.axpy(1.0, &emb.restrict_field(&btfi_streaming(&emb.tree, &f, &lifted)));
        }
        want.scale(1.0 / ens.trees() as f64);
        let got = ens.try_integrate(&f, &x).unwrap();
        let rel = rel_err(&got, &want);
        assert!(rel < 1e-8, "REPRO seed={seed} n={n} d={d} ensemble route: rel {rel}");
    }
}
