//! Superposition / mutation harness for the streaming delta subsystem.
//!
//! Field integration is linear in the field, so
//! `integrate(x + Δ) = integrate(x) + integrate_delta(rows(Δ), Δ)` up
//! to float rounding. The harness pins that identity across the size
//! ladder n ∈ {1, 2, 17, 64, 257} × every applicable forced `Strategy`
//! × the `FDist` classes × threads ∈ {1, 4}, plus the degenerate
//! **bit-identity** case: a delta listing *every* row skips nothing and
//! must reproduce `integrate(Δ)` bit for bit (same kernels, same
//! reduction order).
//!
//! **ULP budget.** Both sides evaluate the same prepared plans, so the
//! only divergence is rounding non-linearity (`fl(a+b)` integrated vs
//! `fl(∫a) + fl(∫b)`). We bound the *relative Frobenius* deviation by
//! `2²⁴·ε ≈ 3.7e-9` for the exactly-planned classes (observed drift is
//! orders of magnitude below; the budget leaves headroom for
//! cancellation-heavy fields) and loosen to the per-strategy floors of
//! `tests/ftfi_property.rs` for the LDR paths (their coefficient-basis
//! pipelines amplify rounding, not linearity).
//!
//! No proptest offline: seeded sweeps, every assertion leading with
//! `REPRO seed=…` so `Pcg::seed(seed)` replays the exact case.

use ftfi::ftfi::brute::BruteForceIntegrator;
use ftfi::ftfi::cordial::{CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{random_rational_tree, random_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::tree::integrator_tree::PreparedPlans;
use ftfi::{FieldIntegrator, FtfiError, SharedPlans, StreamingIntegrator, TreeFieldIntegrator};
use std::sync::Arc;

/// The size ladder of `tests/ftfi_property.rs`: singleton, single edge,
/// one leaf, a few IT levels, above the batch-axis cutoff (odd).
const SIZES: [usize; 5] = [1, 2, 17, 64, 257];

/// Superposition budget for the exactly-planned classes: 2²⁴ ulps of
/// the output scale.
const ULP_BUDGET: f64 = (1 << 24) as f64 * f64::EPSILON;

/// Per-class `FDist` representatives (mirrors `ftfi_property.rs`).
fn f_cases(rng: &mut Pcg) -> Vec<FDist> {
    vec![
        FDist::Identity,
        FDist::Polynomial(vec![rng.normal(), rng.normal(), rng.normal() * 0.3]),
        FDist::Exponential { lambda: rng.uniform_in(-1.0, -0.1), scale: 1.0 },
        FDist::Trig {
            omega: rng.uniform_in(0.2, 1.5),
            phase: rng.uniform_in(0.0, 1.0),
            scale: 1.0,
        },
        FDist::inverse_quadratic(rng.uniform_in(0.1, 2.0)),
        FDist::ExpOverLinear { lambda: rng.uniform_in(-0.5, 0.0), c: rng.uniform_in(0.5, 2.0) },
        FDist::gaussian(rng.uniform_in(0.05, 0.5)),
        FDist::Custom(std::sync::Arc::new(|x: f64| (0.4 * x).sin() / (1.0 + 0.3 * x))),
    ]
}

/// Strategy-specific superposition budgets: the LDR coefficient
/// pipelines amplify per-op rounding (see `ftfi_property::strategy_tol`).
fn strategy_budget(s: Strategy) -> f64 {
    match s {
        Strategy::RationalSum | Strategy::Cauchy => 5e-6,
        Strategy::Chebyshev | Strategy::Vandermonde => 1e-8,
        _ => ULP_BUDGET,
    }
}

fn rel_err(got: &Matrix, want: &Matrix) -> f64 {
    got.frobenius_diff(want) / (1.0 + want.frobenius())
}

/// k distinct rows (partial Fisher–Yates) plus a dense delta field
/// supported on them — the shared `bench_util` staging helper.
fn random_delta(n: usize, d: usize, k: usize, rng: &mut Pcg) -> (Vec<u32>, Matrix) {
    ftfi::bench_util::sparse_delta(n, d, k, rng)
}

/// Superposition check on one prepared handle: `integrate(x + Δ)` vs
/// `integrate(x) + integrate_delta(Δ)` within `tol`, and `Δ` over all
/// rows bit-identical to a plain integration.
fn check_superposition(
    tfi: &TreeFieldIntegrator,
    plans: &PreparedPlans,
    n: usize,
    d: usize,
    tol: f64,
    rng: &mut Pcg,
    label: &str,
) {
    let x = Matrix::randn(n, d, rng);
    for &k in &[1usize.min(n), (n / 3).max(1), n] {
        let (rows, dx) = random_delta(n, d, k, rng);
        let mut x2 = x.clone();
        x2.axpy(1.0, &dx);
        let full = tfi.integrate_prepared(&x2, plans).unwrap();
        let mut approx = tfi.integrate_prepared(&x, plans).unwrap();
        let dout = tfi.integrate_delta_prepared(&rows, &dx, plans).unwrap();
        approx.axpy(1.0, &dout);
        let rel = rel_err(&approx, &full);
        assert!(rel < tol, "{label} k={k}: superposition rel {rel} > {tol}");
        if k == n {
            let want = tfi.integrate_prepared(&dx, plans).unwrap();
            assert!(
                dout == want,
                "{label}: full-rows delta must be bit-identical to integrate(Δ)"
            );
        }
    }
}

/// Property: superposition holds on every ladder size for every default
/// policy function class, for threads ∈ {1, 4}, and the full-rows delta
/// is bit-identical to a plain integration.
#[test]
fn property_superposition_default_policy_across_size_ladder() {
    for &n in &SIZES {
        for &threads in &[1usize, 4] {
            let seed = 400_000 + (n as u64) * 10 + threads as u64;
            let mut rng = Pcg::seed(seed);
            let d = 1 + rng.below(3);
            let tree = random_tree(n, 0.05, 1.0, &mut rng);
            let t = [2usize, 8, 48][rng.below(3)];
            for f in f_cases(&mut rng) {
                let tfi = TreeFieldIntegrator::builder(&tree)
                    .leaf_threshold(t)
                    .threads(threads)
                    .build()
                    .unwrap();
                let plans = tfi.prepare_plans(&f, d).unwrap();
                // Default-policy plans may route smooth classes through
                // Chebyshev/LDR blocks: use the loosest matching budget.
                let tol = 1e-8f64.max(ULP_BUDGET);
                let label = format!("REPRO seed={seed} n={n} d={d} t={t} thr={threads} {f:?}");
                check_superposition(&tfi, &plans, n, d, tol, &mut rng, &label);
            }
        }
    }
}

/// Property: superposition holds for every *applicable* forced strategy
/// on rational-weight trees (the ladder sweep of
/// `ftfi_property::property_every_applicable_forced_strategy_matches_brute`,
/// pointed at the delta path), for threads ∈ {1, 4}. Inapplicable
/// combos surface as the typed `StrategyInapplicable` and are skipped;
/// a floor pins the sweep cannot degenerate into skipping everything.
#[test]
fn property_superposition_every_applicable_forced_strategy() {
    let all = [
        Strategy::Dense,
        Strategy::Separable,
        Strategy::Lattice,
        Strategy::RationalSum,
        Strategy::Cauchy,
        Strategy::Vandermonde,
        Strategy::Chebyshev,
    ];
    let mut applicable = 0usize;
    for &n in &SIZES {
        for &threads in &[1usize, 4] {
            let seed = 500_000 + (n as u64) * 10 + threads as u64;
            let mut rng = Pcg::seed(seed);
            let tree = random_rational_tree(n, 3, 4, &mut rng);
            let d = 1 + rng.below(3);
            for f in f_cases(&mut rng) {
                for &s in &all {
                    let policy =
                        CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
                    let tfi = TreeFieldIntegrator::builder(&tree)
                        .leaf_threshold(8)
                        .policy(policy)
                        .threads(threads)
                        .build()
                        .unwrap();
                    match tfi.prepare_plans(&f, d) {
                        Err(FtfiError::StrategyInapplicable { .. }) => continue,
                        Err(e) => panic!(
                            "REPRO seed={seed} n={n} {f:?} forced {s:?}: unexpected {e}"
                        ),
                        Ok(plans) => {
                            applicable += 1;
                            let label = format!(
                                "REPRO seed={seed} n={n} d={d} threads={threads} {f:?} \
                                 forced {s:?}"
                            );
                            check_superposition(
                                &tfi,
                                &plans,
                                n,
                                d,
                                strategy_budget(s),
                                &mut rng,
                                &label,
                            );
                        }
                    }
                }
            }
        }
    }
    assert!(applicable >= 100, "only {applicable} (f, strategy) combos were applicable");
}

/// Threads must not change delta outputs: the sparse pass forks on the
/// same rule as the full pass, under the pool's bit-identity contract.
#[test]
fn delta_outputs_are_bit_identical_across_thread_counts() {
    let seed = 600_001u64;
    let mut rng = Pcg::seed(seed);
    // n above the fork cutoff so the recursion actually forks.
    let n = 1100;
    let tree = random_tree(n, 0.1, 1.0, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
    let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
    let plans_s = serial.prepare_plans(&f, 2).unwrap();
    let plans_p = par.prepare_plans(&f, 2).unwrap();
    for &k in &[1usize, 16, 256, n] {
        let (rows, dx) = random_delta(n, 2, k, &mut rng);
        let a = serial.integrate_delta_prepared(&rows, &dx, &plans_s).unwrap();
        let b = par.integrate_delta_prepared(&rows, &dx, &plans_p).unwrap();
        assert!(a == b, "REPRO seed={seed} k={k}: delta must be bit-identical across threads");
    }
}

/// Mutation sequences: random interleavings of `apply_update` / full
/// `refresh` on a [`StreamingIntegrator`] tracked against a
/// rebuild-from-scratch [`BruteForceIntegrator`] oracle, including the
/// degenerate updates (k = 0, k = n, repeated same-row, n = 1).
#[test]
fn property_mutation_sequences_track_the_brute_oracle() {
    for &n in &SIZES {
        for &threads in &[1usize, 4] {
            let seed = 700_000 + (n as u64) * 10 + threads as u64;
            let mut rng = Pcg::seed(seed);
            let d = 1 + rng.below(2);
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let f = FDist::Exponential { lambda: rng.uniform_in(-0.8, -0.2), scale: 1.0 };
            let builder = TreeFieldIntegrator::builder(&tree).leaf_threshold(8);
            let tfi = builder.threads(threads).build().unwrap();
            let plans = tfi.prepare_plans(&f, d).unwrap();
            let brute = BruteForceIntegrator::from_tree(tree.clone());
            let refresh_every = 1 + rng.below(6);
            let field = Matrix::randn(n, d, &mut rng);
            let shared = Arc::new(SharedPlans::new(tfi, plans));
            let mut session =
                StreamingIntegrator::new(Arc::clone(&shared), field, refresh_every).unwrap();
            for step in 0..15 {
                let op = rng.below(8);
                if op == 0 {
                    session.refresh().unwrap();
                } else {
                    // k = 0, 1, n and "repeated same row" all occur.
                    let k = [0usize, 1, 1 + rng.below(n), n][rng.below(4)].min(n);
                    let (mut rows, _) = random_delta(n, d, k, &mut rng);
                    if !rows.is_empty() && rng.below(3) == 0 {
                        let dup = rows[0];
                        rows.push(dup); // same row twice in one update
                    }
                    let vals = Matrix::randn(rows.len(), d, &mut rng);
                    session.apply_update(&rows, &vals).unwrap();
                }
                let want = brute.integrate(&f, session.field()).unwrap();
                let rel = rel_err(session.output(), &want);
                assert!(
                    rel < 1e-8,
                    "REPRO seed={seed} n={n} threads={threads} step={step}: \
                     session drifted to rel {rel}"
                );
            }
        }
    }
}

/// Drift-policy pin: the state right after the `refresh_every`-th
/// update is **bit-identical** to a cold prepared integration of the
/// current field, for threads ∈ {1, 4}.
#[test]
fn refresh_cadence_restores_bit_exact_state() {
    for &threads in &[1usize, 4] {
        let seed = 800_000 + threads as u64;
        let mut rng = Pcg::seed(seed);
        let n = 300;
        let r = 4;
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(threads).build().unwrap();
        let plans = tfi.prepare_plans(&f, 2).unwrap();
        let shared = Arc::new(SharedPlans::new(tfi, plans));
        let field = Matrix::randn(n, 2, &mut rng);
        let mut session = StreamingIntegrator::new(Arc::clone(&shared), field, r).unwrap();
        for round in 1..=3 {
            for _ in 0..r - 1 {
                let (rows, _) = random_delta(n, 2, 1 + rng.below(4), &mut rng);
                let vals = Matrix::randn(rows.len(), 2, &mut rng);
                session.apply_update(&rows, &vals).unwrap();
                assert_eq!(session.stats().delta_refreshes, round - 1);
            }
            let (rows, _) = random_delta(n, 2, 1, &mut rng);
            let vals = Matrix::randn(1, 2, &mut rng);
            session.apply_update(&rows, &vals).unwrap();
            let cold = shared
                .with(|tfi, plans| tfi.integrate_prepared(session.field(), plans))
                .unwrap()
                .unwrap();
            assert!(
                *session.output() == cold,
                "REPRO seed={seed} round={round}: post-refresh state must be bit-identical"
            );
            assert_eq!(session.stats().delta_refreshes, round);
        }
    }
}

/// Interleaved field deltas × edge re-plans: a session whose metric
/// AND field both mutate (every third step reweights a tree edge
/// through [`StreamingIntegrator::update_edge`], the rest apply sparse
/// row updates) tracks a rebuild-from-scratch [`BruteForceIntegrator`]
/// oracle on the *current* tree and field at every step, for
/// threads ∈ {1, 4}.
#[test]
fn property_interleaved_deltas_and_replans_track_the_brute_oracle() {
    // n = 1 has no edges to re-plan; the rest of the ladder applies.
    for &n in &[2usize, 17, 64, 257] {
        for &threads in &[1usize, 4] {
            let seed = 900_000 + (n as u64) * 10 + threads as u64;
            let mut rng = Pcg::seed(seed);
            let d = 1 + rng.below(2);
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let f = FDist::Exponential { lambda: rng.uniform_in(-0.8, -0.2), scale: 1.0 };
            let tfi = TreeFieldIntegrator::builder(&tree)
                .leaf_threshold(8)
                .threads(threads)
                .build()
                .unwrap();
            let plans = tfi.prepare_plans(&f, d).unwrap();
            let shared = Arc::new(SharedPlans::new(tfi, plans));
            let field = Matrix::randn(n, d, &mut rng);
            let mut session = StreamingIntegrator::new(Arc::clone(&shared), field, 4).unwrap();
            let mut cur = tree.clone();
            for step in 0..12 {
                if step % 3 == 2 {
                    let (eu, ev, old) = cur.edges()[rng.below(cur.edges().len())];
                    let (u, v) = (eu as usize, ev as usize);
                    let w = old * rng.uniform_in(1.1, 1.9);
                    let st = session.update_edge(u, v, w).unwrap();
                    assert!(st.changed, "REPRO seed={seed} step={step}: replan must commit");
                    cur.set_edge_weight(u, v, w).unwrap();
                } else {
                    let k = 1 + rng.below(n);
                    let (rows, _) = random_delta(n, d, k, &mut rng);
                    let vals = Matrix::randn(rows.len(), d, &mut rng);
                    session.apply_update(&rows, &vals).unwrap();
                }
                // Fresh oracle on the current tree: the metric itself
                // may have changed since the last step.
                let brute = BruteForceIntegrator::from_tree(cur.clone());
                let want = brute.integrate(&f, session.field()).unwrap();
                let rel = rel_err(session.output(), &want);
                assert!(
                    rel < 1e-8,
                    "REPRO seed={seed} n={n} threads={threads} step={step}: \
                     interleaved session drifted to rel {rel}"
                );
            }
        }
    }
}
