//! Counting-allocator pin for the zero-allocation prepared hot path.
//!
//! Lives in its own test binary because it installs a process-wide
//! `#[global_allocator]` (the shared `bench_util::CountingAlloc`). The
//! counter is **thread-local**, so the other tests in this binary (and
//! libtest's own threads) never pollute a measurement: everything a
//! warmed serial `integrate_into` does runs on the calling thread, and
//! that thread's counter must not move.
//!
//! The workspace design this pins (see `DESIGN.md` §Memory layout):
//! `prepare` sizes slab/arena/FFT/Chebyshev scratch once from the tree
//! shape and the built plans; `integrate_into` checks a workspace out of
//! the plan's pool, permutes the field once into the nested-dissection
//! layout, recurses on slices, and un-permutes once. After one warming
//! call per channel width there is nothing left to allocate.

use ftfi::bench_util::{thread_allocs as allocs, CountingAlloc};
use ftfi::ftfi::cordial::{CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::generators::{random_rational_tree, random_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::tree::Tree;
use ftfi::TreeFieldIntegrator;

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Build a `threads(1)` integrator (the whole call runs on this thread,
/// so the thread-local count sees all of it), warm the workspace pool,
/// then pin: `integrate_into` allocates nothing, `integrate` allocates
/// exactly the returned matrix.
fn assert_zero_alloc(name: &str, tree: &Tree, f: &FDist, policy: CrossPolicy, d: usize) {
    let tfi = TreeFieldIntegrator::builder(tree)
        .threads(1)
        .policy(policy)
        .build()
        .expect("valid tree");
    let prepared = tfi.prepare_with_channels(f, d).expect("plannable f");
    let mut rng = Pcg::seed(99);
    let x = Matrix::randn(tree.n(), d, &mut rng);
    let mut out = Matrix::zeros(tree.n(), d);
    // Warm: the first call builds the arenas, the second proves reuse.
    prepared.integrate_into(&x, &mut out).expect("integrate");
    prepared.integrate_into(&x, &mut out).expect("integrate");

    let before = allocs();
    prepared.integrate_into(&x, &mut out).expect("integrate");
    let during = allocs() - before;
    assert_eq!(during, 0, "{name}: warmed integrate_into performed {during} heap allocations");

    let before = allocs();
    let y = prepared.integrate(&x).expect("integrate");
    let during = allocs() - before;
    assert!(
        during <= 1,
        "{name}: warmed integrate performed {during} heap allocations (expected ≤ 1: \
         the returned matrix)"
    );
    assert!(y == out, "{name}: integrate and integrate_into must agree bitwise");
}

/// Default-policy smooth kernel: the large cross blocks plan through
/// Chebyshev, the small ones densely — the serving workload shape of
/// the `hotpath_alloc` ablation.
#[test]
fn chebyshev_hot_path_is_allocation_free_when_warmed() {
    let mut rng = Pcg::seed(1);
    let tree = random_tree(1200, 0.1, 1.0, &mut rng);
    assert_zero_alloc(
        "chebyshev",
        &tree,
        &FDist::inverse_quadratic(0.5),
        CrossPolicy::default(),
        2,
    );
}

/// Forced-lattice on a rational-weight tree: every internal node runs
/// the FFT multiplier, exercising the cached twiddle tables, the cached
/// lattice index maps and the complex scratch arena.
#[test]
fn lattice_hot_path_is_allocation_free_when_warmed() {
    let mut rng = Pcg::seed(2);
    let tree = random_rational_tree(900, 3, 4, &mut rng);
    let f = FDist::Custom(std::sync::Arc::new(|t: f64| (0.4 * t).sin() / (1.0 + 0.3 * t)));
    let policy =
        CrossPolicy { force: Some(Strategy::Lattice), dense_cutoff: 0, ..Default::default() };
    assert_zero_alloc("lattice", &tree, &f, policy, 3);
}

/// Forced-RationalSum on a rational kernel: every internal node runs
/// the prepared basis-polynomial rational multiplier
/// (`RationalPlan::apply_into` — shift products and denominator-inverse
/// tables frozen at plan time, coefficient accumulation in the
/// `CrossScratch::rat_w` arena). PR 4 left this path on an allocating
/// `Matrix` shim; it is now a first-class zero-allocation citizen.
#[test]
fn rational_hot_path_is_allocation_free_when_warmed() {
    let mut rng = Pcg::seed(5);
    let tree = random_tree(700, 0.1, 1.0, &mut rng);
    let f = FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.5] };
    let policy =
        CrossPolicy { force: Some(Strategy::RationalSum), dense_cutoff: 0, ..Default::default() };
    assert_zero_alloc("rational", &tree, &f, policy, 2);
}

/// Forced-Cauchy (`e^{λx}/(x+c)`): the same prepared rational core with
/// its exponential row/column scale tables.
#[test]
fn cauchy_hot_path_is_allocation_free_when_warmed() {
    let mut rng = Pcg::seed(6);
    let tree = random_tree(600, 0.1, 1.0, &mut rng);
    let f = FDist::ExpOverLinear { lambda: -0.2, c: 1.0 };
    let policy =
        CrossPolicy { force: Some(Strategy::Cauchy), dense_cutoff: 0, ..Default::default() };
    assert_zero_alloc("cauchy", &tree, &f, policy, 2);
}

/// The streaming delta path: a warmed k = 1 update must not allocate —
/// neither the raw `integrate_delta_prepared_into` (slab fill, dirty
/// prefix, sparse recursion all live in the plan's workspace pool) nor
/// the full `StreamingIntegrator::apply_update` session surface
/// (delta staging, cached-output accumulation).
#[test]
fn delta_update_hot_path_is_allocation_free_when_warmed() {
    use ftfi::{SharedPlans, StreamingIntegrator};
    use std::sync::Arc;
    let mut rng = Pcg::seed(7);
    let tree = random_tree(900, 0.1, 1.0, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
    let plans = tfi.prepare_plans(&f, 2).expect("plannable f");
    let x = Matrix::randn(900, 2, &mut rng);
    let mut dout = Matrix::zeros(900, 2);
    let mut dx = Matrix::zeros(900, 2);
    dx.set(123, 0, 1.5);
    dx.set(123, 1, -0.5);
    let rows = [123u32];
    // Raw core path: warm twice (arena build, then reuse), then pin.
    tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout).expect("delta");
    tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout).expect("delta");
    let before = allocs();
    tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout).expect("delta");
    let during = allocs() - before;
    assert_eq!(during, 0, "warmed k=1 delta performed {during} heap allocations");

    // Session surface: refresh_every = 0 keeps every update on the
    // delta path; two warmed updates grow the dirty-list capacity.
    let shared = Arc::new(SharedPlans::new(tfi, plans));
    let mut session =
        StreamingIntegrator::new(Arc::clone(&shared), x, 0).expect("valid session");
    let vals = Matrix::from_vec(1, 2, vec![0.25, -1.0]);
    session.apply_update(&rows, &vals).expect("update");
    session.apply_update(&rows, &vals).expect("update");
    let before = allocs();
    session.apply_update(&rows, &vals).expect("update");
    let during = allocs() - before;
    assert_eq!(during, 0, "warmed apply_update performed {during} heap allocations");
}

/// The post-replan hot path: an edge re-plan rebuilds O(log n) plans
/// (allocating — that is the defined cold path), but the *serving*
/// calls after it must return to the zero-allocation steady state. One
/// warming call after the replan re-ensures the (monotone) workspace
/// sizing; from the second call on, nothing allocates.
#[test]
fn prepared_integrate_after_a_replan_is_allocation_free_when_warmed() {
    let mut rng = Pcg::seed(8);
    let tree = random_tree(1000, 0.1, 1.0, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    let mut tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
    let mut plans = tfi.prepare_plans(&f, 2).expect("plannable f");
    let x = Matrix::randn(1000, 2, &mut rng);
    let mut out = Matrix::zeros(1000, 2);
    // Warm the pre-replan steady state.
    tfi.integrate_prepared_into(&x, &plans, &mut out).expect("integrate");
    tfi.integrate_prepared_into(&x, &plans, &mut out).expect("integrate");

    let (eu, ev, old) = tree.edges()[11];
    let st = tfi.replan_edge_prepared(eu as usize, ev as usize, old * 1.7, &mut plans)
        .expect("replan");
    assert!(st.changed, "the replan must commit for this pin to mean anything");

    // Re-warm once: a grown distinct-distance table may ratchet the
    // workspace sizing, and the first post-replan call pays it.
    tfi.integrate_prepared_into(&x, &plans, &mut out).expect("integrate");
    tfi.integrate_prepared_into(&x, &plans, &mut out).expect("integrate");
    let before = allocs();
    tfi.integrate_prepared_into(&x, &plans, &mut out).expect("integrate");
    let during = allocs() - before;
    assert_eq!(
        during, 0,
        "warmed post-replan integrate_prepared_into performed {during} heap allocations"
    );

    // And the delta fast path too: replans must not knock the sparse
    // pass off its zero-alloc contract either.
    let mut dout = Matrix::zeros(1000, 2);
    let mut dx = Matrix::zeros(1000, 2);
    dx.set(77, 0, 0.5);
    let rows = [77u32];
    tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout).expect("delta");
    tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout).expect("delta");
    let before = allocs();
    tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout).expect("delta");
    let during = allocs() - before;
    assert_eq!(
        during, 0,
        "warmed post-replan k=1 delta performed {during} heap allocations"
    );
}

/// The multi-graph migration hot path: a session migrating onto a
/// graph whose plans were prewarmed the way the serving plan cache
/// prewarms them — workspace and fork-scratch pools stocked at the
/// *cache-wide* size maxima (`WorkspaceSizes::max_with` fold) — must
/// re-warm nothing. Both the migration itself (a full integrate on the
/// target plus the base swap) and the first delta update after it are
/// pinned at zero allocations, on the session's *first* ever touch of
/// the target graph.
#[test]
fn migration_onto_a_prewarmed_cached_graph_is_allocation_free() {
    use ftfi::{SharedPlans, StreamingIntegrator};
    use std::sync::Arc;
    let n = 900;
    let mut rng = Pcg::seed(10);
    let tree_a = random_tree(n, 0.1, 1.0, &mut rng);
    let tree_b = random_tree(n, 0.15, 1.2, &mut rng);
    let f = FDist::inverse_quadratic(0.5);
    let tfi_a = TreeFieldIntegrator::builder(&tree_a).threads(1).build().expect("valid tree");
    let tfi_b = TreeFieldIntegrator::builder(&tree_b).threads(1).build().expect("valid tree");
    let plans_a = tfi_a.prepare_plans(&f, 2).expect("plannable f");
    let plans_b = tfi_b.prepare_plans(&f, 2).expect("plannable f");
    // What `PlanCache::insert` does for both entries: fold the
    // cache-wide maxima and stock each pool at them.
    let maxima = plans_a.sizes().max_with(&plans_b.sizes());
    plans_a.prewarm(1, &maxima, 2);
    plans_b.prewarm(1, &maxima, 2);
    let a = Arc::new(SharedPlans::new(tfi_a, plans_a));
    let b = Arc::new(SharedPlans::new(tfi_b, plans_b));

    let x = Matrix::randn(n, 2, &mut rng);
    let mut session = StreamingIntegrator::new(Arc::clone(&a), x, 0).expect("valid session");
    // Warm the session surface on A only: two updates grow the
    // dirty-list capacity; graph B stays untouched by this session.
    let rows = [17u32];
    let vals = Matrix::from_vec(1, 2, vec![0.25, -1.0]);
    session.apply_update(&rows, &vals).expect("update");
    session.apply_update(&rows, &vals).expect("update");

    let before = allocs();
    session.migrate(Arc::clone(&b)).expect("migrate");
    let during = allocs() - before;
    assert_eq!(
        during, 0,
        "first migration onto a prewarmed cached graph performed {during} heap allocations"
    );

    let before = allocs();
    session.apply_update(&rows, &vals).expect("update");
    let during = allocs() - before;
    assert_eq!(
        during, 0,
        "first post-migration update performed {during} heap allocations"
    );
}

/// Forced-separable exponential kernel: the rank-1 outer-product path
/// with its arena accumulator.
#[test]
fn separable_hot_path_is_allocation_free_when_warmed() {
    let mut rng = Pcg::seed(3);
    let tree = random_tree(800, 0.1, 1.0, &mut rng);
    let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
    let policy =
        CrossPolicy { force: Some(Strategy::Separable), dense_cutoff: 0, ..Default::default() };
    assert_zero_alloc("separable", &tree, &f, policy, 1);
}

/// Arena sizing is surfaced so regressions in workspace accounting are
/// visible: the structural part through `ItStats::workspace_bytes`, the
/// full figure (monotone in the channel width) through the prepared
/// handle.
#[test]
fn workspace_sizing_is_surfaced_and_monotone() {
    let mut rng = Pcg::seed(4);
    let tree = random_tree(600, 0.1, 1.0, &mut rng);
    let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
    let st = tfi.stats();
    assert!(
        st.workspace_bytes >= 2 * 600 * std::mem::size_of::<f64>(),
        "slabs must cover at least 2n single-channel rows, got {}",
        st.workspace_bytes
    );
    let prepared = tfi.prepare_with_channels(&FDist::inverse_quadratic(0.5), 1).unwrap();
    assert!(prepared.workspace_bytes(1) >= st.workspace_bytes);
    assert!(prepared.workspace_bytes(4) > prepared.workspace_bytes(1));
    assert!(prepared.workspace_bytes(8) > prepared.workspace_bytes(4));
}
