//! Cross-module integration tests: the application pipelines composed end
//! to end (no PJRT required — those live in runtime_integration.rs).

use ftfi::ftfi::functions::FDist;
use ftfi::graph::mesh::{sphere_mesh, Mesh};
use ftfi::graph::mst::minimum_spanning_tree;
use ftfi::graph::point_cloud::{epsilon_graph, sample_cloud};
use ftfi::graph::tu_dataset::{generate, TuSpec};
use ftfi::linalg::eigen::lanczos_smallest;
use ftfi::linalg::matrix::{cosine_similarity, Matrix};
use ftfi::ml::dataset::{fold_split, stratified_kfold};
use ftfi::ml::fit_rational::{fit, relative_frobenius_error, sample_pairs, RationalModel};
use ftfi::ml::metrics::accuracy;
use ftfi::ml::random_forest::{ForestParams, RandomForest};
use ftfi::ml::rng::Pcg;
use ftfi::ot::gw::{gromov_wasserstein, GwBackend, GwParams};
use ftfi::ot::sinkhorn::{sinkhorn, uniform_marginal, DenseKernel, FtfiKernel};
use ftfi::{GraphFieldIntegrator, TreeFieldIntegrator};

/// Mesh → graph → MST → FTFI interpolation recovers normals decently on a
/// smooth surface and beats the zero-prediction baseline massively.
#[test]
fn mesh_interpolation_pipeline() {
    let mut rng = Pcg::seed(1);
    let mesh = sphere_mesh(18, 24, 0.1, &mut rng);
    let n = mesh.n_vertices();
    let g = mesh.to_graph();
    let tree = minimum_spanning_tree(&g);
    let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();

    let mut masked = vec![true; n];
    for i in rng.sample_distinct(n, n / 5) {
        masked[i] = false;
    }
    let mut field = Matrix::zeros(n, 3);
    for i in 0..n {
        if !masked[i] {
            field.row_mut(i).copy_from_slice(&mesh.normals[i]);
        }
    }
    let pred = tfi
        .prepare(&FDist::inverse_quadratic(8.0))
        .unwrap()
        .integrate(&field)
        .unwrap();
    let mut total = 0.0;
    let mut count = 0;
    for i in 0..n {
        if masked[i] {
            total += cosine_similarity(pred.row(i), &mesh.normals[i]);
            count += 1;
        }
    }
    let cos = total / count as f64;
    assert!(cos > 0.6, "cosine {cos}");
}

/// TU dataset → SP-kernel eigenfeatures → random forest beats chance.
#[test]
fn graph_classification_pipeline() {
    let spec = TuSpec { name: "ITEST", n_graphs: 60, avg_nodes: 30, n_classes: 2 };
    let ds = generate(&spec, 3);
    let mut rng = Pcg::seed(5);
    let feats: Vec<Vec<f64>> = ds
        .graphs
        .iter()
        .map(|g| {
            let gfi = GraphFieldIntegrator::try_new(g).unwrap();
            let prepared = gfi.prepare(&FDist::Identity).unwrap();
            lanczos_smallest(
                g.n(),
                6.min(g.n()),
                |v| prepared.integrate_vec(v).unwrap(),
                &mut rng,
            )
            .into_iter()
            .chain(std::iter::repeat(0.0))
            .take(6)
            .collect()
        })
        .collect();
    let folds = stratified_kfold(&ds.labels, 4, &mut rng);
    let mut accs = Vec::new();
    for f in 0..4 {
        let (tr, te) = fold_split(&folds, f);
        let xtr: Vec<Vec<f64>> = tr.iter().map(|&i| feats[i].clone()).collect();
        let ytr: Vec<usize> = tr.iter().map(|&i| ds.labels[i]).collect();
        let rf = RandomForest::fit(&xtr, &ytr, &ForestParams::default(), &mut rng);
        let pred: Vec<usize> = te.iter().map(|&i| rf.predict(&feats[i])).collect();
        let truth: Vec<usize> = te.iter().map(|&i| ds.labels[i]).collect();
        accs.push(accuracy(&pred, &truth));
    }
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    assert!(mean > 0.6, "accuracy {mean} not better than chance");
}

/// Learnable-f training improves the metric approximation, and the
/// trained f runs through the fast integrator.
#[test]
fn learnable_f_pipeline() {
    let mut rng = Pcg::seed(7);
    let g = ftfi::graph::generators::path_plus_random_edges(150, 110, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let data = sample_pairs(&g, &tree, 80, &mut rng);
    let mut model = RationalModel::new(2, 2);
    let before = relative_frobenius_error(&g, &tree, &model.to_fdist());
    fit(&mut model, &data, 250, 0.02);
    let after = relative_frobenius_error(&g, &tree, &model.to_fdist());
    assert!(after < before * 0.9, "no improvement: {before} -> {after}");
    // Trained f through FTFI matches brute.
    let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
    let x = Matrix::randn(150, 1, &mut rng);
    let fast = tfi.try_integrate(&model.to_fdist(), &x).unwrap();
    let slow = ftfi::ftfi::brute::btfi(&tree, &model.to_fdist(), &x);
    assert!(fast.frobenius_diff(&slow) / (1.0 + slow.frobenius()) < 1e-6);
}

/// Sinkhorn with the FTFI kernel converges and matches the dense kernel.
#[test]
fn sinkhorn_pipeline() {
    let mut rng = Pcg::seed(9);
    let tree = ftfi::graph::generators::random_tree(80, 0.2, 1.0, &mut rng);
    let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
    let a = uniform_marginal(80);
    let mut b = rng.uniform_vec(80, 0.2, 1.8);
    let s: f64 = b.iter().sum();
    b.iter_mut().for_each(|x| *x /= s);
    let fast = sinkhorn(&FtfiKernel::new(&tfi, 0.6).unwrap(), &a, &b, 1e-9, 400).unwrap();
    let dense = sinkhorn(&DenseKernel::new(&tree, 0.6), &a, &b, 1e-9, 400).unwrap();
    assert!(fast.marginal_error < 1e-8);
    assert!((fast.cost - dense.cost).abs() < 1e-6 * (1.0 + dense.cost));
}

/// Point-cloud ε-graph pipeline stays connected and classifiable shapes
/// produce different GW discrepancies than same shapes.
#[test]
fn point_cloud_gw_pipeline() {
    let mut rng = Pcg::seed(11);
    let c_sphere = sample_cloud(0, 40, 0.01, &mut rng);
    let c_cross = sample_cloud(7, 40, 0.01, &mut rng);
    let t_sphere = minimum_spanning_tree(&epsilon_graph(&c_sphere, 0.5));
    let t_cross = minimum_spanning_tree(&epsilon_graph(&c_cross, 0.5));
    let p = uniform_marginal(40);
    let params = GwParams { max_iter: 20, ..Default::default() };
    let self_d = gromov_wasserstein(&t_sphere, &t_sphere, &p, &p, GwBackend::Ftfi, &params)
        .unwrap()
        .discrepancy;
    let cross_d = gromov_wasserstein(&t_sphere, &t_cross, &p, &p, GwBackend::Ftfi, &params)
        .unwrap()
        .discrepancy;
    assert!(
        cross_d > self_d,
        "GW failed to separate shapes: self {self_d} vs cross {cross_d}"
    );
}

/// Config + OFF round trip through the filesystem.
#[test]
fn config_and_mesh_io() {
    let dir = std::env::temp_dir().join(format!("ftfi-pipe-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg::seed(13);
    let mesh = sphere_mesh(6, 8, 0.0, &mut rng);
    let off = dir.join("m.off");
    std::fs::write(&off, mesh.to_off()).unwrap();
    let back = Mesh::from_off(&std::fs::read_to_string(&off).unwrap()).unwrap();
    assert_eq!(back.n_vertices(), mesh.n_vertices());

    let cfg_path = dir.join("server.cfg");
    std::fs::write(&cfg_path, "[server]\nbatch_size = 4\n").unwrap();
    let cfg = ftfi::config::Config::load(cfg_path.to_str().unwrap()).unwrap();
    assert_eq!(ftfi::config::ServerConfig::from_config(&cfg).batch_size, 4);
    std::fs::remove_dir_all(&dir).ok();
}
