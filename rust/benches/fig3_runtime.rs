//! Fig. 3: runtime of FTFI vs BTFI as a function of vertex count, on
//! (left) the synthetic path+random-edges graphs and (right) procedural
//! meshes (the Thingi10K substitute). Reports preprocessing and
//! integration separately, plus the end-to-end speedup — the paper's
//! headline claim is 5.7×+ (synthetic ≥10K) and up to 13× (20K meshes).
//!
//! Run: `cargo bench --bench fig3_runtime`

use ftfi::bench_util::{banner, time_once, Table};
use ftfi::ftfi::brute::btfi_streaming;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::mesh::mesh_zoo;
use ftfi::graph::mst::minimum_spanning_tree;
use ftfi::graph::{generators, Graph};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::TreeFieldIntegrator;

fn run_point(name: &str, g: &Graph, f: &FDist, table: &Table) {
    let n = g.n();
    let mut rng = Pcg::seed(n as u64);
    let tree = minimum_spanning_tree(g);
    let x = Matrix::randn(n, 1, &mut rng);

    let (tfi, t_pre) =
        time_once(|| TreeFieldIntegrator::builder(&tree).build().expect("valid tree"));
    let (fast, t_int) = time_once(|| tfi.try_integrate(f, &x).expect("well-shaped field"));
    let (slow, t_brute) = time_once(|| btfi_streaming(&tree, f, &x));
    let rel = fast.frobenius_diff(&slow) / (1.0 + slow.frobenius());
    let speedup = t_brute / (t_pre + t_int);
    table.row(&[
        name.to_string(),
        n.to_string(),
        format!("{:.3}", t_pre),
        format!("{:.3}", t_int),
        format!("{:.3}", t_brute),
        format!("{:.1}x", speedup),
        format!("{rel:.1e}"),
    ]);
}

fn main() {
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };

    banner("Fig 3 (left): synthetic path + random edges, f(x)=e^{-x/2}");
    let table = Table::new(
        &["graph", "N", "FTFI pre (s)", "FTFI int (s)", "BTFI (s)", "speedup", "rel err"],
        &[10, 7, 12, 12, 10, 8, 9],
    );
    for &n in &[1000usize, 2000, 5000, 10_000, 20_000] {
        let mut rng = Pcg::seed(1);
        let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
        run_point("synth", &g, &f, &table);
    }

    banner("Fig 3 (right): procedural meshes (Thingi10K substitute)");
    let table = Table::new(
        &["mesh", "N", "FTFI pre (s)", "FTFI int (s)", "BTFI (s)", "speedup", "rel err"],
        &[10, 7, 12, 12, 10, 8, 9],
    );
    for &target in &[1000usize, 4000, 10_000, 20_000] {
        for (name, mesh) in mesh_zoo(target, 7) {
            if name == "torus" {
                continue; // one closed + one open surface suffice per size
            }
            run_point(&name, &mesh.to_graph(), &f, &table);
        }
    }
}
