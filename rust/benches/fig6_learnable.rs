//! Fig. 6 / Fig. 8: learnable f-distance matrices — relative Frobenius
//! error vs training iterations for different graph sizes (left), and
//! rational degrees (middle: synthetic graph, right: mesh graph).
//!
//! Run: `cargo bench --bench fig6_learnable`

use ftfi::bench_util::banner;
use ftfi::graph::mesh::mesh_zoo;
use ftfi::graph::mst::minimum_spanning_tree;
use ftfi::graph::{generators, Graph};
use ftfi::ml::fit_rational::{fit, relative_frobenius_error, sample_pairs, RationalModel};
use ftfi::ml::rng::Pcg;

/// Error trace at checkpoints during training.
fn error_curve(g: &Graph, num_deg: usize, den_deg: usize, iters: &[usize]) -> Vec<f64> {
    let tree = minimum_spanning_tree(g);
    let mut rng = Pcg::seed(9);
    let data = sample_pairs(g, &tree, 100, &mut rng);
    let mut out = Vec::new();
    let mut model = RationalModel::new(num_deg, den_deg);
    let mut done = 0;
    for &it in iters {
        fit(&mut model, &data, it - done, 0.02);
        done = it;
        out.push(relative_frobenius_error(g, &tree, &model.to_fdist()));
    }
    out
}

fn main() {
    let checkpoints = [0usize, 25, 50, 100, 200, 400];

    banner("Fig 6 (left): rel. Frobenius error vs iterations, quadratic f, sizes n");
    print!("{:>6}", "n");
    for c in &checkpoints {
        print!("{c:>9}");
    }
    println!();
    for &n in &[200usize, 400, 800] {
        let mut rng = Pcg::seed(1);
        let g = generators::path_plus_random_edges(n, 3 * n / 4, &mut rng);
        let curve = error_curve(&g, 2, 2, &checkpoints);
        print!("{n:>6}");
        for e in curve {
            print!("{e:>9.4}");
        }
        println!();
    }

    banner("Fig 6 (middle): degrees sweep on path(800)+600 random edges");
    print!("{:>12}", "num:den");
    for c in &checkpoints {
        print!("{c:>9}");
    }
    println!();
    let mut rng = Pcg::seed(2);
    let g = generators::path_plus_random_edges(800, 600, &mut rng);
    for &(nd, dd) in &[(1usize, 1usize), (2, 2), (3, 3), (2, 0)] {
        let curve = error_curve(&g, nd, dd, &checkpoints);
        print!("{:>12}", format!("{nd}:{dd}"));
        for e in curve {
            print!("{e:>9.4}");
        }
        println!();
    }

    banner("Fig 6 (right) / Fig 8: degrees sweep on mesh graphs");
    for (name, mesh) in mesh_zoo(700, 11) {
        let g = mesh.to_graph();
        print!("{:>12}", name);
        for c in &checkpoints {
            print!("{c:>9}");
        }
        println!();
        for &(nd, dd) in &[(1usize, 1usize), (2, 2), (3, 3)] {
            let curve = error_curve(&g, nd, dd, &checkpoints);
            print!("{:>12}", format!("{nd}:{dd}"));
            for e in curve {
                print!("{e:>9.4}");
            }
            println!();
        }
    }
}
