//! Fig. 5 + Table 4: graph classification accuracy vs feature-processing
//! time, FTFI vs BGFI, over the synthetic TU-style datasets (sizes per
//! Table 2). 5-fold stratified CV with a random forest over the k
//! smallest kernel eigenvalues (de Lara & Pineau 2018).
//!
//! Run: `cargo bench --bench fig5_classification`

use ftfi::bench_util::{banner, time_once, Table};
use ftfi::ftfi::brute::f_distance_matrix_graph;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::tu_dataset::{generate, standard_specs};
use ftfi::graph::Graph;
use ftfi::linalg::eigen::lanczos_smallest;
use ftfi::ml::dataset::{fold_split, stratified_kfold};
use ftfi::ml::metrics::{accuracy, mean_std};
use ftfi::ml::random_forest::{ForestParams, RandomForest};
use ftfi::ml::rng::Pcg;
use ftfi::GraphFieldIntegrator;

const K_EIG: usize = 6;

fn features(g: &Graph, use_ftfi: bool, rng: &mut Pcg) -> Vec<f64> {
    let f = FDist::Identity;
    let eig = if use_ftfi {
        let gfi = GraphFieldIntegrator::try_new(g).expect("connected graph");
        let prepared = gfi.prepare(&f).expect("plannable kernel");
        lanczos_smallest(
            g.n(),
            K_EIG.min(g.n()),
            |v| prepared.integrate_vec(v).expect("field length matches graph"),
            rng,
        )
    } else {
        let m = f_distance_matrix_graph(g, &f);
        lanczos_smallest(g.n(), K_EIG.min(g.n()), |v| m.matvec(v), rng)
    };
    eig.into_iter().chain(std::iter::repeat(0.0)).take(K_EIG).collect()
}

fn main() {
    banner("Fig 5 / Table 4: accuracy vs feature-processing time (FTFI vs BGFI)");
    let table = Table::new(
        &["dataset", "graphs", "FTFI acc", "±", "BGFI acc", "±", "FTFI fp(s)", "BGFI fp(s)", "Δfp"],
        &[14, 7, 9, 6, 9, 6, 10, 10, 7],
    );
    for spec in standard_specs() {
        let ds = generate(&spec, 1);
        let mut row: Vec<String> = vec![ds.name.clone(), ds.graphs.len().to_string()];
        let mut fp = [0.0f64; 2];
        for (slot, use_ftfi) in [(0usize, true), (1usize, false)] {
            let mut rng = Pcg::seed(17);
            let (feats, fp_time) = time_once(|| {
                ds.graphs.iter().map(|g| features(g, use_ftfi, &mut rng)).collect::<Vec<_>>()
            });
            fp[slot] = fp_time;
            // 5-fold CV, 3 repeats.
            let mut accs = Vec::new();
            for rep in 0..3u64 {
                let mut r = Pcg::seed(100 + rep);
                let folds = stratified_kfold(&ds.labels, 5, &mut r);
                for f in 0..folds.len() {
                    let (tr, te) = fold_split(&folds, f);
                    let xtr: Vec<Vec<f64>> = tr.iter().map(|&i| feats[i].clone()).collect();
                    let ytr: Vec<usize> = tr.iter().map(|&i| ds.labels[i]).collect();
                    let rf = RandomForest::fit(&xtr, &ytr, &ForestParams::default(), &mut r);
                    let pred: Vec<usize> = te.iter().map(|&i| rf.predict(&feats[i])).collect();
                    let truth: Vec<usize> = te.iter().map(|&i| ds.labels[i]).collect();
                    accs.push(accuracy(&pred, &truth));
                }
            }
            let (m, s) = mean_std(&accs);
            row.push(format!("{m:.3}"));
            row.push(format!("{s:.3}"));
        }
        let dfp = (fp[1] - fp[0]) / fp[1].max(1e-9) * 100.0;
        row.push(format!("{:.2}", fp[0]));
        row.push(format!("{:.2}", fp[1]));
        row.push(format!("{dfp:+.0}%"));
        table.row(&row);
    }
    println!(
        "\n(Paper's Fig 5/Table 3: FTFI reduces fp time up to 90% on the large datasets\n\
         while matching BGFI accuracy within noise; small datasets can be slightly\n\
         negative — same shape as the paper's MUTAG/PTC rows.)"
    );
}
