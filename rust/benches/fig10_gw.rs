//! Fig. 10: field-integration time inside the Gromov–Wasserstein
//! conditional-gradient solver — dense (POT-style) vs FTFI backends, on
//! random trees of growing size, averaged over seeds. The paper claims
//! FTFI-GW runs 2–6× faster with no accuracy drop.
//!
//! Run: `cargo bench --bench fig10_gw`

use ftfi::bench_util::{banner, Table};
use ftfi::graph::generators;
use ftfi::ml::rng::Pcg;
use ftfi::ot::gw::{gromov_wasserstein, GwBackend, GwParams};
use ftfi::ot::sinkhorn::uniform_marginal;

fn main() {
    banner("Fig 10: GW field-integration time, dense vs FTFI");
    let table = Table::new(
        &["n", "seeds", "int dense (s)", "int ftfi (s)", "speedup", "|ΔGW|/GW"],
        &[6, 6, 13, 13, 8, 10],
    );
    let params = GwParams { max_iter: 12, ..Default::default() };
    for &n in &[100usize, 200, 400, 800] {
        let seeds = if n >= 400 { 2u64 } else { 4 };
        let (mut td, mut tf, mut dgap) = (0.0, 0.0, 0.0f64);
        for seed in 0..seeds {
            let mut rng = Pcg::seed(seed);
            let ta = generators::random_tree(n, 0.1, 1.0, &mut rng);
            let tb = generators::random_tree(n, 0.1, 1.0, &mut rng);
            let p = uniform_marginal(n);
            let rd = gromov_wasserstein(&ta, &tb, &p, &p, GwBackend::Dense, &params)
                .expect("bench inputs are well-formed");
            let rf = gromov_wasserstein(&ta, &tb, &p, &p, GwBackend::Ftfi, &params)
                .expect("bench inputs are well-formed");
            td += rd.integration_seconds;
            tf += rf.integration_seconds;
            dgap = dgap
                .max((rd.discrepancy - rf.discrepancy).abs() / (1.0 + rd.discrepancy));
        }
        table.row(&[
            n.to_string(),
            seeds.to_string(),
            format!("{:.3}", td / seeds as f64),
            format!("{:.3}", tf / seeds as f64),
            format!("{:.1}x", td / tf.max(1e-9)),
            format!("{dgap:.1e}"),
        ]);
    }
}
