//! Table 3: feature-processing time of FTFI vs the exact shortest-path
//! kernel (BGFI) across the TU-style datasets — the paper reports up to
//! 90% reduction on the large (REDDIT-scale) datasets and small
//! regressions on the tiny ones.
//!
//! Run: `cargo bench --bench table3_feature_time`

use ftfi::bench_util::{banner, time_once, Table};
use ftfi::ftfi::brute::f_distance_matrix_graph;
use ftfi::ftfi::functions::FDist;
use ftfi::graph::tu_dataset::{generate, standard_specs, TuSpec};
use ftfi::linalg::eigen::{jacobi_eigenvalues, lanczos_smallest};
use ftfi::ml::rng::Pcg;
use ftfi::GraphFieldIntegrator;

const K_EIG: usize = 6;

fn main() {
    banner("Table 3: feature-processing time (seconds)");
    println!(
        "exact pipeline = materialise M_f^G + full eigendecomposition (de Lara &\n         Pineau 2018); FTFI pipeline = MST integrator + Lanczos on the operator.\n"
    );
    let table = Table::new(
        &["dataset", "graphs", "avg n", "BGFI (s)", "FTFI (s)", "improvement"],
        &[16, 7, 7, 9, 9, 12],
    );
    // Standard scaled specs + the paper-sized REDDIT rows (Table 2 lists
    // avg 430/509 nodes — the regime where the paper reports 88–90%).
    let mut specs = standard_specs();
    specs.retain(|s| !s.name.starts_with("REDDIT"));
    specs.push(TuSpec { name: "REDDIT-BINARY", n_graphs: 16, avg_nodes: 430, n_classes: 2 });
    specs.push(TuSpec { name: "REDDIT-MULTI-5K", n_graphs: 12, avg_nodes: 509, n_classes: 5 });
    for spec in specs {
        let ds = generate(&spec, 1);
        let avg_n =
            ds.graphs.iter().map(|g| g.n()).sum::<usize>() / ds.graphs.len().max(1);
        let f = FDist::Identity;

        let (_, t_bgfi) = time_once(|| {
            ds.graphs
                .iter()
                .map(|g| {
                    let m = f_distance_matrix_graph(g, &f);
                    let mut eig = jacobi_eigenvalues(&m, 30);
                    eig.truncate(K_EIG);
                    eig
                })
                .collect::<Vec<_>>()
        });
        let mut rng = Pcg::seed(3);
        let (_, t_ftfi) = time_once(|| {
            ds.graphs
                .iter()
                .map(|g| {
                    let gfi = GraphFieldIntegrator::try_new(g).expect("connected graph");
                    let prepared = gfi.prepare(&f).expect("plannable kernel");
                    lanczos_smallest(
                        g.n(),
                        K_EIG.min(g.n()),
                        |v| prepared.integrate_vec(v).expect("field length matches graph"),
                        &mut rng,
                    )
                })
                .collect::<Vec<_>>()
        });
        let imp = (t_bgfi - t_ftfi) / t_bgfi.max(1e-9) * 100.0;
        table.row(&[
            ds.name,
            ds.graphs.len().to_string(),
            avg_n.to_string(),
            format!("{t_bgfi:.2}"),
            format!("{t_ftfi:.2}"),
            format!("{imp:+.1}%"),
        ]);
    }
}
