//! Ablations beyond the paper's figures, motivated by its design
//! discussions:
//!
//! - leaf-threshold `t` sweep (§4.1 says practice wants t ≫ the
//!   theoretical 6);
//! - prepared-plan reuse vs per-call re-planning (the §3.1 "build once,
//!   integrate many" claim, measured);
//! - parallel scaling of the multi-threaded execution engine (threads ∈
//!   {1, 2, 4, 8} on an n = 4000 batch-of-8 workload), with a serial
//!   bit-identity check and a machine-readable `BENCH_parallel.json`;
//! - tree-ensemble scaling (m ∈ {1, 4, 8, 16} random FRT/Bartal
//!   embeddings): median metric distortion and wall-clock vs the
//!   single-MST and brute-force backends, with a seed-determinism
//!   bit-identity check and a machine-readable `BENCH_ensemble.json`;
//! - cross-multiplier strategy crossover on the same tree (separable vs
//!   lattice vs Chebyshev vs dense);
//! - RFF feature count vs error (§A.2.1's variance claim);
//! - Fig. 9: CUBES-like classification accuracy and fit loss vs the
//!   rational degree of the learnable f;
//! - ModelNet10-substitute point-cloud classification (Appendix D.1).
//!
//! - zero-allocation prepared hot path (legacy per-node allocation vs
//!   nested-dissection workspace): wall clock + allocations/call, with
//!   a pre-timing bit-identity assert and `BENCH_hotpath.json`;
//! - streaming delta integration (sparse k-row update vs full prepared
//!   re-integration, k ∈ {1, 16, 256, n}): wall clock + max-abs drift,
//!   with pre-timing superposition / bit-identity asserts and
//!   `BENCH_delta.json`;
//! - in-place edge re-plans (k reweighted edges via the O(log n)
//!   separator walk vs a full rebuild + re-prepare, k ∈ {1, 4, 16,
//!   64}): wall clock + nodes visited per replan, with a pre-timing
//!   rebuild bit-identity assert and `BENCH_replan.json`;
//! - SIMD lane kernels (lane-chunked inner loops vs the scalar
//!   reference kernels, d ∈ {1, 8, 64}) + f32-serving-tier drift, with
//!   pre-timing f64 bit-identity / f32-budget asserts and
//!   `BENCH_simd.json`;
//! - multi-graph plan cache + fused delta batching (16 sessions over
//!   G ∈ {1, 4, 16} cached graphs, fused vs unfused update runs), with
//!   pre- and post-timing bit-identity asserts and `BENCH_cache.json`;
//!
//! Run: `cargo bench --bench ablations`. The CI bench-smoke job runs
//! `cargo bench --bench ablations -- --quick`, which executes only the
//! cheap parallel-scaling, ensemble-scaling, hot-path, delta, replan,
//! lane-kernel and cache-fusion sweeps and emits `BENCH_parallel.json`
//! + `BENCH_ensemble.json` + `BENCH_hotpath.json` + `BENCH_delta.json`
//! + `BENCH_replan.json` + `BENCH_simd.json` + `BENCH_cache.json` as
//! the perf-trajectory artifacts; `cargo xtask bench-gate` then checks
//! every artifact against `benches/thresholds.json`.

use ftfi::bench_util::{banner, bench, time_once, Table};
use ftfi::ftfi::cordial::{cross_apply, cross_apply_dense, CrossPolicy, Strategy};
use ftfi::ftfi::functions::FDist;
use ftfi::ftfi::rff::RffExpansion;
use ftfi::graph::mst::minimum_spanning_tree;
use ftfi::graph::point_cloud::{epsilon_graph, sample_dataset};
use ftfi::graph::tu_dataset::cubes_like;
use ftfi::graph::{generators, Graph};
use ftfi::linalg::eigen::lanczos_smallest;
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::dataset::{fold_split, stratified_kfold};
use ftfi::ml::fit_rational::{fit, sample_pairs, RationalModel};
use ftfi::ml::metrics::accuracy;
use ftfi::ml::random_forest::{ForestParams, RandomForest};
use ftfi::ml::rng::Pcg;
use ftfi::TreeFieldIntegrator;

/// Thread-local allocation counting for the `hotpath_alloc` ablation
/// (allocations/call, legacy vs workspace prepared paths); shared
/// implementation in `ftfi::bench_util`.
#[global_allocator]
static ALLOC: ftfi::bench_util::CountingAlloc = ftfi::bench_util::CountingAlloc;

fn leaf_threshold_sweep() {
    banner("Ablation: IntegratorTree leaf threshold t (n = 8000, f = exp)");
    let mut rng = Pcg::seed(1);
    let g = generators::path_plus_random_edges(8000, 4000, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let x = Matrix::randn(8000, 1, &mut rng);
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
    let table = Table::new(&["t", "build (s)", "integrate (ms)", "IT depth"], &[6, 10, 14, 9]);
    for &t in &[4usize, 8, 16, 32, 64, 128, 256] {
        let (tfi, t_build) = time_once(|| {
            TreeFieldIntegrator::builder(&tree)
                .leaf_threshold(t)
                .build()
                .expect("valid tree")
        });
        let timing = bench(1, 5, || tfi.try_integrate(&f, &x).expect("integrate"));
        table.row(&[
            t.to_string(),
            format!("{t_build:.3}"),
            format!("{:.2}", timing.median * 1e3),
            tfi.stats().depth.to_string(),
        ]);
    }
}

/// The headline claim of the prepared-plan API: `prepare(&f)` runs
/// `make_plan` once per cross block, and k repeated `integrate` calls
/// reuse the cached plans (Chebyshev expansions above all — the probe
/// loop dominates re-planning for rational kernels). The prepared
/// column includes the one-off prepare cost, so the speedup shown is
/// the honest end-to-end one.
fn prepared_vs_replan() {
    banner("Ablation: prepared plans vs per-call re-planning (n = 4000, f = 1/(1+x^2/2))");
    let mut rng = Pcg::seed(4);
    let g = generators::path_plus_random_edges(4000, 2000, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let tfi = TreeFieldIntegrator::builder(&tree).build().expect("valid tree");
    let f = FDist::inverse_quadratic(0.5); // cross blocks plan via Chebyshev
    let x = Matrix::randn(4000, 4, &mut rng);
    let table = Table::new(
        &["k", "re-plan (ms)", "prepare+k (ms)", "speedup", "plans built"],
        &[4, 13, 15, 8, 12],
    );
    for &k in &[1usize, 4, 8, 16, 32] {
        let (_, t_replan) = time_once(|| {
            for _ in 0..k {
                tfi.try_integrate(&f, &x).expect("integrate");
            }
        });
        let before = tfi.stats().plan_builds;
        let (prepared, t_prep) =
            time_once(|| tfi.prepare_with_channels(&f, 4).expect("prepare"));
        let (_, t_apply) = time_once(|| {
            for _ in 0..k {
                prepared.integrate(&x).expect("integrate");
            }
        });
        let built = tfi.stats().plan_builds - before;
        let t_prepared = t_prep + t_apply;
        table.row(&[
            k.to_string(),
            format!("{:.1}", t_replan * 1e3),
            format!("{:.1}", t_prepared * 1e3),
            format!("{:.2}x", t_replan / t_prepared.max(1e-12)),
            built.to_string(),
        ]);
    }
    println!("(plans built stays constant in k: planning happens once, at prepare time)");
}

/// Tentpole bench: throughput scaling of the multi-threaded execution
/// engine on the serving workload shape — a prepared handle integrating
/// a fused batch of 8 tensor fields on an n = 4000 MST metric. The
/// engine parallelises three axes at once (batch fan-out, IT recursion
/// forks, and — at prepare time — per-node plan building); outputs are
/// asserted bit-identical to the serial run before anything is timed.
/// Always writes `BENCH_parallel.json` for the CI artifact / perf
/// trajectory.
fn parallel_scaling(quick: bool) {
    banner("Ablation: parallel scaling (n = 4000, batch = 8, f = 1/(1+x^2/2))");
    let mut rng = Pcg::seed(12);
    let n = 4000;
    let batch = 8;
    let d = 4;
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let f = FDist::inverse_quadratic(0.5);
    let xs: Vec<Matrix> = (0..batch).map(|_| Matrix::randn(n, d, &mut rng)).collect();
    let refs: Vec<&Matrix> = xs.iter().collect();
    let (warmup, runs) = if quick { (0, 3) } else { (1, 5) };
    let table = Table::new(
        &["threads", "batch (ms)", "fields/s", "speedup", "par forks"],
        &[7, 11, 9, 8, 10],
    );
    let mut medians: Vec<(usize, f64)> = Vec::new();
    let mut reference: Option<Vec<Matrix>> = None;
    for &threads in &[1usize, 2, 4, 8] {
        let tfi = TreeFieldIntegrator::builder(&tree)
            .threads(threads)
            .build()
            .expect("valid tree");
        let prepared = tfi.prepare_with_channels(&f, d).expect("plannable f");
        let out = prepared.integrate_batch(&refs).expect("batch");
        match &reference {
            None => reference = Some(out),
            Some(want) => {
                for (got, want) in out.iter().zip(want) {
                    assert!(
                        got == want,
                        "threads={threads}: output must be bit-identical to serial"
                    );
                }
            }
        }
        let timing = bench(warmup, runs, || prepared.integrate_batch(&refs).expect("batch"));
        medians.push((threads, timing.median));
        let speedup = medians[0].1 / timing.median.max(1e-12);
        table.row(&[
            threads.to_string(),
            format!("{:.1}", timing.median * 1e3),
            format!("{:.0}", batch as f64 / timing.median),
            format!("{speedup:.2}x"),
            tfi.stats().par_forks.to_string(),
        ]);
    }
    let base = medians[0].1;
    let mut json = String::from("{\n  \"bench\": \"parallel_scaling\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"batch\": {batch},\n  \"channels\": {d},\n  \"quick\": {quick},\n"
    ));
    json.push_str("  \"bit_identical_to_serial\": true,\n  \"results\": [\n");
    for (i, (threads, median)) in medians.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {threads}, \"median_s\": {median:.6}, \"speedup\": {:.3}}}{}\n",
            base / median.max(1e-12),
            if i + 1 < medians.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_parallel.json", &json).expect("write BENCH_parallel.json");
    println!("wrote BENCH_parallel.json (outputs bit-identical across thread counts)");
}

/// Tentpole bench (PR 3): the tree-ensemble route — accuracy/cost
/// scaling in the ensemble size m against the single-MST and brute-force
/// (exact graph metric) backends. Reports the median pair distortion of
/// the *averaged* ensemble metric, the prepared-integrate wall-clock and
/// the relative integration error vs brute force. Asserts the
/// seed-determinism contract (threads 1 vs 4 bit-identical) before
/// timing, and always writes `BENCH_ensemble.json` for the CI artifact.
fn ensemble_scaling(quick: bool) {
    use ftfi::ftfi::brute::BruteForceIntegrator;
    use ftfi::ftfi::ensemble::EnsembleMethod;
    use ftfi::graph::shortest_path::dijkstra;
    use ftfi::{EnsembleFieldIntegrator, FieldIntegrator, GraphFieldIntegrator};

    let (n, d, ms): (usize, usize, &[usize]) =
        if quick { (400, 2, &[1, 4]) } else { (1000, 2, &[1, 4, 8, 16]) };
    banner(&format!("Ablation: tree-ensemble scaling (n = {n}, f = exp, FRT)"));
    let mut rng = Pcg::seed(31);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let x = Matrix::randn(n, d, &mut rng);
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };

    // Distortion probe pairs and their true graph distances.
    let n_pairs = if quick { 100 } else { 300 };
    let pairs: Vec<(usize, usize)> = (0..n_pairs)
        .map(|_| {
            let u = rng.below(n);
            let mut v = rng.below(n);
            while v == u {
                v = rng.below(n);
            }
            (u, v)
        })
        .collect();
    let mut graph_d = std::collections::HashMap::new();
    for &(u, _) in &pairs {
        graph_d.entry(u).or_insert_with(|| dijkstra(&g, u));
    }
    let median = |mut xs: Vec<f64>| -> f64 {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    };

    // Ground truth + baselines.
    let brute = BruteForceIntegrator::from_graph(&g);
    let (want, t_brute) = time_once(|| brute.integrate(&f, &x).expect("brute"));
    let mst = GraphFieldIntegrator::try_new(&g).expect("connected graph");
    let mst_prep = mst.prepare(&f).expect("plannable f");
    let mst_timing = bench(0, 3, || mst_prep.integrate(&x).expect("mst integrate"));
    let mst_out = mst_prep.integrate(&x).expect("mst integrate");
    let rel_mst = mst_out.frobenius_diff(&want) / (1.0 + want.frobenius());
    let mst_distortion = median(
        pairs
            .iter()
            .map(|&(u, v)| mst.tree().distance(u, v) / graph_d[&u][v])
            .collect(),
    );

    let table = Table::new(
        &["backend", "m", "distortion", "integrate (ms)", "rel err"],
        &[10, 4, 11, 15, 10],
    );
    table.row(&[
        "brute".into(),
        "-".into(),
        "1.00".into(),
        format!("{:.1}", t_brute * 1e3),
        "0".into(),
    ]);
    table.row(&[
        "mst".into(),
        "1".into(),
        format!("{mst_distortion:.2}"),
        format!("{:.1}", mst_timing.median * 1e3),
        format!("{rel_mst:.2e}"),
    ]);

    let mut json_rows: Vec<String> = vec![
        format!(
            "    {{\"backend\": \"brute\", \"m\": 0, \"distortion\": 1.0, \
             \"median_s\": {t_brute:.6}, \"rel_err\": 0.0}}"
        ),
        format!(
            "    {{\"backend\": \"mst\", \"m\": 1, \"distortion\": {mst_distortion:.4}, \
             \"median_s\": {:.6}, \"rel_err\": {rel_mst:.3e}}}",
            mst_timing.median
        ),
    ];
    for &m in ms {
        // Determinism gate: fixed (seed, m) must be bit-identical across
        // thread counts before anything is timed. The parallel build is
        // then reused as the timed integrator (it is the same ensemble).
        let serial = EnsembleFieldIntegrator::builder(&g)
            .trees(m)
            .seed(97)
            .method(EnsembleMethod::Frt)
            .threads(1)
            .build()
            .expect("connected graph");
        let ens = EnsembleFieldIntegrator::builder(&g)
            .trees(m)
            .seed(97)
            .method(EnsembleMethod::Frt)
            .threads(4)
            .build()
            .expect("connected graph");
        let a = serial.try_integrate(&f, &x).expect("serial");
        let b = ens.try_integrate(&f, &x).expect("parallel");
        assert!(a == b, "m={m}: ensemble output must be bit-identical across thread counts");

        let prepared = ens.prepare_with_channels(&f, d).expect("plannable f");
        let timing = bench(0, 3, || prepared.integrate(&x).expect("ensemble integrate"));
        let out = prepared.integrate(&x).expect("ensemble integrate");
        let rel = out.frobenius_diff(&want) / (1.0 + want.frobenius());
        let distortion = median(
            pairs
                .iter()
                .map(|&(u, v)| {
                    let avg: f64 = (0..m)
                        .map(|i| ens.embedding(i).distance(u, v))
                        .sum::<f64>()
                        / m as f64;
                    avg / graph_d[&u][v]
                })
                .collect(),
        );
        table.row(&[
            "frt".into(),
            m.to_string(),
            format!("{distortion:.2}"),
            format!("{:.1}", timing.median * 1e3),
            format!("{rel:.2e}"),
        ]);
        json_rows.push(format!(
            "    {{\"backend\": \"frt\", \"m\": {m}, \"distortion\": {distortion:.4}, \
             \"median_s\": {:.6}, \"rel_err\": {rel:.3e}}}",
            timing.median
        ));
    }

    let mut json = String::from("{\n  \"bench\": \"ensemble_scaling\",\n");
    json.push_str(&format!(
        "  \"n\": {n}, \"channels\": {d}, \"seed\": 97, \"quick\": {quick},\n"
    ));
    json.push_str("  \"bit_identical_across_threads\": true,\n  \"results\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_ensemble.json", &json).expect("write BENCH_ensemble.json");
    println!("wrote BENCH_ensemble.json (fixed (seed, m) bit-identical across thread counts)");
}

/// Tentpole bench (PR 4): the zero-allocation prepared hot path. One
/// `(tree, f)` pair, `threads = 1` (the per-call constant is the
/// single-thread story; the thread axes multiply on top), legacy
/// (per-node gather/alloc) vs workspace (nested-dissection slabs +
/// arenas) prepared integration: wall clock and allocations/call.
/// Outputs are asserted bit-identical before anything is timed. Always
/// writes `BENCH_hotpath.json` for the CI artifact / perf trajectory.
fn hotpath_alloc(quick: bool) {
    banner("Ablation: prepared hot path, legacy vs workspace (threads = 1, f = 1/(1+x^2/2))");
    let mut rng = Pcg::seed(41);
    let (warmup, runs) = if quick { (1, 3) } else { (2, 7) };
    let table = Table::new(
        &["n", "d", "legacy (ms)", "workspace (ms)", "speedup", "allocs old", "allocs new"],
        &[6, 3, 12, 15, 8, 11, 11],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &n in &[1000usize, 4000] {
        let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
        let tree = minimum_spanning_tree(&g);
        let f = FDist::inverse_quadratic(0.5);
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
        for &d in &[1usize, 8] {
            let plans = tfi.prepare_plans(&f, d).expect("plannable f");
            let x = Matrix::randn(n, d, &mut rng);
            // Bit-identity gate before anything is timed or counted.
            let want = tfi.integrate_prepared_legacy(&x, &plans).expect("legacy");
            let got = tfi.integrate_prepared(&x, &plans).expect("workspace");
            assert!(got == want, "n={n} d={d}: workspace path must be bit-identical");
            let mut out = Matrix::zeros(n, d);
            tfi.integrate_prepared_into(&x, &plans, &mut out).expect("warm");
            // Allocations per call (single-threaded → the thread-local
            // counter sees every allocation of the call).
            let before = ftfi::bench_util::thread_allocs();
            tfi.integrate_prepared_legacy(&x, &plans).expect("legacy");
            let allocs_old = ftfi::bench_util::thread_allocs() - before;
            let before = ftfi::bench_util::thread_allocs();
            tfi.integrate_prepared_into(&x, &plans, &mut out).expect("workspace");
            let allocs_new = ftfi::bench_util::thread_allocs() - before;
            let t_old = bench(warmup, runs, || {
                tfi.integrate_prepared_legacy(&x, &plans).expect("legacy")
            });
            let t_new = bench(warmup, runs, || {
                tfi.integrate_prepared_into(&x, &plans, &mut out).expect("workspace")
            });
            let speedup = t_old.median / t_new.median.max(1e-12);
            table.row(&[
                n.to_string(),
                d.to_string(),
                format!("{:.2}", t_old.median * 1e3),
                format!("{:.2}", t_new.median * 1e3),
                format!("{speedup:.2}x"),
                allocs_old.to_string(),
                allocs_new.to_string(),
            ]);
            json_rows.push(format!(
                "    {{\"n\": {n}, \"d\": {d}, \"legacy_s\": {:.6}, \"workspace_s\": {:.6}, \
                 \"speedup\": {speedup:.3}, \"allocs_legacy\": {allocs_old}, \
                 \"allocs_workspace\": {allocs_new}}}",
                t_old.median, t_new.median
            ));
        }
    }
    let mut json = String::from("{\n  \"bench\": \"hotpath_alloc\",\n");
    json.push_str(&format!("  \"threads\": 1, \"quick\": {quick},\n"));
    json.push_str("  \"bit_identical_to_legacy\": true,\n  \"results\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json (workspace path bit-identical; allocs/call pinned)");
}

/// Tentpole bench (PR 5): streaming delta integration — the sparse
/// k-row update path vs a full prepared re-integration on the n = 4000
/// serving metric, k ∈ {1, 16, 256, n}. Before timing, every k asserts
/// the superposition identity (`base + Δout` vs a full recompute of the
/// updated field, max-abs drift reported) and the k = n degenerate case
/// asserts **bit-identity** with a plain prepared integration. Always
/// writes `BENCH_delta.json` for the CI artifact / perf trajectory.
/// Acceptance: ≥ 5x wall-clock for k = 1.
fn delta_scaling(quick: bool) {
    banner("Ablation: streaming delta vs full re-integration (n = 4000, threads = 1)");
    let mut rng = Pcg::seed(51);
    let n = 4000;
    let d = 4;
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let f = FDist::inverse_quadratic(0.5);
    let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
    let plans = tfi.prepare_plans(&f, d).expect("plannable f");
    let x = Matrix::randn(n, d, &mut rng);
    let mut base = Matrix::zeros(n, d);
    tfi.integrate_prepared_into(&x, &plans, &mut base).expect("base integrate");
    let (warmup, runs) = if quick { (1, 3) } else { (2, 7) };
    let table = Table::new(
        &["k", "delta (ms)", "full (ms)", "speedup", "max abs drift", "nodes visited"],
        &[6, 11, 10, 8, 14, 14],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &k in &[1usize, 16, 256, n] {
        let (rows, dx) = ftfi::bench_util::sparse_delta(n, d, k, &mut rng);
        let rows = &rows[..];
        let mut x2 = x.clone();
        x2.axpy(1.0, &dx);
        // Equivalence gates before anything is timed.
        let full = tfi.integrate_prepared(&x2, &plans).expect("full");
        let dout = tfi.integrate_delta_prepared(rows, &dx, &plans).expect("delta");
        let mut approx = base.clone();
        approx.axpy(1.0, &dout);
        let drift = approx.max_abs_diff(&full);
        let rel = drift / (1.0 + full.frobenius());
        assert!(rel < 1e-8, "k={k}: superposition drifted to rel {rel}");
        if k == n {
            let want = tfi.integrate_prepared(&dx, &plans).expect("full of delta");
            assert!(dout == want, "k=n delta must be bit-identical to integrate(Δ)");
        }
        let visited_before = tfi.stats().delta_nodes_visited;
        let mut dbuf = Matrix::zeros(n, d);
        let mut fbuf = Matrix::zeros(n, d);
        let t_delta = bench(warmup, runs, || {
            tfi.integrate_delta_prepared_into(rows, &dx, &plans, &mut dbuf).expect("delta")
        });
        let delta_visits = tfi.stats().delta_nodes_visited - visited_before;
        let per_call_visits = delta_visits / (warmup + runs);
        let t_full = bench(warmup, runs, || {
            tfi.integrate_prepared_into(&x2, &plans, &mut fbuf).expect("full")
        });
        let speedup = t_full.median / t_delta.median.max(1e-12);
        table.row(&[
            k.to_string(),
            format!("{:.3}", t_delta.median * 1e3),
            format!("{:.3}", t_full.median * 1e3),
            format!("{speedup:.2}x"),
            format!("{drift:.2e}"),
            per_call_visits.to_string(),
        ]);
        json_rows.push(format!(
            "    {{\"k\": {k}, \"delta_s\": {:.6}, \"full_s\": {:.6}, \
             \"speedup\": {speedup:.3}, \"max_abs_drift\": {drift:.3e}, \
             \"nodes_visited\": {per_call_visits}}}",
            t_delta.median, t_full.median
        ));
    }
    let mut json = String::from("{\n  \"bench\": \"delta_scaling\",\n");
    json.push_str(&format!(
        "  \"n\": {n}, \"channels\": {d}, \"threads\": 1, \"quick\": {quick},\n"
    ));
    json.push_str("  \"superposition_asserted\": true,\n  \"results\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_delta.json", &json).expect("write BENCH_delta.json");
    println!("wrote BENCH_delta.json (equivalence asserted before timing)");
}

/// Tentpole bench (PR 8): in-place edge re-plans — reweighting k tree
/// edges through `TreeFieldIntegrator::replan_edge_prepared` (the
/// O(log n) separator walk rebuilding only the affected pivot-distance
/// tables and per-node plans) vs a full rebuild-from-scratch +
/// re-prepare, k ∈ {1, 4, 16, 64} on the n = 4000 serving metric.
/// Before timing, every k asserts that the replanned handle serves
/// **bit-identical** output to a from-scratch rebuild on the mutated
/// tree (the separator hierarchy is weight-independent, so the re-plan
/// is exact, not approximate). Reports nodes visited per replan (the
/// O(log n) invalidation footprint). Always writes `BENCH_replan.json`
/// for the CI artifact / perf trajectory. Acceptance: ≥ 5x wall-clock
/// for k = 1 vs rebuild+prepare.
fn replan_scaling(quick: bool) {
    banner("Ablation: in-place edge re-plan vs rebuild+prepare (n = 4000, threads = 1)");
    let mut rng = Pcg::seed(71);
    let n = 4000;
    let d = 4;
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let mut tree = minimum_spanning_tree(&g);
    let f = FDist::inverse_quadratic(0.5);
    let mut tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
    let mut plans = tfi.prepare_plans(&f, d).expect("plannable f");
    let x = Matrix::randn(n, d, &mut rng);
    let (warmup, runs) = if quick { (1, 3) } else { (2, 7) };
    let table = Table::new(
        &["k", "replan (ms)", "rebuild (ms)", "speedup", "nodes visited"],
        &[6, 12, 13, 9, 14],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &k in &[1usize, 4, 16, 64] {
        // k distinct edges; each timed pass flips them between their
        // current weight and 1.5× (a same-weight replan is a validated
        // no-op that rebuilds nothing, so alternation keeps every timed
        // call on the real re-plan path).
        let picks: Vec<(usize, usize, f64)> = rng
            .sample_distinct(tree.edges().len(), k)
            .into_iter()
            .map(|i| {
                let (u, v, w) = tree.edges()[i];
                (u as usize, v as usize, w)
            })
            .collect();
        // Rebuild-equivalence gate before anything is timed: after
        // replanning all k edges, the handle must serve bit-identical
        // output to a from-scratch build on the mutated tree.
        for &(u, v, w) in &picks {
            tfi.replan_edge_prepared(u, v, w * 1.5, &mut plans).expect("replan edge");
            assert!(tree.set_edge_weight(u, v, w * 1.5).is_some(), "pick must be a tree edge");
        }
        let got = tfi.integrate_prepared(&x, &plans).expect("replanned integrate");
        let oracle =
            TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
        let oplans = oracle.prepare_plans(&f, d).expect("plannable f");
        let want = oracle.integrate_prepared(&x, &oplans).expect("rebuilt integrate");
        assert!(got == want, "k={k}: replanned handle must match a from-scratch rebuild");

        let visits_before = tfi.stats().replan_nodes_visited;
        let mut pass = 0usize;
        let t_replan = bench(warmup, runs, || {
            pass += 1;
            let scale = if pass % 2 == 1 { 1.0 } else { 1.5 };
            for &(u, v, w) in &picks {
                tfi.replan_edge_prepared(u, v, w * scale, &mut plans).expect("replan edge");
            }
        });
        let per_replan_visits =
            (tfi.stats().replan_nodes_visited - visits_before) / ((warmup + runs) * k);
        // Leave the shared tree mirror in sync with the final timed pass.
        let final_scale = if (warmup + runs) % 2 == 1 { 1.0 } else { 1.5 };
        for &(u, v, w) in &picks {
            assert!(tree.set_edge_weight(u, v, w * final_scale).is_some());
        }
        let t_full = bench(warmup, runs, || {
            let t = TreeFieldIntegrator::builder(&tree)
                .threads(1)
                .build()
                .expect("valid tree");
            t.prepare_plans(&f, d).expect("plannable f");
        });
        let speedup = t_full.median / t_replan.median.max(1e-12);
        table.row(&[
            k.to_string(),
            format!("{:.3}", t_replan.median * 1e3),
            format!("{:.3}", t_full.median * 1e3),
            format!("{speedup:.2}x"),
            per_replan_visits.to_string(),
        ]);
        json_rows.push(format!(
            "    {{\"k\": {k}, \"replan_s\": {:.6}, \"rebuild_s\": {:.6}, \
             \"speedup\": {speedup:.3}, \"nodes_visited\": {per_replan_visits}}}",
            t_replan.median, t_full.median
        ));
    }
    let mut json = String::from("{\n  \"bench\": \"replan_scaling\",\n");
    json.push_str(&format!(
        "  \"n\": {n}, \"channels\": {d}, \"threads\": 1, \"quick\": {quick},\n"
    ));
    json.push_str("  \"rebuild_bit_identity_asserted\": true,\n  \"results\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_replan.json", &json).expect("write BENCH_replan.json");
    println!("wrote BENCH_replan.json (rebuild bit-identity asserted before timing)");
}

/// Tentpole bench (PR 7): lane-structured inner kernels + the f32
/// serving tier. Times the chunked lane kernels (`linalg::lanes` — the
/// default path of every prepared inner loop since this PR) against
/// the scalar reference kernels (`lanes::*_scalar`, the PR-6-style
/// elementwise loops kept as the bit-identity oracle) on an n = 4000
/// single-thread workload, d ∈ {1, 8, 64}. Before anything is timed it
/// asserts (a) the f64 lane path is bit-identical to the scalar
/// reference — per kernel on real field rows AND end-to-end via the
/// legacy-vs-workspace prepared integration — and (b) the opt-in f32
/// serving tier stays inside its relative error budget against the f64
/// oracle. Always writes `BENCH_simd.json` for the CI artifact; the
/// bench-gate step checks its speedups, f32 drift and allocation
/// counts against `benches/thresholds.json`.
fn simd_scaling(quick: bool) {
    use ftfi::linalg::lanes::{self, Precision};
    use std::hint::black_box;

    let n = 4000;
    banner(&format!(
        "Ablation: lane kernels vs scalar reference (n = {n}, threads = 1, lane width = {})",
        lanes::LANE_WIDTH
    ));
    let mut rng = Pcg::seed(61);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let tree = minimum_spanning_tree(&g);
    let f = FDist::inverse_quadratic(0.5);
    let (warmup, runs) = if quick { (1, 3) } else { (2, 7) };
    let table = Table::new(
        &["d", "scalar (ms)", "lane (ms)", "speedup", "f32 rel err", "allocs new"],
        &[4, 12, 10, 8, 12, 11],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &d in &[1usize, 8, 64] {
        let x = Matrix::randn(n, d, &mut rng);
        let coeffs = rng.uniform_vec(n, -1.0, 1.0);

        // (a) f64 bit-identity gate, kernel level: the lane-chunked
        // axpy/combine against their scalar references on real rows.
        {
            let mut got = vec![0.0f64; n * d];
            let mut want = vec![0.0f64; n * d];
            for i in 0..n {
                let (s, e) = (i * d, (i + 1) * d);
                lanes::axpy(coeffs[i], &x.data()[s..e], &mut got[s..e]);
                lanes::axpy_scalar(coeffs[i], &x.data()[s..e], &mut want[s..e]);
            }
            let pivot: Vec<f64> = x.data()[..d].to_vec();
            for i in 1..n {
                let (s, e) = (i * d, (i + 1) * d);
                let (head, tail) = got.split_at_mut(d);
                lanes::combine(&mut tail[s - d..e - d], &head[..d], coeffs[i], &pivot);
                let (head_w, tail_w) = want.split_at_mut(d);
                lanes::combine_scalar(&mut tail_w[s - d..e - d], &head_w[..d], coeffs[i], &pivot);
            }
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "d={d}: lane kernels must be bit-identical to the scalar reference"
            );
        }

        // …and end-to-end: the lane-kernel workspace path against the
        // legacy prepared path.
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().expect("valid tree");
        let plans = tfi.prepare_plans(&f, d).expect("plannable f");
        let want = tfi.integrate_prepared_legacy(&x, &plans).expect("legacy");
        let got = tfi.integrate_prepared(&x, &plans).expect("workspace");
        assert!(got == want, "d={d}: f64 lane path must stay bit-identical");

        // (b) f32-tier budget gate vs the f64 oracle (the fine-grained
        // per-strategy ULP sweep lives in tests/ftfi_precision.rs; this
        // is the end-to-end drift on the serving workload).
        let tfi32 = TreeFieldIntegrator::builder(&tree)
            .threads(1)
            .precision(Precision::F32)
            .build()
            .expect("valid tree");
        let plans32 = tfi32.prepare_plans(&f, d).expect("plannable f");
        let got32 = tfi32.integrate_prepared(&x, &plans32).expect("f32 tier");
        let f32_rel = got32.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(
            f32_rel < 5e-4,
            "d={d}: f32 tier rel err {f32_rel:.3e} exceeds the serving budget"
        );

        // Zero-allocation contract on the warmed lane-path call.
        let mut out = Matrix::zeros(n, d);
        tfi.integrate_prepared_into(&x, &plans, &mut out).expect("warm");
        let before = ftfi::bench_util::thread_allocs();
        tfi.integrate_prepared_into(&x, &plans, &mut out).expect("workspace");
        let allocs_new = ftfi::bench_util::thread_allocs() - before;
        assert_eq!(allocs_new, 0, "d={d}: warmed lane path must stay allocation-free");

        // Timing: one sweep = axpy + combine over every row — the same
        // memory traffic through both kernel families.
        let mut acc = vec![0.0f64; n * d];
        let pivot: Vec<f64> = x.data()[..d].to_vec();
        let t_scalar = bench(warmup, runs, || {
            for i in 0..n {
                let (s, e) = (i * d, (i + 1) * d);
                lanes::axpy_scalar(coeffs[i], &x.data()[s..e], &mut acc[s..e]);
                lanes::combine_scalar(&mut acc[s..e], &x.data()[s..e], coeffs[i], &pivot);
            }
            black_box(&mut acc);
        });
        acc.iter_mut().for_each(|v| *v = 0.0);
        let t_lane = bench(warmup, runs, || {
            for i in 0..n {
                let (s, e) = (i * d, (i + 1) * d);
                lanes::axpy(coeffs[i], &x.data()[s..e], &mut acc[s..e]);
                lanes::combine(&mut acc[s..e], &x.data()[s..e], coeffs[i], &pivot);
            }
            black_box(&mut acc);
        });
        let speedup = t_scalar.median / t_lane.median.max(1e-12);
        table.row(&[
            d.to_string(),
            format!("{:.3}", t_scalar.median * 1e3),
            format!("{:.3}", t_lane.median * 1e3),
            format!("{speedup:.2}x"),
            format!("{f32_rel:.2e}"),
            allocs_new.to_string(),
        ]);
        json_rows.push(format!(
            "    {{\"d\": {d}, \"scalar_s\": {:.6}, \"lane_s\": {:.6}, \
             \"speedup\": {speedup:.3}, \"f32_rel_err\": {f32_rel:.3e}, \
             \"allocs_new\": {allocs_new}}}",
            t_scalar.median, t_lane.median
        ));
    }
    let mut json = String::from("{\n  \"bench\": \"simd_scaling\",\n");
    json.push_str(&format!(
        "  \"n\": {n}, \"threads\": 1, \"lane_width\": {}, \"quick\": {quick},\n",
        lanes::LANE_WIDTH
    ));
    json.push_str("  \"bit_identical_f64\": true,\n  \"results\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_simd.json", &json).expect("write BENCH_simd.json");
    println!("wrote BENCH_simd.json (f64 bit-identity + f32 budget asserted before timing)");
}

/// Tentpole bench (PR 10): multi-graph prepared-plan cache + fused
/// delta batching. Drives the streaming serving executor over 16
/// sessions spread round-robin across G ∈ {1, 4, 16} cached graphs,
/// every batch window carrying a 4-update run per session, fused vs
/// unfused. Before anything is timed it asserts the two executors are
/// bit-identical: on the final member of every update run and on every
/// session's full lease state after every window (non-final members of
/// a fused run carry the post-run output by documented contract — the
/// exhaustive churn harness lives in tests/serving_cache.rs). The same
/// lease probe re-runs *after* timing, so the timed iterations are
/// covered too. Always writes `BENCH_cache.json`; the bench-gate step
/// checks fusion speedups, fused-update/rows-saved counters and cache
/// hit counts against `benches/thresholds.json`.
fn cache_fusion(quick: bool) {
    use ftfi::config::CacheConfig;
    use ftfi::coordinator::protocol::{self, StreamRequest};
    use ftfi::coordinator::{BatchExecutor, MetricsRegistry, StreamingFieldExecutor};
    use std::sync::Arc;

    let n = 1000;
    let d = 2usize;
    let sessions: u32 = 16;
    let run = 4usize; // updates per session per window — what fusion collapses
    banner(&format!(
        "Ablation: plan cache + update fusion (n = {n}, d = {d}, {sessions} sessions, threads = 1)"
    ));
    let (warmup, runs) = if quick { (1, 3) } else { (2, 7) };
    let table = Table::new(
        &["G", "unfused (ms)", "fused (ms)", "speedup", "rows saved", "hits", "misses"],
        &[4, 13, 11, 8, 11, 6, 7],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &g in &[1usize, 4, 16] {
        // G same-sized trees; graph 0 is the executors' default, the
        // rest resolve through `OpenGraph` and the plan cache.
        let trees: Vec<ftfi::Tree> = (0..g)
            .map(|gi| {
                let mut trng = Pcg::seed(0xBE7A ^ (0xCA00 + gi as u64));
                generators::random_tree(n, 0.2, 1.0, &mut trng)
            })
            .collect();
        let f = FDist::Exponential { lambda: -0.45, scale: 1.0 };
        let build = |fuse: bool, metrics: &Arc<MetricsRegistry>| {
            let tfi =
                TreeFieldIntegrator::builder(&trees[0]).threads(1).build().expect("valid tree");
            StreamingFieldExecutor::new(tfi, &f, d, 0, sessions as usize, 64)
                .expect("plannable f")
                .with_cache(CacheConfig { max_graphs: 16, max_bytes_mb: 0, fuse_updates: fuse })
                .with_metrics(Arc::clone(metrics))
        };
        let mf = Arc::new(MetricsRegistry::new());
        let mu = Arc::new(MetricsRegistry::new());
        let fused = build(true, &mf);
        let unfused = build(false, &mu);

        let mut rng = Pcg::seed(0xCAFE + g as u64);
        let mut next_id = 0u64;
        // Each session updates rows drawn from a fixed 32-row pool, so
        // the cumulative dirty set — and with it the per-window delta
        // cost — stays bounded across the timed iterations.
        let pools: Vec<Vec<u32>> = (0..sessions)
            .map(|_| (0..32).map(|_| rng.below(n) as u32).collect())
            .collect();
        let admit = |rng: &mut Pcg| -> Vec<StreamRequest> {
            let mut w = Vec::new();
            for s in 0..sessions {
                let gi = s as usize % g;
                if gi > 0 {
                    let t = &trees[gi];
                    w.push(StreamRequest::OpenGraph {
                        session: s,
                        n: t.n() as u32,
                        edges: t.edges().to_vec(),
                    });
                }
                w.push(StreamRequest::Set {
                    session: s,
                    rows: n as u32,
                    channels: d as u32,
                    values: (0..n * d).map(|_| rng.normal() as f32).collect(),
                });
            }
            w
        };
        let update_window = |rng: &mut Pcg, pools: &[Vec<u32>]| -> Vec<StreamRequest> {
            let mut w = Vec::new();
            for s in 0..sessions {
                for _ in 0..run {
                    let k = 8usize;
                    let pool = &pools[s as usize];
                    w.push(StreamRequest::Update {
                        session: s,
                        rows: (0..k).map(|_| pool[rng.below(pool.len())]).collect(),
                        channels: d as u32,
                        values: (0..k * d).map(|_| rng.normal() as f32).collect(),
                    });
                }
            }
            w
        };
        let encode = |w: &[StreamRequest], next_id: &mut u64| -> Vec<Vec<f32>> {
            w.iter()
                .map(|r| {
                    let id = *next_id;
                    *next_id += 1;
                    protocol::request_words(r, id)
                })
                .collect()
        };
        // Bit-exact comparison of raw response frames (request ids are
        // identical by construction, payload floats compare by bits).
        let assert_frames_eq = |a: &Result<Vec<f32>, String>,
                                b: &Result<Vec<f32>, String>,
                                what: &str| match (a, b) {
            (Ok(fa), Ok(fb)) => {
                let ba: Vec<u32> = fa.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = fb.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ba, bb, "G={g} {what}: fused and unfused frames diverged");
            }
            (a, b) => assert_eq!(a, b, "G={g} {what}: fused and unfused results diverged"),
        };
        let lease_probe = |next_id: &mut u64| {
            let probes: Vec<StreamRequest> =
                (0..sessions).map(|s| StreamRequest::Lease { session: s }).collect();
            let words = encode(&probes, next_id);
            let a = fused.execute_each(&words);
            let b = unfused.execute_each(&words);
            for (ra, rb) in a.iter().zip(&b) {
                assert_frames_eq(ra, rb, "lease probe");
            }
        };

        // Pre-timing bit-identity gate: admission, a re-open wave (the
        // cache-hit path: every session re-resolves its already-cached
        // graph), then mixed update windows — final-member responses
        // and full lease state compared after every window.
        for wave in 0..2 {
            let words = encode(&admit(&mut rng), &mut next_id);
            let a = fused.execute_each(&words);
            let b = unfused.execute_each(&words);
            for (ra, rb) in a.iter().zip(&b) {
                assert_frames_eq(ra, rb, if wave == 0 { "admission" } else { "re-open wave" });
            }
        }
        for _ in 0..2 {
            let words = encode(&update_window(&mut rng, &pools), &mut next_id);
            let a = fused.execute_each(&words);
            let b = unfused.execute_each(&words);
            for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
                assert!(ra.is_ok(), "G={g}: update {i} failed: {ra:?}");
                if i % run == run - 1 {
                    assert_frames_eq(ra, rb, "update-run final member");
                }
            }
            lease_probe(&mut next_id);
        }
        let sf = mf.snapshot();
        let su = mu.snapshot();
        assert!(sf.fused_updates > 0, "G={g}: fused executor never fused a run");
        assert_eq!(su.fused_updates, 0, "G={g}: unfused executor must not fuse");
        assert_eq!(
            (sf.cache_hits, sf.cache_misses),
            (su.cache_hits, su.cache_misses),
            "G={g}: serial cache traffic must be identical"
        );

        // Timing: both executors replay the same pre-encoded windows
        // the same number of times (bench = warmup + runs calls), so
        // their states stay aligned for the post-timing lease probe.
        let timed: Vec<Vec<Vec<f32>>> =
            (0..4).map(|_| encode(&update_window(&mut rng, &pools), &mut next_id)).collect();
        let t_unfused = bench(warmup, runs, || {
            for w in &timed {
                for r in unfused.execute_each(w) {
                    r.expect("unfused update");
                }
            }
        });
        let t_fused = bench(warmup, runs, || {
            for w in &timed {
                for r in fused.execute_each(w) {
                    r.expect("fused update");
                }
            }
        });
        lease_probe(&mut next_id);

        let sf = mf.snapshot();
        let lookups = sf.cache_hits + sf.cache_misses;
        let hit_rate =
            if lookups == 0 { 1.0 } else { sf.cache_hits as f64 / lookups as f64 };
        let speedup = t_unfused.median / t_fused.median.max(1e-12);
        table.row(&[
            g.to_string(),
            format!("{:.3}", t_unfused.median * 1e3),
            format!("{:.3}", t_fused.median * 1e3),
            format!("{speedup:.2}x"),
            sf.fusion_rows_saved.to_string(),
            sf.cache_hits.to_string(),
            sf.cache_misses.to_string(),
        ]);
        json_rows.push(format!(
            "    {{\"graphs\": {g}, \"unfused_s\": {:.6}, \"fused_s\": {:.6}, \
             \"speedup\": {speedup:.3}, \"fused_updates\": {}, \"fusion_rows_saved\": {}, \
             \"cache_hits\": {}, \"cache_misses\": {}, \"cache_evictions\": {}, \
             \"cache_hit_rate\": {hit_rate:.4}}}",
            t_unfused.median,
            t_fused.median,
            sf.fused_updates,
            sf.fusion_rows_saved,
            sf.cache_hits,
            sf.cache_misses,
            sf.cache_evictions,
        ));
    }
    let mut json = String::from("{\n  \"bench\": \"cache_fusion\",\n");
    json.push_str(&format!(
        "  \"n\": {n}, \"d\": {d}, \"sessions\": {sessions}, \"run_len\": {run}, \
         \"threads\": 1, \"quick\": {quick},\n"
    ));
    json.push_str("  \"bit_identical_fused_vs_unfused\": true,\n  \"results\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_cache.json", &json).expect("write BENCH_cache.json");
    println!("wrote BENCH_cache.json (fused vs unfused bit-identity asserted before timing)");
}

fn strategy_crossover() {
    banner("Ablation: cross-multiplier strategies, C in R^{k x l}, d=4");
    let table =
        Table::new(&["k=l", "f", "strategy", "time (ms)", "rel err"], &[7, 10, 12, 10, 9]);
    let mut rng = Pcg::seed(2);
    for &k in &[256usize, 1024, 4096] {
        // Real-weight distances (generic case).
        let xs = rng.uniform_vec(k, 0.0, 10.0);
        let ys = rng.uniform_vec(k, 0.0, 10.0);
        let v = Matrix::randn(k, 4, &mut rng);
        let cases: Vec<(&str, FDist, Vec<Strategy>)> = vec![
            (
                "exp",
                FDist::Exponential { lambda: -0.3, scale: 1.0 },
                vec![Strategy::Separable, Strategy::Dense],
            ),
            (
                "invquad",
                FDist::inverse_quadratic(0.5),
                vec![Strategy::Chebyshev, Strategy::RationalSum, Strategy::Dense],
            ),
        ];
        for (fname, f, strategies) in cases {
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            for s in strategies {
                if s == Strategy::RationalSum && k > 1024 {
                    continue; // documented f64 block-size limit
                }
                let policy = CrossPolicy { force: Some(s), ..Default::default() };
                let timing = bench(0, 3, || cross_apply(&f, &xs, &ys, &v, &policy));
                let got = cross_apply(&f, &xs, &ys, &v, &policy);
                let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
                table.row(&[
                    k.to_string(),
                    fname.into(),
                    format!("{s:?}"),
                    format!("{:.2}", timing.median * 1e3),
                    format!("{rel:.1e}"),
                ]);
            }
        }
    }
}

fn rff_sweep() {
    banner("Ablation (§A.2.1): RFF feature count vs error, gaussian kernel");
    let table = Table::new(&["m", "rel err", "time (ms)"], &[8, 10, 10]);
    let mut rng = Pcg::seed(3);
    let xs = rng.uniform_vec(2000, 0.0, 4.0);
    let ys = rng.uniform_vec(2000, 0.0, 4.0);
    let v = Matrix::randn(2000, 2, &mut rng);
    let f = FDist::gaussian(0.5);
    let want = cross_apply_dense(&f, &xs, &ys, &v);
    for &m in &[32usize, 128, 512, 2048] {
        let exp = RffExpansion::gaussian(0.5, m, &mut rng);
        let timing = bench(0, 3, || exp.cross_apply(&xs, &ys, &v));
        let got = exp.cross_apply(&xs, &ys, &v);
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        table.row(&[m.to_string(), format!("{rel:.2e}"), format!("{:.2}", timing.median * 1e3)]);
    }
}

/// Shared classification harness over labelled graphs.
fn classify(graphs: &[Graph], labels: &[usize], f: &FDist, seed: u64) -> f64 {
    let mut rng = Pcg::seed(seed);
    let feats: Vec<Vec<f64>> = graphs
        .iter()
        .map(|g| {
            // One prepared handle per graph: the Lanczos iteration hits
            // the same (tree, f) pair dozens of times.
            let gfi = ftfi::GraphFieldIntegrator::try_new(g).expect("connected graph");
            let prepared = gfi.prepare(f).expect("plannable f");
            lanczos_smallest(
                g.n(),
                6.min(g.n()),
                |v| prepared.integrate_vec(v).expect("field length matches graph"),
                &mut rng,
            )
            .into_iter()
            .chain(std::iter::repeat(0.0))
            .take(6)
            .collect()
        })
        .collect();
    let folds = stratified_kfold(labels, 5, &mut rng);
    let mut accs = Vec::new();
    for fi in 0..folds.len() {
        let (tr, te) = fold_split(&folds, fi);
        let xtr: Vec<Vec<f64>> = tr.iter().map(|&i| feats[i].clone()).collect();
        let ytr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let rf = RandomForest::fit(&xtr, &ytr, &ForestParams::default(), &mut rng);
        let pred: Vec<usize> = te.iter().map(|&i| rf.predict(&feats[i])).collect();
        let truth: Vec<usize> = te.iter().map(|&i| labels[i]).collect();
        accs.push(accuracy(&pred, &truth));
    }
    accs.iter().sum::<f64>() / accs.len() as f64
}

fn fig9_cubes() {
    banner("Fig 9: CUBES-like — accuracy & fit loss vs rational degree of f");
    let ds = cubes_like(60, 5);
    let table = Table::new(&["GRF degree", "accuracy", "fit loss"], &[11, 9, 10]);
    // SP-kernel baseline (degree 0 = identity).
    let base = classify(&ds.graphs, &ds.labels, &FDist::Identity, 7);
    table.row(&["SP (id)".into(), format!("{base:.3}"), "-".into()]);
    for deg in [1usize, 2, 3] {
        // Fit one shared f on a few graphs (the paper: "learnt using a few
        // graph instances"), then featurise with it.
        let mut model = RationalModel::new(deg, deg);
        let mut rng = Pcg::seed(8);
        let mut loss = 0.0;
        for g in ds.graphs.iter().take(4) {
            let tree = minimum_spanning_tree(g);
            let data = sample_pairs(g, &tree, 50, &mut rng);
            loss = *fit(&mut model, &data, 150, 0.02).loss.last().unwrap();
        }
        let acc = classify(&ds.graphs, &ds.labels, &model.to_fdist(), 7);
        table.row(&[format!("GRF({deg})"), format!("{acc:.3}"), format!("{loss:.4}")]);
    }
}

fn pointcloud_modelnet() {
    banner("Appendix D.1: ModelNet10-substitute point-cloud classification");
    let mut rng = Pcg::seed(9);
    let clouds = sample_dataset(6, 48, 0.02, &mut rng);
    let graphs: Vec<Graph> = clouds.iter().map(|c| epsilon_graph(c, 0.45)).collect();
    let labels: Vec<usize> = clouds.iter().map(|c| c.label).collect();
    let acc_sp = classify(&graphs, &labels, &FDist::Identity, 11);
    let acc_deg2 = classify(
        &graphs,
        &labels,
        &FDist::Rational { num: vec![0.0, 1.0, 0.3], den: vec![1.0, 0.2] },
        11,
    );
    println!("SP kernel acc {acc_sp:.3}  vs  degree-2 rational f acc {acc_deg2:.3}");
    println!("(paper: 39.6% → 44.2%, a ~10% relative improvement)");
}

fn main() {
    // `cargo bench --bench ablations -- --quick`: the cheap CI smoke
    // mode — only the parallel-scaling and ensemble-scaling sweeps,
    // still emitting both JSON artifacts.
    if std::env::args().any(|a| a == "--quick") {
        parallel_scaling(true);
        ensemble_scaling(true);
        hotpath_alloc(true);
        delta_scaling(true);
        replan_scaling(true);
        simd_scaling(true);
        cache_fusion(true);
        return;
    }
    leaf_threshold_sweep();
    prepared_vs_replan();
    parallel_scaling(false);
    ensemble_scaling(false);
    hotpath_alloc(false);
    delta_scaling(false);
    replan_scaling(false);
    simd_scaling(false);
    cache_fusion(false);
    strategy_crossover();
    rff_sweep();
    fig9_cubes();
    pointcloud_modelnet();
}
