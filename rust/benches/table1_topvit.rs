//! Table 1 / Fig. 7 (scaled): Topological ViT with tree-based masking vs
//! the unmasked performer baseline — trained from rust through the AOT
//! train-step artifact on the synthetic-shapes corpus, evaluated on a
//! held-out split. The paper's claim is *relative*: the FTFI topological
//! mask (3 extra learnable parameters per layer, `synced`) beats the
//! unmasked low-rank-attention baseline by 1–2%.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench table1_topvit`

use ftfi::bench_util::{banner, Table};
use ftfi::ml::metrics::accuracy;
use ftfi::ml::rng::Pcg;
use ftfi::ml::shapes;
use ftfi::runtime::topvit::{TopVit, TRAIN_BATCH};
use ftfi::runtime::Runtime;

const STEPS: usize = 220;
const LR: f32 = 0.01;

fn train_eval(params_bin: &str, seed: u64) -> anyhow::Result<(f64, f32)> {
    let rt = Runtime::cpu()?;
    let mut model = TopVit::load(&rt, "artifacts", params_bin, &[8], true)?;
    model.freeze_mask = params_bin.contains("unmasked");
    let mut rng = Pcg::seed(seed);
    let train = shapes::dataset(64, &mut rng);
    let test = shapes::dataset(16, &mut rng);
    let mut last_loss = f32::NAN;
    for step in 0..STEPS {
        let (images, labels) = shapes::pack_batch(&train, step * TRAIN_BATCH, TRAIN_BATCH);
        last_loss = model.train_step(&images, &labels, LR)?;
    }
    let mut preds = Vec::new();
    let mut truth = Vec::new();
    for chunk in test.chunks(8) {
        let mut flat = Vec::new();
        for ex in chunk {
            flat.extend_from_slice(&ex.pixels);
        }
        flat.resize(8 * shapes::IMG * shapes::IMG, 0.0);
        preds.extend(model.classify(8, &flat)?.into_iter().take(chunk.len()));
        truth.extend(chunk.iter().map(|e| e.label));
    }
    Ok((accuracy(&preds, &truth), last_loss))
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/topvit_train_b32.hlo.txt").exists() {
        println!("SKIP: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    banner("Table 1 (scaled): masked TopViT vs unmasked performer (3 seeds)");
    let table = Table::new(
        &["variant", "mask params/layer", "acc mean", "acc ±", "loss mean"],
        &[10, 17, 9, 7, 10],
    );
    let mut deltas = Vec::new();
    let mut rows: Vec<(String, String, Vec<f64>, Vec<f64>)> = vec![
        ("masked".into(), "3 (synced)".into(), Vec::new(), Vec::new()),
        ("unmasked".into(), "0 (baseline)".into(), Vec::new(), Vec::new()),
    ];
    for seed in [100u64, 200, 300] {
        let (acc_m, loss_m) = train_eval("topvit_init_masked.bin", seed)?;
        let (acc_u, loss_u) = train_eval("topvit_init_unmasked.bin", seed)?;
        rows[0].2.push(acc_m);
        rows[0].3.push(loss_m as f64);
        rows[1].2.push(acc_u);
        rows[1].3.push(loss_u as f64);
        deltas.push(acc_m - acc_u);
    }
    for (name, params, accs, losses) in &rows {
        let (am, astd) = ftfi::ml::metrics::mean_std(accs);
        let (lm, _) = ftfi::ml::metrics::mean_std(losses);
        table.row(&[name.clone(), params.clone(), format!("{am:.3}"), format!("{astd:.3}"), format!("{lm:.4}")]);
    }
    let (dm, ds) = ftfi::ml::metrics::mean_std(&deltas);
    println!(
        "\nΔacc = {dm:+.3} ± {ds:.3} over 3 seeds (paper: +1.0–1.5% for synced masking\n         at ImageNet/ViT-B scale, +7% at ViT-L; see DESIGN.md measurement log)"
    );
    Ok(())
}
