//! Fig. 4: vertex-normal interpolation on meshes — preprocessing time and
//! cosine similarity for FTFI vs BGFI (exact graph kernel), BTFI
//! (materialised tree kernel), Bartal and FRT probabilistic trees.
//!
//! Run: `cargo bench --bench fig4_mesh`

use ftfi::bench_util::{banner, time_once, Table};
use ftfi::ftfi::brute::{f_distance_matrix_graph, BruteTreeIntegrator};
use ftfi::ftfi::functions::FDist;
use ftfi::graph::mesh::mesh_zoo;
use ftfi::graph::mst::minimum_spanning_tree;
use ftfi::linalg::matrix::{cosine_similarity, Matrix};
use ftfi::ml::rng::Pcg;
use ftfi::tree::bartal::bartal_tree;
use ftfi::tree::frt::frt_tree;
use ftfi::TreeFieldIntegrator;

fn mean_cos(pred: &Matrix, truth: &[[f64; 3]], masked: &[bool]) -> f64 {
    let mut total = 0.0;
    let mut count = 0;
    for (i, &m) in masked.iter().enumerate() {
        if m {
            total += cosine_similarity(pred.row(i), &truth[i]);
            count += 1;
        }
    }
    total / count.max(1) as f64
}

fn main() {
    banner("Fig 4: mesh interpolation — preprocessing time vs cosine similarity");
    let table = Table::new(
        &["mesh", "N", "method", "preprocess (s)", "cosine"],
        &[9, 7, 8, 14, 8],
    );
    // Grid-search λ per mesh like the paper (small grid keeps runtime sane).
    let lambdas = [1.0, 4.0, 16.0];
    for &target in &[1000usize, 3000] {
        for (name, mesh) in mesh_zoo(target, 42) {
            let n = mesh.n_vertices();
            let g = mesh.to_graph();
            let mut rng = Pcg::seed(5);
            let mut masked = vec![true; n];
            for i in rng.sample_distinct(n, n / 5) {
                masked[i] = false;
            }
            let mut field = Matrix::zeros(n, 3);
            for i in 0..n {
                if !masked[i] {
                    field.row_mut(i).copy_from_slice(&mesh.normals[i]);
                }
            }
            let best = |preds: Vec<(f64, Matrix)>| -> (f64, f64) {
                preds
                    .into_iter()
                    .map(|(t, p)| (t, mean_cos(&p, &mesh.normals, &masked)))
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap()
            };

            // FTFI on the MST (preprocessing = MST + IT build, reused per λ).
            let (tree, t_mst) = time_once(|| minimum_spanning_tree(&g));
            let (tfi, t_it) =
                time_once(|| TreeFieldIntegrator::builder(&tree).build().expect("valid tree"));
            let (_, c) = best(
                lambdas
                    .iter()
                    .map(|&l| {
                        (0.0, tfi.try_integrate(&FDist::inverse_quadratic(l), &field).expect("field"))
                    })
                    .collect(),
            );
            table.row(&[name.clone(), n.to_string(), "FTFI".into(), format!("{:.3}", t_mst + t_it), format!("{c:.4}")]);

            // BTFI: materialised tree kernel per λ (preprocess = worst λ).
            let mut t_btfi = 0.0;
            let (_, c_btfi) = best(
                lambdas
                    .iter()
                    .map(|&l| {
                        let (b, t) =
                            time_once(|| BruteTreeIntegrator::new(&tree, &FDist::inverse_quadratic(l)));
                        t_btfi += t;
                        (t, b.integrate(&field))
                    })
                    .collect(),
            );
            table.row(&[name.clone(), n.to_string(), "BTFI".into(), format!("{t_btfi:.3}"), format!("{c_btfi:.4}")]);

            // BGFI: exact graph kernel per λ.
            let mut t_bgfi = 0.0;
            let (_, c_bgfi) = best(
                lambdas
                    .iter()
                    .map(|&l| {
                        let (k, t) =
                            time_once(|| f_distance_matrix_graph(&g, &FDist::inverse_quadratic(l)));
                        t_bgfi += t;
                        (t, k.matmul(&field))
                    })
                    .collect(),
            );
            table.row(&[name.clone(), n.to_string(), "BGFI".into(), format!("{t_bgfi:.3}"), format!("{c_bgfi:.4}")]);

            // FRT + Bartal probabilistic trees (preprocess = embedding).
            let (emb, t_frt) = time_once(|| frt_tree(&g, &mut rng));
            let frt_int =
                TreeFieldIntegrator::builder(&emb.tree).build().expect("valid tree");
            let (_, c_frt) = best(
                lambdas
                    .iter()
                    .map(|&l| {
                        (0.0, emb.restrict_field(&frt_int.try_integrate(&FDist::inverse_quadratic(l), &emb.lift_field(&field)).expect("field")))
                    })
                    .collect(),
            );
            table.row(&[name.clone(), n.to_string(), "FRT".into(), format!("{t_frt:.3}"), format!("{c_frt:.4}")]);

            let (emb_b, t_bar) = time_once(|| bartal_tree(&g, &mut rng));
            let bar_int =
                TreeFieldIntegrator::builder(&emb_b.tree).build().expect("valid tree");
            let (_, c_bar) = best(
                lambdas
                    .iter()
                    .map(|&l| {
                        (0.0, emb_b.restrict_field(&bar_int.try_integrate(&FDist::inverse_quadratic(l), &emb_b.lift_field(&field)).expect("field")))
                    })
                    .collect(),
            );
            table.row(&[name, n.to_string(), "Bartal".into(), format!("{t_bar:.3}"), format!("{c_bar:.4}")]);
        }
    }
}
