//! Minimal CLI argument parsing (offline — no clap): positional
//! subcommands plus `--key value` / `--key=value` / `--flag` options.
//!
//! Conventions:
//!
//! - `--key=value` always binds `value` to `key` (the safe spelling).
//! - Known boolean flags ([`BOOL_FLAGS`]: `--verbose`, `--quiet`,
//!   `--unmasked`, `--streaming`) are value-free and never consume the
//!   next token — `serve --verbose input.txt` keeps `input.txt`
//!   positional.
//! - Any other `--flag` consumes the next token as its value unless that
//!   token starts with `--`.

use std::collections::HashMap;

/// Flags that never take a value: `--verbose input.txt` must not swallow
/// the positional. Extend via [`Args::parse_with_bool_flags`].
pub const BOOL_FLAGS: &[&str] = &["verbose", "quiet", "unmasked", "streaming"];

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]) with the
    /// default [`BOOL_FLAGS`] set.
    pub fn parse(args: impl Iterator<Item = String>) -> Args {
        Self::parse_with_bool_flags(args, BOOL_FLAGS)
    }

    /// Parse with an explicit set of value-free boolean flags.
    pub fn parse_with_bool_flags(
        args: impl Iterator<Item = String>,
        bool_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut it = args.peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value` binds unambiguously.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                // Known boolean flags never consume the next token.
                if bool_flags.contains(&key) {
                    out.options.insert(key.to_string(), "true".to_string());
                    continue;
                }
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.options.insert(key.to_string(), val);
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_options_positionals() {
        let a = parse("serve --batch-size 16 input.txt --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("batch-size", 0), 16);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get_usize("n", 42), 42);
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
        assert_eq!(a.get_str("mode", "fast"), "fast");
        assert!(!a.get_flag("quiet"));
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.command.is_none());
    }

    #[test]
    fn bool_flags_do_not_swallow_positionals() {
        // The historical footgun: `--verbose input.txt` used to bind
        // "input.txt" as the value of --verbose.
        let a = parse("serve --verbose input.txt");
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
        // `--streaming` is value-free too: the following option keeps
        // its own value.
        let s = parse("serve --streaming --requests 10");
        assert!(s.get_flag("streaming"));
        assert_eq!(s.get_usize("requests", 0), 10);
        let b = parse("train --quiet data.bin --unmasked out.bin");
        assert!(b.get_flag("quiet"));
        assert!(b.get_flag("unmasked"));
        assert_eq!(b.positional, vec!["data.bin", "out.bin"]);
    }

    #[test]
    fn equals_syntax_binds_values() {
        let a = parse("integrate --n=5000 --f=exp --lambda=0.25 file.txt");
        assert_eq!(a.get_usize("n", 0), 5000);
        assert_eq!(a.get_str("f", ""), "exp");
        assert!((a.get_f64("lambda", 0.0) - 0.25).abs() < 1e-12);
        assert_eq!(a.positional, vec!["file.txt"]);
        // `=` wins even for known boolean flags.
        let b = parse("serve --verbose=false");
        assert!(!b.get_flag("verbose"));
        // Empty value after `=` is preserved as empty.
        let c = parse("run --name= x");
        assert_eq!(c.get("name"), Some(""));
        assert_eq!(c.positional, vec!["x"]);
    }

    #[test]
    fn precision_flag_binds_a_tier_name() {
        // Both spellings reach `integrator.precision` (main.rs wires
        // the override); the flag takes a value and must not swallow a
        // following option or get mistaken for a boolean.
        let a = parse("integrate --precision f32 --n 100 file.txt");
        assert_eq!(a.get_str("precision", "f64"), "f32");
        assert_eq!(a.get_usize("n", 0), 100);
        assert_eq!(a.positional, vec!["file.txt"]);
        let b = parse("serve --precision=f32 --streaming");
        assert_eq!(b.get_str("precision", "f64"), "f32");
        assert!(b.get_flag("streaming"));
        // Absent → the f64 default tier.
        let c = parse("integrate file.txt");
        assert_eq!(c.get_str("precision", "f64"), "f64");
    }

    #[test]
    fn wire_flag_binds_a_wire_name() {
        // `--wire` selects the serving wire format (typed|legacy): it
        // takes a value, must not swallow a following option, and stays
        // out of [`BOOL_FLAGS`].
        let a = parse("serve --streaming --wire legacy --requests 10");
        assert_eq!(a.get_str("wire", "typed"), "legacy");
        assert!(a.get_flag("streaming"));
        assert_eq!(a.get_usize("requests", 0), 10);
        let b = parse("serve --streaming --wire=typed file.txt");
        assert_eq!(b.get_str("wire", "legacy"), "typed");
        assert_eq!(b.positional, vec!["file.txt"]);
        // Absent → the typed default.
        let c = parse("serve --streaming");
        assert_eq!(c.get_str("wire", "typed"), "typed");
    }

    #[test]
    fn non_bool_flags_still_consume_values() {
        let a = parse("integrate --n 100 --f exp");
        assert_eq!(a.get_usize("n", 0), 100);
        assert_eq!(a.get_str("f", ""), "exp");
        // Trailing value-less flag defaults to "true".
        let b = parse("integrate --check");
        assert!(b.get_flag("check"));
    }
}
