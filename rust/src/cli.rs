//! Minimal CLI argument parsing (offline — no clap): positional
//! subcommands plus `--key value` / `--flag` options.
//!
//! Convention: a `--flag` with no value consumes the next token unless it
//! starts with `--`, so boolean flags should either be written `--flag
//! true` or placed after all positionals.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(args: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = args.peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.options.insert(key.to_string(), val);
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_options_positionals() {
        let a = parse("serve --batch-size 16 input.txt --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("batch-size", 0), 16);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get_usize("n", 42), 42);
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
        assert_eq!(a.get_str("mode", "fast"), "fast");
        assert!(!a.get_flag("quiet"));
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.command.is_none());
    }
}
