//! Bartal probabilistic tree embeddings (Bartal 1996) — the second
//! low-distortion tree baseline of Fig. 4.
//!
//! Recursive randomized low-diameter decomposition: a cluster of diameter
//! `Δ` is carved into pieces of diameter ≤ `Δ/2` by growing balls of
//! exponentially-distributed radius around random centres; the recursion
//! tree (edge weights `Δ`) is the embedding. Like FRT it needs the full
//! distance matrix, which is why the paper's Fig. 4 shows both orders of
//! magnitude slower than FTFI's MST preprocessing.

use super::frt::TreeEmbedding;
use super::Tree;
use crate::graph::shortest_path::all_pairs;
use crate::graph::Graph;
use crate::ml::rng::Pcg;

/// Build a Bartal tree for the shortest-path metric of `g`.
pub fn bartal_tree(g: &Graph, rng: &mut Pcg) -> TreeEmbedding {
    bartal_tree_with_dists(g.n(), &all_pairs(g), rng)
}

/// [`bartal_tree`] over a precomputed dense `n×n` row-major metric — the
/// ensemble integrator samples many trees of one graph and pays the
/// `O(n²)` all-pairs preprocessing once instead of once per tree.
pub fn bartal_tree_with_dists(n: usize, d: &[f64], rng: &mut Pcg) -> TreeEmbedding {
    assert!(n >= 1);
    assert_eq!(d.len(), n * n, "distance matrix must be n×n row-major");
    if n == 1 {
        return TreeEmbedding { tree: Tree::from_edges(1, &[]), leaf_of: vec![0] };
    }
    let dist = |i: usize, j: usize| d[i * n + j];

    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    let mut leaf_of = vec![u32::MAX; n];
    let mut n_nodes: u32 = 0;

    // Iterative recursion over (cluster, parent_node, parent_diameter).
    // HST convention: the edge from a node to its child carries HALF the
    // node's own diameter, so two vertices split at a node of diameter Δ
    // end up ≥ Δ apart in the tree — the domination property.
    struct Item {
        verts: Vec<usize>,
        parent: Option<(u32, f64)>,
    }
    let mut stack = vec![Item { verts: (0..n).collect(), parent: None }];
    while let Some(Item { verts, parent }) = stack.pop() {
        let node = n_nodes;
        n_nodes += 1;
        let diam = cluster_diameter(&verts, &dist);
        if let Some((p, pdiam)) = parent {
            edges.push((p, node, (0.5 * pdiam).max(1e-9)));
        }
        if verts.len() == 1 {
            leaf_of[verts[0]] = node;
            continue;
        }
        // Ball carving: random centres, exponential radii ~ Δ/8 capped at
        // Δ/4 so child diameter ≤ Δ/2.
        let mut remaining = verts;
        let mut children: Vec<Vec<usize>> = Vec::new();
        while !remaining.is_empty() {
            let c = remaining[rng.below(remaining.len())];
            let radius = (diam / 8.0 * (1.0 + rng.exponential(1.0))).min(diam / 4.0);
            let (ball, rest): (Vec<usize>, Vec<usize>) =
                remaining.into_iter().partition(|&v| dist(c, v) <= radius);
            // Ball always contains the centre, so progress is guaranteed.
            children.push(ball);
            remaining = rest;
        }
        if children.len() == 1 {
            // Degenerate carve (everything in one ball): split the
            // farthest pair apart to guarantee termination.
            let verts = children.pop().unwrap();
            let (mut a, mut b, mut best) = (verts[0], verts[0], -1.0);
            for &u in &verts {
                for &v in &verts {
                    if dist(u, v) > best {
                        best = dist(u, v);
                        a = u;
                        b = v;
                    }
                }
            }
            let (ball, rest): (Vec<usize>, Vec<usize>) =
                verts.into_iter().partition(|&v| dist(a, v) <= dist(b, v));
            children.push(ball);
            children.push(rest);
        }
        for ch in children {
            if !ch.is_empty() {
                stack.push(Item { verts: ch, parent: Some((node, diam)) });
            }
        }
    }
    debug_assert!(leaf_of.iter().all(|&l| l != u32::MAX));
    TreeEmbedding { tree: Tree::from_edges(n_nodes as usize, &edges), leaf_of }
}

fn cluster_diameter(verts: &[usize], dist: &impl Fn(usize, usize) -> f64) -> f64 {
    let mut diam = 0.0f64;
    for (i, &u) in verts.iter().enumerate() {
        for &v in &verts[i + 1..] {
            diam = diam.max(dist(u, v));
        }
    }
    diam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn bartal_covers_all_vertices() {
        let mut rng = Pcg::seed(1);
        let g = generators::path_plus_random_edges(50, 25, &mut rng);
        let emb = bartal_tree(&g, &mut rng);
        assert_eq!(emb.leaf_of.len(), 50);
        let set: std::collections::HashSet<_> = emb.leaf_of.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn bartal_dominates() {
        // With half-parent-diameter edges the HST dominates the metric.
        let mut rng = Pcg::seed(2);
        let g = generators::path_plus_random_edges(30, 10, &mut rng);
        let d = all_pairs(&g);
        let emb = bartal_tree(&g, &mut rng);
        for i in 0..30 {
            for j in (i + 1)..30 {
                let dt = emb.distance(i, j);
                let dg = d[i * 30 + j];
                assert!(dt + 1e-9 >= dg, "({i},{j}): tree {dt} < graph {dg}");
            }
        }
    }

    #[test]
    fn distortion_finite_and_modest() {
        let mut rng = Pcg::seed(3);
        let g = generators::erdos_renyi(25, 0.2, &mut rng);
        let d = all_pairs(&g);
        let emb = bartal_tree(&g, &mut rng);
        let mut worst = 0.0f64;
        for i in 0..25 {
            for j in (i + 1)..25 {
                worst = worst.max(emb.distance(i, j) / d[i * 25 + j]);
            }
        }
        assert!(worst.is_finite());
        assert!(worst < 200.0, "worst-case distortion {worst}");
    }

    #[test]
    fn two_vertex_graph() {
        let g = Graph::from_edges(2, &[(0, 1, 3.0)]);
        let mut rng = Pcg::seed(4);
        let emb = bartal_tree(&g, &mut rng);
        assert!(emb.distance(0, 1) > 0.0);
    }
}
