//! Weighted trees and the IntegratorTree machinery (§3 of the paper).

pub mod bartal;
pub mod frt;
pub mod integrator_tree;
pub(crate) mod invariants;
pub mod separator;

use crate::graph::Graph;

/// A weighted undirected tree on vertices `0..n`. Stored as an adjacency
/// list; invariant: exactly `n-1` edges and connected (checked at build).
#[derive(Clone, Debug)]
pub struct Tree {
    n: usize,
    adj: Vec<Vec<(u32, f64)>>,
    edges: Vec<(u32, u32, f64)>,
}

impl Tree {
    /// Build from an edge list; panics unless the edges form a spanning
    /// tree of `0..n`.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> Self {
        assert_eq!(edges.len(), n.saturating_sub(1), "a tree on {n} vertices needs {} edges", n.saturating_sub(1));
        let mut adj = vec![Vec::new(); n];
        for &(u, v, w) in edges {
            assert!((u as usize) < n && (v as usize) < n);
            assert!(w > 0.0, "tree edge weights must be positive");
            adj[u as usize].push((v, w));
            adj[v as usize].push((u, w));
        }
        let t = Tree { n, adj, edges: edges.to_vec() };
        assert!(t.is_connected(), "edge list does not span the vertex set");
        t
    }

    /// A path graph 0-1-…-(n-1) with the given edge weights
    /// (`weights.len() == n-1`).
    pub fn path(weights: &[f64]) -> Self {
        let n = weights.len() + 1;
        let edges: Vec<(u32, u32, f64)> =
            weights.iter().enumerate().map(|(i, &w)| (i as u32, i as u32 + 1, w)).collect();
        Tree::from_edges(n, &edges)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> &[(u32, f64)] {
        &self.adj[v]
    }

    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    #[inline]
    pub fn edges(&self) -> &[(u32, u32, f64)] {
        &self.edges
    }

    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|&(_, _, w)| w).sum()
    }

    /// Weight of the tree edge `{u, v}`, or `None` when the vertices are
    /// not tree-adjacent (or out of range).
    pub fn edge_weight(&self, u: usize, v: usize) -> Option<f64> {
        if u >= self.n || v >= self.n {
            return None;
        }
        self.adj[u].iter().find(|&&(x, _)| x as usize == v).map(|&(_, w)| w)
    }

    /// Reassign the weight of the existing tree edge `{u, v}` (both
    /// adjacency directions and the edge list). Returns the previous
    /// weight, or `None` — leaving the tree untouched — when the edge
    /// does not exist or the new weight is not finite and positive.
    pub fn set_edge_weight(&mut self, u: usize, v: usize, w: f64) -> Option<f64> {
        if !(w.is_finite() && w > 0.0) || self.edge_weight(u, v).is_none() {
            return None;
        }
        let mut old = None;
        for &(a, b) in &[(u, v), (v, u)] {
            for e in &mut self.adj[a] {
                if e.0 as usize == b {
                    old = Some(e.1);
                    e.1 = w;
                }
            }
        }
        for e in &mut self.edges {
            if (e.0 as usize == u && e.1 as usize == v) || (e.0 as usize == v && e.1 as usize == u)
            {
                e.2 = w;
            }
        }
        old
    }

    fn is_connected(&self) -> bool {
        if self.n <= 1 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &(u, _) in &self.adj[v] {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    count += 1;
                    stack.push(u as usize);
                }
            }
        }
        count == self.n
    }

    /// Single-source distances on the tree in O(n) (DFS).
    pub fn distances_from(&self, source: usize) -> Vec<f64> {
        let mut dist = vec![f64::INFINITY; self.n];
        let mut stack = vec![source];
        dist[source] = 0.0;
        while let Some(v) = stack.pop() {
            for &(u, w) in &self.adj[v] {
                if dist[u as usize].is_infinite() {
                    dist[u as usize] = dist[v] + w;
                    stack.push(u as usize);
                }
            }
        }
        dist
    }

    /// Distance between one pair of vertices, O(n).
    pub fn distance(&self, u: usize, v: usize) -> f64 {
        self.distances_from(u)[v]
    }

    /// All-pairs tree distances as a dense row-major buffer — O(n²); this
    /// is exactly the preprocessing the brute-force BTFI baseline pays.
    pub fn all_pairs(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n * self.n];
        for s in 0..self.n {
            let d = self.distances_from(s);
            out[s * self.n..(s + 1) * self.n].copy_from_slice(&d);
        }
        out
    }

    /// View as a [`Graph`] (used by embeddings and tests).
    pub fn to_graph(&self) -> Graph {
        Graph::from_edges(self.n, &self.edges)
    }

    /// Sub-tree induced by `vertices` (must itself be connected). Returns
    /// the sub-tree with local ids `0..k` plus the local→parent id map
    /// (which is just `vertices` in order).
    pub fn induced_subtree(&self, vertices: &[u32]) -> Tree {
        let mut local = std::collections::BTreeMap::new();
        for (i, &v) in vertices.iter().enumerate() {
            local.insert(v, i as u32);
        }
        let mut edges = Vec::with_capacity(vertices.len().saturating_sub(1));
        for &v in vertices {
            for &(u, w) in &self.adj[v as usize] {
                if u > v {
                    if let (Some(&lv), Some(&lu)) = (local.get(&v), local.get(&u)) {
                        edges.push((lv, lu, w));
                    }
                }
            }
        }
        Tree::from_edges(vertices.len(), &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star() -> Tree {
        Tree::from_edges(4, &[(0, 1, 1.0), (0, 2, 2.0), (0, 3, 3.0)])
    }

    #[test]
    fn path_constructor() {
        let t = Tree::path(&[1.0, 2.0, 3.0]);
        assert_eq!(t.n(), 4);
        assert!((t.distance(0, 3) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn distances_on_star() {
        let t = star();
        let d = t.distances_from(1);
        assert_eq!(d, vec![1.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn all_pairs_matches_pointwise() {
        let t = star();
        let ap = t.all_pairs();
        for i in 0..4 {
            for j in 0..4 {
                assert!((ap[i * 4 + j] - t.distance(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn induced_subtree_preserves_weights() {
        let t = Tree::path(&[1.0, 2.0, 3.0]);
        let s = t.induced_subtree(&[1, 2, 3]);
        assert_eq!(s.n(), 3);
        assert!((s.distance(0, 2) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn edge_weight_lookup_and_reassignment() {
        let mut t = star();
        assert_eq!(t.edge_weight(0, 2), Some(2.0));
        assert_eq!(t.edge_weight(2, 0), Some(2.0));
        assert_eq!(t.edge_weight(1, 2), None); // not tree-adjacent
        assert_eq!(t.edge_weight(0, 9), None); // out of range
        assert_eq!(t.set_edge_weight(2, 0, 5.0), Some(2.0));
        assert_eq!(t.edge_weight(0, 2), Some(5.0));
        // Both the adjacency and the edge list see the new weight.
        assert!((t.distance(1, 2) - 6.0).abs() < 1e-12);
        assert!(t.edges().iter().any(|&(a, b, w)| a.min(b) == 0 && a.max(b) == 2 && w == 5.0));
        // Rejected mutations leave the tree untouched.
        assert_eq!(t.set_edge_weight(1, 2, 1.0), None);
        assert_eq!(t.set_edge_weight(0, 2, f64::NAN), None);
        assert_eq!(t.set_edge_weight(0, 2, -1.0), None);
        assert_eq!(t.edge_weight(0, 2), Some(5.0));
    }

    #[test]
    fn singleton_tree() {
        let t = Tree::from_edges(1, &[]);
        assert_eq!(t.n(), 1);
        assert_eq!(t.distances_from(0), vec![0.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_cycle() {
        // 3 edges on 3 vertices is not a tree.
        Tree::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        Tree::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)]);
    }

    #[test]
    #[should_panic]
    fn rejects_disconnected_forest() {
        Tree::from_edges(4, &[(0, 1, 1.0), (0, 1, 1.0), (2, 3, 1.0)]);
    }
}
