//! Runtime audits of the structural invariants the prepared hot paths
//! silently rely on. The fast recursion never bounds-checks its slot
//! arithmetic semantically — it trusts that the nested-dissection
//! layout produced by `assign_slots` is exactly what the module docs
//! claim. This module re-derives those claims from first principles and
//! asserts them:
//!
//! - every internal node's slot region is its children's regions,
//!   contiguous and disjoint, tiling `[0, total_slots)` exactly;
//! - `total_slots = n + #internal nodes ≤ 2n − 1`;
//! - the vertex → slot-copies CSR round-trips the slot permutation;
//! - a delta call's `dirty_prefix` is monotone with unit steps;
//! - the frozen workspace sizes dominate every plan's declared scratch
//!   demand.
//!
//! Checks run when [`enabled`] is true — debug builds (so the entire
//! existing test and property-harness suite exercises them for free)
//! and release builds with the `ftfi_invariants` cargo feature. The
//! guard is a runtime constant, so release builds without the feature
//! compile the calls out entirely. [`check_dirty_prefix`] is on the
//! zero-allocation delta hot path and therefore performs no allocation
//! on success (the hotpath pins run in debug mode with these checks
//! live).
//!
//! This module is the crate's assertion machinery, so the unchecked-
//! panic lint exempts it wholesale (see `xtask`).

use super::integrator_tree::{IntegratorTree, ItNode, Side, WorkspaceSizes};

/// Are the invariant audits active in this build/run?
#[inline]
pub(crate) fn enabled() -> bool {
    cfg!(any(debug_assertions, feature = "ftfi_invariants"))
}

/// Audit the slot layout of a freshly built [`IntegratorTree`]
/// (called at the end of construction).
pub(crate) fn check_tree(it: &IntegratorTree) {
    if it.n == 0 {
        assert_eq!(it.total_slots, 0, "an empty tree must have no slots");
        assert!(it.slot_src.is_empty() && it.root_slot.is_empty());
        return;
    }
    let internal = it.nodes.iter().filter(|n| matches!(n, ItNode::Internal { .. })).count();
    assert_eq!(
        it.total_slots,
        it.n + internal,
        "total_slots must be n + #internal nodes (one pivot copy per level)"
    );
    assert!(
        it.total_slots <= 2 * it.n - 1,
        "total_slots {} exceeds the 2n−1 bound (n = {})",
        it.total_slots,
        it.n
    );
    assert_eq!(it.slot_src.len(), it.total_slots);
    assert!(
        it.slot_src.iter().all(|&v| (v as usize) < it.n),
        "slot_src refers to an out-of-range vertex"
    );

    // Sibling regions are disjoint, contiguous, and tile the parent:
    // walk the arena re-deriving each node's region from the recorded
    // child region sizes and check they compose exactly.
    check_regions(it, 0, 0, it.total_slots);

    // The vertex → slot-copies CSR round-trips the slot permutation.
    assert_eq!(it.vert_slot_off.len(), it.n + 1);
    assert_eq!(it.vert_slot_off[0], 0);
    assert_eq!(it.vert_slot_off[it.n] as usize, it.total_slots);
    assert_eq!(it.vert_slot_items.len(), it.total_slots);
    for v in 0..it.n {
        let lo = it.vert_slot_off[v] as usize;
        let hi = it.vert_slot_off[v + 1] as usize;
        assert!(lo < hi, "vertex {v} has no slot copy");
        for &s in &it.vert_slot_items[lo..hi] {
            assert_eq!(
                it.slot_src[s as usize] as usize, v,
                "CSR lists slot {s} under vertex {v}, but the slot belongs elsewhere"
            );
        }
    }

    // root_slot is an injective section of the permutation: every
    // vertex's output slot really holds that vertex.
    assert_eq!(it.root_slot.len(), it.n);
    let mut taken = vec![false; it.total_slots];
    for (v, &slot) in it.root_slot.iter().enumerate() {
        let s = slot as usize;
        assert!(s < it.total_slots, "root_slot[{v}] out of range");
        assert_eq!(it.slot_src[s] as usize, v, "root_slot[{v}] points at another vertex's slot");
        assert!(!taken[s], "two vertices share output slot {s}");
        taken[s] = true;
    }
}

/// Recursively verify that node `idx` owns exactly `[start, start+len)`
/// in the slot layout, composed of its children's contiguous regions.
fn check_regions(it: &IntegratorTree, idx: usize, start: usize, len: usize) {
    match &it.nodes[idx] {
        ItNode::Leaf { size, .. } => {
            assert_eq!(*size, len, "leaf {idx}: region size must equal its vertex count");
        }
        ItNode::Internal {
            size,
            left_child,
            right_child,
            left,
            right,
            lslots,
            rslots,
            left_slot,
            right_slot,
        } => {
            assert_eq!(
                lslots + rslots,
                len,
                "internal {idx}: child regions must tile the node's region exactly"
            );
            assert_eq!(
                left.ids.len() + right.ids.len(),
                *size + 1,
                "internal {idx}: sides must partition the node plus one shared pivot"
            );
            // The side → slot maps land inside the correct half-regions
            // and never collide (pivot copies are per-side, so the two
            // maps are injective individually and jointly disjoint).
            assert_eq!(left_slot.len(), left.ids.len());
            assert_eq!(right_slot.len(), right.ids.len());
            let mut seen = vec![false; len];
            for &s in left_slot {
                let s = s as usize;
                assert!(s < *lslots, "internal {idx}: left slot {s} outside the left region");
                assert!(!seen[s], "internal {idx}: left slot {s} assigned twice");
                seen[s] = true;
            }
            for &s in right_slot {
                let s = s as usize;
                assert!(
                    s >= *lslots && s < len,
                    "internal {idx}: right slot {s} outside the right region"
                );
                assert!(!seen[s], "internal {idx}: right slot {s} assigned twice");
                seen[s] = true;
            }
            check_regions(it, *left_child, start, *lslots);
            check_regions(it, *right_child, start + lslots, *rslots);
        }
    }
}

/// Audit a delta call's freshly built dirty-slot prefix sums: monotone,
/// unit steps, and at least one dirty slot per (distinct) updated row.
/// Allocation-free — runs on the zero-alloc streaming hot path.
pub(crate) fn check_dirty_prefix(prefix: &[u32], updated_rows: usize) {
    assert!(!prefix.is_empty() && prefix[0] == 0, "dirty prefix must start at 0");
    for i in 1..prefix.len() {
        let step = prefix[i].wrapping_sub(prefix[i - 1]);
        assert!(step <= 1, "dirty prefix must be monotone with unit steps (slot {})", i - 1);
    }
    assert!(
        prefix[prefix.len() - 1] as usize >= updated_rows,
        "fewer dirty slots than updated rows"
    );
}

/// Audit the seam a committed edge re-plan leaves behind: every patched
/// node's freshly retabulated tables must satisfy the same local
/// invariants `make_side` / `leaf_distances` guarantee at build time
/// (sorted distinct distances anchored at the pivot's 0, a consistent
/// distance-group CSR over the side's vertices, a zero-diagonal
/// symmetric leaf matrix), and the structural skeleton a replan promises
/// not to touch — slot layout, CSR, root slots — must still pass the
/// full build-time audit.
pub(crate) fn check_replan_seam(it: &IntegratorTree, affected: &[usize]) {
    for &idx in affected {
        match &it.nodes[idx] {
            ItNode::Internal { left, right, .. } => {
                check_side(idx, left);
                check_side(idx, right);
            }
            ItNode::Leaf { size, dmat } => {
                assert_eq!(dmat.len(), size * size, "leaf {idx}: dmat shape after replan");
                for i in 0..*size {
                    assert_eq!(dmat[i * size + i], 0.0, "leaf {idx}: nonzero diagonal");
                    for j in 0..*size {
                        let d = dmat[i * size + j];
                        assert!(d.is_finite() && d >= 0.0, "leaf {idx}: bad distance {d}");
                        assert_eq!(d, dmat[j * size + i], "leaf {idx}: asymmetric distances");
                    }
                }
            }
        }
    }
    // A replan only reweights: the slot layout must survive bit-for-bit.
    check_tree(it);
}

/// The side-table half of [`check_replan_seam`]: the invariants every
/// consumer of a [`Side`] assumes.
fn check_side(idx: usize, side: &Side) {
    let k = side.ids.len();
    assert_eq!(side.id_d.len(), k, "node {idx}: id_d must cover the side");
    assert_eq!(side.group_items.len(), k, "node {idx}: groups must cover the side");
    assert_eq!(side.group_off.len(), side.d.len() + 1, "node {idx}: CSR offsets vs distances");
    assert_eq!(side.d.first().copied(), Some(0.0), "node {idx}: d[0] must be the pivot's 0");
    assert!(
        side.d.windows(2).all(|w| w[0] < w[1] && w[1].is_finite()),
        "node {idx}: distances must be finite and strictly increasing"
    );
    assert_eq!(
        side.group_off[1] - side.group_off[0],
        1,
        "node {idx}: the pivot group must be a singleton"
    );
    assert_eq!(side.group_items[0], side.pivot, "node {idx}: group 0 must hold the pivot");
    assert_eq!(side.group_off[0], 0, "node {idx}: CSR must start at 0");
    assert_eq!(*side.group_off.last().unwrap() as usize, k, "node {idx}: CSR must end at k");
    assert!(
        side.id_d.iter().all(|&t| (t as usize) < side.d.len()),
        "node {idx}: id_d points past the distance table"
    );
}

/// Audit the workspace sizes frozen at prepare time: the slabs cover
/// the slot layout, the aggregate arena covers the widest node, and the
/// cross-multiplier scratch dominates every plan's declared demand
/// (`(fft_len, cheb_rank, rat_len)` triples from `plan_scratch_demand`).
pub(crate) fn check_workspace_sizes(
    it: &IntegratorTree,
    sizes: &WorkspaceSizes,
    demands: &[(usize, usize, usize)],
) {
    assert_eq!(sizes.slab_rows, it.total_slots, "slab rows must cover the slot layout");
    assert_eq!(sizes.agg_rows, it.agg_rows_max, "aggregate rows must cover the widest node");
    for &(fft, cheb, rat) in demands {
        assert!(sizes.fft_len >= fft, "a plan demands more FFT scratch than the workspace");
        assert!(sizes.cheb_rank >= cheb, "a plan demands more Chebyshev rank than the workspace");
        assert!(sizes.rat_len >= rat, "a plan demands more rational scratch than the workspace");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::CrossPolicy;
    use crate::ftfi::functions::FDist;
    use crate::graph::generators::random_tree;
    use crate::ml::rng::Pcg;

    #[test]
    fn audits_pass_on_random_trees_and_prepare() {
        assert!(enabled(), "tests run in debug mode, so the audits must be live");
        let mut rng = Pcg::seed(11);
        for &(n, t) in &[(1usize, 2usize), (2, 2), (5, 2), (64, 4), (300, 8)] {
            let tree = random_tree(n, 0.2, 1.5, &mut rng);
            let it = IntegratorTree::with_leaf_threshold(&tree, t);
            check_tree(&it); // explicit call on top of the build-time one
            // prepare runs check_workspace_sizes internally.
            let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
            it.prepare(&f, 2, &CrossPolicy::default()).expect("prepare on a valid tree");
        }
    }

    #[test]
    fn replan_seam_audit_accepts_replans_and_rejects_corrupt_sides() {
        let mut rng = Pcg::seed(12);
        let tree = random_tree(80, 0.2, 1.5, &mut rng);
        let mut it = IntegratorTree::with_leaf_threshold(&tree, 4);
        let (u, v, w) = tree.edges()[7];
        // The commit path runs the seam audit itself in debug builds;
        // on top of that, the post-replan tree must pass the audit over
        // EVERY node — replans may not disturb untouched ones either.
        it.replan_edge(u as usize, v as usize, w * 3.0).expect("valid replan");
        let all: Vec<usize> = (0..it.nodes.len()).collect();
        check_replan_seam(&it, &all);
        // A corrupted side (pivot distance knocked off 0) must trip it.
        for node in &mut it.nodes {
            if let ItNode::Internal { left, .. } = node {
                left.d[0] = 0.5;
                break;
            }
        }
        let corrupt = std::panic::catch_unwind(|| check_replan_seam(&it, &all));
        assert!(corrupt.is_err(), "a non-anchored side must fail the seam audit");
    }

    #[test]
    fn dirty_prefix_audit_accepts_valid_and_rejects_corrupt() {
        check_dirty_prefix(&[0, 0, 1, 1, 2], 2);
        let corrupt = std::panic::catch_unwind(|| check_dirty_prefix(&[0, 2, 2], 1));
        assert!(corrupt.is_err(), "a non-unit step must fail the audit");
        let backwards = std::panic::catch_unwind(|| check_dirty_prefix(&[0, 1, 0], 1));
        assert!(backwards.is_err(), "a decreasing prefix must fail the audit");
    }
}
