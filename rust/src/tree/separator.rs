//! Balanced tree separators (Lemma 3.1 / Appendix A.1).
//!
//! Every tree `K` with `|K| ≥ 6` admits a decomposition
//! `(K_left, K_right, p)` with `K_left ∩ K_right = {p}` and
//! `|K_x| ≥ |K|/4` on both sides, computable in linear time. The
//! construction: find a 1/2-balanced separator vertex `p` (a centroid —
//! every component of `K − p` has ≤ |K|/2 vertices), then greedily group
//! the components of `K − p` into two sides.
//!
//! This module operates on a *subset* of a larger tree's vertices (the
//! divide-and-conquer of the IntegratorTree recurses on vertex subsets)
//! using an epoch-stamped membership array to avoid re-allocating
//! hash sets at every level.

use super::Tree;

/// Result of splitting a vertex subset of a tree around a pivot.
#[derive(Debug)]
pub struct Split {
    /// The pivot vertex `p` (global id). Present in both sides.
    pub pivot: u32,
    /// Vertices of the left side, pivot included (global ids).
    pub left: Vec<u32>,
    /// Vertices of the right side, pivot included (global ids).
    pub right: Vec<u32>,
}

/// Scratch space reused across recursive calls: `stamp[v] == epoch` marks
/// membership of `v` in the current subset.
pub struct SeparatorScratch {
    stamp: Vec<u32>,
    epoch: u32,
    subtree_size: Vec<u32>,
    order: Vec<u32>,
    parent: Vec<u32>,
}

impl SeparatorScratch {
    pub fn new(n: usize) -> Self {
        SeparatorScratch {
            stamp: vec![0; n],
            epoch: 0,
            subtree_size: vec![0; n],
            order: Vec::with_capacity(n),
            parent: vec![u32::MAX; n],
        }
    }

    fn mark(&mut self, verts: &[u32]) {
        self.epoch += 1;
        for &v in verts {
            self.stamp[v as usize] = self.epoch;
        }
    }

    #[inline]
    fn contains(&self, v: u32) -> bool {
        self.stamp[v as usize] == self.epoch
    }
}

/// Find a centroid of the sub-tree induced by `verts` (which must induce
/// a connected sub-tree of `tree`): a vertex whose removal leaves
/// components of size ≤ |verts|/2. Linear time.
pub fn centroid(tree: &Tree, verts: &[u32], scratch: &mut SeparatorScratch) -> u32 {
    let k = verts.len();
    assert!(k >= 1);
    scratch.mark(verts);
    // Iterative DFS from verts[0] restricted to the subset, recording a
    // post-order so subtree sizes can be accumulated bottom-up.
    let root = verts[0];
    scratch.order.clear();
    scratch.parent[root as usize] = u32::MAX;
    let mut stack = vec![root];
    // Use subtree_size==0 as "unvisited" marker within this call.
    for &v in verts {
        scratch.subtree_size[v as usize] = 0;
    }
    scratch.subtree_size[root as usize] = 1;
    while let Some(v) = stack.pop() {
        scratch.order.push(v);
        for &(u, _) in tree.neighbors(v as usize) {
            if scratch.contains(u) && scratch.subtree_size[u as usize] == 0 {
                scratch.subtree_size[u as usize] = 1;
                scratch.parent[u as usize] = v;
                stack.push(u);
            }
        }
    }
    debug_assert_eq!(scratch.order.len(), k, "vertex subset is not connected in the tree");
    // Accumulate sizes bottom-up (reverse DFS order).
    for i in (1..scratch.order.len()).rev() {
        let v = scratch.order[i];
        let p = scratch.parent[v as usize];
        scratch.subtree_size[p as usize] += scratch.subtree_size[v as usize];
    }
    // Walk down from the root towards the heaviest child until balanced.
    let half = k / 2;
    let mut v = root;
    loop {
        let mut heavy: Option<u32> = None;
        for &(u, _) in tree.neighbors(v as usize) {
            if scratch.contains(u)
                && scratch.parent[v as usize] != u
                && scratch.subtree_size[u as usize] > half as u32
            {
                heavy = Some(u);
                break;
            }
        }
        match heavy {
            Some(u) => {
                // Re-root: v's side becomes k - size(u).
                scratch.subtree_size[v as usize] =
                    k as u32 - scratch.subtree_size[u as usize];
                scratch.parent[v as usize] = u;
                scratch.parent[u as usize] = u32::MAX;
                v = u;
            }
            None => return v,
        }
    }
}

/// Split the sub-tree induced by `verts` around its centroid into two
/// sides, each of size ≥ |verts|/4 + 1 (pivot included on both sides).
/// Requires `verts.len() >= 3`; the Lemma 3.1 guarantee needs ≥ 6 but the
/// greedy grouping below degrades gracefully for 3–5.
pub fn split(tree: &Tree, verts: &[u32], scratch: &mut SeparatorScratch) -> Split {
    let k = verts.len();
    assert!(k >= 3, "split needs at least 3 vertices, got {k}");
    let p = centroid(tree, verts, scratch);

    // Collect the components of (subset − p): one per neighbour of p in
    // the subset. Flood fill each, reusing the epoch marks from centroid()
    // (still valid — same subset).
    let mut components: Vec<Vec<u32>> = Vec::new();
    scratch.epoch += 1; // new epoch for "assigned to a component"
    let assigned_epoch = scratch.epoch;
    // contains() must still answer membership: re-mark with a trick — we
    // re-mark membership as epoch, and use a separate visited set via the
    // subtree_size buffer (0 = unvisited within this call).
    for &v in verts {
        scratch.stamp[v as usize] = assigned_epoch;
        scratch.subtree_size[v as usize] = 0;
    }
    scratch.subtree_size[p as usize] = 1;
    for &(start, _) in tree.neighbors(p as usize) {
        if scratch.stamp[start as usize] != assigned_epoch
            || scratch.subtree_size[start as usize] != 0
        {
            continue;
        }
        let mut comp = Vec::new();
        let mut stack = vec![start];
        scratch.subtree_size[start as usize] = 1;
        while let Some(v) = stack.pop() {
            comp.push(v);
            for &(u, _) in tree.neighbors(v as usize) {
                if scratch.stamp[u as usize] == assigned_epoch
                    && scratch.subtree_size[u as usize] == 0
                {
                    scratch.subtree_size[u as usize] = 1;
                    stack.push(u);
                }
            }
        }
        components.push(comp);
    }
    // Largest-first greedy: put components into the lighter side. This
    // meets the ≥ k/4 bound whenever the centroid bound (≤ k/2 per
    // component) holds, and is usually much more balanced than the
    // paper's prefix rule.
    components.sort_by_key(|c| std::cmp::Reverse(c.len()));
    let mut left: Vec<u32> = vec![p];
    let mut right: Vec<u32> = vec![p];
    let mut lsize = 0usize;
    let mut rsize = 0usize;
    for comp in components {
        if lsize <= rsize {
            lsize += comp.len();
            left.extend(comp);
        } else {
            rsize += comp.len();
            right.extend(comp);
        }
    }
    Split { pivot: p, left, right }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_tree;
    use crate::ml::rng::Pcg;

    fn check_split(tree: &Tree, verts: &[u32], s: &Split) {
        let k = verts.len();
        // Pivot in both, sizes sum to k + 1 (pivot double-counted).
        assert!(s.left.contains(&s.pivot));
        assert!(s.right.contains(&s.pivot));
        assert_eq!(s.left.len() + s.right.len(), k + 1);
        // Lemma 3.1 balance (holds for k >= 6 with a true centroid).
        if k >= 6 {
            assert!(s.left.len() * 4 >= k, "left {} of {k}", s.left.len());
            assert!(s.right.len() * 4 >= k, "right {} of {k}", s.right.len());
        }
        // Disjoint apart from pivot.
        let sl: std::collections::HashSet<_> = s.left.iter().collect();
        let sr: std::collections::HashSet<_> = s.right.iter().collect();
        let inter: Vec<_> = sl.intersection(&sr).collect();
        assert_eq!(inter.len(), 1);
    }

    #[test]
    fn split_path() {
        let t = Tree::path(&vec![1.0; 9]);
        let verts: Vec<u32> = (0..10).collect();
        let mut scratch = SeparatorScratch::new(10);
        let s = split(&t, &verts, &mut scratch);
        check_split(&t, &verts, &s);
    }

    #[test]
    fn split_star() {
        // Star: centroid must be the hub; components are single leaves.
        let edges: Vec<(u32, u32, f64)> = (1..9).map(|v| (0, v, 1.0)).collect();
        let t = Tree::from_edges(9, &edges);
        let verts: Vec<u32> = (0..9).collect();
        let mut scratch = SeparatorScratch::new(9);
        let s = split(&t, &verts, &mut scratch);
        assert_eq!(s.pivot, 0);
        check_split(&t, &verts, &s);
    }

    #[test]
    fn split_random_trees_many_sizes() {
        let mut rng = Pcg::seed(42);
        for &n in &[6usize, 7, 10, 33, 100, 501, 2000] {
            let t = random_tree(n, 0.1, 1.0, &mut rng);
            let verts: Vec<u32> = (0..n as u32).collect();
            let mut scratch = SeparatorScratch::new(n);
            let s = split(&t, &verts, &mut scratch);
            check_split(&t, &verts, &s);
        }
    }

    #[test]
    fn split_on_subset() {
        // Take a sub-path of a longer path and split only that subset.
        let t = Tree::path(&vec![1.0; 19]);
        let verts: Vec<u32> = (5..15).collect();
        let mut scratch = SeparatorScratch::new(20);
        let s = split(&t, &verts, &mut scratch);
        check_split(&t, &verts, &s);
        for v in s.left.iter().chain(&s.right) {
            assert!((5..15).contains(v));
        }
    }

    #[test]
    fn centroid_of_path_is_middle() {
        let t = Tree::path(&vec![1.0; 10]); // 11 vertices
        let verts: Vec<u32> = (0..11).collect();
        let mut scratch = SeparatorScratch::new(11);
        let c = centroid(&t, &verts, &mut scratch);
        assert_eq!(c, 5);
    }

    #[test]
    fn centroid_components_bounded() {
        let mut rng = Pcg::seed(3);
        for &n in &[10usize, 50, 333] {
            let t = random_tree(n, 0.5, 1.5, &mut rng);
            let verts: Vec<u32> = (0..n as u32).collect();
            let mut scratch = SeparatorScratch::new(n);
            let c = centroid(&t, &verts, &mut scratch);
            // Check: every component of T - c has size <= n/2 via BFS.
            let mut seen = vec![false; n];
            seen[c as usize] = true;
            for &(start, _) in t.neighbors(c as usize) {
                if seen[start as usize] {
                    continue;
                }
                let mut size = 0;
                let mut stack = vec![start];
                seen[start as usize] = true;
                while let Some(v) = stack.pop() {
                    size += 1;
                    for &(u, _) in t.neighbors(v as usize) {
                        if !seen[u as usize] {
                            seen[u as usize] = true;
                            stack.push(u);
                        }
                    }
                }
                assert!(size * 2 <= n, "component {size} of {n}");
            }
        }
    }
}
