//! The IntegratorTree (IT) data structure — §3.1/§3.2 of the paper.
//!
//! An IT is a rooted binary decomposition of an input tree `T` built with
//! balanced separators (Lemma 3.1): each internal node covers a connected
//! vertex subset `S`, stores a pivot `p` and two children covering
//! `S_left`/`S_right` with `S_left ∩ S_right = {p}` and `|S_x| ≥ |S|/4`.
//! It is built **once per tree** and reused for any number of tensor
//! fields and any `f` (leaves store *raw* distances; `f` is applied at
//! integration time — this is what makes the learnable-`f` training of
//! §4.3 cheap, since the coefficients change every step but the IT does
//! not).
//!
//! On top of the structure, [`IntegratorTree::prepare`] freezes a
//! specific `f` into a [`PreparedPlans`] handle: one cross-term [`Plan`]
//! per internal-node direction plus the `f`-evaluated leaf matrices and
//! pivot-distance coefficient tables. Repeated integrations with the
//! same `f` then skip all planning (Chebyshev probe loops, lattice
//! detection, FFT table construction) — the repeated-integration pattern
//! of the serving coordinator and of the GW/Sinkhorn inner loops.
//!
//! On top of the prepared path, [`IntegratorTree::integrate_delta_prepared`]
//! serves the streaming scenario: integration is linear in the field,
//! so a k-row update needs only the sparse twin of the workspace
//! recursion over the O(k log n) nodes whose slot regions contain a
//! changed row (dirty-slot prefix sums over the nested-dissection
//! layout), in O(k·polylog(n)·d + n·d).
//!
//! Per internal node, the paper's eight fields materialise as:
//! `left_ids` / `right_ids` (child-local → node-local id maps),
//! `left_d` / `right_d` (sorted distinct pivot distances),
//! `left_id_d` / `right_id_d` (vertex → distance index), and
//! `left groups` / `right groups` (CSR: distance index → vertices),
//! with `*_d[0] = 0` always being the pivot's own singleton group.

use super::invariants;
use super::separator::{split, SeparatorScratch};
use super::Tree;
use crate::ftfi::cordial::{
    apply_plan, apply_plan_into, plan_scratch_demand, try_make_plan, CrossPolicy, CrossScratch,
    Plan,
};
use crate::ftfi::error::FtfiError;
use crate::ftfi::functions::FDist;
use crate::linalg::lanes::{self, Precision};
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::ArenaPool;
// The id counter is a process-lifetime static, so it stays on the std
// atomics (loom's constructors are not `const` and panic outside a
// model); everything else synchronizes through `crate::sync`.
use std::sync::atomic::{AtomicU64, Ordering as StdOrdering};

/// Internal nodes at least this large fork their left/right subtree
/// integrations onto the work pool (Lemma 3.1 guarantees both children
/// hold ≥ ¼ of the node, so a fork always splits real work). Below the
/// cutoff the per-fork thread-spawn cost would dominate the subtree
/// work. The reduction order is unchanged by forking — see the
/// bit-identical determinism contract in `runtime/pool.rs`.
const PAR_FORK_MIN_SIZE: usize = 512;

/// Monotonic id source: every built IntegratorTree gets a unique id so
/// [`PreparedPlans`] can be pinned to the exact instance they were built
/// for (vertex/node counts alone cannot distinguish same-shape trees).
static IT_IDS: AtomicU64 = AtomicU64::new(1);

/// One side (left or right) of an internal IT node.
#[derive(Debug)]
pub struct Side {
    /// Child-local index → node-local index.
    pub ids: Vec<u32>,
    /// Sorted distinct distances from the pivot; `d[0] == 0.0` (pivot).
    pub d: Vec<f64>,
    /// Child-local vertex → index into `d`.
    pub id_d: Vec<u32>,
    /// CSR offsets into `group_items`, one group per distance.
    pub group_off: Vec<u32>,
    /// Child-local vertex ids grouped by distance index.
    pub group_items: Vec<u32>,
    /// Child-local index of the pivot.
    pub pivot: u32,
}

/// IT node: leaf (small sub-tree, dense distance matrix) or internal.
#[derive(Debug)]
pub enum ItNode {
    Leaf {
        /// Number of vertices.
        size: usize,
        /// Raw (not f-transformed) `size×size` distance matrix.
        dmat: Vec<f64>,
    },
    Internal {
        size: usize,
        left_child: usize,
        right_child: usize,
        left: Side,
        right: Side,
        /// Slot-region size of the left child in the nested-dissection
        /// layout (see [`IntegratorTree::assign_slots`]). The node's own
        /// region is `[left region][right region]`, so the recursion
        /// forks with one `split_at_mut` instead of a gather/scatter.
        lslots: usize,
        /// Slot-region size of the right child.
        rslots: usize,
        /// Child-local left vertex → slot offset within this node's
        /// region (all `< lslots`).
        left_slot: Vec<u32>,
        /// Child-local right vertex → slot offset within this node's
        /// region (all `≥ lslots` — the right region follows the left).
        right_slot: Vec<u32>,
    },
}

/// The IntegratorTree: an arena of [`ItNode`]s, root at index 0.
/// (Structural fields are `pub(crate)` so [`super::invariants`] can
/// audit the slot layout without going through accessors.)
pub struct IntegratorTree {
    pub(crate) nodes: Vec<ItNode>,
    pub(crate) n: usize,
    leaf_threshold: usize,
    /// The underlying weighted tree (cloned at build). Kept so
    /// [`IntegratorTree::replan_edge`] can retabulate pivot distances
    /// after an edge-weight mutation without a caller-held tree handle.
    tree: Tree,
    /// Unique instance id (see [`IT_IDS`]).
    id: u64,
    /// Bumped once per committed edge re-plan. [`PreparedPlans`]
    /// snapshot it at prepare/replan time; a mismatch means a handle's
    /// tables predate a mutation, and every prepared integrate entry
    /// point refuses the handle with a typed error.
    replan_epoch: u64,
    /// IT nodes visited by replan walks over this tree's lifetime
    /// (**lifetime aggregate** — compare deltas, not absolutes). A
    /// single edge re-plan visits only the O(log n) root-to-leaf path
    /// whose side regions contain the edge.
    replan_nodes_visited: usize,
    /// Cross-term plans rebuilt by prepared replans over this tree's
    /// lifetime (2 per affected internal node per replan; lifetime
    /// aggregate like `replan_nodes_visited`).
    replan_plan_rebuilds: usize,
    /// Cross-term plans built over this IT's lifetime (both by the
    /// re-planning `integrate` path — 2 per internal node per call — and
    /// once by `prepare`). Exposed through [`ItStats::plan_builds`]; the
    /// prepared-path regression test pins it.
    plan_builds: AtomicUsize,
    /// Nested-dissection layout: slot → original vertex. Each internal
    /// node duplicates its pivot into both child regions, so
    /// `total_slots = n + #internal nodes` and every node's vertex set
    /// is one contiguous slot range. The prepared hot path permutes the
    /// field into this layout once per call and recurses on disjoint
    /// sub-slices.
    pub(crate) slot_src: Vec<u32>,
    /// Original vertex → its output slot in the root region (pivots
    /// resolve to their *left* copy — the side that produces their
    /// output row).
    pub(crate) root_slot: Vec<u32>,
    /// `slot_src.len()` (cached).
    pub(crate) total_slots: usize,
    /// max over internal nodes of `2·(left.d.len() + right.d.len())` —
    /// the row capacity of the per-task aggregate bump arena (only one
    /// node's aggregates are ever live per task: children finish before
    /// a node's combine step allocates).
    pub(crate) agg_rows_max: usize,
    /// CSR offsets of the inverse slot map: vertex `v`'s slot copies are
    /// `vert_slot_items[vert_slot_off[v]..vert_slot_off[v+1]]` (pivots
    /// have one copy per level they pivot at). The delta path uses this
    /// to mark exactly the dirty slots of a sparse field update.
    pub(crate) vert_slot_off: Vec<u32>,
    /// CSR items of the inverse slot map (see [`Self::vert_slot_off`]).
    pub(crate) vert_slot_items: Vec<u32>,
    /// IT nodes actually processed (not skipped as clean) by the sparse
    /// delta passes over this tree's lifetime. Exposed through
    /// [`ItStats::delta_nodes_visited`]; the sparsity tests pin that a
    /// k = 1 update visits far fewer nodes than a full integration.
    delta_nodes_visited: AtomicUsize,
}

/// Summary statistics (used by the perf log and the ablation benches).
#[derive(Debug, Clone, Default)]
pub struct ItStats {
    pub nodes: usize,
    pub leaves: usize,
    pub depth: usize,
    pub max_leaf_size: usize,
    pub total_distinct_distances: usize,
    pub max_distinct_distances: usize,
    /// Total cross-term plans built so far (see
    /// [`IntegratorTree::prepare`] — a prepared handle freezes this).
    pub plan_builds: usize,
    /// Two-way recursion forks that actually ran on two threads. Zero
    /// for the bare `IntegratorTree` (which has no pool of its own);
    /// populated by `TreeFieldIntegrator::stats` from its work pool.
    /// **Pool-scoped**: lifetime aggregate of that pool — on a shared
    /// pool this includes every sharer's activity, so compare deltas,
    /// not absolutes.
    pub par_forks: usize,
    /// Parallel-map tasks (plan preparations, batch fields, serving
    /// requests) executed on helper threads. Populated (and pool-scoped)
    /// like `par_forks`.
    pub par_tasks: usize,
    /// Structural workspace footprint of the prepared hot path at d = 1,
    /// in bytes: the two nested-dissection slabs (`2·total_slots` rows)
    /// plus the aggregate bump arena (`agg_rows_max` rows). The
    /// plan-dependent cross-multiplier scratch (FFT buffer, Chebyshev
    /// aggregation) is on top — `PreparedPlans::workspace_bytes` reports
    /// the full per-workspace figure for a given channel width.
    pub workspace_bytes: usize,
    /// IT nodes actually processed (not skipped as clean) by sparse
    /// delta integrations (`integrate_delta_prepared*`). **Lifetime
    /// aggregate** of the tree instance — compare deltas, not absolutes.
    /// A k-row update visits only the O(k log n) nodes whose slot
    /// regions contain a changed row.
    pub delta_nodes_visited: usize,
    /// Full bit-exact re-integrations triggered by a
    /// [`crate::ftfi::streaming::StreamingIntegrator`]'s drift policy.
    /// Zero at the bare-tree level (trees do not refresh); populated by
    /// `StreamingIntegrator::stats` from its session counter.
    pub delta_refreshes: usize,
    /// IT nodes visited by [`IntegratorTree::replan_edge`] walks.
    /// **Lifetime aggregate** — compare deltas, not absolutes. The
    /// replan harness pins a single replan's delta at O(log n).
    pub replan_nodes_visited: usize,
    /// Cross-term plans rebuilt by [`PreparedPlans::replan_edge`]
    /// (lifetime aggregate, 2 per affected internal node per replan).
    pub replan_plan_rebuilds: usize,
}

/// What one [`IntegratorTree::replan_edge`] /
/// [`PreparedPlans::replan_edge`] call actually did. `Default` is the
/// no-op result (weight already current: nothing visited, nothing
/// rebuilt, `changed == false`, no epoch bump).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplanStats {
    /// False iff the new weight equalled the current one (a no-op).
    pub changed: bool,
    /// IT nodes on the walked root-to-leaf invalidation path.
    pub nodes_visited: usize,
    /// Side tables (pivot distances + groups) recomputed.
    pub sides_rebuilt: usize,
    /// Leaf distance matrices recomputed.
    pub leaves_rebuilt: usize,
    /// Cross-term plans rebuilt (0 for the raw tree-level replan; 2 per
    /// affected internal node for the prepared replan).
    pub plan_rebuilds: usize,
}

/// Staged (not yet applied) side/leaf retabulation for one edge
/// re-plan: everything fallible happens against this buffer, the commit
/// that installs it is infallible — so a rejected or failing replan
/// leaves the tree and any plan handle bit-for-bit untouched.
struct ReplanPatch {
    /// The tree with the new edge weight already applied.
    new_tree: Tree,
    nodes_visited: usize,
    /// `(node index, is_left, recomputed side)` for every internal node
    /// on the invalidation path.
    sides: Vec<(usize, bool, Side)>,
    /// `(node index, recomputed dmat)` for the terminal leaf.
    leaves: Vec<(usize, Vec<f64>)>,
}

/// Everything `f`-dependent, frozen at prepare time: per-internal-node
/// cross plans for both directions, `f`-transformed leaf matrices, and
/// the `f(d)` coefficient tables used in the recombination step. Built
/// by [`IntegratorTree::prepare`] / consumed by
/// [`IntegratorTree::integrate_prepared`].
enum PreparedNode {
    Leaf {
        /// `f`-transformed dense leaf matrix.
        fmat: Vec<f64>,
    },
    Internal {
        /// Plan for the cross product into the left side (xs = left.d).
        into_left: Plan,
        /// Plan for the cross product into the right side (xs = right.d).
        into_right: Plan,
        /// `f(left.d[i])` lookup table.
        left_fd: Vec<f64>,
        /// `f(right.d[i])` lookup table.
        right_fd: Vec<f64>,
    },
}

/// Workspace arena sizes for one `(tree, f)` pair, frozen at prepare
/// time: the slab row count comes from the tree's slot layout, the
/// aggregate rows from its side tables, the FFT length / Chebyshev rank
/// from the maxima over the built plans.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkspaceSizes {
    /// Rows of each field slab (`total_slots` of the tree).
    pub(crate) slab_rows: usize,
    /// Rows of the per-task aggregate bump arena.
    pub(crate) agg_rows: usize,
    /// Complex FFT scratch length (max lattice-plan transform size).
    pub(crate) fft_len: usize,
    /// Chebyshev aggregation rank (max expansion rank).
    pub(crate) cheb_rank: usize,
    /// Rational/Cauchy numerator-coefficient scratch length (max
    /// prepared basis degree + 1 over the rational plans).
    pub(crate) rat_len: usize,
    /// Compute tier every kernel of this plan set runs at. `F64` (the
    /// default) is bit-identical to the pre-lane kernels; `F32` is the
    /// opt-in serving tier (f32 products, f64 accumulation) — see
    /// `linalg/lanes.rs`. Frozen at prepare time so one plan handle
    /// can never mix tiers across calls.
    pub(crate) precision: Precision,
}

impl WorkspaceSizes {
    /// Element-wise maximum with another size vector (the plan cache
    /// prewarms every entry's pools at the cache-wide maxima, so a
    /// session migrating between cached graphs re-warms nothing).
    /// Precision is not a size and must agree; callers keep cache
    /// entries tier-homogeneous.
    pub fn max_with(&self, other: &WorkspaceSizes) -> WorkspaceSizes {
        WorkspaceSizes {
            slab_rows: self.slab_rows.max(other.slab_rows),
            agg_rows: self.agg_rows.max(other.agg_rows),
            fft_len: self.fft_len.max(other.fft_len),
            cheb_rank: self.cheb_rank.max(other.cheb_rank),
            rat_len: self.rat_len.max(other.rat_len),
            precision: self.precision,
        }
    }
}

/// Per-task scratch: the aggregate bump arena (one internal node's
/// `xl_agg`/`xr_agg`/`cr`/`cl` rows — only one node's aggregates are
/// live per task at any time) plus the cross-multiplier scratch.
struct NodeScratch {
    agg: Vec<f64>,
    cross: CrossScratch,
}

impl NodeScratch {
    fn new() -> Self {
        NodeScratch { agg: Vec::new(), cross: CrossScratch::new() }
    }

    /// Grow (never shrink) to the steady-state sizes: a no-op once
    /// warmed, which is what makes checkout allocation-free.
    fn ensure(&mut self, sizes: &WorkspaceSizes, d: usize) {
        if self.agg.len() < sizes.agg_rows * d {
            self.agg.resize(sizes.agg_rows * d, 0.0);
        }
        self.cross.ensure(sizes.fft_len, sizes.cheb_rank, sizes.rat_len, d);
    }
}

/// One checked-out-per-call workspace: the two nested-dissection field
/// slabs (permuted input, slot-shaped output) plus the calling task's
/// scratch. Recursion forks borrow disjoint slab sub-slices and check
/// out additional [`NodeScratch`] from the plan's fork pool.
struct Workspace {
    slab_in: Vec<f64>,
    slab_out: Vec<f64>,
    scratch: NodeScratch,
    /// Per-slot dirty prefix sums for the sparse delta pass
    /// (`total_slots + 1` entries): a slot range `[a, b)` contains a
    /// changed row iff `dirty_prefix[b] > dirty_prefix[a]`. Rebuilt per
    /// delta call; unused (stale) on full-field calls.
    dirty_prefix: Vec<u32>,
}

impl Workspace {
    fn new() -> Self {
        Workspace {
            slab_in: Vec::new(),
            slab_out: Vec::new(),
            scratch: NodeScratch::new(),
            dirty_prefix: Vec::new(),
        }
    }
}

/// A frozen (tree, f, policy) integration plan. Cheap to apply, immutable
/// and `f`-specific; obtain one from [`IntegratorTree::prepare`] (or the
/// higher-level `TreeFieldIntegrator::prepare`). Owns a pool of reusable
/// workspaces, so concurrent `integrate_prepared` calls (the batch /
/// serving axes) each check one out and the warmed steady state performs
/// no heap allocation.
pub struct PreparedPlans {
    f: FDist,
    policy: CrossPolicy,
    nodes: Vec<PreparedNode>,
    n: usize,
    /// Id of the IntegratorTree instance these plans were built for —
    /// plans are not portable across trees, even same-shape ones.
    tree_id: u64,
    /// The tree's `replan_epoch` these plans are synchronized with. A
    /// tree-level `replan_edge` bumps the tree's epoch without touching
    /// any handle, so stale handles are refused; the prepared
    /// [`PreparedPlans::replan_edge`] re-synchronizes this handle.
    tree_epoch: u64,
    /// Field width the plans were built for (the planning cost model's
    /// `d`); replan-time plan rebuilds reuse it.
    channels: usize,
    plans_built: usize,
    sizes: WorkspaceSizes,
    /// Per-call workspaces (stock grows to the peak call concurrency).
    workspaces: ArenaPool<Workspace>,
    /// Per-fork scratch (stock grows to the peak fork concurrency).
    fork_scratch: ArenaPool<NodeScratch>,
}

impl PreparedPlans {
    /// The function these plans were built for.
    pub fn f(&self) -> &FDist {
        &self.f
    }

    /// Number of tree vertices the plans expect.
    pub fn n(&self) -> usize {
        self.n
    }

    /// How many cross-term plans were built at prepare time (2 per
    /// internal IT node).
    pub fn plans_built(&self) -> usize {
        self.plans_built
    }

    /// The compute tier these plans were frozen at (see
    /// [`Precision`]): every integration through this handle — full,
    /// delta, pooled or not — runs its inner kernels at this tier.
    pub fn precision(&self) -> Precision {
        self.sizes.precision
    }

    /// Bytes of one fully-sized workspace for a `d`-channel field: the
    /// two slabs, the aggregate arena and the cross-multiplier scratch.
    /// Tests pin arena sizing through this (and through
    /// [`ItStats::workspace_bytes`] for the structural part).
    pub fn workspace_bytes(&self, d: usize) -> usize {
        // In/out slabs + aggregate arena + Chebyshev w/basis + the
        // separable accumulator + the rational coefficient scratch, all
        // f64; the FFT scratch is complex; the delta dirty-prefix is u32.
        let f64s = 2 * self.sizes.slab_rows * d
            + self.sizes.agg_rows * d
            + self.sizes.cheb_rank * (d + 1)
            + self.sizes.rat_len
            + d;
        f64s * std::mem::size_of::<f64>()
            + self.sizes.fft_len * 16
            + (self.sizes.slab_rows + 1) * std::mem::size_of::<u32>()
    }

    /// Field width the plans were built for (the planning cost model's
    /// `d`).
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// The frozen workspace arena sizes (the plan cache folds these
    /// with [`WorkspaceSizes::max_with`] into cache-wide maxima for
    /// pool prewarming; the allocation pins in `tests/hotpath_alloc.rs`
    /// do the same fold by hand).
    pub fn sizes(&self) -> WorkspaceSizes {
        self.sizes
    }

    /// Stock the workspace and fork-scratch pools with at least `count`
    /// idle items each, every one grown to `sizes` (element-wise at
    /// least this plan set's own sizes) for a `d`-channel field. Called
    /// by the multi-graph plan cache on insert and whenever the
    /// cache-wide maxima grow, so warmed calls — including a session's
    /// first call after migrating onto this entry — pop a fully-sized
    /// buffer and allocate nothing.
    pub fn prewarm(&self, count: usize, sizes: &WorkspaceSizes, d: usize) {
        let target = self.sizes.max_with(sizes);
        let rows = target.slab_rows * d;
        let mut held = Vec::with_capacity(count);
        for _ in 0..count {
            let mut ws = self.workspaces.checkout(Workspace::new);
            if ws.slab_in.len() < rows {
                ws.slab_in.resize(rows, 0.0);
            }
            if ws.slab_out.len() < rows {
                ws.slab_out.resize(rows, 0.0);
            }
            if ws.dirty_prefix.len() < target.slab_rows + 1 {
                ws.dirty_prefix.resize(target.slab_rows + 1, 0);
            }
            ws.scratch.ensure(&target, d);
            held.push(ws);
        }
        for ws in held {
            self.workspaces.put_back(ws);
        }
        let mut forks = Vec::with_capacity(count);
        for _ in 0..count {
            let mut s = self.fork_scratch.checkout(NodeScratch::new);
            s.ensure(&target, d);
            forks.push(s);
        }
        for s in forks {
            self.fork_scratch.put_back(s);
        }
    }

    fn checkout_workspace(&self, d: usize) -> Workspace {
        let mut ws = self.workspaces.checkout(Workspace::new);
        let rows = self.sizes.slab_rows * d;
        if ws.slab_in.len() < rows {
            ws.slab_in.resize(rows, 0.0);
        }
        if ws.slab_out.len() < rows {
            ws.slab_out.resize(rows, 0.0);
        }
        if ws.dirty_prefix.len() < self.sizes.slab_rows + 1 {
            ws.dirty_prefix.resize(self.sizes.slab_rows + 1, 0);
        }
        ws.scratch.ensure(&self.sizes, d);
        ws
    }

    fn return_workspace(&self, ws: Workspace) {
        self.workspaces.put_back(ws);
    }

    fn checkout_scratch(&self, d: usize) -> NodeScratch {
        let mut s = self.fork_scratch.checkout(NodeScratch::new);
        s.ensure(&self.sizes, d);
        s
    }

    fn return_scratch(&self, s: NodeScratch) {
        self.fork_scratch.put_back(s);
    }

    /// The prepared twin of [`IntegratorTree::replan_edge`]: re-plan the
    /// tree **and** this handle together, atomically. On top of the
    /// tree-level side/leaf retabulation it rebuilds only the affected
    /// nodes' frozen state — both cross plans (Chebyshev re-probe,
    /// lattice index maps / FFT tables, rational prefix/suffix tables),
    /// the `f(d)` coefficient tables and the terminal leaf's `f`-matrix
    /// — then re-synchronizes the handle's epoch, so integrations keep
    /// flowing with no full re-prepare. Workspace sizes only ratchet up
    /// (monotone maxima), so warmed workspaces stay valid; a first call
    /// after a growth may allocate once, after which the zero-alloc
    /// steady state holds again (pinned by `tests/hotpath_alloc.rs`).
    ///
    /// Everything fallible — input validation and every
    /// [`try_make_plan`] on the new distance tables — runs against
    /// staging buffers first; only then is the patch committed. A
    /// returned error therefore leaves both the tree and this handle
    /// bit-for-bit untouched. A handle that is already stale (the tree
    /// was re-planned behind its back) or foreign is refused.
    pub fn replan_edge(
        &mut self,
        it: &mut IntegratorTree,
        u: usize,
        v: usize,
        w: f64,
    ) -> Result<ReplanStats, FtfiError> {
        if self.tree_id != it.id {
            return Err(FtfiError::InvalidInput(
                "prepared plans were built for a different IntegratorTree".to_string(),
            ));
        }
        if self.tree_epoch != it.replan_epoch {
            return Err(FtfiError::InvalidInput(
                "prepared plans are stale: the tree was re-planned after they were built"
                    .to_string(),
            ));
        }
        let patch = match it.stage_replan(u, v, w)? {
            None => return Ok(ReplanStats::default()),
            Some(p) => p,
        };
        // Stage every affected node's prepared twin before committing
        // anything: a planning failure (e.g. a forced strategy that is
        // inapplicable to the new distance tables) must leave the tree
        // and this handle untouched.
        let mut staged: Vec<(usize, PreparedNode)> =
            Vec::with_capacity(patch.sides.len() + patch.leaves.len());
        let mut built = 0usize;
        for &(idx, is_left, ref new_side) in &patch.sides {
            let (left_d, right_d): (&[f64], &[f64]) = match &it.nodes[idx] {
                ItNode::Internal { left, right, .. } => {
                    if is_left {
                        (&new_side.d, &right.d)
                    } else {
                        (&left.d, &new_side.d)
                    }
                }
                ItNode::Leaf { .. } => unreachable!("replan staged a side for a leaf node"),
            };
            let into_left = try_make_plan(&self.f, left_d, right_d, self.channels, &self.policy)?;
            let into_right = try_make_plan(&self.f, right_d, left_d, self.channels, &self.policy)?;
            built += 2;
            staged.push((
                idx,
                PreparedNode::Internal {
                    into_left,
                    into_right,
                    left_fd: left_d.iter().map(|&t| self.f.eval(t)).collect(),
                    right_fd: right_d.iter().map(|&t| self.f.eval(t)).collect(),
                },
            ));
        }
        for &(idx, ref dmat) in &patch.leaves {
            staged.push((
                idx,
                PreparedNode::Leaf { fmat: dmat.iter().map(|&t| self.f.eval(t)).collect() },
            ));
        }
        // All fallible work done — commit tree and handle atomically.
        let mut stats = it.commit_replan(patch);
        for (idx, node) in staged {
            if let PreparedNode::Internal { into_left, into_right, .. } = &node {
                for plan in [into_left, into_right] {
                    let (fft, cheb, rat) = plan_scratch_demand(plan);
                    self.sizes.fft_len = self.sizes.fft_len.max(fft);
                    self.sizes.cheb_rank = self.sizes.cheb_rank.max(cheb);
                    self.sizes.rat_len = self.sizes.rat_len.max(rat);
                }
            }
            self.nodes[idx] = node;
        }
        self.sizes.agg_rows = self.sizes.agg_rows.max(it.agg_rows_max);
        self.tree_epoch = it.replan_epoch;
        it.plan_builds.fetch_add(built, Ordering::Relaxed);
        it.replan_plan_rebuilds += built;
        stats.plan_rebuilds = built;
        Ok(stats)
    }
}

impl IntegratorTree {
    /// Build with the default leaf threshold (32 — see the ablation bench;
    /// the paper likewise uses `t` well above the theoretical minimum 6).
    pub fn new(tree: &Tree) -> Self {
        Self::with_leaf_threshold(tree, 32)
    }

    /// Build with an explicit leaf threshold `t ≥ 2`.
    pub fn with_leaf_threshold(tree: &Tree, leaf_threshold: usize) -> Self {
        let t = leaf_threshold.max(2);
        let n = tree.n();
        let mut it = IntegratorTree {
            nodes: Vec::new(),
            n,
            leaf_threshold: t,
            tree: tree.clone(),
            id: IT_IDS.fetch_add(1, StdOrdering::Relaxed),
            replan_epoch: 0,
            replan_nodes_visited: 0,
            replan_plan_rebuilds: 0,
            plan_builds: AtomicUsize::new(0),
            slot_src: Vec::new(),
            root_slot: Vec::new(),
            total_slots: 0,
            agg_rows_max: 0,
            vert_slot_off: Vec::new(),
            vert_slot_items: Vec::new(),
            delta_nodes_visited: AtomicUsize::new(0),
        };
        let mut scratch = SeparatorScratch::new(n);
        let verts: Vec<u32> = (0..n as u32).collect();
        it.build(tree, verts, &mut scratch);
        it.assign_slots();
        if invariants::enabled() {
            invariants::check_tree(&it);
        }
        it
    }

    /// Number of vertices of the underlying tree.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Recursively build the node for `verts`; returns its arena index.
    fn build(&mut self, tree: &Tree, verts: Vec<u32>, scratch: &mut SeparatorScratch) -> usize {
        let idx = self.nodes.len();
        if verts.len() <= self.leaf_threshold || verts.len() < 3 {
            let dmat = leaf_distances(tree, &verts);
            self.nodes.push(ItNode::Leaf { size: verts.len(), dmat });
            return idx;
        }
        let s = split(tree, &verts, scratch);
        // node-local index of each global vertex. BTreeMap (not HashMap):
        // construction-side maps must never be a nondeterminism hazard,
        // even though this one is only ever looked up, never iterated.
        let mut local = std::collections::BTreeMap::new();
        for (i, &v) in verts.iter().enumerate() {
            local.insert(v, i as u32);
        }
        let left = make_side(tree, &s.left, s.pivot, &local);
        let right = make_side(tree, &s.right, s.pivot, &local);
        // Reserve the slot, then recurse.
        self.nodes.push(ItNode::Leaf { size: 0, dmat: Vec::new() }); // placeholder
        let left_child = self.build(tree, s.left, scratch);
        let right_child = self.build(tree, s.right, scratch);
        self.nodes[idx] = ItNode::Internal {
            size: verts.len(),
            left_child,
            right_child,
            left,
            right,
            // Filled by the `assign_slots` post-pass.
            lslots: 0,
            rslots: 0,
            left_slot: Vec::new(),
            right_slot: Vec::new(),
        };
        idx
    }

    /// Post-build pass: compute the nested-dissection slot layout. Every
    /// internal node's region is `[left child region][right child
    /// region]` with the pivot duplicated into both (the children share
    /// it), so child regions are disjoint *contiguous* ranges and the
    /// prepared recursion forks with `split_at_mut` instead of
    /// gather/scatter. Total slots = `n + #internal nodes ≤ 2n − 1`.
    fn assign_slots(&mut self) {
        let mut slot_src: Vec<u32> = Vec::new();
        if self.n > 0 {
            let verts: Vec<u32> = (0..self.n as u32).collect();
            // The root's node-local order is the global vertex order, so
            // its vertex→slot map is exactly the un-permute map.
            self.root_slot = self.assign_slots_rec(0, &verts, &mut slot_src);
        }
        self.total_slots = slot_src.len();
        self.slot_src = slot_src;
        let mut agg = 0usize;
        for node in &self.nodes {
            if let ItNode::Internal { left, right, .. } = node {
                agg = agg.max(2 * (left.d.len() + right.d.len()));
            }
        }
        self.agg_rows_max = agg;
        // Invert the slot map into a vertex → slot-copies CSR (counting
        // sort over `slot_src`): the delta path marks a changed vertex
        // dirty by touching exactly its slot copies.
        let mut off = vec![0u32; self.n + 1];
        for &v in &self.slot_src {
            off[v as usize + 1] += 1;
        }
        for i in 0..self.n {
            off[i + 1] += off[i];
        }
        let mut items = vec![0u32; self.slot_src.len()];
        let mut cursor: Vec<u32> = off[..self.n].to_vec();
        for (s, &v) in self.slot_src.iter().enumerate() {
            items[cursor[v as usize] as usize] = s as u32;
            cursor[v as usize] += 1;
        }
        self.vert_slot_off = off;
        self.vert_slot_items = items;
    }

    /// Assign the slot range of node `idx` (covering the global vertices
    /// `verts`, in node-local order), appending to `slot_src` in DFS
    /// order so child regions are contiguous. Returns the node's
    /// vertex→slot map (node-local index → slot offset within the
    /// node's region; the shared pivot resolves to its *left* copy —
    /// the side that produces its output row).
    fn assign_slots_rec(&mut self, idx: usize, verts: &[u32], slot_src: &mut Vec<u32>) -> Vec<u32> {
        let (left_child, right_child, left_ids, right_ids) = match &self.nodes[idx] {
            ItNode::Leaf { size, .. } => {
                debug_assert_eq!(*size, verts.len());
                slot_src.extend_from_slice(verts);
                return (0..verts.len() as u32).collect();
            }
            ItNode::Internal { left_child, right_child, left, right, .. } => {
                (*left_child, *right_child, left.ids.clone(), right.ids.clone())
            }
        };
        let left_verts: Vec<u32> = left_ids.iter().map(|&i| verts[i as usize]).collect();
        let lstart = slot_src.len();
        let lmap = self.assign_slots_rec(left_child, &left_verts, slot_src);
        let lslots = slot_src.len() - lstart;
        let right_verts: Vec<u32> = right_ids.iter().map(|&i| verts[i as usize]).collect();
        let rstart = slot_src.len();
        let rmap = self.assign_slots_rec(right_child, &right_verts, slot_src);
        let rslots = slot_src.len() - rstart;
        let right_slot: Vec<u32> = rmap.iter().map(|&s| s + lslots as u32).collect();
        let mut vmap = vec![0u32; verts.len()];
        for (i, &node_local) in right_ids.iter().enumerate() {
            vmap[node_local as usize] = right_slot[i];
        }
        // Left wins for the pivot: its output row comes from the left pass.
        for (i, &node_local) in left_ids.iter().enumerate() {
            vmap[node_local as usize] = lmap[i];
        }
        match &mut self.nodes[idx] {
            ItNode::Internal { lslots: ls, rslots: rs, left_slot, right_slot: rsl, .. } => {
                *ls = lslots;
                *rs = rslots;
                *left_slot = lmap;
                *rsl = right_slot;
            }
            ItNode::Leaf { .. } => unreachable!("leaf handled above"),
        }
        vmap
    }

    /// Fallible integration: `out[v] = Σ_u f(dist(v,u))·x[u]` for a
    /// tensor field `x` (`n×d`, rows indexed by tree vertex id). Exact
    /// (up to the floating-point accuracy of the selected cross-term
    /// multiplier). Plans every cross block on each call — use
    /// [`IntegratorTree::prepare`] to amortise planning over repeated
    /// integrations with the same `f`.
    pub fn try_integrate(
        &self,
        f: &FDist,
        x: &Matrix,
        policy: &CrossPolicy,
    ) -> Result<Matrix, FtfiError> {
        self.try_integrate_pooled(f, x, policy, &WorkPool::serial())
    }

    /// [`IntegratorTree::try_integrate`] running the recursion on a work
    /// pool: sub-tree integrations above [`PAR_FORK_MIN_SIZE`] fork onto
    /// helper threads. The per-block reduction order is identical to the
    /// serial path, so the output is bit-identical for any thread count.
    pub fn try_integrate_pooled(
        &self,
        f: &FDist,
        x: &Matrix,
        policy: &CrossPolicy,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        if x.rows() != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: x.rows() });
        }
        if self.n == 0 {
            return Ok(Matrix::zeros(0, x.cols()));
        }
        self.integrate_node(0, x, f, policy, pool)
    }

    /// Infallible [`IntegratorTree::try_integrate`] shim; panics on shape
    /// mismatch or a forced-inapplicable strategy.
    pub fn integrate(&self, f: &FDist, x: &Matrix, policy: &CrossPolicy) -> Matrix {
        self.try_integrate(f, x, policy)
            .expect("IntegratorTree::integrate failed (use try_integrate for a Result)")
    }

    /// Convenience wrapper for scalar fields.
    pub fn integrate_vec(&self, f: &FDist, x: &[f64], policy: &CrossPolicy) -> Vec<f64> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        self.integrate(f, &m, policy).into_vec()
    }

    /// Freeze `f` into a reusable [`PreparedPlans`] handle: runs
    /// [`try_make_plan`] once per internal-node direction (caching the
    /// Chebyshev expansions, lattice FFT tables, separable
    /// decompositions and rational options inside the returned plans)
    /// and `f`-transforms the leaf distance matrices. `channels` is the
    /// expected field width `d` (only used by the planning cost model —
    /// correctness does not depend on it).
    pub fn prepare(
        &self,
        f: &FDist,
        channels: usize,
        policy: &CrossPolicy,
    ) -> Result<PreparedPlans, FtfiError> {
        self.prepare_pooled(f, channels, policy, &WorkPool::serial())
    }

    /// [`IntegratorTree::prepare`] with the per-node plan construction
    /// fanned out over a work pool: the Chebyshev probe loops and FFT
    /// table builds of different internal nodes are independent, so they
    /// parallelise embarrassingly. Plans are identical to the serial
    /// path; on failure a typed error from a failing node is surfaced
    /// and the remaining per-node work is short-circuited (the serial
    /// path surfaces the first failing node in arena order).
    pub fn prepare_pooled(
        &self,
        f: &FDist,
        channels: usize,
        policy: &CrossPolicy,
        pool: &WorkPool,
    ) -> Result<PreparedPlans, FtfiError> {
        self.prepare_pooled_with(f, channels, policy, Precision::F64, pool)
    }

    /// [`IntegratorTree::prepare_pooled`] with an explicit compute tier
    /// for the resulting plans. `Precision::F64` reproduces the default
    /// path bit for bit; `Precision::F32` freezes the mixed-precision
    /// serving tier into the handle (see [`Precision`] and the ULP
    /// contract in DESIGN.md). Planning itself (probe loops, lattice
    /// detection, `f` evaluation) always runs in f64 — the tier only
    /// selects the integration kernels.
    pub fn prepare_pooled_with(
        &self,
        f: &FDist,
        channels: usize,
        policy: &CrossPolicy,
        precision: Precision,
        pool: &WorkPool,
    ) -> Result<PreparedPlans, FtfiError> {
        policy.validate()?;
        let build = |node: &ItNode| -> Result<PreparedNode, FtfiError> {
            match node {
                ItNode::Leaf { dmat, .. } => Ok(PreparedNode::Leaf {
                    fmat: dmat.iter().map(|&t| f.eval(t)).collect(),
                }),
                ItNode::Internal { left, right, .. } => {
                    let into_left = try_make_plan(f, &left.d, &right.d, channels, policy)?;
                    let into_right = try_make_plan(f, &right.d, &left.d, channels, policy)?;
                    Ok(PreparedNode::Internal {
                        into_left,
                        into_right,
                        left_fd: left.d.iter().map(|&t| f.eval(t)).collect(),
                        right_fd: right.d.iter().map(|&t| f.eval(t)).collect(),
                    })
                }
            }
        };
        let mut nodes = Vec::with_capacity(self.nodes.len());
        let mut built = 0usize;
        if pool.threads() <= 1 || self.n < PAR_MAP_MIN_N {
            // Serial path: plain short-circuiting walk in arena order.
            for node in &self.nodes {
                let node = build(node)?;
                if matches!(node, PreparedNode::Internal { .. }) {
                    built += 2;
                }
                nodes.push(node);
            }
        } else {
            // Parallel fan-out with short-circuit: the map itself cannot
            // early-return, so after the first failing node every
            // remaining task bails with the `Ok(None)` sentinel instead
            // of paying its probe loops / FFT builds. A sentinel can
            // only exist if some task stored a real `Err` at its own
            // index, so the scan below always finds a typed error.
            let failed = AtomicBool::new(false);
            let prepared = pool.map(&self.nodes, |_, node| {
                if failed.load(Ordering::Relaxed) {
                    return Ok(None);
                }
                match build(node) {
                    Ok(p) => Ok(Some(p)),
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        Err(e)
                    }
                }
            });
            let mut aborted = false;
            for slot in prepared {
                match slot? {
                    Some(node) => {
                        if matches!(node, PreparedNode::Internal { .. }) {
                            built += 2;
                        }
                        nodes.push(node);
                    }
                    None => aborted = true,
                }
            }
            if aborted {
                // Defensive: structurally unreachable (see above), but
                // the prepare surface must stay panic-free.
                return Err(FtfiError::InvalidInput(
                    "prepare aborted without a recorded node error".to_string(),
                ));
            }
        }
        self.plan_builds.fetch_add(built, Ordering::Relaxed);
        // Freeze the workspace arena sizes: slab/aggregate rows from the
        // tree structure, FFT length / Chebyshev rank from the maxima
        // over the plans just built.
        let mut sizes = WorkspaceSizes {
            slab_rows: self.total_slots,
            agg_rows: self.agg_rows_max,
            fft_len: 0,
            cheb_rank: 0,
            rat_len: 0,
            precision,
        };
        for node in &nodes {
            if let PreparedNode::Internal { into_left, into_right, .. } = node {
                for plan in [into_left, into_right] {
                    let (fft, cheb, rat) = plan_scratch_demand(plan);
                    sizes.fft_len = sizes.fft_len.max(fft);
                    sizes.cheb_rank = sizes.cheb_rank.max(cheb);
                    sizes.rat_len = sizes.rat_len.max(rat);
                }
            }
        }
        if invariants::enabled() {
            let mut demands: Vec<(usize, usize, usize)> = Vec::new();
            for node in &nodes {
                if let PreparedNode::Internal { into_left, into_right, .. } = node {
                    demands.push(plan_scratch_demand(into_left));
                    demands.push(plan_scratch_demand(into_right));
                }
            }
            invariants::check_workspace_sizes(self, &sizes, &demands);
        }
        Ok(PreparedPlans {
            f: f.clone(),
            policy: policy.clone(),
            nodes,
            n: self.n,
            tree_id: self.id,
            tree_epoch: self.replan_epoch,
            channels,
            plans_built: built,
            sizes,
            workspaces: ArenaPool::new(),
            fork_scratch: ArenaPool::new(),
        })
    }

    /// Integrate using plans frozen by [`IntegratorTree::prepare`]:
    /// no planning work happens on this path (the `plan_builds` counter
    /// does not move). Panic-free on malformed input.
    ///
    /// This is the *workspace* hot path: the field is permuted once into
    /// the nested-dissection slot layout, the recursion runs on disjoint
    /// slab sub-slices with all scratch drawn from the plan's reusable
    /// arenas, and the result is un-permuted once. A warmed call
    /// allocates only the returned matrix (use
    /// [`IntegratorTree::integrate_prepared_into`] for the
    /// zero-allocation variant). Output is bit-identical to the legacy
    /// per-node-allocation path, kept as
    /// [`IntegratorTree::integrate_prepared_legacy`].
    pub fn integrate_prepared(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.integrate_prepared_pooled(x, plans, &WorkPool::serial())
    }

    /// [`IntegratorTree::integrate_prepared`] running the recursion on a
    /// work pool (same forking and bit-identity contract as
    /// [`IntegratorTree::try_integrate_pooled`]).
    pub fn integrate_prepared_pooled(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        let mut out = Matrix::zeros(self.n, x.cols());
        self.integrate_prepared_into_pooled(x, plans, pool, &mut out)?;
        Ok(out)
    }

    /// Zero-allocation prepared integration: writes into a
    /// caller-provided `n×d` matrix. On a warmed plan handle (one prior
    /// call with the same channel width) this performs **no heap
    /// allocation** on the serial path — pinned by the counting-allocator
    /// test in `tests/hotpath_alloc.rs`.
    pub fn integrate_prepared_into(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        self.integrate_prepared_into_pooled(x, plans, &WorkPool::serial(), out)
    }

    /// [`IntegratorTree::integrate_prepared_into`] on a work pool. The
    /// parallel path is allocation-free in steady state too, once the
    /// fork-scratch stock has grown to the peak fork concurrency.
    pub fn integrate_prepared_into_pooled(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        // lint: allow(alloc-in-hot-path) — cold validation/error path,
        // never reached by a warmed steady-state call.
        if plans.tree_id != self.id {
            return Err(FtfiError::InvalidInput(
                "prepared plans were built for a different IntegratorTree".to_string(),
            ));
        }
        if plans.tree_epoch != self.replan_epoch {
            // lint: allow(alloc-in-hot-path) — cold validation/error path.
            return Err(FtfiError::InvalidInput(
                "prepared plans are stale: the tree was re-planned after they were built"
                    .to_string(),
            ));
        }
        if x.rows() != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: x.rows() });
        }
        if out.rows() != self.n || out.cols() != x.cols() {
            // lint: allow(alloc-in-hot-path) — cold validation/error path.
            return Err(FtfiError::InvalidInput(format!(
                "output buffer is {}x{}, expected {}x{}",
                out.rows(),
                out.cols(),
                self.n,
                x.cols()
            )));
        }
        if self.n == 0 {
            return Ok(());
        }
        let d = x.cols();
        let rows = self.total_slots * d;
        let mut ws = plans.checkout_workspace(d);
        {
            let Workspace { slab_in, slab_out, scratch, .. } = &mut ws;
            // Permute the field once into the nested-dissection layout:
            // every IT node then sees its vertex set as one contiguous
            // row range (pivots are duplicated into both child regions).
            for (slot, &src) in self.slot_src.iter().enumerate() {
                slab_in[slot * d..(slot + 1) * d].copy_from_slice(x.row(src as usize));
            }
            let (sin, sout) = (&slab_in[..rows], &mut slab_out[..rows]);
            self.integrate_ws(0, sin, sout, d, plans, scratch, pool);
            // Un-permute once: vertex v's output lives at its root slot.
            for (v, &slot) in self.root_slot.iter().enumerate() {
                let s = slot as usize * d;
                out.row_mut(v).copy_from_slice(&slab_out[s..s + d]);
            }
        }
        plans.return_workspace(ws);
        Ok(())
    }

    /// The pre-workspace (PR-3) prepared execution path: gathers rows
    /// and allocates fresh aggregate / cross matrices at every internal
    /// node. Kept as the bit-identity reference for the workspace path
    /// (`tests/ftfi_equivalence.rs`) and as the "old" side of the
    /// `hotpath_alloc` ablation; not used by the serving stack.
    pub fn integrate_prepared_legacy(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.integrate_prepared_legacy_pooled(x, plans, &WorkPool::serial())
    }

    /// [`IntegratorTree::integrate_prepared_legacy`] on a work pool.
    pub fn integrate_prepared_legacy_pooled(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        if plans.tree_id != self.id {
            return Err(FtfiError::InvalidInput(
                "prepared plans were built for a different IntegratorTree".to_string(),
            ));
        }
        if plans.tree_epoch != self.replan_epoch {
            return Err(FtfiError::InvalidInput(
                "prepared plans are stale: the tree was re-planned after they were built"
                    .to_string(),
            ));
        }
        if x.rows() != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: x.rows() });
        }
        if self.n == 0 {
            return Ok(Matrix::zeros(0, x.cols()));
        }
        Ok(self.integrate_prepared_node_legacy(0, x, plans, pool))
    }

    /// Sparse delta integration: the exact change of the integral under
    /// a sparse field update. Field integration is linear in the field,
    /// so for `x' = x + Δ` with `Δ` supported on `rows`,
    /// `integrate(x') = integrate(x) + integrate(Δ)` — and `Δ`'s own
    /// integral only needs the upward work (leaf multiplies, aggregates,
    /// cross-applications) of the O(k log n) IT nodes whose slot regions
    /// contain a changed row. Clean sub-trees contribute exact zeros and
    /// are skipped (their output regions are zeroed); clean-side cross
    /// terms are zero and are skipped too. Cost:
    /// O(k · polylog(n) · d + n · d) against the full path's
    /// O(n · polylog(n) · d).
    ///
    /// `rows` are the changed vertex ids (must be unique and `< n`);
    /// `dx` is the **dense** `n×d` delta field of which only the listed
    /// rows are read (the serving session stages deltas densely, and a
    /// full-rows call is then literally the full integration). Returns
    /// `Δout = integrate(Δ)`, exact up to the multiplier accuracy: with
    /// every row listed the pass skips nothing and is **bit-identical**
    /// to [`IntegratorTree::integrate_prepared`] on `dx`.
    pub fn integrate_delta_prepared(
        &self,
        rows: &[u32],
        dx: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.integrate_delta_prepared_pooled(rows, dx, plans, &WorkPool::serial())
    }

    /// [`IntegratorTree::integrate_delta_prepared`] on a work pool (same
    /// forking and bit-identity contract as the full prepared path).
    pub fn integrate_delta_prepared_pooled(
        &self,
        rows: &[u32],
        dx: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        let mut out = Matrix::zeros(self.n, dx.cols());
        self.integrate_delta_prepared_into_pooled(rows, dx, plans, pool, &mut out)?;
        Ok(out)
    }

    /// Zero-allocation sparse delta integration into a caller-provided
    /// `n×d` matrix: the streaming hot path. On a warmed plan handle a
    /// serial k = 1 update performs **no heap allocation** (pinned by
    /// `tests/hotpath_alloc.rs`).
    pub fn integrate_delta_prepared_into(
        &self,
        rows: &[u32],
        dx: &Matrix,
        plans: &PreparedPlans,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        self.integrate_delta_prepared_into_pooled(rows, dx, plans, &WorkPool::serial(), out)
    }

    /// [`IntegratorTree::integrate_delta_prepared_into`] on a work pool.
    pub fn integrate_delta_prepared_into_pooled(
        &self,
        rows: &[u32],
        dx: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        // lint: allow(alloc-in-hot-path) — cold validation/error path,
        // never reached by a warmed steady-state call.
        if plans.tree_id != self.id {
            return Err(FtfiError::InvalidInput(
                "prepared plans were built for a different IntegratorTree".to_string(),
            ));
        }
        if plans.tree_epoch != self.replan_epoch {
            // lint: allow(alloc-in-hot-path) — cold validation/error path.
            return Err(FtfiError::InvalidInput(
                "prepared plans are stale: the tree was re-planned after they were built"
                    .to_string(),
            ));
        }
        if dx.rows() != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: dx.rows() });
        }
        if out.rows() != self.n || out.cols() != dx.cols() {
            // lint: allow(alloc-in-hot-path) — cold validation/error path.
            return Err(FtfiError::InvalidInput(format!(
                "output buffer is {}x{}, expected {}x{}",
                out.rows(),
                out.cols(),
                self.n,
                dx.cols()
            )));
        }
        for &v in rows {
            if v as usize >= self.n {
                // lint: allow(alloc-in-hot-path) — cold validation/error path.
                return Err(FtfiError::InvalidInput(format!(
                    "delta row {v} out of range (n = {})",
                    self.n
                )));
            }
        }
        if self.n == 0 || dx.cols() == 0 {
            return Ok(());
        }
        let d = dx.cols();
        let total = self.total_slots;
        let slab_rows = total * d;
        let mut ws = plans.checkout_workspace(d);
        let mut duplicate = None;
        {
            let Workspace { slab_in, slab_out, scratch, dirty_prefix } = &mut ws;
            // Mark dirty slots (0/1 per slot, shifted by one so the same
            // buffer turns into prefix sums below) and stage the delta
            // rows: a clean slot keeps an exact-zero field row.
            let prefix = &mut dirty_prefix[..total + 1];
            prefix.iter_mut().for_each(|p| *p = 0);
            slab_in[..slab_rows].iter_mut().for_each(|x| *x = 0.0);
            'mark: for &v in rows {
                let v = v as usize;
                let lo = self.vert_slot_off[v] as usize;
                let hi = self.vert_slot_off[v + 1] as usize;
                for &s in &self.vert_slot_items[lo..hi] {
                    let s = s as usize;
                    if prefix[s + 1] != 0 {
                        // A slot belongs to exactly one vertex, so a
                        // re-marked slot means a duplicate update row.
                        duplicate = Some(v);
                        break 'mark;
                    }
                    prefix[s + 1] = 1;
                    slab_in[s * d..(s + 1) * d].copy_from_slice(dx.row(v));
                }
            }
            if duplicate.is_none() {
                for i in 0..total {
                    prefix[i + 1] += prefix[i];
                }
                if invariants::enabled() {
                    // Allocation-free by design: this guard runs on the
                    // debug-mode zero-alloc hot path (tests/hotpath_alloc).
                    invariants::check_dirty_prefix(prefix, rows.len());
                }
                let (sin, sout) = (&slab_in[..slab_rows], &mut slab_out[..slab_rows]);
                self.integrate_ws_delta(0, 0, sin, sout, d, plans, scratch, prefix, pool);
                for (v, &slot) in self.root_slot.iter().enumerate() {
                    let s = slot as usize * d;
                    out.row_mut(v).copy_from_slice(&slab_out[s..s + d]);
                }
            }
        }
        plans.return_workspace(ws);
        match duplicate {
            // lint: allow(alloc-in-hot-path) — cold error path (malformed input).
            Some(v) => Err(FtfiError::InvalidInput(format!(
                "duplicate delta row {v} (aggregate updates per row before integrating)"
            ))),
            None => Ok(()),
        }
    }

    fn integrate_node(
        &self,
        idx: usize,
        x: &Matrix,
        f: &FDist,
        policy: &CrossPolicy,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        match &self.nodes[idx] {
            ItNode::Leaf { size, dmat } => {
                Ok(leaf_apply(*size, x, |k| f.eval(dmat[k])))
            }
            ItNode::Internal { size, left_child, right_child, left, right, .. } => {
                let d = x.cols();
                let xl = x.gather_rows(&left.ids);
                let xr = x.gather_rows(&right.ids);
                // Inner sums within each side (pivot belongs to both, but
                // its output is taken from the left side only). The two
                // sub-tree integrations are independent; large nodes fork
                // them onto the pool, and the `(left, right)` assembly
                // order keeps the result bit-identical to serial.
                let (ol, or_) = if *size >= PAR_FORK_MIN_SIZE && pool.threads() > 1 {
                    pool.join(
                        || self.integrate_node(*left_child, &xl, f, policy, pool),
                        || self.integrate_node(*right_child, &xr, f, policy, pool),
                    )
                } else {
                    (
                        self.integrate_node(*left_child, &xl, f, policy, pool),
                        self.integrate_node(*right_child, &xr, f, policy, pool),
                    )
                };
                let (ol, or_) = (ol?, or_?);

                // Aggregated fields per distinct pivot distance (Eq. 3).
                let xr_agg = aggregate(right, &xr);
                let xl_agg = aggregate(left, &xl);

                // Cross contributions (Eq. 4): C[i][j] = f(d_i + d_j) into
                // the left side, Cᵀ (roles swapped) into the right side.
                // Plans are rebuilt on every call here — that is exactly
                // what `prepare` amortises away.
                let plan_l = try_make_plan(f, &left.d, &right.d, d, policy)?;
                let plan_r = try_make_plan(f, &right.d, &left.d, d, policy)?;
                self.plan_builds.fetch_add(2, Ordering::Relaxed);
                let cr = apply_plan(&plan_l, f, &left.d, &right.d, &xr_agg, policy);
                let cl = apply_plan(&plan_r, f, &right.d, &left.d, &xl_agg, policy);
                let left_fd: Vec<f64> = left.d.iter().map(|&t| f.eval(t)).collect();
                let right_fd: Vec<f64> = right.d.iter().map(|&t| f.eval(t)).collect();
                Ok(combine_sides(
                    *size, d, left, right, &ol, &or_, &cr, &cl, &xl_agg, &xr_agg, &left_fd,
                    &right_fd,
                ))
            }
        }
    }

    fn integrate_prepared_node_legacy(
        &self,
        idx: usize,
        x: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
    ) -> Matrix {
        match (&self.nodes[idx], &plans.nodes[idx]) {
            (ItNode::Leaf { size, .. }, PreparedNode::Leaf { fmat }) => {
                leaf_apply(*size, x, |k| fmat[k])
            }
            (
                ItNode::Internal { size, left_child, right_child, left, right, .. },
                PreparedNode::Internal { into_left, into_right, left_fd, right_fd },
            ) => {
                let d = x.cols();
                let xl = x.gather_rows(&left.ids);
                let xr = x.gather_rows(&right.ids);
                // Same fork rule and assembly order as `integrate_node`.
                let (ol, or_) = if *size >= PAR_FORK_MIN_SIZE && pool.threads() > 1 {
                    pool.join(
                        || self.integrate_prepared_node_legacy(*left_child, &xl, plans, pool),
                        || self.integrate_prepared_node_legacy(*right_child, &xr, plans, pool),
                    )
                } else {
                    (
                        self.integrate_prepared_node_legacy(*left_child, &xl, plans, pool),
                        self.integrate_prepared_node_legacy(*right_child, &xr, plans, pool),
                    )
                };
                let xr_agg = aggregate(right, &xr);
                let xl_agg = aggregate(left, &xl);
                // Cached plans: no probe loops, no lattice detection, no
                // FFT-table construction on this path.
                let cr = apply_plan(into_left, &plans.f, &left.d, &right.d, &xr_agg, &plans.policy);
                let cl = apply_plan(into_right, &plans.f, &right.d, &left.d, &xl_agg, &plans.policy);
                combine_sides(
                    *size, d, left, right, &ol, &or_, &cr, &cl, &xl_agg, &xr_agg, left_fd,
                    right_fd,
                )
            }
            _ => unreachable!("prepared plans desynced from the IntegratorTree arena"),
        }
    }

    /// The workspace recursion: `input`/`out` are this node's slot
    /// region (`node_slots × d`, row-major). Child regions are disjoint
    /// contiguous prefix/suffix slices, so the fork borrows them with
    /// one `split_at_mut`; all aggregate/cross scratch comes from
    /// `scratch`. Arithmetic (values *and* reduction order) is identical
    /// to [`IntegratorTree::integrate_prepared_node_legacy`], so outputs
    /// are bit-identical — only the memory layout changed.
    #[allow(clippy::too_many_arguments)]
    fn integrate_ws(
        &self,
        idx: usize,
        input: &[f64],
        out: &mut [f64],
        d: usize,
        plans: &PreparedPlans,
        scratch: &mut NodeScratch,
        pool: &WorkPool,
    ) {
        let prec = plans.sizes.precision;
        match (&self.nodes[idx], &plans.nodes[idx]) {
            (ItNode::Leaf { size, .. }, PreparedNode::Leaf { fmat }) => {
                leaf_apply_into(*size, d, fmat, input, out, prec);
            }
            (
                ItNode::Internal {
                    size,
                    left_child,
                    right_child,
                    left,
                    right,
                    lslots,
                    left_slot,
                    right_slot,
                    ..
                },
                PreparedNode::Internal { into_left, into_right, left_fd, right_fd },
            ) => {
                let (in_l, in_r) = input.split_at(lslots * d);
                let (out_l, out_r) = out.split_at_mut(lslots * d);
                // Same fork rule as the legacy path; the forked branch
                // checks its own task scratch out of the plan's pool
                // (slabs are shared through the disjoint sub-slices).
                if *size >= PAR_FORK_MIN_SIZE && pool.threads() > 1 {
                    pool.join(
                        || self.integrate_ws(*left_child, in_l, out_l, d, plans, scratch, pool),
                        || {
                            let mut fork = plans.checkout_scratch(d);
                            let rc = *right_child;
                            self.integrate_ws(rc, in_r, out_r, d, plans, &mut fork, pool);
                            plans.return_scratch(fork);
                        },
                    );
                } else {
                    self.integrate_ws(*left_child, in_l, out_l, d, plans, scratch, pool);
                    self.integrate_ws(*right_child, in_r, out_r, d, plans, scratch, pool);
                }
                // Aggregates and cross products live in the bump arena:
                // the children are done (their arena use is over), the
                // parent's combine has not started — only this node's
                // rows are live per task.
                let ll = left.d.len();
                let lr = right.d.len();
                let NodeScratch { agg, cross } = scratch;
                let (xl_agg, rest) = agg[..2 * (ll + lr) * d].split_at_mut(ll * d);
                let (xr_agg, rest) = rest.split_at_mut(lr * d);
                let (cr, cl) = rest.split_at_mut(ll * d);
                aggregate_into(right, right_slot, input, d, xr_agg);
                aggregate_into(left, left_slot, input, d, xl_agg);
                apply_plan_into(
                    into_left, &plans.f, &left.d, &right.d, xr_agg, d, cr, &plans.policy, cross,
                    prec,
                );
                apply_plan_into(
                    into_right, &plans.f, &right.d, &left.d, xl_agg, d, cl, &plans.policy, cross,
                    prec,
                );
                combine_sides_into(
                    d, left, right, left_slot, right_slot, out, cr, cl, xl_agg, xr_agg, left_fd,
                    right_fd, prec,
                );
            }
            _ => unreachable!("prepared plans desynced from the IntegratorTree arena"),
        }
    }

    /// The sparse-delta twin of [`IntegratorTree::integrate_ws`]:
    /// identical arithmetic and reduction order, but a node whose slot
    /// region holds no dirty slot is *skipped* (its output region is
    /// zeroed — its subtree integral of an all-zero field is exactly
    /// zero), and a clean side's aggregate / cross-application / combine
    /// half is skipped (a zero aggregate cross-applies to exact zeros).
    /// With every slot dirty no branch skips, so the pass degenerates to
    /// [`IntegratorTree::integrate_ws`] bit for bit — the harness pins
    /// `integrate_delta(full rows) == integrate(Δ)` exactly.
    ///
    /// `slot_base` is this node's offset into the global slot layout;
    /// `prefix[a..=b]` are dirty-slot prefix sums, so region `[a, b)` is
    /// clean iff `prefix[b] == prefix[a]`.
    #[allow(clippy::too_many_arguments)]
    fn integrate_ws_delta(
        &self,
        idx: usize,
        slot_base: usize,
        input: &[f64],
        out: &mut [f64],
        d: usize,
        plans: &PreparedPlans,
        scratch: &mut NodeScratch,
        prefix: &[u32],
        pool: &WorkPool,
    ) {
        let slots = out.len() / d;
        if prefix[slot_base + slots] == prefix[slot_base] {
            out.iter_mut().for_each(|o| *o = 0.0);
            return;
        }
        self.delta_nodes_visited.fetch_add(1, Ordering::Relaxed);
        let prec = plans.sizes.precision;
        match (&self.nodes[idx], &plans.nodes[idx]) {
            (ItNode::Leaf { size, .. }, PreparedNode::Leaf { fmat }) => {
                leaf_apply_into(*size, d, fmat, input, out, prec);
            }
            (
                ItNode::Internal {
                    size,
                    left_child,
                    right_child,
                    left,
                    right,
                    lslots,
                    left_slot,
                    right_slot,
                    ..
                },
                PreparedNode::Internal { into_left, into_right, left_fd, right_fd },
            ) => {
                let (in_l, in_r) = input.split_at(lslots * d);
                let (out_l, out_r) = out.split_at_mut(lslots * d);
                let lbase = slot_base;
                let rbase = slot_base + lslots;
                let left_dirty = prefix[rbase] > prefix[lbase];
                let right_dirty = prefix[slot_base + slots] > prefix[rbase];
                // Fork only when BOTH children hold real work: a clean
                // child just memsets its region, and spawning a helper
                // thread for that would cost more than the whole sparse
                // update (a k = 1 path has one dirty child per level).
                // The output is unchanged either way — the pool's
                // determinism contract makes fork vs serial bit-equal.
                if *size >= PAR_FORK_MIN_SIZE && pool.threads() > 1 && left_dirty && right_dirty {
                    pool.join(
                        || {
                            self.integrate_ws_delta(
                                *left_child, lbase, in_l, out_l, d, plans, scratch, prefix, pool,
                            )
                        },
                        || {
                            let mut fork = plans.checkout_scratch(d);
                            let rc = *right_child;
                            self.integrate_ws_delta(
                                rc, rbase, in_r, out_r, d, plans, &mut fork, prefix, pool,
                            );
                            plans.return_scratch(fork);
                        },
                    );
                } else {
                    self.integrate_ws_delta(
                        *left_child, lbase, in_l, out_l, d, plans, scratch, prefix, pool,
                    );
                    self.integrate_ws_delta(
                        *right_child, rbase, in_r, out_r, d, plans, scratch, prefix, pool,
                    );
                }
                let ll = left.d.len();
                let lr = right.d.len();
                let NodeScratch { agg, cross } = scratch;
                let (xl_agg, rest) = agg[..2 * (ll + lr) * d].split_at_mut(ll * d);
                let (xr_agg, rest) = rest.split_at_mut(lr * d);
                let (cr, cl) = rest.split_at_mut(ll * d);
                // Skipped sides leave stale arena rows behind — safe,
                // because the matching combine half is skipped too, so
                // stale aggregates / cross rows are never read. The four
                // dirty-side operations write disjoint buffers (each
                // cross-apply reads only its own side's aggregate), so
                // grouping them per side keeps every value bit-identical
                // to the full path's aggregate-aggregate-apply-apply
                // order.
                let fi = &plans.f;
                let pol = &plans.policy;
                if right_dirty {
                    aggregate_into(right, right_slot, input, d, xr_agg);
                    apply_plan_into(
                        into_left, fi, &left.d, &right.d, xr_agg, d, cr, pol, cross, prec,
                    );
                }
                if left_dirty {
                    aggregate_into(left, left_slot, input, d, xl_agg);
                    apply_plan_into(
                        into_right, fi, &right.d, &left.d, xl_agg, d, cl, pol, cross, prec,
                    );
                }
                if right_dirty {
                    combine_left_into(d, left, left_slot, out, cr, xr_agg, left_fd, prec);
                }
                if left_dirty {
                    combine_right_into(d, right, right_slot, out, cl, xl_agg, right_fd, prec);
                }
            }
            _ => unreachable!("prepared plans desynced from the IntegratorTree arena"),
        }
    }

    /// Structure statistics.
    pub fn stats(&self) -> ItStats {
        let mut st = ItStats {
            nodes: self.nodes.len(),
            plan_builds: self.plan_builds.load(Ordering::Relaxed),
            delta_nodes_visited: self.delta_nodes_visited.load(Ordering::Relaxed),
            replan_nodes_visited: self.replan_nodes_visited,
            replan_plan_rebuilds: self.replan_plan_rebuilds,
            workspace_bytes: (2 * self.total_slots + self.agg_rows_max)
                * std::mem::size_of::<f64>(),
            ..Default::default()
        };
        self.stats_rec(0, 1, &mut st);
        st
    }

    /// Total slots of the nested-dissection layout
    /// (`n + #internal nodes`).
    pub fn total_slots(&self) -> usize {
        self.total_slots
    }

    fn stats_rec(&self, idx: usize, depth: usize, st: &mut ItStats) {
        st.depth = st.depth.max(depth);
        match &self.nodes[idx] {
            ItNode::Leaf { size, .. } => {
                st.leaves += 1;
                st.max_leaf_size = st.max_leaf_size.max(*size);
            }
            ItNode::Internal { left_child, right_child, left, right, .. } => {
                st.total_distinct_distances += left.d.len() + right.d.len();
                st.max_distinct_distances =
                    st.max_distinct_distances.max(left.d.len().max(right.d.len()));
                self.stats_rec(*left_child, depth + 1, st);
                self.stats_rec(*right_child, depth + 1, st);
            }
        }
    }

    /// Re-plan a single edge-weight change **in place**: walk the
    /// separator hierarchy from the root to the leaf block containing
    /// the edge and retabulate only the affected nodes' side
    /// pivot-distance tables (or the terminal leaf's distance matrix).
    /// The separator hierarchy itself is weight-*independent* (centroids
    /// and the component grouping use only subtree sizes and adjacency
    /// order), so pivots, vertex orders and the whole slot layout /
    /// vertex→slot CSR survive unchanged — the re-planned tree is
    /// structurally identical to a from-scratch rebuild on the new
    /// weights, and distinct-distance growth only pushes the monotone
    /// `agg_rows_max` maximum (no workspace re-warm).
    ///
    /// Only one side per internal node can contain the edge (both
    /// endpoints land in the same component of `S − pivot`, or one
    /// endpoint *is* the pivot), so the walk is a single O(log n)
    /// root-to-leaf path; retabulation cost is O(n) total over the
    /// geometric side sizes, against the full rebuild's O(n log n).
    ///
    /// Setting the weight to its current value is a no-op: nothing is
    /// visited or rebuilt and the replan epoch does not move. Any
    /// committed change bumps [`Self::stats`]' `replan_epoch`, so
    /// existing [`PreparedPlans`] handles become stale and are refused;
    /// use [`PreparedPlans::replan_edge`] to re-plan tree and handle
    /// together. Invalid input — out-of-range or non-adjacent `(u, v)`,
    /// non-finite or non-positive `w` — returns a typed
    /// [`FtfiError::InvalidInput`] and mutates nothing.
    pub fn replan_edge(&mut self, u: usize, v: usize, w: f64) -> Result<ReplanStats, FtfiError> {
        match self.stage_replan(u, v, w)? {
            None => Ok(ReplanStats::default()),
            Some(patch) => Ok(self.commit_replan(patch)),
        }
    }

    /// Validate the mutation and stage the affected side/leaf tables
    /// against a patch buffer without touching `self`. `Ok(None)` means
    /// the weight is already current (no-op).
    fn stage_replan(&self, u: usize, v: usize, w: f64) -> Result<Option<ReplanPatch>, FtfiError> {
        if u >= self.n || v >= self.n {
            return Err(FtfiError::InvalidInput(format!(
                "replan endpoint out of range: edge ({u}, {v}) on a tree with n = {}",
                self.n
            )));
        }
        if !(w.is_finite() && w > 0.0) {
            return Err(FtfiError::InvalidInput(format!(
                "replan weight must be finite and positive, got {w}"
            )));
        }
        let old = self.tree.edge_weight(u, v).ok_or_else(|| {
            FtfiError::InvalidInput(format!(
                "({u}, {v}) is not a tree edge — replan_edge only reweights existing edges"
            ))
        })?;
        if w == old {
            return Ok(None);
        }
        let mut new_tree = self.tree.clone();
        let replaced = new_tree.set_edge_weight(u, v, w);
        debug_assert_eq!(replaced, Some(old));
        let mut patch =
            ReplanPatch { new_tree, nodes_visited: 0, sides: Vec::new(), leaves: Vec::new() };
        let verts: Vec<u32> = (0..self.n as u32).collect();
        self.stage_walk(0, verts, u as u32, v as u32, &mut patch);
        Ok(Some(patch))
    }

    /// One step of the invalidation walk: node `idx` covers the global
    /// vertices `verts` (in node-local order) and contains both edge
    /// endpoints. Stage the affected side (internal) or distance matrix
    /// (leaf) computed against `patch.new_tree`, then descend into the
    /// single child whose vertex set still contains the edge.
    fn stage_walk(&self, idx: usize, verts: Vec<u32>, u: u32, v: u32, patch: &mut ReplanPatch) {
        patch.nodes_visited += 1;
        match &self.nodes[idx] {
            ItNode::Leaf { .. } => {
                let dmat = leaf_distances(&patch.new_tree, &verts);
                patch.leaves.push((idx, dmat));
            }
            ItNode::Internal { left_child, right_child, left, right, .. } => {
                let pivot_global = verts[left.ids[left.pivot as usize] as usize];
                // The non-pivot endpoint decides the side: removing the
                // pivot splits the node's sub-tree into components that
                // each lie wholly in one side, and adjacent vertices
                // share a component — so exactly one side's distance
                // tables see the new weight.
                let probe = if u == pivot_global { v } else { u };
                let in_left = left.ids.iter().any(|&i| verts[i as usize] == probe);
                let (side, is_left, child) =
                    if in_left { (left, true, *left_child) } else { (right, false, *right_child) };
                debug_assert!(
                    u == pivot_global
                        || v == pivot_global
                        || side.ids.iter().any(|&i| verts[i as usize] == v),
                    "edge endpoints must share a side"
                );
                let side_verts: Vec<u32> =
                    side.ids.iter().map(|&i| verts[i as usize]).collect();
                let mut node_local = std::collections::BTreeMap::new();
                for (i, &g) in verts.iter().enumerate() {
                    node_local.insert(g, i as u32);
                }
                let new_side = make_side(&patch.new_tree, &side_verts, pivot_global, &node_local);
                debug_assert_eq!(
                    new_side.ids, side.ids,
                    "a replan must preserve the side's vertex order"
                );
                patch.sides.push((idx, is_left, new_side));
                self.stage_walk(child, side_verts, u, v, patch);
            }
        }
    }

    /// Install a staged patch. Infallible by construction (strong
    /// exception safety: every fallible step ran during staging).
    fn commit_replan(&mut self, patch: ReplanPatch) -> ReplanStats {
        let ReplanPatch { new_tree, nodes_visited, sides, leaves } = patch;
        self.tree = new_tree;
        let sides_rebuilt = sides.len();
        let leaves_rebuilt = leaves.len();
        let mut affected = Vec::with_capacity(sides.len() + leaves.len());
        for (idx, is_left, side) in sides {
            affected.push(idx);
            match &mut self.nodes[idx] {
                ItNode::Internal { left, right, .. } => {
                    if is_left {
                        *left = side;
                    } else {
                        *right = side;
                    }
                }
                ItNode::Leaf { .. } => unreachable!("replan staged a side for a leaf node"),
            }
            // Distinct-distance counts may grow (or shrink) with the new
            // weight; workspace sizing is a monotone maximum, so plan
            // handles and warmed workspaces never need a re-warm.
            if let ItNode::Internal { left, right, .. } = &self.nodes[idx] {
                self.agg_rows_max = self.agg_rows_max.max(2 * (left.d.len() + right.d.len()));
            }
        }
        for (idx, new_dmat) in leaves {
            affected.push(idx);
            match &mut self.nodes[idx] {
                ItNode::Leaf { dmat, .. } => *dmat = new_dmat,
                ItNode::Internal { .. } => {
                    unreachable!("replan staged a distance matrix for an internal node")
                }
            }
        }
        self.replan_epoch += 1;
        self.replan_nodes_visited += nodes_visited;
        if invariants::enabled() {
            invariants::check_replan_seam(self, &affected);
        }
        ReplanStats {
            changed: true,
            nodes_visited,
            sides_rebuilt,
            leaves_rebuilt,
            plan_rebuilds: 0,
        }
    }
}

/// Dense leaf multiply with the coefficient for flat index `i*size+j`
/// supplied by `coeff` (raw `f.eval` on the re-planning path, the cached
/// `f`-matrix on the prepared path).
fn leaf_apply(size: usize, x: &Matrix, coeff: impl Fn(usize) -> f64) -> Matrix {
    let d = x.cols();
    let mut out = Matrix::zeros(size, d);
    for i in 0..size {
        let orow = out.row_mut(i);
        for j in 0..size {
            let c = coeff(i * size + j);
            if c == 0.0 {
                continue;
            }
            for (o, &v) in orow.iter_mut().zip(x.row(j)) {
                *o += c * v;
            }
        }
    }
    out
}

/// Recombination step shared by the re-planning and prepared paths:
/// scatter inner sums + cross contributions into node-local rows, with
/// the pivot-group correction (row τ(v) minus the pivot term removes
/// j = p from the sum; the pivot row itself is produced by the left
/// pass only).
#[allow(clippy::too_many_arguments)]
fn combine_sides(
    size: usize,
    d: usize,
    left: &Side,
    right: &Side,
    ol: &Matrix,
    or_: &Matrix,
    cr: &Matrix,
    cl: &Matrix,
    xl_agg: &Matrix,
    xr_agg: &Matrix,
    left_fd: &[f64],
    right_fd: &[f64],
) -> Matrix {
    let mut out = Matrix::zeros(size, d);
    for (vloc, &tau) in left.id_d.iter().enumerate() {
        let coeff = left_fd[tau as usize];
        let node_row = left.ids[vloc] as usize;
        let dst = out.row_mut(node_row);
        let src = ol.row(vloc);
        let crr = cr.row(tau as usize);
        let piv = xr_agg.row(0);
        for c in 0..d {
            dst[c] += src[c] + crr[c] - coeff * piv[c];
        }
    }
    for (uloc, &tau) in right.id_d.iter().enumerate() {
        if uloc as u32 == right.pivot {
            continue;
        }
        let coeff = right_fd[tau as usize];
        let node_row = right.ids[uloc] as usize;
        let dst = out.row_mut(node_row);
        let src = or_.row(uloc);
        let clr = cl.row(tau as usize);
        let piv = xl_agg.row(0);
        for c in 0..d {
            dst[c] += src[c] + clr[c] - coeff * piv[c];
        }
    }
    out
}

/// [`leaf_apply`] on slot-region slices: a leaf's slot range is its
/// vertex set in leaf-local order (the map is the identity), so the
/// dense multiply runs directly on the contiguous slab rows. The inner
/// axpy is lane-chunked over the d-channel axis (`linalg/lanes.rs`);
/// at [`Precision::F64`] it is bit-identical to [`leaf_apply`].
fn leaf_apply_into(
    size: usize,
    d: usize,
    fmat: &[f64],
    input: &[f64],
    out: &mut [f64],
    prec: Precision,
) {
    let out = &mut out[..size * d];
    out.iter_mut().for_each(|o| *o = 0.0);
    for i in 0..size {
        let orow = &mut out[i * d..(i + 1) * d];
        for j in 0..size {
            let c = fmat[i * size + j];
            if c == 0.0 {
                continue;
            }
            lanes::axpy_prec(prec, c, &input[j * d..(j + 1) * d], orow);
        }
    }
}

/// Eq. 3 on the slot layout: aggregate the side's field rows (fetched
/// through its slot map) by distance group, into an arena slice.
/// Same accumulation order over the same values as [`aggregate`] —
/// bit-identical.
fn aggregate_into(side: &Side, slots: &[u32], input: &[f64], d: usize, out: &mut [f64]) {
    let l = side.d.len();
    let out = &mut out[..l * d];
    out.iter_mut().for_each(|o| *o = 0.0);
    for g in 0..l {
        let lo = side.group_off[g] as usize;
        let hi = side.group_off[g + 1] as usize;
        let orow = &mut out[g * d..(g + 1) * d];
        for &v in &side.group_items[lo..hi] {
            let s = slots[v as usize] as usize * d;
            // Pure addition: tier-independent (no product to round),
            // so both precision tiers share this kernel.
            lanes::add_assign(orow, &input[s..s + d]);
        }
    }
}

/// [`combine_sides`] on the slot layout, *in place*: each vertex's
/// child-recursion output already sits at its slot (the child wrote it
/// there), so the cross contribution and pivot correction are added
/// where the row lives — no fresh output matrix, no scatter. The update
/// `out[s] = out[s] + cr[τ] − f(d_τ)·piv` evaluates exactly the
/// `0 + (src + crr − coeff·piv)` of the legacy path (the leading zero
/// add is the identity), so outputs are bit-identical.
#[allow(clippy::too_many_arguments)]
fn combine_sides_into(
    d: usize,
    left: &Side,
    right: &Side,
    left_slot: &[u32],
    right_slot: &[u32],
    out: &mut [f64],
    cr: &[f64],
    cl: &[f64],
    xl_agg: &[f64],
    xr_agg: &[f64],
    left_fd: &[f64],
    right_fd: &[f64],
    prec: Precision,
) {
    combine_left_into(d, left, left_slot, out, cr, xr_agg, left_fd, prec);
    combine_right_into(d, right, right_slot, out, cl, xl_agg, right_fd, prec);
}

/// The left-side half of [`combine_sides_into`]: adds the cross
/// contribution from the *right* aggregates (plus the pivot-group
/// correction) onto every left-side row. The delta path calls it only
/// when the right region is dirty — a clean right side contributes
/// exact zeros, so skipping it preserves the integral exactly.
#[allow(clippy::too_many_arguments)]
fn combine_left_into(
    d: usize,
    left: &Side,
    left_slot: &[u32],
    out: &mut [f64],
    cr: &[f64],
    xr_agg: &[f64],
    left_fd: &[f64],
    prec: Precision,
) {
    for (vloc, &tau) in left.id_d.iter().enumerate() {
        let coeff = left_fd[tau as usize];
        let base = left_slot[vloc] as usize * d;
        let crr = &cr[tau as usize * d..(tau as usize + 1) * d];
        let piv = &xr_agg[..d];
        // (out + cr[τ]) − f(d_τ)·piv, lane-chunked; same per-element
        // expression order as the pre-lane loop (bit-identical at F64).
        lanes::combine_prec(prec, &mut out[base..base + d], crr, coeff, piv);
    }
}

/// The right-side half of [`combine_sides_into`] (cross contribution
/// from the *left* aggregates; the pivot row is produced by the left
/// pass only and is skipped here). Delta-path masking as in
/// [`combine_left_into`].
#[allow(clippy::too_many_arguments)]
fn combine_right_into(
    d: usize,
    right: &Side,
    right_slot: &[u32],
    out: &mut [f64],
    cl: &[f64],
    xl_agg: &[f64],
    right_fd: &[f64],
    prec: Precision,
) {
    for (uloc, &tau) in right.id_d.iter().enumerate() {
        if uloc as u32 == right.pivot {
            continue;
        }
        let coeff = right_fd[tau as usize];
        let base = right_slot[uloc] as usize * d;
        let clr = &cl[tau as usize * d..(tau as usize + 1) * d];
        let piv = &xl_agg[..d];
        lanes::combine_prec(prec, &mut out[base..base + d], clr, coeff, piv);
    }
}

/// Distances from `pivot` to every vertex of `side_verts`, restricted to
/// the side's vertex set; then grouped into the paper's `d`/`id-d`/`s`
/// arrays.
fn make_side(
    tree: &Tree,
    side_verts: &[u32],
    pivot: u32,
    node_local: &std::collections::BTreeMap<u32, u32>,
) -> Side {
    let k = side_verts.len();
    let mut member = std::collections::BTreeMap::new();
    for (i, &v) in side_verts.iter().enumerate() {
        member.insert(v, i as u32);
    }
    // DFS from the pivot inside the side.
    let mut dist = vec![f64::NAN; k];
    let pivot_local = member[&pivot];
    dist[pivot_local as usize] = 0.0;
    let mut stack = vec![pivot];
    while let Some(v) = stack.pop() {
        let dv = dist[member[&v] as usize];
        for &(u, w) in tree.neighbors(v as usize) {
            if let Some(&lu) = member.get(&u) {
                if dist[lu as usize].is_nan() {
                    dist[lu as usize] = dv + w;
                    stack.push(u);
                }
            }
        }
    }
    debug_assert!(dist.iter().all(|d| !d.is_nan()), "side not connected through pivot");

    // Sort vertices by distance, group equal distances (tolerance scaled
    // to the magnitude — exact ties happen on lattice-weight trees).
    let mut order: Vec<u32> = (0..k as u32).collect();
    // total_cmp is bit-identical to partial_cmp here (the DFS above
    // leaves no NaNs and distances are non-negative, so no -0.0 ties).
    order.sort_by(|&a, &b| dist[a as usize].total_cmp(&dist[b as usize]));
    let maxd = dist.iter().fold(0.0f64, |m, &v| m.max(v));
    let eps = 1e-9 * (1.0 + maxd);
    let mut d: Vec<f64> = Vec::new();
    let mut id_d = vec![0u32; k];
    let mut group_off: Vec<u32> = vec![0];
    let mut group_items: Vec<u32> = Vec::with_capacity(k);
    for &v in &order {
        let dv = dist[v as usize];
        if d.is_empty() || dv - *d.last().unwrap() > eps {
            d.push(dv);
            group_off.push(group_items.len() as u32);
        }
        group_items.push(v);
        id_d[v as usize] = (d.len() - 1) as u32;
        *group_off.last_mut().unwrap() += 1;
    }
    debug_assert_eq!(d[0], 0.0);
    debug_assert_eq!(group_off[1] - group_off[0], 1, "pivot group must be a singleton");

    let ids: Vec<u32> = side_verts.iter().map(|v| node_local[v]).collect();
    Side { ids, d, id_d, group_off, group_items, pivot: pivot_local }
}

/// Eq. 3: aggregate the side's field rows by distance group.
fn aggregate(side: &Side, x: &Matrix) -> Matrix {
    let l = side.d.len();
    let d = x.cols();
    let mut out = Matrix::zeros(l, d);
    for g in 0..l {
        let lo = side.group_off[g] as usize;
        let hi = side.group_off[g + 1] as usize;
        let orow = out.row_mut(g);
        for &v in &side.group_items[lo..hi] {
            for (o, &val) in orow.iter_mut().zip(x.row(v as usize)) {
                *o += val;
            }
        }
    }
    out
}

/// Dense all-pairs distances within the sub-tree induced by `verts`
/// (leaf construction): one restricted DFS per vertex, O(t²).
fn leaf_distances(tree: &Tree, verts: &[u32]) -> Vec<f64> {
    let k = verts.len();
    let mut member = std::collections::BTreeMap::new();
    for (i, &v) in verts.iter().enumerate() {
        member.insert(v, i as u32);
    }
    let mut dmat = vec![0.0; k * k];
    let mut stack = Vec::with_capacity(k);
    for (si, &s) in verts.iter().enumerate() {
        let row = &mut dmat[si * k..(si + 1) * k];
        let mut seen = vec![false; k];
        seen[si] = true;
        stack.push((s, 0.0));
        while let Some((v, dv)) = stack.pop() {
            for &(u, w) in tree.neighbors(v as usize) {
                if let Some(&lu) = member.get(&u) {
                    if !seen[lu as usize] {
                        seen[lu as usize] = true;
                        row[lu as usize] = dv + w;
                        stack.push((u, dv + w));
                    }
                }
            }
        }
    }
    dmat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::btfi;
    use crate::graph::generators::{random_rational_tree, random_tree};
    use crate::ml::rng::Pcg;

    fn check_exact(tree: &Tree, f: &FDist, d: usize, seed: u64, tol: f64) {
        let mut rng = Pcg::seed(seed);
        let x = Matrix::randn(tree.n(), d, &mut rng);
        let want = btfi(tree, f, &x);
        for &t in &[2usize, 8, 32] {
            let it = IntegratorTree::with_leaf_threshold(tree, t);
            let got = it.integrate(f, &x, &CrossPolicy::default());
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < tol, "{f:?} t={t} n={}: rel={rel}", tree.n());
            // The prepared path must agree with the re-planning path.
            let plans = it.prepare(f, d, &CrossPolicy::default()).unwrap();
            let got_p = it.integrate_prepared(&x, &plans).unwrap();
            let rel_p = got_p.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel_p < tol, "prepared {f:?} t={t} n={}: rel={rel_p}", tree.n());
        }
    }

    #[test]
    fn matches_brute_small_path() {
        let tree = Tree::path(&[1.0, 2.0, 0.5, 1.5, 3.0]);
        check_exact(&tree, &FDist::Identity, 1, 1, 1e-10);
        check_exact(&tree, &FDist::Exponential { lambda: -0.5, scale: 1.0 }, 3, 2, 1e-10);
    }

    #[test]
    fn matches_brute_random_trees_all_f_classes() {
        let mut rng = Pcg::seed(7);
        let fs: Vec<(FDist, f64)> = vec![
            (FDist::Identity, 1e-9),
            (FDist::Polynomial(vec![1.0, -0.5, 0.25]), 1e-9),
            (FDist::Exponential { lambda: -0.3, scale: 2.0 }, 1e-9),
            (FDist::Trig { omega: 0.7, phase: 0.2, scale: 1.0 }, 1e-9),
            (FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.4] }, 1e-6),
            (FDist::ExpOverLinear { lambda: -0.1, c: 1.0 }, 1e-6),
        ];
        for &n in &[3usize, 6, 17, 100, 400] {
            let tree = random_tree(n, 0.05, 1.0, &mut rng);
            for (f, tol) in &fs {
                check_exact(&tree, f, 2, n as u64, *tol);
            }
        }
    }

    #[test]
    fn matches_brute_on_lattice_trees_any_f() {
        // Rational weights → Hankel path must engage and stay exact.
        let mut rng = Pcg::seed(8);
        let tree = random_rational_tree(300, 6, 4, &mut rng);
        let f = FDist::Custom(std::sync::Arc::new(|x: f64| (0.3 * x).sin() / (1.0 + x)));
        check_exact(&tree, &f, 2, 99, 1e-8);
        // Exponentiated quadratic on a lattice tree (§3.2.1 last case).
        let g = FDist::ExpQuadratic { u: -0.05, v: 0.01, w: 0.1 };
        check_exact(&tree, &g, 1, 100, 1e-8);
    }

    #[test]
    fn unit_weight_tree_gaussian() {
        let mut rng = Pcg::seed(9);
        let tree = random_rational_tree(200, 1, 1, &mut rng); // unit weights
        check_exact(&tree, &FDist::gaussian(0.1), 3, 101, 1e-8);
    }

    #[test]
    fn singleton_and_tiny_trees() {
        let t1 = Tree::from_edges(1, &[]);
        let it = IntegratorTree::new(&t1);
        let x = Matrix::from_vec(1, 1, vec![2.0]);
        let out = it.integrate(&FDist::Exponential { lambda: 1.0, scale: 1.0 }, &x, &CrossPolicy::default());
        assert!((out.get(0, 0) - 2.0).abs() < 1e-12); // f(0)·x = 1·2

        let t2 = Tree::from_edges(2, &[(0, 1, 3.0)]);
        let it2 = IntegratorTree::with_leaf_threshold(&t2, 2);
        let x2 = Matrix::from_vec(2, 1, vec![1.0, 1.0]);
        let out2 = it2.integrate(&FDist::Identity, &x2, &CrossPolicy::default());
        assert!((out2.get(0, 0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn stats_balanced_depth() {
        let mut rng = Pcg::seed(10);
        let tree = random_tree(1000, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let st = it.stats();
        // depth ≤ log_{4/3}(n/t) + slack
        assert!(st.depth <= 30, "depth={}", st.depth);
        assert!(st.leaves >= 1000 / 8 / 4);
        assert!(st.max_leaf_size <= 8);
    }

    #[test]
    fn preserves_total_mass_for_constant_f() {
        // f ≡ 1: every output row equals the column sums of x.
        let mut rng = Pcg::seed(11);
        let tree = random_tree(150, 0.2, 1.0, &mut rng);
        let x = Matrix::randn(150, 2, &mut rng);
        let it = IntegratorTree::new(&tree);
        let f = FDist::Polynomial(vec![1.0]);
        let out = it.integrate(&f, &x, &CrossPolicy::default());
        let mut colsum = vec![0.0; 2];
        for i in 0..150 {
            for c in 0..2 {
                colsum[c] += x.get(i, c);
            }
        }
        for i in 0..150 {
            for c in 0..2 {
                assert!((out.get(i, c) - colsum[c]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn prepared_path_builds_plans_exactly_once() {
        let mut rng = Pcg::seed(12);
        let tree = random_tree(300, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let f = FDist::inverse_quadratic(0.5);
        let policy = CrossPolicy::default();
        assert_eq!(it.stats().plan_builds, 0);
        let plans = it.prepare(&f, 2, &policy).unwrap();
        let after_prepare = it.stats().plan_builds;
        assert_eq!(after_prepare, plans.plans_built());
        assert!(after_prepare > 0, "an n=300, t=8 IT must have internal nodes");
        // Repeated prepared integrations build no further plans…
        let x = Matrix::randn(300, 2, &mut rng);
        for _ in 0..5 {
            it.integrate_prepared(&x, &plans).unwrap();
        }
        assert_eq!(it.stats().plan_builds, after_prepare);
        // …while each re-planning call rebuilds all of them.
        it.integrate(&f, &x, &policy);
        assert_eq!(it.stats().plan_builds, 2 * after_prepare);
    }

    #[test]
    fn pooled_recursion_is_bit_identical_to_serial() {
        // n is comfortably above PAR_FORK_MIN_SIZE so the recursion
        // actually forks; `forks > 0` pins that the parallel path ran.
        let mut rng = Pcg::seed(15);
        let tree = random_tree(1100, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 32);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let policy = CrossPolicy::default();
        let x = Matrix::randn(1100, 2, &mut rng);
        let pool = WorkPool::new(4);
        let serial = it.try_integrate_pooled(&f, &x, &policy, &WorkPool::serial()).unwrap();
        let par = it.try_integrate_pooled(&f, &x, &policy, &pool).unwrap();
        assert!(serial == par, "pooled re-planning output must be bit-identical");
        assert!(pool.stats().forks > 0, "the 4-thread recursion never forked");
        let plans_s = it.prepare(&f, 2, &policy).unwrap();
        let plans_p = it.prepare_pooled(&f, 2, &policy, &pool).unwrap();
        let a = it.integrate_prepared_pooled(&x, &plans_s, &WorkPool::serial()).unwrap();
        let b = it.integrate_prepared_pooled(&x, &plans_p, &pool).unwrap();
        assert!(a == b, "pooled prepared output must be bit-identical");
        assert_eq!(plans_s.plans_built(), plans_p.plans_built());
    }

    /// The nested-dissection slot layout: `n + #internal` slots, every
    /// leaf region lists its vertices in leaf-local order, every vertex
    /// has a root slot that round-trips through `slot_src`, and every
    /// original vertex appears at least once (pivots more than once).
    #[test]
    fn slot_layout_invariants() {
        let mut rng = Pcg::seed(20);
        for &n in &[1usize, 2, 5, 40, 400] {
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let it = IntegratorTree::with_leaf_threshold(&tree, 8);
            let internal = it
                .nodes
                .iter()
                .filter(|nd| matches!(nd, ItNode::Internal { .. }))
                .count();
            assert_eq!(it.total_slots(), n + internal, "n={n}");
            assert_eq!(it.slot_src.len(), it.total_slots());
            assert_eq!(it.root_slot.len(), n);
            let mut seen = vec![0usize; n];
            for &v in &it.slot_src {
                seen[v as usize] += 1;
            }
            assert!(seen.iter().all(|&c| c >= 1), "every vertex needs a slot");
            for v in 0..n {
                assert_eq!(
                    it.slot_src[it.root_slot[v] as usize] as usize, v,
                    "root slot of {v} must hold {v}"
                );
            }
            // Internal regions: child sizes sum to the node's, side slot
            // maps stay within their half.
            for nd in &it.nodes {
                if let ItNode::Internal { lslots, rslots, left_slot, right_slot, left, right, .. } =
                    nd
                {
                    assert_eq!(left_slot.len(), left.ids.len());
                    assert_eq!(right_slot.len(), right.ids.len());
                    assert!(left_slot.iter().all(|&s| (s as usize) < *lslots));
                    assert!(right_slot
                        .iter()
                        .all(|&s| (s as usize) >= *lslots && (s as usize) < lslots + rslots));
                }
            }
        }
    }

    /// Tentpole acceptance (structure level): the workspace hot path is
    /// **bit-identical** to the legacy per-node-allocation path, for
    /// serial and forked recursions, repeated calls on one handle
    /// (workspace reuse must not leak state between calls), and the
    /// `_into` variant with a dirty output buffer.
    #[test]
    fn workspace_path_bit_identical_to_legacy() {
        let mut rng = Pcg::seed(21);
        for &(n, d) in &[(1usize, 1usize), (2, 2), (37, 3), (300, 2), (1100, 2)] {
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let it = IntegratorTree::with_leaf_threshold(&tree, 16);
            let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
            let plans = it.prepare(&f, d, &CrossPolicy::default()).unwrap();
            let pool = WorkPool::new(4);
            for trial in 0..3 {
                let x = Matrix::randn(n, d, &mut rng);
                let want = it.integrate_prepared_legacy(&x, &plans).unwrap();
                let got = it.integrate_prepared(&x, &plans).unwrap();
                assert!(got == want, "n={n} d={d} trial={trial}: serial ws != legacy");
                let got_p = it.integrate_prepared_pooled(&x, &plans, &pool).unwrap();
                assert!(got_p == want, "n={n} d={d} trial={trial}: pooled ws != legacy");
                let mut dirty = Matrix::from_fn(n, d, |_, _| f64::NAN);
                it.integrate_prepared_into(&x, &plans, &mut dirty).unwrap();
                assert!(dirty == want, "n={n} d={d} trial={trial}: into != legacy");
            }
        }
    }

    #[test]
    fn integrate_prepared_into_validates_the_output_buffer() {
        let mut rng = Pcg::seed(22);
        let tree = random_tree(30, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::new(&tree);
        let f = FDist::Identity;
        let plans = it.prepare(&f, 2, &CrossPolicy::default()).unwrap();
        let x = Matrix::randn(30, 2, &mut rng);
        let mut wrong_rows = Matrix::zeros(29, 2);
        assert!(matches!(
            it.integrate_prepared_into(&x, &plans, &mut wrong_rows),
            Err(FtfiError::InvalidInput(_))
        ));
        let mut wrong_cols = Matrix::zeros(30, 3);
        assert!(matches!(
            it.integrate_prepared_into(&x, &plans, &mut wrong_cols),
            Err(FtfiError::InvalidInput(_))
        ));
        let mut ok = Matrix::zeros(30, 2);
        assert!(it.integrate_prepared_into(&x, &plans, &mut ok).is_ok());
    }

    /// Workspace sizing is surfaced and consistent: the structural part
    /// through `ItStats`, the full per-channel-width figure through
    /// `PreparedPlans::workspace_bytes` (monotone in d, and at least the
    /// structural slab footprint).
    #[test]
    fn workspace_sizing_is_pinned() {
        let mut rng = Pcg::seed(23);
        let tree = random_tree(500, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 16);
        let st = it.stats();
        assert_eq!(
            st.workspace_bytes,
            (2 * it.total_slots() + it.agg_rows_max) * std::mem::size_of::<f64>()
        );
        assert!(st.workspace_bytes >= 2 * 500 * 8, "slabs cover at least 2n rows");
        let f = FDist::inverse_quadratic(0.5);
        let plans = it.prepare(&f, 4, &CrossPolicy::default()).unwrap();
        assert!(plans.workspace_bytes(1) >= st.workspace_bytes);
        assert!(plans.workspace_bytes(4) > plans.workspace_bytes(1));
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let mut rng = Pcg::seed(13);
        let tree = random_tree(50, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::new(&tree);
        let f = FDist::Identity;
        let x = Matrix::zeros(49, 2);
        assert!(matches!(
            it.try_integrate(&f, &x, &CrossPolicy::default()),
            Err(FtfiError::ShapeMismatch { expected: 50, got: 49 })
        ));
        let plans = it.prepare(&f, 2, &CrossPolicy::default()).unwrap();
        assert!(matches!(
            it.integrate_prepared(&x, &plans),
            Err(FtfiError::ShapeMismatch { expected: 50, got: 49 })
        ));
    }

    /// Tentpole pin (value level): the sparse delta pass equals the full
    /// prepared integration of the same delta field *exactly* — skipped
    /// clean sub-trees / cross halves contribute exact zeros, so no
    /// value can differ (only zero signs may).
    #[test]
    fn delta_pass_is_value_identical_to_full_integration_of_the_delta() {
        let mut rng = Pcg::seed(31);
        for &(n, d) in &[(1usize, 1usize), (2, 2), (37, 3), (300, 2), (1100, 2)] {
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let it = IntegratorTree::with_leaf_threshold(&tree, 16);
            let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
            let plans = it.prepare(&f, d, &CrossPolicy::default()).unwrap();
            let pool = WorkPool::new(4);
            for &k in &[0usize, 1, (n / 3).max(1).min(n), n] {
                let (perm, dx) = crate::bench_util::sparse_delta(n, d, k, &mut rng);
                let rows = &perm[..];
                let want = it.integrate_prepared(&dx, &plans).unwrap();
                let got = it.integrate_delta_prepared(rows, &dx, &plans).unwrap();
                assert!(
                    got.max_abs_diff(&want) == 0.0,
                    "n={n} d={d} k={k}: delta pass must be value-identical"
                );
                let got_p = it.integrate_delta_prepared_pooled(rows, &dx, &plans, &pool);
                let got_p = got_p.unwrap();
                assert!(
                    got_p.max_abs_diff(&want) == 0.0,
                    "n={n} d={d} k={k}: pooled delta pass must be value-identical"
                );
            }
        }
    }

    /// Tentpole pin (bit level): with every row listed the delta pass
    /// skips nothing and must be **bit-identical** to the full prepared
    /// integration — same kernels, same reduction order.
    #[test]
    fn delta_with_all_rows_is_bit_identical_to_full_integration() {
        let mut rng = Pcg::seed(32);
        for &n in &[1usize, 2, 37, 300, 1100] {
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let it = IntegratorTree::with_leaf_threshold(&tree, 16);
            let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
            let plans = it.prepare(&f, 2, &CrossPolicy::default()).unwrap();
            let rows: Vec<u32> = (0..n as u32).collect();
            let dx = Matrix::randn(n, 2, &mut rng);
            let want = it.integrate_prepared(&dx, &plans).unwrap();
            let got = it.integrate_delta_prepared(&rows, &dx, &plans).unwrap();
            assert!(got == want, "n={n}: full-rows delta must be bit-identical");
            let pool = WorkPool::new(4);
            let got_p = it.integrate_delta_prepared_pooled(&rows, &dx, &plans, &pool).unwrap();
            assert!(got_p == want, "n={n}: pooled full-rows delta must be bit-identical");
        }
    }

    /// Sparsity pin: a k = 1 update visits only the nodes on one
    /// root-path (plus their leaves), far fewer than the full arena; a
    /// k = 0 update visits none and returns exact zeros.
    #[test]
    fn delta_visits_only_dirty_nodes() {
        let mut rng = Pcg::seed(33);
        let tree = random_tree(1000, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let plans = it.prepare(&f, 1, &CrossPolicy::default()).unwrap();
        let dx = Matrix::zeros(1000, 1);
        let before = it.stats().delta_nodes_visited;
        let out = it.integrate_delta_prepared(&[], &dx, &plans).unwrap();
        assert_eq!(it.stats().delta_nodes_visited, before, "k=0 must visit no node");
        assert!(out.data().iter().all(|&v| v == 0.0));
        let mut dx = Matrix::zeros(1000, 1);
        dx.set(123, 0, 1.0);
        let before = it.stats().delta_nodes_visited;
        it.integrate_delta_prepared(&[123], &dx, &plans).unwrap();
        let visited = it.stats().delta_nodes_visited - before;
        let total = it.stats().nodes;
        assert!(visited >= 1, "a dirty row must visit its root path");
        assert!(
            visited * 2 < total,
            "k=1 visited {visited} of {total} nodes — the sparse pass is not sparse"
        );
    }

    #[test]
    fn delta_validates_rows_shapes_and_plan_ownership() {
        let mut rng = Pcg::seed(34);
        let tree = random_tree(50, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::new(&tree);
        let f = FDist::Identity;
        let plans = it.prepare(&f, 2, &CrossPolicy::default()).unwrap();
        let dx = Matrix::zeros(50, 2);
        // Out-of-range row.
        assert!(matches!(
            it.integrate_delta_prepared(&[50], &dx, &plans),
            Err(FtfiError::InvalidInput(_))
        ));
        // Duplicate row.
        assert!(matches!(
            it.integrate_delta_prepared(&[3, 3], &dx, &plans),
            Err(FtfiError::InvalidInput(_))
        ));
        // Wrong delta-field height.
        let short = Matrix::zeros(49, 2);
        assert!(matches!(
            it.integrate_delta_prepared(&[0], &short, &plans),
            Err(FtfiError::ShapeMismatch { expected: 50, got: 49 })
        ));
        // Wrong output buffer.
        let mut bad_out = Matrix::zeros(50, 3);
        assert!(matches!(
            it.integrate_delta_prepared_into(&[0], &dx, &plans, &mut bad_out),
            Err(FtfiError::InvalidInput(_))
        ));
        // Foreign plans.
        let other = IntegratorTree::new(&random_tree(50, 0.1, 1.0, &mut rng));
        let foreign = other.prepare(&f, 2, &CrossPolicy::default()).unwrap();
        assert!(matches!(
            it.integrate_delta_prepared(&[0], &dx, &foreign),
            Err(FtfiError::InvalidInput(_))
        ));
        // A failed call must not poison the handle.
        assert!(it.integrate_delta_prepared(&[0, 1], &dx, &plans).is_ok());
    }

    #[test]
    fn prepared_plans_are_pinned_to_their_tree() {
        // Two same-shape trees (identical n, weights drawn the same way)
        // must not accept each other's plans: distance tables differ, so
        // cross-application would be silently wrong or out of bounds.
        let mut rng = Pcg::seed(14);
        let ta = random_tree(120, 0.1, 1.0, &mut rng);
        let tb = random_tree(120, 0.1, 1.0, &mut rng);
        let ita = IntegratorTree::with_leaf_threshold(&ta, 8);
        let itb = IntegratorTree::with_leaf_threshold(&tb, 8);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let plans_a = ita.prepare(&f, 1, &CrossPolicy::default()).unwrap();
        let x = Matrix::randn(120, 1, &mut rng);
        assert!(matches!(
            itb.integrate_prepared(&x, &plans_a),
            Err(FtfiError::InvalidInput(_))
        ));
        // …and the rightful owner still accepts them.
        assert!(ita.integrate_prepared(&x, &plans_a).is_ok());
    }

    /// Tentpole pin (bit level): the separator hierarchy is
    /// weight-independent, so a prepared replan must leave the tree +
    /// handle **bit-identical** to a from-scratch rebuild + re-prepare
    /// on the mutated tree — for every strategy the default policy
    /// dispatches to. Also pins the O(log n) walk budget.
    #[test]
    fn prepared_replan_is_bit_identical_to_from_scratch_rebuild() {
        let mut rng = Pcg::seed(40);
        for &n in &[5usize, 37, 400] {
            let mut tree = random_tree(n, 0.1, 1.0, &mut rng);
            let mut it = IntegratorTree::with_leaf_threshold(&tree, 8);
            let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
            let policy = CrossPolicy::default();
            let mut plans = it.prepare(&f, 2, &policy).unwrap();
            let x = Matrix::randn(n, 2, &mut rng);
            let budget = 5 * (usize::BITS - (n - 1).leading_zeros()) as usize + 2;
            for step in 0..4 {
                let (u, v, w) = tree.edges()[(step * 7 + 3) % (n - 1)];
                let nw = w * (1.25 + 0.1 * step as f64);
                tree.set_edge_weight(u as usize, v as usize, nw).unwrap();
                let st = plans.replan_edge(&mut it, u as usize, v as usize, nw).unwrap();
                assert!(st.changed, "REPRO seed=40 n={n} step={step}");
                assert!(
                    st.nodes_visited <= budget,
                    "REPRO seed=40 n={n} step={step}: visited {} > budget {budget}",
                    st.nodes_visited
                );
                let got = it.integrate_prepared(&x, &plans).unwrap();
                let fresh_it = IntegratorTree::with_leaf_threshold(&tree, 8);
                let fresh_plans = fresh_it.prepare(&f, 2, &policy).unwrap();
                let want = fresh_it.integrate_prepared(&x, &fresh_plans).unwrap();
                assert!(
                    got == want,
                    "REPRO seed=40 n={n} step={step}: replanned output != rebuilt output"
                );
            }
            let st = it.stats();
            assert!(st.replan_nodes_visited >= 4, "walks must be counted");
            assert!(
                st.replan_nodes_visited <= 4 * budget,
                "lifetime replan visits {} exceed 4 walks' budget",
                st.replan_nodes_visited
            );
        }
    }

    #[test]
    fn replan_to_current_weight_is_a_noop_rebuilding_nothing() {
        let mut rng = Pcg::seed(41);
        let tree = random_tree(120, 0.1, 1.0, &mut rng);
        let mut it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let f = FDist::inverse_quadratic(0.5);
        let mut plans = it.prepare(&f, 1, &CrossPolicy::default()).unwrap();
        let x = Matrix::randn(120, 1, &mut rng);
        let (u, v, w) = tree.edges()[5];
        let builds_before = it.stats().plan_builds;
        let st = plans.replan_edge(&mut it, u as usize, v as usize, w).unwrap();
        assert!(!st.changed);
        assert_eq!(st, ReplanStats::default(), "a same-weight replan must do nothing");
        assert_eq!(it.stats().plan_builds, builds_before);
        assert_eq!(it.stats().replan_nodes_visited, 0);
        // No epoch bump: the handle is still accepted (raw level too).
        assert!(it.integrate_prepared(&x, &plans).is_ok());
        let st = it.replan_edge(u as usize, v as usize, w).unwrap();
        assert!(!st.changed);
        assert!(it.integrate_prepared(&x, &plans).is_ok());
    }

    /// Satellite fix pin: malformed replans are typed errors, not
    /// panics, and a rejected replan leaves tree + handle bit-for-bit
    /// untouched (strong exception safety).
    #[test]
    fn replan_validation_is_typed_and_leaves_state_untouched() {
        let mut rng = Pcg::seed(42);
        let tree = random_tree(60, 0.1, 1.0, &mut rng);
        let mut it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let f = FDist::Exponential { lambda: -0.2, scale: 1.0 };
        let mut plans = it.prepare(&f, 2, &CrossPolicy::default()).unwrap();
        let x = Matrix::randn(60, 2, &mut rng);
        let baseline = it.integrate_prepared(&x, &plans).unwrap();
        // A non-adjacent pair always exists for n = 60 (max degree < 59).
        let (na_u, na_v) = (0..60usize)
            .flat_map(|a| (0..60usize).map(move |b| (a, b)))
            .find(|&(a, b)| a != b && tree.edge_weight(a, b).is_none())
            .unwrap();
        let (eu, ev, _) = tree.edges()[0];
        let bad: Vec<(usize, usize, f64)> = vec![
            (60, 0, 1.0),                              // u out of range
            (0, 77, 1.0),                              // v out of range
            (3, 3, 1.0),                               // self-loop
            (na_u, na_v, 1.0),                         // not tree-adjacent
            (eu as usize, ev as usize, f64::NAN),      // NaN weight
            (eu as usize, ev as usize, f64::INFINITY), // non-finite weight
            (eu as usize, ev as usize, -1.0),          // negative weight
            (eu as usize, ev as usize, 0.0),           // zero weight
        ];
        for &(u, v, w) in &bad {
            assert!(
                matches!(it.replan_edge(u, v, w), Err(FtfiError::InvalidInput(_))),
                "raw replan ({u}, {v}, {w}) must be a typed error"
            );
            assert!(
                matches!(plans.replan_edge(&mut it, u, v, w), Err(FtfiError::InvalidInput(_))),
                "prepared replan ({u}, {v}, {w}) must be a typed error"
            );
        }
        let after = it.integrate_prepared(&x, &plans).unwrap();
        assert!(after == baseline, "rejected replans must not perturb tree or plans");
        assert_eq!(it.stats().replan_nodes_visited, 0);
        assert_eq!(it.stats().replan_plan_rebuilds, 0);
    }

    /// The replan seam: a tree-level replan bumps the epoch, so every
    /// prepared surface refuses the now-stale handle instead of reading
    /// tables that no longer match the tree.
    #[test]
    fn raw_replan_invalidates_existing_prepared_handles() {
        let mut rng = Pcg::seed(43);
        let tree = random_tree(90, 0.1, 1.0, &mut rng);
        let mut it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let f = FDist::Identity;
        let mut plans = it.prepare(&f, 1, &CrossPolicy::default()).unwrap();
        let x = Matrix::randn(90, 1, &mut rng);
        let (u, v, w) = tree.edges()[2];
        let st = it.replan_edge(u as usize, v as usize, w * 2.0).unwrap();
        assert!(st.changed && st.plan_rebuilds == 0);
        assert!(matches!(
            it.integrate_prepared(&x, &plans),
            Err(FtfiError::InvalidInput(_))
        ));
        assert!(matches!(
            it.integrate_prepared_legacy(&x, &plans),
            Err(FtfiError::InvalidInput(_))
        ));
        assert!(matches!(
            it.integrate_delta_prepared(&[0], &x, &plans),
            Err(FtfiError::InvalidInput(_))
        ));
        // A stale handle cannot replan either — only a fresh prepare
        // resynchronizes.
        assert!(matches!(
            plans.replan_edge(&mut it, u as usize, v as usize, w * 3.0),
            Err(FtfiError::InvalidInput(_))
        ));
        let plans2 = it.prepare(&f, 1, &CrossPolicy::default()).unwrap();
        assert!(it.integrate_prepared(&x, &plans2).is_ok());
    }
}
