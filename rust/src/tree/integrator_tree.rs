//! The IntegratorTree (IT) data structure — §3.1/§3.2 of the paper.
//!
//! An IT is a rooted binary decomposition of an input tree `T` built with
//! balanced separators (Lemma 3.1): each internal node covers a connected
//! vertex subset `S`, stores a pivot `p` and two children covering
//! `S_left`/`S_right` with `S_left ∩ S_right = {p}` and `|S_x| ≥ |S|/4`.
//! It is built **once per tree** and reused for any number of tensor
//! fields and any `f` (leaves store *raw* distances; `f` is applied at
//! integration time — this is what makes the learnable-`f` training of
//! §4.3 cheap, since the coefficients change every step but the IT does
//! not).
//!
//! On top of the structure, [`IntegratorTree::prepare`] freezes a
//! specific `f` into a [`PreparedPlans`] handle: one cross-term [`Plan`]
//! per internal-node direction plus the `f`-evaluated leaf matrices and
//! pivot-distance coefficient tables. Repeated integrations with the
//! same `f` then skip all planning (Chebyshev probe loops, lattice
//! detection, FFT table construction) — the repeated-integration pattern
//! of the serving coordinator and of the GW/Sinkhorn inner loops.
//!
//! Per internal node, the paper's eight fields materialise as:
//! `left_ids` / `right_ids` (child-local → node-local id maps),
//! `left_d` / `right_d` (sorted distinct pivot distances),
//! `left_id_d` / `right_id_d` (vertex → distance index), and
//! `left groups` / `right groups` (CSR: distance index → vertices),
//! with `*_d[0] = 0` always being the pivot's own singleton group.

use super::separator::{split, SeparatorScratch};
use super::Tree;
use crate::ftfi::cordial::{apply_plan, try_make_plan, CrossPolicy, Plan};
use crate::ftfi::error::FtfiError;
use crate::ftfi::functions::FDist;
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Internal nodes at least this large fork their left/right subtree
/// integrations onto the work pool (Lemma 3.1 guarantees both children
/// hold ≥ ¼ of the node, so a fork always splits real work). Below the
/// cutoff the per-fork thread-spawn cost would dominate the subtree
/// work. The reduction order is unchanged by forking — see the
/// bit-identical determinism contract in `runtime/pool.rs`.
const PAR_FORK_MIN_SIZE: usize = 512;

/// Monotonic id source: every built IntegratorTree gets a unique id so
/// [`PreparedPlans`] can be pinned to the exact instance they were built
/// for (vertex/node counts alone cannot distinguish same-shape trees).
static IT_IDS: AtomicU64 = AtomicU64::new(1);

/// One side (left or right) of an internal IT node.
#[derive(Debug)]
pub struct Side {
    /// Child-local index → node-local index.
    pub ids: Vec<u32>,
    /// Sorted distinct distances from the pivot; `d[0] == 0.0` (pivot).
    pub d: Vec<f64>,
    /// Child-local vertex → index into `d`.
    pub id_d: Vec<u32>,
    /// CSR offsets into `group_items`, one group per distance.
    pub group_off: Vec<u32>,
    /// Child-local vertex ids grouped by distance index.
    pub group_items: Vec<u32>,
    /// Child-local index of the pivot.
    pub pivot: u32,
}

/// IT node: leaf (small sub-tree, dense distance matrix) or internal.
#[derive(Debug)]
pub enum ItNode {
    Leaf {
        /// Number of vertices.
        size: usize,
        /// Raw (not f-transformed) `size×size` distance matrix.
        dmat: Vec<f64>,
    },
    Internal {
        size: usize,
        left_child: usize,
        right_child: usize,
        left: Side,
        right: Side,
    },
}

/// The IntegratorTree: an arena of [`ItNode`]s, root at index 0.
pub struct IntegratorTree {
    nodes: Vec<ItNode>,
    n: usize,
    leaf_threshold: usize,
    /// Unique instance id (see [`IT_IDS`]).
    id: u64,
    /// Cross-term plans built over this IT's lifetime (both by the
    /// re-planning `integrate` path — 2 per internal node per call — and
    /// once by `prepare`). Exposed through [`ItStats::plan_builds`]; the
    /// prepared-path regression test pins it.
    plan_builds: AtomicUsize,
}

/// Summary statistics (used by the perf log and the ablation benches).
#[derive(Debug, Clone, Default)]
pub struct ItStats {
    pub nodes: usize,
    pub leaves: usize,
    pub depth: usize,
    pub max_leaf_size: usize,
    pub total_distinct_distances: usize,
    pub max_distinct_distances: usize,
    /// Total cross-term plans built so far (see
    /// [`IntegratorTree::prepare`] — a prepared handle freezes this).
    pub plan_builds: usize,
    /// Two-way recursion forks that actually ran on two threads. Zero
    /// for the bare `IntegratorTree` (which has no pool of its own);
    /// populated by `TreeFieldIntegrator::stats` from its work pool.
    /// **Pool-scoped**: lifetime aggregate of that pool — on a shared
    /// pool this includes every sharer's activity, so compare deltas,
    /// not absolutes.
    pub par_forks: usize,
    /// Parallel-map tasks (plan preparations, batch fields, serving
    /// requests) executed on helper threads. Populated (and pool-scoped)
    /// like `par_forks`.
    pub par_tasks: usize,
}

/// Everything `f`-dependent, frozen at prepare time: per-internal-node
/// cross plans for both directions, `f`-transformed leaf matrices, and
/// the `f(d)` coefficient tables used in the recombination step. Built
/// by [`IntegratorTree::prepare`] / consumed by
/// [`IntegratorTree::integrate_prepared`].
enum PreparedNode {
    Leaf {
        /// `f`-transformed dense leaf matrix.
        fmat: Vec<f64>,
    },
    Internal {
        /// Plan for the cross product into the left side (xs = left.d).
        into_left: Plan,
        /// Plan for the cross product into the right side (xs = right.d).
        into_right: Plan,
        /// `f(left.d[i])` lookup table.
        left_fd: Vec<f64>,
        /// `f(right.d[i])` lookup table.
        right_fd: Vec<f64>,
    },
}

/// A frozen (tree, f, policy) integration plan. Cheap to apply, immutable
/// and `f`-specific; obtain one from [`IntegratorTree::prepare`] (or the
/// higher-level `TreeFieldIntegrator::prepare`).
pub struct PreparedPlans {
    f: FDist,
    policy: CrossPolicy,
    nodes: Vec<PreparedNode>,
    n: usize,
    /// Id of the IntegratorTree instance these plans were built for —
    /// plans are not portable across trees, even same-shape ones.
    tree_id: u64,
    plans_built: usize,
}

impl PreparedPlans {
    /// The function these plans were built for.
    pub fn f(&self) -> &FDist {
        &self.f
    }

    /// Number of tree vertices the plans expect.
    pub fn n(&self) -> usize {
        self.n
    }

    /// How many cross-term plans were built at prepare time (2 per
    /// internal IT node).
    pub fn plans_built(&self) -> usize {
        self.plans_built
    }
}

impl IntegratorTree {
    /// Build with the default leaf threshold (32 — see the ablation bench;
    /// the paper likewise uses `t` well above the theoretical minimum 6).
    pub fn new(tree: &Tree) -> Self {
        Self::with_leaf_threshold(tree, 32)
    }

    /// Build with an explicit leaf threshold `t ≥ 2`.
    pub fn with_leaf_threshold(tree: &Tree, leaf_threshold: usize) -> Self {
        let t = leaf_threshold.max(2);
        let n = tree.n();
        let mut it = IntegratorTree {
            nodes: Vec::new(),
            n,
            leaf_threshold: t,
            id: IT_IDS.fetch_add(1, Ordering::Relaxed),
            plan_builds: AtomicUsize::new(0),
        };
        let mut scratch = SeparatorScratch::new(n);
        let verts: Vec<u32> = (0..n as u32).collect();
        it.build(tree, verts, &mut scratch);
        it
    }

    /// Number of vertices of the underlying tree.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Recursively build the node for `verts`; returns its arena index.
    fn build(&mut self, tree: &Tree, verts: Vec<u32>, scratch: &mut SeparatorScratch) -> usize {
        let idx = self.nodes.len();
        if verts.len() <= self.leaf_threshold || verts.len() < 3 {
            let dmat = leaf_distances(tree, &verts);
            self.nodes.push(ItNode::Leaf { size: verts.len(), dmat });
            return idx;
        }
        let s = split(tree, &verts, scratch);
        // node-local index of each global vertex.
        let mut local = std::collections::HashMap::with_capacity(verts.len());
        for (i, &v) in verts.iter().enumerate() {
            local.insert(v, i as u32);
        }
        let left = make_side(tree, &s.left, s.pivot, &local);
        let right = make_side(tree, &s.right, s.pivot, &local);
        // Reserve the slot, then recurse.
        self.nodes.push(ItNode::Leaf { size: 0, dmat: Vec::new() }); // placeholder
        let left_child = self.build(tree, s.left, scratch);
        let right_child = self.build(tree, s.right, scratch);
        self.nodes[idx] =
            ItNode::Internal { size: verts.len(), left_child, right_child, left, right };
        idx
    }

    /// Fallible integration: `out[v] = Σ_u f(dist(v,u))·x[u]` for a
    /// tensor field `x` (`n×d`, rows indexed by tree vertex id). Exact
    /// (up to the floating-point accuracy of the selected cross-term
    /// multiplier). Plans every cross block on each call — use
    /// [`IntegratorTree::prepare`] to amortise planning over repeated
    /// integrations with the same `f`.
    pub fn try_integrate(
        &self,
        f: &FDist,
        x: &Matrix,
        policy: &CrossPolicy,
    ) -> Result<Matrix, FtfiError> {
        self.try_integrate_pooled(f, x, policy, &WorkPool::serial())
    }

    /// [`IntegratorTree::try_integrate`] running the recursion on a work
    /// pool: sub-tree integrations above [`PAR_FORK_MIN_SIZE`] fork onto
    /// helper threads. The per-block reduction order is identical to the
    /// serial path, so the output is bit-identical for any thread count.
    pub fn try_integrate_pooled(
        &self,
        f: &FDist,
        x: &Matrix,
        policy: &CrossPolicy,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        if x.rows() != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: x.rows() });
        }
        if self.n == 0 {
            return Ok(Matrix::zeros(0, x.cols()));
        }
        self.integrate_node(0, x, f, policy, pool)
    }

    /// Infallible [`IntegratorTree::try_integrate`] shim; panics on shape
    /// mismatch or a forced-inapplicable strategy.
    pub fn integrate(&self, f: &FDist, x: &Matrix, policy: &CrossPolicy) -> Matrix {
        self.try_integrate(f, x, policy)
            .expect("IntegratorTree::integrate failed (use try_integrate for a Result)")
    }

    /// Convenience wrapper for scalar fields.
    pub fn integrate_vec(&self, f: &FDist, x: &[f64], policy: &CrossPolicy) -> Vec<f64> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        self.integrate(f, &m, policy).into_vec()
    }

    /// Freeze `f` into a reusable [`PreparedPlans`] handle: runs
    /// [`try_make_plan`] once per internal-node direction (caching the
    /// Chebyshev expansions, lattice FFT tables, separable
    /// decompositions and rational options inside the returned plans)
    /// and `f`-transforms the leaf distance matrices. `channels` is the
    /// expected field width `d` (only used by the planning cost model —
    /// correctness does not depend on it).
    pub fn prepare(
        &self,
        f: &FDist,
        channels: usize,
        policy: &CrossPolicy,
    ) -> Result<PreparedPlans, FtfiError> {
        self.prepare_pooled(f, channels, policy, &WorkPool::serial())
    }

    /// [`IntegratorTree::prepare`] with the per-node plan construction
    /// fanned out over a work pool: the Chebyshev probe loops and FFT
    /// table builds of different internal nodes are independent, so they
    /// parallelise embarrassingly. Plans are identical to the serial
    /// path; on failure a typed error from a failing node is surfaced
    /// and the remaining per-node work is short-circuited (the serial
    /// path surfaces the first failing node in arena order).
    pub fn prepare_pooled(
        &self,
        f: &FDist,
        channels: usize,
        policy: &CrossPolicy,
        pool: &WorkPool,
    ) -> Result<PreparedPlans, FtfiError> {
        policy.validate()?;
        let build = |node: &ItNode| -> Result<PreparedNode, FtfiError> {
            match node {
                ItNode::Leaf { dmat, .. } => Ok(PreparedNode::Leaf {
                    fmat: dmat.iter().map(|&t| f.eval(t)).collect(),
                }),
                ItNode::Internal { left, right, .. } => {
                    let into_left = try_make_plan(f, &left.d, &right.d, channels, policy)?;
                    let into_right = try_make_plan(f, &right.d, &left.d, channels, policy)?;
                    Ok(PreparedNode::Internal {
                        into_left,
                        into_right,
                        left_fd: left.d.iter().map(|&t| f.eval(t)).collect(),
                        right_fd: right.d.iter().map(|&t| f.eval(t)).collect(),
                    })
                }
            }
        };
        let mut nodes = Vec::with_capacity(self.nodes.len());
        let mut built = 0usize;
        if pool.threads() <= 1 || self.n < PAR_MAP_MIN_N {
            // Serial path: plain short-circuiting walk in arena order.
            for node in &self.nodes {
                let node = build(node)?;
                if matches!(node, PreparedNode::Internal { .. }) {
                    built += 2;
                }
                nodes.push(node);
            }
        } else {
            // Parallel fan-out with short-circuit: the map itself cannot
            // early-return, so after the first failing node every
            // remaining task bails with the `Ok(None)` sentinel instead
            // of paying its probe loops / FFT builds. A sentinel can
            // only exist if some task stored a real `Err` at its own
            // index, so the scan below always finds a typed error.
            let failed = AtomicBool::new(false);
            let prepared = pool.map(&self.nodes, |_, node| {
                if failed.load(Ordering::Relaxed) {
                    return Ok(None);
                }
                match build(node) {
                    Ok(p) => Ok(Some(p)),
                    Err(e) => {
                        failed.store(true, Ordering::Relaxed);
                        Err(e)
                    }
                }
            });
            let mut aborted = false;
            for slot in prepared {
                match slot? {
                    Some(node) => {
                        if matches!(node, PreparedNode::Internal { .. }) {
                            built += 2;
                        }
                        nodes.push(node);
                    }
                    None => aborted = true,
                }
            }
            if aborted {
                // Defensive: structurally unreachable (see above), but
                // the prepare surface must stay panic-free.
                return Err(FtfiError::InvalidInput(
                    "prepare aborted without a recorded node error".to_string(),
                ));
            }
        }
        self.plan_builds.fetch_add(built, Ordering::Relaxed);
        Ok(PreparedPlans {
            f: f.clone(),
            policy: policy.clone(),
            nodes,
            n: self.n,
            tree_id: self.id,
            plans_built: built,
        })
    }

    /// Integrate using plans frozen by [`IntegratorTree::prepare`]:
    /// no planning work happens on this path (the `plan_builds` counter
    /// does not move). Panic-free on malformed input.
    pub fn integrate_prepared(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.integrate_prepared_pooled(x, plans, &WorkPool::serial())
    }

    /// [`IntegratorTree::integrate_prepared`] running the recursion on a
    /// work pool (same forking and bit-identity contract as
    /// [`IntegratorTree::try_integrate_pooled`]).
    pub fn integrate_prepared_pooled(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        if plans.tree_id != self.id {
            return Err(FtfiError::InvalidInput(
                "prepared plans were built for a different IntegratorTree".to_string(),
            ));
        }
        if x.rows() != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: x.rows() });
        }
        if self.n == 0 {
            return Ok(Matrix::zeros(0, x.cols()));
        }
        Ok(self.integrate_prepared_node(0, x, plans, pool))
    }

    fn integrate_node(
        &self,
        idx: usize,
        x: &Matrix,
        f: &FDist,
        policy: &CrossPolicy,
        pool: &WorkPool,
    ) -> Result<Matrix, FtfiError> {
        match &self.nodes[idx] {
            ItNode::Leaf { size, dmat } => {
                Ok(leaf_apply(*size, x, |k| f.eval(dmat[k])))
            }
            ItNode::Internal { size, left_child, right_child, left, right } => {
                let d = x.cols();
                let xl = x.gather_rows(&left.ids);
                let xr = x.gather_rows(&right.ids);
                // Inner sums within each side (pivot belongs to both, but
                // its output is taken from the left side only). The two
                // sub-tree integrations are independent; large nodes fork
                // them onto the pool, and the `(left, right)` assembly
                // order keeps the result bit-identical to serial.
                let (ol, or_) = if *size >= PAR_FORK_MIN_SIZE && pool.threads() > 1 {
                    pool.join(
                        || self.integrate_node(*left_child, &xl, f, policy, pool),
                        || self.integrate_node(*right_child, &xr, f, policy, pool),
                    )
                } else {
                    (
                        self.integrate_node(*left_child, &xl, f, policy, pool),
                        self.integrate_node(*right_child, &xr, f, policy, pool),
                    )
                };
                let (ol, or_) = (ol?, or_?);

                // Aggregated fields per distinct pivot distance (Eq. 3).
                let xr_agg = aggregate(right, &xr);
                let xl_agg = aggregate(left, &xl);

                // Cross contributions (Eq. 4): C[i][j] = f(d_i + d_j) into
                // the left side, Cᵀ (roles swapped) into the right side.
                // Plans are rebuilt on every call here — that is exactly
                // what `prepare` amortises away.
                let plan_l = try_make_plan(f, &left.d, &right.d, d, policy)?;
                let plan_r = try_make_plan(f, &right.d, &left.d, d, policy)?;
                self.plan_builds.fetch_add(2, Ordering::Relaxed);
                let cr = apply_plan(&plan_l, f, &left.d, &right.d, &xr_agg, policy);
                let cl = apply_plan(&plan_r, f, &right.d, &left.d, &xl_agg, policy);
                let left_fd: Vec<f64> = left.d.iter().map(|&t| f.eval(t)).collect();
                let right_fd: Vec<f64> = right.d.iter().map(|&t| f.eval(t)).collect();
                Ok(combine_sides(
                    *size, d, left, right, &ol, &or_, &cr, &cl, &xl_agg, &xr_agg, &left_fd,
                    &right_fd,
                ))
            }
        }
    }

    fn integrate_prepared_node(
        &self,
        idx: usize,
        x: &Matrix,
        plans: &PreparedPlans,
        pool: &WorkPool,
    ) -> Matrix {
        match (&self.nodes[idx], &plans.nodes[idx]) {
            (ItNode::Leaf { size, .. }, PreparedNode::Leaf { fmat }) => {
                leaf_apply(*size, x, |k| fmat[k])
            }
            (
                ItNode::Internal { size, left_child, right_child, left, right },
                PreparedNode::Internal { into_left, into_right, left_fd, right_fd },
            ) => {
                let d = x.cols();
                let xl = x.gather_rows(&left.ids);
                let xr = x.gather_rows(&right.ids);
                // Same fork rule and assembly order as `integrate_node`.
                let (ol, or_) = if *size >= PAR_FORK_MIN_SIZE && pool.threads() > 1 {
                    pool.join(
                        || self.integrate_prepared_node(*left_child, &xl, plans, pool),
                        || self.integrate_prepared_node(*right_child, &xr, plans, pool),
                    )
                } else {
                    (
                        self.integrate_prepared_node(*left_child, &xl, plans, pool),
                        self.integrate_prepared_node(*right_child, &xr, plans, pool),
                    )
                };
                let xr_agg = aggregate(right, &xr);
                let xl_agg = aggregate(left, &xl);
                // Cached plans: no probe loops, no lattice detection, no
                // FFT-table construction on this path.
                let cr = apply_plan(into_left, &plans.f, &left.d, &right.d, &xr_agg, &plans.policy);
                let cl = apply_plan(into_right, &plans.f, &right.d, &left.d, &xl_agg, &plans.policy);
                combine_sides(
                    *size, d, left, right, &ol, &or_, &cr, &cl, &xl_agg, &xr_agg, left_fd,
                    right_fd,
                )
            }
            _ => unreachable!("prepared plans desynced from the IntegratorTree arena"),
        }
    }

    /// Structure statistics.
    pub fn stats(&self) -> ItStats {
        let mut st = ItStats {
            nodes: self.nodes.len(),
            plan_builds: self.plan_builds.load(Ordering::Relaxed),
            ..Default::default()
        };
        self.stats_rec(0, 1, &mut st);
        st
    }

    fn stats_rec(&self, idx: usize, depth: usize, st: &mut ItStats) {
        st.depth = st.depth.max(depth);
        match &self.nodes[idx] {
            ItNode::Leaf { size, .. } => {
                st.leaves += 1;
                st.max_leaf_size = st.max_leaf_size.max(*size);
            }
            ItNode::Internal { left_child, right_child, left, right, .. } => {
                st.total_distinct_distances += left.d.len() + right.d.len();
                st.max_distinct_distances =
                    st.max_distinct_distances.max(left.d.len().max(right.d.len()));
                self.stats_rec(*left_child, depth + 1, st);
                self.stats_rec(*right_child, depth + 1, st);
            }
        }
    }
}

/// Dense leaf multiply with the coefficient for flat index `i*size+j`
/// supplied by `coeff` (raw `f.eval` on the re-planning path, the cached
/// `f`-matrix on the prepared path).
fn leaf_apply(size: usize, x: &Matrix, coeff: impl Fn(usize) -> f64) -> Matrix {
    let d = x.cols();
    let mut out = Matrix::zeros(size, d);
    for i in 0..size {
        let orow = out.row_mut(i);
        for j in 0..size {
            let c = coeff(i * size + j);
            if c == 0.0 {
                continue;
            }
            for (o, &v) in orow.iter_mut().zip(x.row(j)) {
                *o += c * v;
            }
        }
    }
    out
}

/// Recombination step shared by the re-planning and prepared paths:
/// scatter inner sums + cross contributions into node-local rows, with
/// the pivot-group correction (row τ(v) minus the pivot term removes
/// j = p from the sum; the pivot row itself is produced by the left
/// pass only).
#[allow(clippy::too_many_arguments)]
fn combine_sides(
    size: usize,
    d: usize,
    left: &Side,
    right: &Side,
    ol: &Matrix,
    or_: &Matrix,
    cr: &Matrix,
    cl: &Matrix,
    xl_agg: &Matrix,
    xr_agg: &Matrix,
    left_fd: &[f64],
    right_fd: &[f64],
) -> Matrix {
    let mut out = Matrix::zeros(size, d);
    for (vloc, &tau) in left.id_d.iter().enumerate() {
        let coeff = left_fd[tau as usize];
        let node_row = left.ids[vloc] as usize;
        let dst = out.row_mut(node_row);
        let src = ol.row(vloc);
        let crr = cr.row(tau as usize);
        let piv = xr_agg.row(0);
        for c in 0..d {
            dst[c] += src[c] + crr[c] - coeff * piv[c];
        }
    }
    for (uloc, &tau) in right.id_d.iter().enumerate() {
        if uloc as u32 == right.pivot {
            continue;
        }
        let coeff = right_fd[tau as usize];
        let node_row = right.ids[uloc] as usize;
        let dst = out.row_mut(node_row);
        let src = or_.row(uloc);
        let clr = cl.row(tau as usize);
        let piv = xl_agg.row(0);
        for c in 0..d {
            dst[c] += src[c] + clr[c] - coeff * piv[c];
        }
    }
    out
}

/// Distances from `pivot` to every vertex of `side_verts`, restricted to
/// the side's vertex set; then grouped into the paper's `d`/`id-d`/`s`
/// arrays.
fn make_side(
    tree: &Tree,
    side_verts: &[u32],
    pivot: u32,
    node_local: &std::collections::HashMap<u32, u32>,
) -> Side {
    let k = side_verts.len();
    let mut member = std::collections::HashMap::with_capacity(k);
    for (i, &v) in side_verts.iter().enumerate() {
        member.insert(v, i as u32);
    }
    // DFS from the pivot inside the side.
    let mut dist = vec![f64::NAN; k];
    let pivot_local = member[&pivot];
    dist[pivot_local as usize] = 0.0;
    let mut stack = vec![pivot];
    while let Some(v) = stack.pop() {
        let dv = dist[member[&v] as usize];
        for &(u, w) in tree.neighbors(v as usize) {
            if let Some(&lu) = member.get(&u) {
                if dist[lu as usize].is_nan() {
                    dist[lu as usize] = dv + w;
                    stack.push(u);
                }
            }
        }
    }
    debug_assert!(dist.iter().all(|d| !d.is_nan()), "side not connected through pivot");

    // Sort vertices by distance, group equal distances (tolerance scaled
    // to the magnitude — exact ties happen on lattice-weight trees).
    let mut order: Vec<u32> = (0..k as u32).collect();
    order.sort_by(|&a, &b| dist[a as usize].partial_cmp(&dist[b as usize]).unwrap());
    let maxd = dist.iter().fold(0.0f64, |m, &v| m.max(v));
    let eps = 1e-9 * (1.0 + maxd);
    let mut d: Vec<f64> = Vec::new();
    let mut id_d = vec![0u32; k];
    let mut group_off: Vec<u32> = vec![0];
    let mut group_items: Vec<u32> = Vec::with_capacity(k);
    for &v in &order {
        let dv = dist[v as usize];
        if d.is_empty() || dv - *d.last().unwrap() > eps {
            d.push(dv);
            group_off.push(group_items.len() as u32);
        }
        group_items.push(v);
        id_d[v as usize] = (d.len() - 1) as u32;
        *group_off.last_mut().unwrap() += 1;
    }
    debug_assert_eq!(d[0], 0.0);
    debug_assert_eq!(group_off[1] - group_off[0], 1, "pivot group must be a singleton");

    let ids: Vec<u32> = side_verts.iter().map(|v| node_local[v]).collect();
    Side { ids, d, id_d, group_off, group_items, pivot: pivot_local }
}

/// Eq. 3: aggregate the side's field rows by distance group.
fn aggregate(side: &Side, x: &Matrix) -> Matrix {
    let l = side.d.len();
    let d = x.cols();
    let mut out = Matrix::zeros(l, d);
    for g in 0..l {
        let lo = side.group_off[g] as usize;
        let hi = side.group_off[g + 1] as usize;
        let orow = out.row_mut(g);
        for &v in &side.group_items[lo..hi] {
            for (o, &val) in orow.iter_mut().zip(x.row(v as usize)) {
                *o += val;
            }
        }
    }
    out
}

/// Dense all-pairs distances within the sub-tree induced by `verts`
/// (leaf construction): one restricted DFS per vertex, O(t²).
fn leaf_distances(tree: &Tree, verts: &[u32]) -> Vec<f64> {
    let k = verts.len();
    let mut member = std::collections::HashMap::with_capacity(k);
    for (i, &v) in verts.iter().enumerate() {
        member.insert(v, i as u32);
    }
    let mut dmat = vec![0.0; k * k];
    let mut stack = Vec::with_capacity(k);
    for (si, &s) in verts.iter().enumerate() {
        let row = &mut dmat[si * k..(si + 1) * k];
        let mut seen = vec![false; k];
        seen[si] = true;
        stack.push((s, 0.0));
        while let Some((v, dv)) = stack.pop() {
            for &(u, w) in tree.neighbors(v as usize) {
                if let Some(&lu) = member.get(&u) {
                    if !seen[lu as usize] {
                        seen[lu as usize] = true;
                        row[lu as usize] = dv + w;
                        stack.push((u, dv + w));
                    }
                }
            }
        }
    }
    dmat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::btfi;
    use crate::graph::generators::{random_rational_tree, random_tree};
    use crate::ml::rng::Pcg;

    fn check_exact(tree: &Tree, f: &FDist, d: usize, seed: u64, tol: f64) {
        let mut rng = Pcg::seed(seed);
        let x = Matrix::randn(tree.n(), d, &mut rng);
        let want = btfi(tree, f, &x);
        for &t in &[2usize, 8, 32] {
            let it = IntegratorTree::with_leaf_threshold(tree, t);
            let got = it.integrate(f, &x, &CrossPolicy::default());
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < tol, "{f:?} t={t} n={}: rel={rel}", tree.n());
            // The prepared path must agree with the re-planning path.
            let plans = it.prepare(f, d, &CrossPolicy::default()).unwrap();
            let got_p = it.integrate_prepared(&x, &plans).unwrap();
            let rel_p = got_p.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel_p < tol, "prepared {f:?} t={t} n={}: rel={rel_p}", tree.n());
        }
    }

    #[test]
    fn matches_brute_small_path() {
        let tree = Tree::path(&[1.0, 2.0, 0.5, 1.5, 3.0]);
        check_exact(&tree, &FDist::Identity, 1, 1, 1e-10);
        check_exact(&tree, &FDist::Exponential { lambda: -0.5, scale: 1.0 }, 3, 2, 1e-10);
    }

    #[test]
    fn matches_brute_random_trees_all_f_classes() {
        let mut rng = Pcg::seed(7);
        let fs: Vec<(FDist, f64)> = vec![
            (FDist::Identity, 1e-9),
            (FDist::Polynomial(vec![1.0, -0.5, 0.25]), 1e-9),
            (FDist::Exponential { lambda: -0.3, scale: 2.0 }, 1e-9),
            (FDist::Trig { omega: 0.7, phase: 0.2, scale: 1.0 }, 1e-9),
            (FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.4] }, 1e-6),
            (FDist::ExpOverLinear { lambda: -0.1, c: 1.0 }, 1e-6),
        ];
        for &n in &[3usize, 6, 17, 100, 400] {
            let tree = random_tree(n, 0.05, 1.0, &mut rng);
            for (f, tol) in &fs {
                check_exact(&tree, f, 2, n as u64, *tol);
            }
        }
    }

    #[test]
    fn matches_brute_on_lattice_trees_any_f() {
        // Rational weights → Hankel path must engage and stay exact.
        let mut rng = Pcg::seed(8);
        let tree = random_rational_tree(300, 6, 4, &mut rng);
        let f = FDist::Custom(std::sync::Arc::new(|x: f64| (0.3 * x).sin() / (1.0 + x)));
        check_exact(&tree, &f, 2, 99, 1e-8);
        // Exponentiated quadratic on a lattice tree (§3.2.1 last case).
        let g = FDist::ExpQuadratic { u: -0.05, v: 0.01, w: 0.1 };
        check_exact(&tree, &g, 1, 100, 1e-8);
    }

    #[test]
    fn unit_weight_tree_gaussian() {
        let mut rng = Pcg::seed(9);
        let tree = random_rational_tree(200, 1, 1, &mut rng); // unit weights
        check_exact(&tree, &FDist::gaussian(0.1), 3, 101, 1e-8);
    }

    #[test]
    fn singleton_and_tiny_trees() {
        let t1 = Tree::from_edges(1, &[]);
        let it = IntegratorTree::new(&t1);
        let x = Matrix::from_vec(1, 1, vec![2.0]);
        let out = it.integrate(&FDist::Exponential { lambda: 1.0, scale: 1.0 }, &x, &CrossPolicy::default());
        assert!((out.get(0, 0) - 2.0).abs() < 1e-12); // f(0)·x = 1·2

        let t2 = Tree::from_edges(2, &[(0, 1, 3.0)]);
        let it2 = IntegratorTree::with_leaf_threshold(&t2, 2);
        let x2 = Matrix::from_vec(2, 1, vec![1.0, 1.0]);
        let out2 = it2.integrate(&FDist::Identity, &x2, &CrossPolicy::default());
        assert!((out2.get(0, 0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn stats_balanced_depth() {
        let mut rng = Pcg::seed(10);
        let tree = random_tree(1000, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let st = it.stats();
        // depth ≤ log_{4/3}(n/t) + slack
        assert!(st.depth <= 30, "depth={}", st.depth);
        assert!(st.leaves >= 1000 / 8 / 4);
        assert!(st.max_leaf_size <= 8);
    }

    #[test]
    fn preserves_total_mass_for_constant_f() {
        // f ≡ 1: every output row equals the column sums of x.
        let mut rng = Pcg::seed(11);
        let tree = random_tree(150, 0.2, 1.0, &mut rng);
        let x = Matrix::randn(150, 2, &mut rng);
        let it = IntegratorTree::new(&tree);
        let f = FDist::Polynomial(vec![1.0]);
        let out = it.integrate(&f, &x, &CrossPolicy::default());
        let mut colsum = vec![0.0; 2];
        for i in 0..150 {
            for c in 0..2 {
                colsum[c] += x.get(i, c);
            }
        }
        for i in 0..150 {
            for c in 0..2 {
                assert!((out.get(i, c) - colsum[c]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn prepared_path_builds_plans_exactly_once() {
        let mut rng = Pcg::seed(12);
        let tree = random_tree(300, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 8);
        let f = FDist::inverse_quadratic(0.5);
        let policy = CrossPolicy::default();
        assert_eq!(it.stats().plan_builds, 0);
        let plans = it.prepare(&f, 2, &policy).unwrap();
        let after_prepare = it.stats().plan_builds;
        assert_eq!(after_prepare, plans.plans_built());
        assert!(after_prepare > 0, "an n=300, t=8 IT must have internal nodes");
        // Repeated prepared integrations build no further plans…
        let x = Matrix::randn(300, 2, &mut rng);
        for _ in 0..5 {
            it.integrate_prepared(&x, &plans).unwrap();
        }
        assert_eq!(it.stats().plan_builds, after_prepare);
        // …while each re-planning call rebuilds all of them.
        it.integrate(&f, &x, &policy);
        assert_eq!(it.stats().plan_builds, 2 * after_prepare);
    }

    #[test]
    fn pooled_recursion_is_bit_identical_to_serial() {
        // n is comfortably above PAR_FORK_MIN_SIZE so the recursion
        // actually forks; `forks > 0` pins that the parallel path ran.
        let mut rng = Pcg::seed(15);
        let tree = random_tree(1100, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::with_leaf_threshold(&tree, 32);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let policy = CrossPolicy::default();
        let x = Matrix::randn(1100, 2, &mut rng);
        let pool = WorkPool::new(4);
        let serial = it.try_integrate_pooled(&f, &x, &policy, &WorkPool::serial()).unwrap();
        let par = it.try_integrate_pooled(&f, &x, &policy, &pool).unwrap();
        assert!(serial == par, "pooled re-planning output must be bit-identical");
        assert!(pool.stats().forks > 0, "the 4-thread recursion never forked");
        let plans_s = it.prepare(&f, 2, &policy).unwrap();
        let plans_p = it.prepare_pooled(&f, 2, &policy, &pool).unwrap();
        let a = it.integrate_prepared_pooled(&x, &plans_s, &WorkPool::serial()).unwrap();
        let b = it.integrate_prepared_pooled(&x, &plans_p, &pool).unwrap();
        assert!(a == b, "pooled prepared output must be bit-identical");
        assert_eq!(plans_s.plans_built(), plans_p.plans_built());
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let mut rng = Pcg::seed(13);
        let tree = random_tree(50, 0.1, 1.0, &mut rng);
        let it = IntegratorTree::new(&tree);
        let f = FDist::Identity;
        let x = Matrix::zeros(49, 2);
        assert!(matches!(
            it.try_integrate(&f, &x, &CrossPolicy::default()),
            Err(FtfiError::ShapeMismatch { expected: 50, got: 49 })
        ));
        let plans = it.prepare(&f, 2, &CrossPolicy::default()).unwrap();
        assert!(matches!(
            it.integrate_prepared(&x, &plans),
            Err(FtfiError::ShapeMismatch { expected: 50, got: 49 })
        ));
    }

    #[test]
    fn prepared_plans_are_pinned_to_their_tree() {
        // Two same-shape trees (identical n, weights drawn the same way)
        // must not accept each other's plans: distance tables differ, so
        // cross-application would be silently wrong or out of bounds.
        let mut rng = Pcg::seed(14);
        let ta = random_tree(120, 0.1, 1.0, &mut rng);
        let tb = random_tree(120, 0.1, 1.0, &mut rng);
        let ita = IntegratorTree::with_leaf_threshold(&ta, 8);
        let itb = IntegratorTree::with_leaf_threshold(&tb, 8);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let plans_a = ita.prepare(&f, 1, &CrossPolicy::default()).unwrap();
        let x = Matrix::randn(120, 1, &mut rng);
        assert!(matches!(
            itb.integrate_prepared(&x, &plans_a),
            Err(FtfiError::InvalidInput(_))
        ));
        // …and the rightful owner still accepts them.
        assert!(ita.integrate_prepared(&x, &plans_a).is_ok());
    }
}
