//! FRT probabilistic tree embeddings (Fakcharoenphol, Rao & Talwar 2004)
//! — one of the low-distortion tree baselines of Fig. 4.
//!
//! Produces a 2-HST dominating the input metric with `O(log n)` expected
//! distortion. The construction requires the full distance matrix
//! (`O(n²)` preprocessing — exactly the cost the paper's Fig. 4 shows
//! making these baselines much slower than FTFI's MST route).

use super::Tree;
use crate::graph::shortest_path::all_pairs;
use crate::graph::Graph;
use crate::ml::rng::Pcg;

/// A tree over the original vertices plus Steiner (internal) nodes.
/// Original vertex `v` lives at tree vertex `leaf_of[v]`.
#[derive(Debug)]
pub struct TreeEmbedding {
    pub tree: Tree,
    pub leaf_of: Vec<u32>,
}

impl TreeEmbedding {
    /// Tree-metric distance between two *original* vertices.
    pub fn distance(&self, u: usize, v: usize) -> f64 {
        self.tree.distance(self.leaf_of[u] as usize, self.leaf_of[v] as usize)
    }

    /// Number of original (pre-embedding) vertices.
    pub fn n_original(&self) -> usize {
        self.leaf_of.len()
    }

    /// Number of Steiner (internal, added-by-the-embedding) nodes.
    pub fn n_steiner(&self) -> usize {
        self.tree.n() - self.leaf_of.len()
    }

    /// Lift a field on original vertices to the full tree (zeros on
    /// Steiner nodes) — lets any tree integrator run over the embedding.
    pub fn lift_field(&self, x: &crate::linalg::matrix::Matrix) -> crate::linalg::matrix::Matrix {
        let mut out = crate::linalg::matrix::Matrix::zeros(self.tree.n(), x.cols());
        for (v, &t) in self.leaf_of.iter().enumerate() {
            out.row_mut(t as usize).copy_from_slice(x.row(v));
        }
        out
    }

    /// Read back the rows of a full-tree field at the original vertices.
    pub fn restrict_field(
        &self,
        y: &crate::linalg::matrix::Matrix,
    ) -> crate::linalg::matrix::Matrix {
        let mut out = crate::linalg::matrix::Matrix::zeros(self.leaf_of.len(), y.cols());
        for (v, &t) in self.leaf_of.iter().enumerate() {
            out.row_mut(v).copy_from_slice(y.row(t as usize));
        }
        out
    }
}

/// Build an FRT tree for the shortest-path metric of `g`.
pub fn frt_tree(g: &Graph, rng: &mut Pcg) -> TreeEmbedding {
    frt_tree_with_dists(g.n(), &all_pairs(g), rng)
}

/// [`frt_tree`] over a precomputed dense `n×n` row-major metric — the
/// ensemble integrator samples many trees of one graph and pays the
/// `O(n²)` all-pairs preprocessing once instead of once per tree.
pub fn frt_tree_with_dists(n: usize, d: &[f64], rng: &mut Pcg) -> TreeEmbedding {
    assert!(n >= 1);
    assert_eq!(d.len(), n * n, "distance matrix must be n×n row-major");
    if n == 1 {
        return TreeEmbedding { tree: Tree::from_edges(1, &[]), leaf_of: vec![0] };
    }
    let dist = |i: usize, j: usize| d[i * n + j];
    let diameter = (0..n)
        .flat_map(|i| (0..n).map(move |j| (i, j)))
        .map(|(i, j)| dist(i, j))
        .fold(0.0f64, f64::max);
    // Levels: radius r_i = β·2^i, from 2^δ ≥ diameter down to below the
    // minimum positive distance.
    let beta = rng.uniform_in(1.0, 2.0);
    let pi = rng.permutation(n);
    let top = diameter.log2().ceil() as i32 + 1;
    let min_d = (0..n)
        .flat_map(|i| (0..n).map(move |j| (i, j)))
        .filter(|&(i, j)| i != j)
        .map(|(i, j)| dist(i, j))
        .fold(f64::INFINITY, f64::min);
    let bottom = (min_d / 2.0).log2().floor() as i32 - 1;

    // Per level, per vertex: the first centre in π within radius.
    // Cluster identity at level i = the chain of assignments from the top,
    // encoded incrementally: clusters refine as the radius shrinks.
    let mut cluster: Vec<usize> = vec![0; n]; // all together at the top
    let mut next_cluster_id = 1usize;
    // Tree construction: node per (level, cluster).
    // BTreeMaps, not HashMaps: both maps are only ever *looked up* in
    // the deterministic v = 0..n loops (never iterated), but ordered
    // maps keep tree construction provably independent of hasher state
    // — the contract the nondet-map lint enforces for this module.
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    let mut node_of_cluster: std::collections::BTreeMap<usize, u32> =
        std::collections::BTreeMap::new();
    let mut n_nodes: u32 = 1; // root = node 0 for the top cluster
    node_of_cluster.insert(0, 0);

    let mut level = top;
    while level >= bottom {
        let r = beta * (2.0f64).powi(level);
        // New sub-cluster = (old cluster, chosen centre).
        let mut remap: std::collections::BTreeMap<(usize, usize), usize> =
            std::collections::BTreeMap::new();
        let mut new_cluster = vec![0usize; n];
        for v in 0..n {
            let centre = *pi
                .iter()
                .find(|&&c| dist(v, c) <= r)
                .unwrap_or(&v); // r below min distance → own singleton
            let key = (cluster[v], centre);
            let id = *remap.entry(key).or_insert_with(|| {
                let id = next_cluster_id;
                next_cluster_id += 1;
                id
            });
            new_cluster[v] = id;
        }
        // Add tree nodes/edges for refined clusters.
        for v in 0..n {
            let parent = node_of_cluster[&cluster[v]];
            let entry = node_of_cluster.entry(new_cluster[v]).or_insert_with(|| {
                let id = n_nodes;
                n_nodes += 1;
                edges.push((parent, id, r.max(1e-9)));
                id
            });
            let _ = entry;
        }
        cluster = new_cluster;
        level -= 1;
    }
    // Attach original vertices as leaves of their final singleton cluster.
    let mut leaf_of = vec![0u32; n];
    let r_leaf = beta * (2.0f64).powi(bottom) / 2.0;
    for v in 0..n {
        let parent = node_of_cluster[&cluster[v]];
        let leaf = n_nodes;
        n_nodes += 1;
        edges.push((parent, leaf, r_leaf.max(1e-9)));
        leaf_of[v] = leaf;
    }
    TreeEmbedding { tree: Tree::from_edges(n_nodes as usize, &edges), leaf_of }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn frt_dominates_metric() {
        let mut rng = Pcg::seed(1);
        let g = generators::path_plus_random_edges(40, 20, &mut rng);
        let d = all_pairs(&g);
        let emb = frt_tree(&g, &mut rng);
        for i in 0..40 {
            for j in 0..40 {
                let dt = emb.distance(i, j);
                let dg = d[i * 40 + j];
                // Dominating up to fp slack.
                assert!(dt + 1e-6 >= dg, "({i},{j}): tree {dt} < graph {dg}");
            }
        }
    }

    #[test]
    fn frt_expected_distortion_reasonable() {
        // Average (over pairs and seeds) distortion should be modest
        // (theory: O(log n); for n=30 expect well under ~30x).
        let mut rng = Pcg::seed(2);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let d = all_pairs(&g);
        let mut total = 0.0;
        let mut count = 0;
        for seed in 0..5u64 {
            let mut r2 = Pcg::seed(seed + 100);
            let emb = frt_tree(&g, &mut r2);
            for i in 0..30 {
                for j in (i + 1)..30 {
                    total += emb.distance(i, j) / d[i * 30 + j];
                    count += 1;
                }
            }
        }
        let avg = total / count as f64;
        assert!(avg < 40.0, "avg distortion {avg}");
        assert!(avg >= 1.0 - 1e-9);
    }

    #[test]
    fn lift_restrict_roundtrip() {
        let mut rng = Pcg::seed(3);
        let g = generators::random_tree(20, 0.5, 1.5, &mut rng).to_graph();
        let emb = frt_tree(&g, &mut rng);
        let x = crate::linalg::matrix::Matrix::randn(20, 2, &mut rng);
        let lifted = emb.lift_field(&x);
        assert_eq!(lifted.rows(), emb.tree.n());
        let back = emb.restrict_field(&lifted);
        assert!(back.max_abs_diff(&x) < 1e-15);
    }

    #[test]
    fn singleton_graph() {
        let g = Graph::from_edges(1, &[]);
        let mut rng = Pcg::seed(4);
        let emb = frt_tree(&g, &mut rng);
        assert_eq!(emb.tree.n(), 1);
    }

    #[test]
    fn construction_is_bit_deterministic_for_a_fixed_seed() {
        // Pins the BTreeMap construction maps: two builds from the same
        // seed must agree bit for bit — edge lists, leaf placement and
        // every pairwise tree distance (no hasher-state dependence).
        let mut rng = Pcg::seed(7);
        let g = generators::path_plus_random_edges(35, 18, &mut rng);
        let emb_a = frt_tree(&g, &mut Pcg::seed(42));
        let emb_b = frt_tree(&g, &mut Pcg::seed(42));
        assert_eq!(emb_a.leaf_of, emb_b.leaf_of);
        assert_eq!(emb_a.tree.edges(), emb_b.tree.edges());
        for i in 0..35 {
            for j in 0..35 {
                let (da, db) = (emb_a.distance(i, j), emb_b.distance(i, j));
                assert!(da.to_bits() == db.to_bits(), "({i},{j}): {da} vs {db}");
            }
        }
    }
}
