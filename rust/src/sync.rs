//! Synchronization shim: the one import path for every primitive the
//! engine's concurrent components use ([`WorkPool`](crate::WorkPool)
//! fork/join, the prepared-plan workspace pools, the coordinator's
//! session table and shutdown flag).
//!
//! Normally this is a plain re-export of `std::sync` / `std::thread`.
//! Under `--cfg loom` (the CI model-checking job; loom is added there
//! with `cargo add`, it is not a dependency of the offline build) the
//! same names resolve to `loom` equivalents, so `tests/loom_models.rs`
//! can exhaustively explore the interleavings of the real pool and
//! arena code rather than of a copy that can drift.
//!
//! What is deliberately **not** shimmed:
//!
//! - `Arc` — plain reference counting with no interesting interleavings
//!   of its own; keeping `std::sync::Arc` everywhere avoids splitting
//!   shared types (`Arc<WorkPool>`, `Arc<TreeFieldIntegrator>`) between
//!   two `Arc` definitions across the modules loom does not model.
//! - `std::sync::mpsc` — loom cannot model channels, so the batcher's
//!   `recv_timeout` handoff is covered by the integration tests and the
//!   sanitizer CI jobs instead (see DESIGN.md "Verification & static
//!   analysis").
//!
//! Loom's primitives panic when used outside `loom::model`, and its
//! constructors are not `const`, so process-lifetime statics (e.g. the
//! integrator-tree id counter) intentionally stay on `std::sync::atomic`.

/// Atomic types and orderings (`loom::sync::atomic` under `cfg(loom)`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

#[cfg(loom)]
pub use loom::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Scoped/plain threads (`loom::thread` under `cfg(loom)`, with a
/// hand-rolled `scope` because loom has no structured-spawn API).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{scope, yield_now, Scope, ScopedJoinHandle};

    #[cfg(loom)]
    pub use self::loom_scope::{scope, Scope, ScopedJoinHandle};
    #[cfg(loom)]
    pub use loom::thread::yield_now;

    /// Minimal `std::thread::scope` lookalike on top of `loom::thread::spawn`.
    ///
    /// Loom only offers free-standing `'static` spawns, so this shim
    /// erases the `'scope` lifetime of the closure with a `transmute`
    /// and restores the soundness argument dynamically: every spawned
    /// thread is joined before its `ScopedJoinHandle` is gone — either
    /// by an explicit `join()` or by the handle's `Drop` — and the
    /// handle itself cannot outlive `'scope`. (Leaking a handle with
    /// `mem::forget` would break this; the engine never does, and this
    /// code only exists inside loom models.) The closure's result
    /// travels through a `std::sync` mutex slot that is written before
    /// the loom join and read after it, so it is never contended and
    /// adds no interleavings to the model.
    #[cfg(loom)]
    #[allow(unsafe_code)]
    mod loom_scope {
        use std::marker::PhantomData;
        use std::sync::{Arc, Mutex};

        pub struct Scope<'scope, 'env: 'scope> {
            _scope: PhantomData<&'scope mut &'scope ()>,
            _env: PhantomData<&'env mut &'env ()>,
        }

        pub struct ScopedJoinHandle<'scope, T> {
            handle: Option<loom::thread::JoinHandle<()>>,
            result: Arc<Mutex<Option<T>>>,
            _marker: PhantomData<&'scope ()>,
        }

        pub fn scope<'env, F, T>(f: F) -> T
        where
            F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
        {
            let s = Scope { _scope: PhantomData, _env: PhantomData };
            f(&s)
        }

        impl<'scope, 'env> Scope<'scope, 'env> {
            pub fn spawn<F, T>(&'scope self, f: F) -> ScopedJoinHandle<'scope, T>
            where
                F: FnOnce() -> T + Send + 'scope,
                T: Send + 'scope,
            {
                let result: Arc<Mutex<Option<T>>> = Arc::new(Mutex::new(None));
                let slot = Arc::clone(&result);
                let task: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    let value = f();
                    match slot.lock() {
                        Ok(mut guard) => *guard = Some(value),
                        Err(poisoned) => *poisoned.into_inner() = Some(value),
                    }
                });
                // SAFETY: the `'scope` borrows inside `task` stay valid
                // until the thread is joined, and the join happens (in
                // `join()` or in `Drop`) strictly before the handle —
                // which cannot outlive `'scope` — is gone.
                let task: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(task) };
                let handle = loom::thread::spawn(move || task());
                ScopedJoinHandle { handle: Some(handle), result, _marker: PhantomData }
            }
        }

        impl<'scope, T> ScopedJoinHandle<'scope, T> {
            pub fn join(mut self) -> std::thread::Result<T> {
                let handle = self.handle.take().expect("scoped handle joined twice");
                match handle.join() {
                    Ok(()) => {
                        let value = match self.result.lock() {
                            Ok(mut guard) => guard.take(),
                            Err(poisoned) => poisoned.into_inner().take(),
                        };
                        Ok(value.expect("scoped thread finished without storing a result"))
                    }
                    Err(panic) => Err(panic),
                }
            }
        }

        impl<T> Drop for ScopedJoinHandle<'_, T> {
            fn drop(&mut self) {
                if let Some(handle) = self.handle.take() {
                    // Upholds the 'scope lifetime erased in `spawn`.
                    let _ = handle.join();
                }
            }
        }
    }
}

/// A lock-protected stack of reusable arenas (workspaces, scratch
/// buffers): `checkout` pops one or builds a fresh one, `put_back`
/// returns it for the next caller. Extracted from `PreparedPlans` so
/// the checkout/return protocol itself is loom-model-checkable with
/// small mock payloads (`tests/loom_models.rs`), independently of the
/// heavyweight real arenas.
///
/// The pool never blocks progress on correctness: a poisoned lock (a
/// panic while pushing/popping) is recovered by taking the inner value,
/// which is safe because the stack only ever holds *idle* arenas —
/// every checked-out arena is resized/zeroed by its consumer before
/// use, so a half-pushed stack cannot corrupt results.
#[derive(Debug)]
pub struct ArenaPool<T> {
    stock: Mutex<Vec<T>>,
}

impl<T> Default for ArenaPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ArenaPool<T> {
    pub fn new() -> Self {
        ArenaPool { stock: Mutex::new(Vec::new()) }
    }

    /// Pop an idle arena, or build one with `make` if none is stocked.
    pub fn checkout(&self, make: impl FnOnce() -> T) -> T {
        self.lock_stock().pop().unwrap_or_else(make)
    }

    /// Return an arena to the stock for reuse.
    pub fn put_back(&self, arena: T) {
        self.lock_stock().push(arena);
    }

    /// Number of idle arenas currently stocked (tests/metrics only).
    pub fn idle(&self) -> usize {
        self.lock_stock().len()
    }

    #[cfg(not(loom))]
    fn lock_stock(&self) -> MutexGuard<'_, Vec<T>> {
        match self.stock.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    // Loom models never poison (a panicking model thread fails the
    // whole model), and loom's poison type differs from std's.
    #[cfg(loom)]
    fn lock_stock(&self) -> MutexGuard<'_, Vec<T>> {
        self.stock.lock().expect("arena pool lock")
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::ArenaPool;

    #[test]
    fn checkout_prefers_stocked_arenas() {
        let pool: ArenaPool<Vec<u8>> = ArenaPool::new();
        assert_eq!(pool.idle(), 0);
        let fresh = pool.checkout(|| vec![1, 2, 3]);
        assert_eq!(fresh, vec![1, 2, 3]);
        pool.put_back(vec![9; 8]);
        pool.put_back(vec![7; 4]);
        assert_eq!(pool.idle(), 2);
        // LIFO: the most recently returned (warmest) arena comes back first.
        assert_eq!(pool.checkout(Vec::new), vec![7; 4]);
        assert_eq!(pool.checkout(Vec::new), vec![9; 8]);
        assert_eq!(pool.checkout(Vec::new), Vec::<u8>::new());
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn contended_checkout_returns_distinct_arenas() {
        let pool: ArenaPool<Vec<u64>> = ArenaPool::new();
        for i in 0..4u64 {
            pool.put_back(vec![i; 16]);
        }
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    s.spawn(|| {
                        let a = pool.checkout(|| vec![u64::MAX; 16]);
                        assert_eq!(a.len(), 16);
                        let tag = a[0];
                        pool.put_back(a);
                        tag
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("arena checkout thread");
            }
        });
        assert_eq!(pool.idle(), 4, "every arena must be returned");
    }
}
