//! Execution runtime — two halves:
//!
//! - [`pool`]: the dependency-free scoped work pool behind every parallel
//!   FTFI path (IntegratorTree recursion forks, `prepare` plan fan-out,
//!   batch / serving fan-out). Always available; resolves its thread
//!   budget from the `threads` knobs (`FTFI_THREADS`, `--threads`,
//!   `integrator.threads`).
//! - the PJRT/XLA model runtime ([`pjrt`], [`params`], [`topvit`]):
//!   loads AOT-compiled HLO-text artifacts produced by
//!   `python/compile/aot.py` and executes them on the CPU PJRT client.
//!   Gated behind the `pjrt` cargo feature because it needs the external
//!   `xla`/`anyhow` crates (see `Cargo.toml`).

pub mod pool;

#[cfg(feature = "pjrt")]
pub mod params;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod topvit;

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Input, Runtime, TensorF32, TensorI32};
