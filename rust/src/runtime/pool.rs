//! A dependency-free scoped work pool: the execution engine behind every
//! parallel FTFI path (the IntegratorTree recursion forks, the `prepare`
//! plan fan-out, the batch / serving fan-out).
//!
//! The offline build has no rayon, so this is a std-only design with two
//! primitives:
//!
//! - [`WorkPool::join`] — structured fork/join for the divide-and-conquer
//!   IT recursion: run two closures, potentially on two threads, and
//!   return `(left, right)` in that fixed order.
//! - [`WorkPool::map`] — an order-preserving parallel map over a slice
//!   for the flat fan-outs (per-node plan building, per-field batches,
//!   per-request serving).
//!
//! **Determinism contract.** Neither primitive ever reorders a
//! floating-point reduction: `join` assembles results positionally and
//! `map` writes each result into its input slot, so outputs are
//! **bit-identical** to serial execution for any thread count (pinned by
//! `tests/ftfi_equivalence.rs`). Parallelism only changes *where* work
//! runs, never the order in which partial results are combined.
//!
//! **Oversubscription control.** A pool admits at most `threads − 1`
//! concurrent helper threads, accounted by a token counter shared by
//! nested regions: an `integrate_batch` map whose per-field integrations
//! fork internally stays bounded by the one pool budget. Helpers are
//! spawned scoped (`std::thread::scope`) per region rather than parked
//! persistently — that keeps the pool free of `unsafe` lifetime erasure,
//! and the spawn cost is amortised by the size cutoffs of the callers
//! (sub-millisecond work is never forked).
//!
//! Thread-count resolution (`FTFI_THREADS`, CLI `--threads`, config
//! `integrator.threads`) lives in [`WorkPool::with_auto`].
//!
//! The pool's primitives come from [`crate::sync`], so the CI loom job
//! (`--cfg loom`) model-checks the exact token and scope code that ships
//! — see `tests/loom_models.rs`.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::thread;
use std::panic;

/// Integration problem size (vertex count) below which one batch item /
/// serving request is too small to justify a helper thread: a scoped
/// spawn costs tens of microseconds, so fanning out sub-millisecond
/// items through [`WorkPool::map`] would make the "parallel" path
/// slower than serial. The batch and serving axes consult this before
/// mapping; the recursion axis has its own (larger) fork cutoff.
pub const PAR_MAP_MIN_N: usize = 256;

/// Scoped work pool with a fixed thread budget. See the module docs for
/// the determinism and oversubscription contracts.
#[derive(Debug)]
pub struct WorkPool {
    threads: usize,
    /// Helper-thread tokens still available (starts at `threads − 1`).
    available: AtomicUsize,
    /// Fork/join regions that actually ran two-threaded.
    forks: AtomicUsize,
    /// Map tasks executed on helper threads (caller-thread tasks are not
    /// counted — the interesting signal is work that left the caller).
    helper_tasks: AtomicUsize,
}

/// Point-in-time parallelism counters (surfaced through `ItStats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    pub threads: usize,
    /// Two-way forks that ran on two threads.
    pub forks: usize,
    /// Parallel-map tasks executed on helper threads.
    pub helper_tasks: usize,
}

/// Releases acquired helper tokens on drop, so a panicking task cannot
/// permanently shrink the pool.
struct TokenGuard<'a> {
    pool: &'a WorkPool,
    count: usize,
}

impl Drop for TokenGuard<'_> {
    fn drop(&mut self) {
        self.pool.available.fetch_add(self.count, Ordering::AcqRel);
    }
}

impl WorkPool {
    /// A pool admitting up to `threads` concurrent threads (the caller
    /// plus `threads − 1` helpers). `threads` is clamped to ≥ 1.
    pub fn new(threads: usize) -> Self {
        let t = threads.max(1);
        WorkPool {
            threads: t,
            available: AtomicUsize::new(t - 1),
            forks: AtomicUsize::new(0),
            helper_tasks: AtomicUsize::new(0),
        }
    }

    /// A single-threaded pool: `join` and `map` run strictly inline.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Resolve a user-facing `threads` knob: `0` means "auto" — honour
    /// `FTFI_THREADS` if set to a positive integer, else use all
    /// available cores; any other value is taken literally.
    pub fn with_auto(requested: usize) -> Self {
        if requested == 0 {
            Self::new(Self::threads_from_env())
        } else {
            Self::new(requested)
        }
    }

    /// The "auto" thread count: `FTFI_THREADS` (positive integer) if
    /// set, else `std::thread::available_parallelism()`, else 1.
    pub fn threads_from_env() -> usize {
        match std::env::var("FTFI_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(t) if t >= 1 => t,
            _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }

    /// The pool's thread budget (caller + helpers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Parallelism counters accumulated over the pool's lifetime.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            threads: self.threads,
            forks: self.forks.load(Ordering::Relaxed),
            helper_tasks: self.helper_tasks.load(Ordering::Relaxed),
        }
    }

    /// Try to reserve one helper token. A plain CAS loop (equivalent to
    /// `fetch_update` with `checked_sub`) so the same code compiles
    /// against both `std` and loom atomics.
    fn try_acquire(&self) -> bool {
        let mut cur = self.available.load(Ordering::Acquire);
        loop {
            let next = match cur.checked_sub(1) {
                Some(next) => next,
                None => return false,
            };
            match self.available.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Run `a` and `b`, on two threads when a helper token is free, and
    /// return `(a(), b())` — always in that order, so callers' reduction
    /// order (and hence floating-point output) is independent of the
    /// thread count. Falls back to inline serial execution when the pool
    /// is serial or saturated. Panics in either closure propagate to the
    /// caller.
    pub fn join<RA, RB, A, B>(&self, a: A, b: B) -> (RA, RB)
    where
        A: FnOnce() -> RA + Send,
        B: FnOnce() -> RB + Send,
        RA: Send,
        RB: Send,
    {
        if self.threads <= 1 || !self.try_acquire() {
            return (a(), b());
        }
        let _token = TokenGuard { pool: self, count: 1 };
        self.forks.fetch_add(1, Ordering::Relaxed);
        thread::scope(|s| {
            let hb = s.spawn(b);
            let ra = a();
            let rb = match hb.join() {
                Ok(v) => v,
                Err(p) => panic::resume_unwind(p),
            };
            (ra, rb)
        })
    }

    /// Order-preserving parallel map: `out[i] = f(i, &items[i])`. Work is
    /// distributed dynamically (an atomic cursor), results are placed by
    /// index, so the output is identical to the serial map for any thread
    /// count. Falls back to inline serial execution when the pool is
    /// serial, the input is trivial, or no helper token is free. Panics
    /// in `f` propagate to the caller.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n < 2 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let want = (self.threads - 1).min(n - 1);
        let mut acquired = 0usize;
        while acquired < want && self.try_acquire() {
            acquired += 1;
        }
        if acquired == 0 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let _tokens = TokenGuard { pool: self, count: acquired };
        let cursor = AtomicUsize::new(0);
        let run = || {
            let mut chunk: Vec<(usize, R)> = Vec::new();
            loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                chunk.push((i, f(i, &items[i])));
            }
            chunk
        };
        let chunks: Vec<Vec<(usize, R)>> = thread::scope(|s| {
            let run_ref = &run;
            let handles: Vec<_> = (0..acquired).map(|_| s.spawn(run_ref)).collect();
            let mut all = vec![run()];
            for h in handles {
                match h.join() {
                    Ok(v) => all.push(v),
                    Err(p) => panic::resume_unwind(p),
                }
            }
            all
        });
        let from_helpers: usize = chunks.iter().skip(1).map(|c| c.len()).sum();
        self.helper_tasks.fetch_add(from_helpers, Ordering::Relaxed);
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for (i, r) in chunks.into_iter().flatten() {
            slots[i] = Some(r);
        }
        // lint: infallible because the atomic cursor hands out every index in
        // 0..n exactly once and each produced chunk entry is placed by index.
        slots.into_iter().map(|o| o.expect("work pool: every map index must be produced")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkPool::new(1);
        let (a, b) = pool.join(|| 1 + 1, || 2 + 2);
        assert_eq!((a, b), (2, 4));
        let items: Vec<usize> = (0..10).collect();
        let out = pool.map(&items, |_, &v| v * 2);
        assert_eq!(out, (0..10).map(|v| v * 2).collect::<Vec<_>>());
        let st = pool.stats();
        assert_eq!(st.forks, 0, "a serial pool must never fork");
        assert_eq!(st.helper_tasks, 0, "a serial pool must never offload");
    }

    #[test]
    fn map_preserves_order_and_values() {
        let pool = WorkPool::new(4);
        let items: Vec<usize> = (0..257).collect();
        let out = pool.map(&items, |i, &v| {
            assert_eq!(i, v, "index must match the item's slot");
            v * 3 + 1
        });
        assert_eq!(out, (0..257).map(|v| v * 3 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn join_runs_both_and_counts_forks() {
        let pool = WorkPool::new(4);
        // Nested joins must not deadlock: tokens are non-blocking, so
        // saturated inner joins degrade to inline execution.
        fn sum(pool: &WorkPool, lo: u64, hi: u64) -> u64 {
            if hi - lo <= 64 {
                return (lo..hi).sum();
            }
            let mid = lo + (hi - lo) / 2;
            let (a, b) = pool.join(|| sum(pool, lo, mid), || sum(pool, mid, hi));
            a + b
        }
        let got = sum(&pool, 0, 10_000);
        assert_eq!(got, 10_000 * 9_999 / 2);
        assert!(pool.stats().forks > 0, "a 4-thread pool must fork at least once");
        // All tokens must have been returned.
        assert_eq!(pool.available.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn concurrency_is_bounded_by_the_thread_budget() {
        let pool = WorkPool::new(3);
        let current = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let items: Vec<usize> = (0..64).collect();
        pool.map(&items, |_, _| {
            let c = current.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(1));
            current.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(
            peak.load(Ordering::SeqCst) <= 3,
            "peak concurrency {} exceeded the 3-thread budget",
            peak.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn with_auto_prefers_the_explicit_request() {
        assert_eq!(WorkPool::with_auto(5).threads(), 5);
        assert_eq!(WorkPool::with_auto(1).threads(), 1);
        assert!(WorkPool::with_auto(0).threads() >= 1);
        assert_eq!(WorkPool::new(0).threads(), 1, "threads clamp to ≥ 1");
    }

    #[test]
    fn zero_thread_pool_behaves_like_serial() {
        let pool = WorkPool::new(0);
        let (a, b) = pool.join(|| "l", || "r");
        assert_eq!((a, b), ("l", "r"));
        let items: Vec<i64> = (0..7).collect();
        assert_eq!(pool.map(&items, |_, &v| -v), (0..7).map(|v| -v).collect::<Vec<_>>());
        assert_eq!(pool.stats().forks, 0);
        assert_eq!(pool.stats().helper_tasks, 0);
    }

    #[test]
    fn join_degrades_to_inline_when_tokens_are_exhausted() {
        let pool = WorkPool::new(2); // one helper token
        assert!(pool.try_acquire(), "the single token must be acquirable");
        assert!(!pool.try_acquire(), "no second token exists");
        // Saturated: join must still run both closures, inline, without
        // forking or touching the (empty) token pool.
        let forks_before = pool.stats().forks;
        let (a, b) = pool.join(|| 10, || 20);
        assert_eq!((a, b), (10, 20));
        assert_eq!(pool.stats().forks, forks_before, "saturated join must not fork");
        assert_eq!(pool.available.load(Ordering::SeqCst), 0);
        pool.available.fetch_add(1, Ordering::AcqRel); // hand the token back
        assert_eq!(pool.available.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn panic_in_map_task_poisons_neither_pool_nor_results() {
        let pool = WorkPool::new(4);
        let items: Vec<usize> = (0..512).collect();
        let caught = panic::catch_unwind(panic::AssertUnwindSafe(|| {
            pool.map(&items, |_, &v| {
                if v == 3 {
                    panic!("injected task failure");
                }
                v * 2
            })
        }));
        assert!(caught.is_err(), "the task panic must propagate to the caller");
        // Every helper token must have been returned by the guard...
        assert_eq!(pool.available.load(Ordering::SeqCst), 3);
        // ...and the pool must keep producing bit-identical results.
        let out = pool.map(&items, |_, &v| (v as f64) * 0.1);
        let serial: Vec<f64> = items.iter().map(|&v| (v as f64) * 0.1).collect();
        assert_eq!(out, serial);
    }

    #[test]
    fn panic_in_join_helper_propagates_and_restores_tokens() {
        let pool = WorkPool::new(2);
        let caught = panic::catch_unwind(panic::AssertUnwindSafe(|| {
            pool.join(|| 1, || -> i32 { panic!("helper side failed") })
        }));
        assert!(caught.is_err());
        assert_eq!(pool.available.load(Ordering::SeqCst), 1, "token restored after panic");
        let (a, b) = pool.join(|| 5, || 6);
        assert_eq!((a, b), (5, 6));
    }
}
