//! Parameter bundle I/O: the flat little-endian f32 blob + manifest that
//! `python/compile/aot.py` dumps alongside the HLO artifacts.

use super::TensorF32;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A named, ordered set of parameter tensors matching the AOT signature.
#[derive(Debug, Clone)]
pub struct ParamBundle {
    pub names: Vec<String>,
    pub tensors: Vec<TensorF32>,
}

impl ParamBundle {
    /// Load from `manifest.txt` (lines: `name dim0 dim1 …`) and the flat
    /// `.bin` blob.
    pub fn load(manifest: impl AsRef<Path>, bin: impl AsRef<Path>) -> Result<ParamBundle> {
        let text = std::fs::read_to_string(manifest.as_ref())
            .with_context(|| format!("reading {:?}", manifest.as_ref()))?;
        let mut names = Vec::new();
        let mut shapes: Vec<Vec<usize>> = Vec::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let mut it = line.split_whitespace();
            let name = it.next().context("empty manifest line")?;
            let dims: Vec<usize> =
                it.map(|t| t.parse().context("bad dim")).collect::<Result<_>>()?;
            names.push(name.to_string());
            shapes.push(dims);
        }
        let bytes =
            std::fs::read(bin.as_ref()).with_context(|| format!("reading {:?}", bin.as_ref()))?;
        if bytes.len() % 4 != 0 {
            bail!("param blob size {} not a multiple of 4", bytes.len());
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        if total != floats.len() {
            bail!("manifest expects {total} floats, blob has {}", floats.len());
        }
        let mut tensors = Vec::with_capacity(shapes.len());
        let mut off = 0;
        for shape in shapes {
            let n: usize = shape.iter().product();
            tensors.push(TensorF32::new(shape, floats[off..off + n].to_vec()));
            off += n;
        }
        Ok(ParamBundle { names, tensors })
    }

    /// Save back to a flat blob (checkpointing trained parameters).
    pub fn save_bin(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut bytes = Vec::new();
        for t in &self.tensors {
            for v in &t.data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path.as_ref(), bytes)
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }

    /// Index of a named parameter.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_tempfiles() {
        let dir = std::env::temp_dir().join(format!("ftfi-params-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = dir.join("m.txt");
        let bin = dir.join("p.bin");
        std::fs::write(&manifest, "a 2 2\nscalar\nb 3\n").unwrap();
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&bin, bytes).unwrap();

        let p = ParamBundle::load(&manifest, &bin).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.tensors[0].shape, vec![2, 2]);
        assert_eq!(p.tensors[1].shape, Vec::<usize>::new());
        assert_eq!(p.tensors[1].data, vec![4.0]);
        assert_eq!(p.index_of("b"), Some(2));

        let out = dir.join("roundtrip.bin");
        p.save_bin(&out).unwrap();
        let p2 = ParamBundle::load(&manifest, &out).unwrap();
        assert_eq!(p2.tensors[2].data, p.tensors[2].data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("ftfi-params-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = dir.join("m.txt");
        let bin = dir.join("p.bin");
        std::fs::write(&manifest, "a 4\n").unwrap();
        std::fs::write(&bin, [0u8; 8]).unwrap(); // 2 floats, need 4
        assert!(ParamBundle::load(&manifest, &bin).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
