//! TopViT-mini driver: the rust-side owner of the AOT-compiled model.
//!
//! Wraps three artifacts (fwd b=1, fwd b=8, train b=32) plus the
//! parameter bundle, exposing classify/train APIs to the coordinator and
//! the examples. All tensor plumbing is explicit: parameters are a flat
//! ordered list fed back into every call (the AOT boundary has no state).

use super::params::ParamBundle;
use super::{Executable, Input, Runtime, TensorF32, TensorI32};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Compile-time model constants — must match python/compile/model.py.
pub const IMG: usize = 32;
pub const N_CLASSES: usize = 8;
pub const TRAIN_BATCH: usize = 32;

/// A loaded TopViT-mini with its parameters.
pub struct TopVit {
    fwd: Vec<(usize, Executable)>,
    train: Option<Executable>,
    pub params: ParamBundle,
    /// When set, mask parameters are re-zeroed after every train step —
    /// the honest *unmasked performer baseline* of Table 1 (otherwise a
    /// zero-initialised mask would still be learnable).
    pub freeze_mask: bool,
}

impl TopVit {
    /// Load from the artifacts directory. `fwd_batches` lists the batch
    /// sizes to load forward executables for; `with_train` additionally
    /// loads the train-step executable.
    pub fn load(
        rt: &Runtime,
        artifacts: impl AsRef<Path>,
        params_bin: &str,
        fwd_batches: &[usize],
        with_train: bool,
    ) -> Result<TopVit> {
        let dir = artifacts.as_ref();
        let params = ParamBundle::load(
            dir.join("topvit_manifest.txt"),
            dir.join(params_bin),
        )?;
        let mut fwd = Vec::new();
        for &b in fwd_batches {
            let exe = rt
                .load_hlo_text(dir.join(format!("topvit_fwd_b{b}.hlo.txt")))
                .with_context(|| format!("loading fwd batch {b}"))?;
            fwd.push((b, exe));
        }
        let train = if with_train {
            Some(rt.load_hlo_text(dir.join(format!("topvit_train_b{TRAIN_BATCH}.hlo.txt")))?)
        } else {
            None
        };
        Ok(TopVit { fwd, train, params, freeze_mask: false })
    }

    /// Classify a batch of images (`images.len() == b·IMG·IMG` for one of
    /// the loaded batch sizes). Returns logits `(b, N_CLASSES)`.
    pub fn forward(&self, batch: usize, images: &[f32]) -> Result<TensorF32> {
        let exe = self
            .fwd
            .iter()
            .find(|(b, _)| *b == batch)
            .map(|(_, e)| e)
            .with_context(|| format!("no fwd executable for batch {batch}"))?;
        if images.len() != batch * IMG * IMG {
            bail!("expected {} pixels, got {}", batch * IMG * IMG, images.len());
        }
        let mut inputs: Vec<TensorF32> = self.params.tensors.clone();
        inputs.push(TensorF32::new(vec![batch, IMG, IMG], images.to_vec()));
        let mut out = exe.run(&inputs)?;
        if out.len() != 1 {
            bail!("fwd returned {} outputs, expected 1", out.len());
        }
        Ok(out.remove(0))
    }

    /// One SGD step on a TRAIN_BATCH batch; updates `self.params` in
    /// place and returns the loss.
    pub fn train_step(&mut self, images: &[f32], labels: &[i32], lr: f32) -> Result<f32> {
        let exe = self.train.as_ref().context("train executable not loaded")?;
        if images.len() != TRAIN_BATCH * IMG * IMG || labels.len() != TRAIN_BATCH {
            bail!("train batch shape mismatch");
        }
        let mut inputs: Vec<Input> =
            self.params.tensors.iter().cloned().map(Input::from).collect();
        inputs.push(TensorF32::new(vec![TRAIN_BATCH, IMG, IMG], images.to_vec()).into());
        inputs.push(TensorI32::new(vec![TRAIN_BATCH], labels.to_vec()).into());
        inputs.push(TensorF32::scalar(lr).into());
        let out = exe.run_mixed(&inputs)?;
        let n = self.params.tensors.len();
        if out.len() != n + 1 {
            bail!("train step returned {} outputs, expected {}", out.len(), n + 1);
        }
        let loss = out[n].data[0];
        for (dst, src) in self.params.tensors.iter_mut().zip(out.into_iter().take(n)) {
            *dst = src;
        }
        if self.freeze_mask {
            for (name, t) in self.params.names.iter().zip(self.params.tensors.iter_mut()) {
                if name.ends_with("mask_a") {
                    t.data.iter_mut().for_each(|v| *v = 0.0);
                }
            }
        }
        Ok(loss)
    }

    /// Argmax classification helper.
    pub fn classify(&self, batch: usize, images: &[f32]) -> Result<Vec<usize>> {
        let logits = self.forward(batch, images)?;
        Ok(logits
            .data
            .chunks(N_CLASSES)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// The per-layer mask parameters (the "3 extra learnable parameters").
    pub fn mask_params(&self) -> Vec<(String, Vec<f32>)> {
        self.params
            .names
            .iter()
            .zip(&self.params.tensors)
            .filter(|(n, _)| n.ends_with("mask_a"))
            .map(|(n, t)| (n.clone(), t.data.clone()))
            .collect()
    }
}

/// A [`crate::coordinator::BatchExecutor`] over a fixed-batch forward
/// executable — plugs TopViT into the serving stack.
pub struct TopVitExecutor {
    model: TopVit,
    batch: usize,
}

impl TopVitExecutor {
    pub fn new(model: TopVit, batch: usize) -> Self {
        TopVitExecutor { model, batch }
    }
}

impl crate::coordinator::BatchExecutor for TopVitExecutor {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> std::result::Result<Vec<Vec<f32>>, String> {
        // Pad to the compiled batch, run, slice per request.
        let mut flat = Vec::with_capacity(self.batch * IMG * IMG);
        for x in inputs {
            if x.len() != IMG * IMG {
                return Err(format!("bad request size {}", x.len()));
            }
            flat.extend_from_slice(x);
        }
        flat.resize(self.batch * IMG * IMG, 0.0);
        let logits = self
            .model
            .forward(self.batch, &flat)
            .map_err(|e| format!("{e:#}"))?;
        Ok(logits
            .data
            .chunks(N_CLASSES)
            .take(inputs.len())
            .map(|c| c.to_vec())
            .collect())
    }
}
