//! PJRT runtime: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — `make artifacts` lowers the JAX/
//! Pallas model to HLO **text** once (text, not serialized protos: the
//! image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction-id
//! protos, while the text parser reassigns ids — see
//! /opt/xla-example/README.md), and this module compiles + runs it.

use anyhow::{Context, Result};
use std::path::Path;

/// A shaped f32 host tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// A shaped i32 host tensor (labels etc.).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorI32 { shape, data }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// A runtime input of either dtype.
#[derive(Debug, Clone)]
pub enum Input {
    F32(TensorF32),
    I32(TensorI32),
}

impl Input {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Input::F32(t) => t.to_literal(),
            Input::I32(t) => t.to_literal(),
        }
    }
}

impl From<TensorF32> for Input {
    fn from(t: TensorF32) -> Input {
        Input::F32(t)
    }
}

impl From<TensorI32> for Input {
    fn from(t: TensorI32) -> Input {
        Input::I32(t)
    }
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorF32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        TensorF32 { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<TensorF32> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(TensorF32 { shape: dims, data })
    }
}

/// The PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string (e.g. "cpu") — used by health checks.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// One compiled model variant.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with f32 inputs; returns the flattened tuple of f32
    /// outputs. (aot.py lowers with `return_tuple=True`, so the single
    /// result literal is always a tuple.)
    pub fn run(&self, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        let mixed: Vec<Input> = inputs.iter().cloned().map(Input::from).collect();
        self.run_mixed(&mixed)
    }

    /// Execute with mixed-dtype inputs (f32 outputs only — all model
    /// outputs in this repo are f32).
    pub fn run_mixed(&self, inputs: &[Input]) -> Result<Vec<TensorF32>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("executing {}", self.name))?;
        let parts = result.to_tuple()?;
        parts.iter().map(TensorF32::from_literal).collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = TensorF32::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = TensorF32::zeros(vec![4]);
        assert_eq!(z.data, vec![0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_mismatch() {
        TensorF32::new(vec![2, 2], vec![0.0; 3]);
    }

    // PJRT-backed tests live in rust/tests/runtime_integration.rs — they
    // need the artifacts built by `make artifacts` and a working
    // libxla_extension, so they are integration- not unit-level.
}
