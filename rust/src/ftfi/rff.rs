//! Random Fourier Feature (RFF) approximate cross-term multiplication —
//! §A.2.1.
//!
//! If `f` has a Fourier transform `τ`, then
//! `f(x+y) = ∫ e^{2πiωx}·e^{2πiωy}·τ(ω) dω = E[μ(x)ᵀ μ(y)]` for random
//! features `μ` drawn from any sampling density `p`, giving the unbiased
//! low-rank factorisation `C ≈ U·Wᵀ` with `m` columns and an
//! `O((a+b)·m·d)` multiply. The estimator variance decays as `1/m`
//! (checked empirically by `rff_error_decays_with_m` below and swept by
//! the ablation bench).
//!
//! Shipped samplers: the Gaussian `f(x) = e^{-x²/(2σ²)}` (self-conjugate
//! FT — sample ω ~ N(0, 1/(2πσ)²) with τ/p ≡ const), and the Cauchy/
//! Laplacian pair `f(x) = 1/(1+(x/γ)²)` whose FT is the Laplace density.

use crate::linalg::matrix::Matrix;
use crate::ml::rng::Pcg;

/// A sampled RFF expansion of some translation-structured `f(x+y)`.
pub struct RffExpansion {
    /// Frequencies ω_l.
    omegas: Vec<f64>,
    /// Per-feature amplitude √(τ(ω_l)/p(ω_l))/√m (may be negative-free
    /// for the kernels we ship, both have non-negative τ).
    amps: Vec<f64>,
}

impl RffExpansion {
    /// Gaussian kernel `f(t) = e^{-γ t²}` (as a function of `t = x+y`).
    /// FT: `τ(ω) = √(π/γ)·e^{-π²ω²/γ}`; sampling `ω ~ N(0, γ/(2π²))`
    /// makes `τ/p` constant — the minimum-variance importance sampler.
    pub fn gaussian(gamma: f64, m: usize, rng: &mut Pcg) -> Self {
        assert!(gamma > 0.0 && m > 0);
        let sigma = (gamma / (2.0 * std::f64::consts::PI * std::f64::consts::PI)).sqrt();
        let omegas: Vec<f64> = (0..m).map(|_| rng.normal_ms(0.0, sigma)).collect();
        // τ(ω)/p(ω) = √(π/γ)·e^{-π²ω²/γ} / (N(0,σ²) pdf) = const = 1
        // after normalisation; the constant folds into amps.
        // lint: allow(mixed-precision-cast) — feature-count normalisation, not field data
        let amp = (1.0 / m as f64).sqrt();
        RffExpansion { omegas, amps: vec![amp; m] }
    }

    /// Inverse-quadratic kernel `f(t) = 1/(1+(t/γ)²)` — the paper's mesh
    /// kernel family. FT is `τ(ω) = πγ·e^{-2πγ|ω|}`; sample from the
    /// matching Laplace density so τ/p is constant.
    pub fn inverse_quadratic(gamma: f64, m: usize, rng: &mut Pcg) -> Self {
        assert!(gamma > 0.0 && m > 0);
        let scale = 1.0 / (2.0 * std::f64::consts::PI * gamma);
        let omegas: Vec<f64> = (0..m)
            .map(|_| {
                let e = rng.exponential(1.0) * scale;
                if rng.bool(0.5) {
                    e
                } else {
                    -e
                }
            })
            .collect();
        // lint: allow(mixed-precision-cast) — feature-count normalisation, not field data
        let amp = (1.0 / m as f64).sqrt();
        RffExpansion { omegas, amps: vec![amp; m] }
    }

    /// Number of features.
    pub fn m(&self) -> usize {
        self.omegas.len()
    }

    /// Feature matrix: rows `[cos(2πω_l t)·a_l , sin(2πω_l t)·a_l]_l`
    /// (real embedding of the complex feature, 2m columns).
    fn features(&self, ts: &[f64]) -> Matrix {
        let m = self.m();
        let mut out = Matrix::zeros(ts.len(), 2 * m);
        for (i, &t) in ts.iter().enumerate() {
            let row = out.row_mut(i);
            for (l, (&w, &a)) in self.omegas.iter().zip(&self.amps).enumerate() {
                let th = 2.0 * std::f64::consts::PI * w * t;
                row[l] = a * th.cos();
                row[m + l] = a * th.sin();
            }
        }
        out
    }

    /// Approximate `C·V` with `C[i][j] ≈ f(x_i + y_j)`:
    /// `U·(Wᵀ·V)` in `O((a+b)·m·d)`.
    pub fn cross_apply(&self, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
        // cos(x+y) = cos x cos y − sin x sin y;
        // the complex features make C = Re(U_c · W_cᵀ) with conjugation —
        // in the real embedding: C ≈ U_cos W_cosᵀ + U_sin W_sinᵀ where the
        // cross sign is handled by conjugating the y features.
        let u = self.features(xs);
        let w = self.features(ys);
        let m = self.m();
        let d = v.cols();
        // t1 = W_cosᵀ V ; t2 = W_sinᵀ V (m×d each)
        let mut t1 = Matrix::zeros(m, d);
        let mut t2 = Matrix::zeros(m, d);
        for (j, vrow) in (0..ys.len()).map(|j| (j, v.row(j))) {
            let wrow = w.row(j);
            for l in 0..m {
                let (c, s) = (wrow[l], wrow[m + l]);
                for ch in 0..d {
                    t1.add_at(l, ch, c * vrow[ch]);
                    t2.add_at(l, ch, s * vrow[ch]);
                }
            }
        }
        let mut out = Matrix::zeros(xs.len(), d);
        for i in 0..xs.len() {
            let urow = u.row(i);
            let orow = out.row_mut(i);
            for l in 0..m {
                let (c, s) = (urow[l], urow[m + l]);
                for (ch, o) in orow.iter_mut().enumerate() {
                    // cos(a)cos(b) - sin(a)sin(b) = cos(a+b) ✓ — note the
                    // minus sign implements the complex conjugation.
                    *o += c * t1.get(l, ch) - s * t2.get(l, ch);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ftfi::functions::FDist;

    fn rel_err(gamma_kind: &str, m: usize, seed: u64) -> f64 {
        let mut rng = Pcg::seed(seed);
        let (f, exp): (FDist, RffExpansion) = match gamma_kind {
            "gauss" => (FDist::gaussian(0.5), RffExpansion::gaussian(0.5, m, &mut rng)),
            _ => (
                FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.25] }, // 1/(1+(x/2)²)
                RffExpansion::inverse_quadratic(2.0, m, &mut rng),
            ),
        };
        let xs = rng.uniform_vec(40, 0.0, 3.0);
        let ys = rng.uniform_vec(35, 0.0, 3.0);
        let v = Matrix::randn(35, 2, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = exp.cross_apply(&xs, &ys, &v);
        got.frobenius_diff(&want) / (1.0 + want.frobenius())
    }

    #[test]
    fn rff_gaussian_is_close_with_many_features() {
        assert!(rel_err("gauss", 4096, 1) < 0.05, "err={}", rel_err("gauss", 4096, 1));
    }

    #[test]
    fn rff_inverse_quadratic_is_close_with_many_features() {
        assert!(rel_err("iq", 8192, 2) < 0.08, "err={}", rel_err("iq", 8192, 2));
    }

    #[test]
    fn rff_error_decays_with_m() {
        // Average over seeds to smooth the Monte-Carlo noise.
        let avg = |m: usize| -> f64 {
            (0..5).map(|s| rel_err("gauss", m, 100 + s)).sum::<f64>() / 5.0
        };
        let e_small = avg(64);
        let e_big = avg(4096);
        assert!(
            e_big < e_small * 0.5,
            "variance did not decay: m=64 → {e_small}, m=4096 → {e_big}"
        );
    }
}
