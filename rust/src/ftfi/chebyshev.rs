//! Chebyshev low-rank cross-term multiplication.
//!
//! For any `f` that is *smooth on the distance range* (rational f with
//! poles off the evaluation interval, exponentiated quadratics with real
//! weights, arbitrary smooth custom kernels), the bivariate function
//! `f(x+y)` is numerically low-rank: Lagrange interpolation through `M`
//! Chebyshev nodes `t_m` in the `y` variable gives
//!
//! `f(x+y) ≈ Σ_m f(x + t_m) · L_m(y)`
//!
//! — a separable rank-`M` expansion with uniform error equal to the
//! Chebyshev interpolation error of `f(x+·)` (spectral for analytic `f`).
//! Evaluated with the stable barycentric formula, this yields an
//! `O((a+b)·M·d)` multiply with `M` typically 16–64 for full fp accuracy.
//!
//! This is the numerically-robust counterpart of the exact rational/LDR
//! paths of §3.2.1: those are exact in exact arithmetic but (as is well
//! known for Trummer-type problems) lose ~1 digit per size doubling in
//! f64; Chebyshev trades "exactness" for spectral-accuracy stability at
//! the same asymptotic cost. DESIGN.md §Numerics discusses the tradeoff.

use crate::ftfi::functions::FDist;
use crate::linalg::lanes::{self, Precision};
use crate::linalg::matrix::Matrix;

/// A rank-`M` Chebyshev expansion of `f(x+y)` valid for `y ∈ [lo, hi]`.
pub struct ChebExpansion {
    /// Chebyshev nodes in the y-domain.
    nodes: Vec<f64>,
    /// Barycentric weights for the nodes.
    weights: Vec<f64>,
}

impl ChebExpansion {
    /// Build an expansion with `m` nodes on `[lo, hi]`.
    pub fn new(lo: f64, hi: f64, m: usize) -> Self {
        let m = m.max(2);
        let (lo, hi) = if hi - lo < 1e-12 { (lo - 0.5, hi + 0.5) } else { (lo, hi) };
        // Chebyshev points of the second kind (Clenshaw–Curtis nodes):
        // barycentric weights are ±1 with halved endpoints — optimally
        // stable (Berrut & Trefethen 2004).
        // lint: allow(mixed-precision-cast) — node-index to angle, planning path
        let nodes: Vec<f64> = (0..m)
            .map(|j| {
                let t = (std::f64::consts::PI * j as f64 / (m - 1) as f64).cos();
                0.5 * (lo + hi) + 0.5 * (hi - lo) * t
            })
            .collect();
        let weights: Vec<f64> = (0..m)
            .map(|j| {
                let w = if j % 2 == 0 { 1.0 } else { -1.0 };
                if j == 0 || j == m - 1 {
                    0.5 * w
                } else {
                    w
                }
            })
            .collect();
        ChebExpansion { nodes, weights }
    }

    /// Number of interpolation nodes (the expansion rank).
    pub fn rank(&self) -> usize {
        self.nodes.len()
    }

    /// Barycentric Lagrange basis values `L_m(y)` for one `y`.
    fn basis(&self, y: f64, out: &mut [f64]) {
        // Exact-hit handling: if y coincides with a node, the basis is a
        // Kronecker delta.
        for (m, &t) in self.nodes.iter().enumerate() {
            if (y - t).abs() < 1e-14 {
                out.iter_mut().for_each(|o| *o = 0.0);
                out[m] = 1.0;
                return;
            }
        }
        let mut denom = 0.0;
        for ((o, &t), &w) in out.iter_mut().zip(&self.nodes).zip(&self.weights) {
            let q = w / (y - t);
            *o = q;
            denom += q;
        }
        for o in out.iter_mut() {
            *o /= denom;
        }
    }

    /// Estimate the interpolation error of `f(x+·)` over probe points
    /// (used by the dispatcher to accept/reject/grow the expansion).
    pub fn probe_error(&self, f: &FDist, xs: &[f64], ys_lo: f64, ys_hi: f64) -> f64 {
        let m = self.rank();
        let mut basis = vec![0.0; m];
        let probes = m + 9;
        let mut worst: f64 = 0.0;
        // Probe the extremes and centre of the x-range (xs is unsorted)
        // against a dense sweep of off-node y's.
        let (xlo, xhi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        let x_samples: Vec<f64> = vec![xlo, 0.5 * (xlo + xhi), xhi];
        for &x in &x_samples {
            for p in 0..probes {
                // lint: allow(mixed-precision-cast) — probe-index to coordinate, planning path
                let y = ys_lo + (ys_hi - ys_lo) * (p as f64 + 0.37) / probes as f64;
                self.basis(y, &mut basis);
                let approx: f64 = self
                    .nodes
                    .iter()
                    .zip(&basis)
                    .map(|(&t, &b)| b * f.eval(x + t))
                    .sum();
                let exact = f.eval(x + y);
                worst = worst.max((approx - exact).abs() / (1.0 + exact.abs()));
            }
        }
        worst
    }

    /// `C·V` with `C[i][j] ≈ f(x_i + y_j)`:
    /// `out[i] = Σ_m f(x_i + t_m)·(Σ_j L_m(y_j)·V[j])` — O((a+b)·M·d).
    pub fn cross_apply(&self, f: &FDist, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
        assert_eq!(v.rows(), ys.len());
        let m = self.rank();
        let d = v.cols();
        let mut out = Matrix::zeros(xs.len(), d);
        let mut w = vec![0.0; m * d];
        let mut basis = vec![0.0; m];
        self.cross_apply_into(
            f,
            xs,
            ys,
            v.data(),
            d,
            out.data_mut(),
            &mut w,
            &mut basis,
            Precision::F64,
        );
        out
    }

    /// [`ChebExpansion::cross_apply`] into caller-provided buffers — the
    /// allocation-free hot-path variant. `v` is `ys.len()×d` row-major,
    /// `out` is `xs.len()×d`; `w` (≥ rank·d) and `basis_buf` (≥ rank) are
    /// scratch and may be dirty on entry. Both Horner-style accumulation
    /// stages (basis gather, node scatter) are lane-chunked over the
    /// d-channel axis; at [`Precision::F64`] this is bit-identical to
    /// [`ChebExpansion::cross_apply`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn cross_apply_into(
        &self,
        f: &FDist,
        xs: &[f64],
        ys: &[f64],
        v: &[f64],
        d: usize,
        out: &mut [f64],
        w: &mut [f64],
        basis_buf: &mut [f64],
        prec: Precision,
    ) {
        let m = self.rank();
        assert_eq!(v.len(), ys.len() * d);
        assert_eq!(out.len(), xs.len() * d);
        // Aggregate: W[m] = Σ_j L_m(y_j)·V[j,:]  (m×d)
        let w = &mut w[..m * d];
        w.iter_mut().for_each(|x| *x = 0.0);
        let basis = &mut basis_buf[..m];
        for (j, &y) in ys.iter().enumerate() {
            self.basis(y, basis);
            let vrow = &v[j * d..(j + 1) * d];
            for (l, &b) in basis.iter().enumerate() {
                if b == 0.0 {
                    continue;
                }
                lanes::axpy_prec(prec, b, vrow, &mut w[l * d..(l + 1) * d]);
            }
        }
        // out[i] = Σ_m f(x_i + t_m)·W[m,:]
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, &x) in xs.iter().enumerate() {
            let orow = &mut out[i * d..(i + 1) * d];
            for (l, &t) in self.nodes.iter().enumerate() {
                let c = f.eval(x + t);
                if c == 0.0 {
                    continue;
                }
                lanes::axpy_prec(prec, c, &w[l * d..(l + 1) * d], orow);
            }
        }
    }
}

/// Build an expansion adaptively: doubles the node count until the probe
/// error is below `tol` or `max_rank` is hit. Returns `None` if the
/// tolerance cannot be met (e.g. f has a pole inside the range).
pub fn adaptive_expansion(
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    tol: f64,
    max_rank: usize,
) -> Option<ChebExpansion> {
    if ys.is_empty() {
        return Some(ChebExpansion::new(0.0, 1.0, 2));
    }
    let (lo, hi) = ys
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &y| (l.min(y), h.max(y)));
    let mut m = 16;
    loop {
        let exp = ChebExpansion::new(lo, hi, m);
        if exp.probe_error(f, xs, lo, hi) < tol {
            return Some(exp);
        }
        if m >= max_rank {
            return None;
        }
        m *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ml::rng::Pcg;

    #[test]
    fn interpolates_rational_kernel_spectrally() {
        let f = FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.5] };
        let mut rng = Pcg::seed(1);
        let xs = rng.uniform_vec(50, 0.0, 8.0);
        let ys = rng.uniform_vec(60, 0.0, 8.0);
        let v = Matrix::randn(60, 3, &mut rng);
        let exp = adaptive_expansion(&f, &xs, &ys, 1e-10, 256).expect("should converge");
        let got = exp.cross_apply(&f, &xs, &ys, &v);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-8, "rel={rel} rank={}", exp.rank());
        // Spectral decay: should not need a huge rank for this kernel.
        assert!(exp.rank() <= 128, "rank={}", exp.rank());
    }

    #[test]
    fn interpolates_gaussian_kernel() {
        let f = FDist::gaussian(0.2);
        let mut rng = Pcg::seed(2);
        let xs = rng.uniform_vec(40, 0.0, 6.0);
        let ys = rng.uniform_vec(40, 0.0, 6.0);
        let v = Matrix::randn(40, 2, &mut rng);
        let exp = adaptive_expansion(&f, &xs, &ys, 1e-10, 256).unwrap();
        let got = exp.cross_apply(&f, &xs, &ys, &v);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }

    #[test]
    fn rejects_pole_in_range() {
        // f = 1/x has a pole at x+y=0; with xs including 0, no expansion
        // over y∈[0,·] can converge.
        let f = FDist::Rational { num: vec![1.0], den: vec![0.0, 1.0] };
        let xs = vec![0.0, 1.0];
        let ys = vec![0.0, 1.0, 2.0];
        assert!(adaptive_expansion(&f, &xs, &ys, 1e-9, 64).is_none());
    }

    #[test]
    fn exact_node_hit() {
        let exp = ChebExpansion::new(0.0, 2.0, 9);
        let f = FDist::Identity;
        let node = exp.nodes[3];
        let ys = vec![node];
        let v = Matrix::from_vec(1, 1, vec![1.0]);
        let got = exp.cross_apply(&f, &[1.0], &ys, &v);
        assert!((got.get(0, 0) - (1.0 + node)).abs() < 1e-12);
    }

    #[test]
    fn degenerate_y_range() {
        // All ys identical: expansion must still work (range widened).
        let f = FDist::gaussian(1.0);
        let ys = vec![2.0; 5];
        let xs = vec![0.5, 1.5];
        let mut rng = Pcg::seed(3);
        let v = Matrix::randn(5, 1, &mut rng);
        let exp = adaptive_expansion(&f, &xs, &ys, 1e-9, 128).unwrap();
        let got = exp.cross_apply(&f, &xs, &ys, &v);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        assert!(got.max_abs_diff(&want) < 1e-8);
    }
}
