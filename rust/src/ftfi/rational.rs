//! Fast rational-sum cross-term multiplication (Cabello 2022, Lemma 1) —
//! the `(2+ε)`-cordial path of §3.2.1.
//!
//! Goal: given a rational `f = P/Q`, shifts `ys`, per-channel weights `V`
//! and evaluation points `xs`, compute `out[i][ch] = Σ_j V[j][ch]·f(x_i + y_j)`
//! in `O((a+b) log²)` instead of `O(a·b)`:
//!
//! 1. Each term is the rational function `N_j(x)/D_j(x)` with
//!    `N_j = V[j]·P(x+y_j)`, `D_j = Q(x+y_j)` (Taylor shifts of P, Q).
//! 2. Divide-and-conquer merge: `(N_L, D_L) ⊕ (N_R, D_R) =
//!    (N_L·D_R + N_R·D_L, D_L·D_R)` with FFT polynomial products.
//!    Denominators are shared across channels (they do not involve V).
//! 3. Fast multipoint evaluation of the final `N_ch` and `D` at all `xs`.
//!
//! **Numerical stability**: coefficient-basis products of many shifted
//! polynomials are ill-conditioned in f64. Two mitigations are built in:
//! every merge renormalises `N` and `D` by the same power of two tracked
//! in log-space (exactness preserved — the ratio is invariant), and the
//! shift set is processed in blocks of at most [`RationalOpts::block`]
//! terms, summing the block results. Even so the merge loses ~1 digit per
//! block doubling (the classic Trummer-problem behaviour), so the default
//! block is small (8) and the strategy dispatcher prefers the spectrally
//! stable Chebyshev low-rank path (`ftfi::chebyshev`) for smooth rational
//! kernels; this module remains the *exact-in-exact-arithmetic* reference
//! implementation of the paper's (2+ε)-cordial claim.
//!
//! For the prepared/workspace hot path, [`RationalPlan`] hoists every
//! field-independent artifact (shifted-basis numerator polynomials,
//! denominator-inverse tables, the scaled domain) to plan time, so a
//! frozen `Plan::RationalSum`/`Plan::Cauchy` applies with zero heap
//! allocations (`tests/hotpath_alloc.rs` pins this).

use crate::linalg::lanes::{self, Precision};
use crate::linalg::matrix::Matrix;
use crate::linalg::polynomial::{multipoint_eval, Poly, SubproductTree};
use crate::linalg::fft::Complex;

/// Tuning knobs for the rational fast path.
///
/// **Block size and f64**: the coefficient-basis D&C merge loses roughly
/// one decimal digit per doubling of the block (the classic Trummer-
/// problem instability). Block 8 keeps results exact to ~1e-10 on the
/// distance ranges produced by tree pivots; larger blocks trade accuracy
/// for speed. The dispatcher prefers the Chebyshev low-rank path for
/// smooth rational kernels, which has no such limit.
#[derive(Clone, Debug)]
pub struct RationalOpts {
    /// Max shifts combined in one divide-and-conquer product.
    pub block: usize,
}

impl Default for RationalOpts {
    fn default() -> Self {
        RationalOpts { block: 8 }
    }
}

/// Taylor shift: coefficients of `p(x + c)` given those of `p(x)`
/// (low→high). O(deg²) — degrees of P and Q are small constants.
pub fn taylor_shift(coeffs: &[f64], c: f64) -> Vec<f64> {
    let n = coeffs.len();
    if n == 0 {
        return Vec::new();
    }
    // Synthetic-division (Horner) form of the shift: repeatedly divide by
    // (x - (-c)); numerically the standard approach.
    let mut work = coeffs.to_vec();
    let mut out = vec![0.0; n];
    for item in out.iter_mut() {
        // Evaluate & deflate at -(-c) = c ... p(x) = (x + (-c))*q(x) + r
        let mut rem = 0.0;
        for w in work.iter_mut().rev() {
            let tmp = *w;
            *w = rem;
            rem = rem * c + tmp;
        }
        *item = rem;
        // drop the now-zero leading slot (the quotient occupies 0..len-1)
        work.pop();
        if work.is_empty() {
            break;
        }
    }
    out
}

/// Real (non-FFT) polynomial product, low→high coefficients. Degrees on
/// this path are tiny (`deg(P) + block·deg(Q)`), so the O(deg²)
/// schoolbook convolution beats the complex-FFT product in both speed
/// and rounding.
fn poly_mul_real(a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let mut out = vec![0.0; a.len() + b.len() - 1];
    for (i, &ai) in a.iter().enumerate() {
        if ai == 0.0 {
            continue;
        }
        for (j, &bj) in b.iter().enumerate() {
            out[i + j] += ai * bj;
        }
    }
    out
}

/// A *prepared* rational-sum cross-application for one fixed
/// `(P/Q, xs, ys)` block: everything that does not involve the field is
/// hoisted to plan time, so the per-call apply is allocation-free (the
/// `*_into` form the workspace hot path demands — see
/// `cordial::apply_plan_into`).
///
/// Derivation: within a shift block `B`, the rational sum factors over a
/// shared denominator,
/// `Σ_{j∈B} v_j·P(x+y_j)/Q(x+y_j) = (Σ_j v_j·B_j(x)) / D(x)` with
/// `D = Π_{l∈B} Q(x+y_l)` and basis numerators
/// `B_j = P(x+y_j)·Π_{l≠j} Q(x+y_l)`. `D` and every `B_j` depend only
/// on `(P, Q, ys, xs-domain)` — built here once, in the scaled variable
/// `u = (x−c0)/s ∈ [−1,1]` with per-shift power-of-two normalisation
/// (exact: the same factor scales `B_j` and `D`, so the ratio is
/// unchanged). Applying is then a per-channel coefficient combination
/// `w = Σ_j v_j·B_j` (O(block·deg)) plus Horner evaluations against the
/// precomputed `1/D(u_i)` table — no divide-and-conquer merge, no
/// complex FFT, no heap traffic.
///
/// The free functions [`rational_cross_apply`] / `cauchy_cross_apply`
/// keep the original per-call D&C + multipoint-evaluation machinery as
/// the standalone reference; this plan is what `Plan::RationalSum` /
/// `Plan::Cauchy` freeze at prepare time.
pub struct RationalPlan {
    /// Scaled evaluation points `u_i = (x_i − c0)/s`.
    u: Vec<f64>,
    blocks: Vec<RatBlock>,
    rows: usize,
    cols: usize,
    /// Max basis length over blocks — the per-task coefficient-scratch
    /// demand (`CrossScratch::rat_w`).
    coeff_len: usize,
    /// Per-column weights folded into the field (the Cauchy `e^{λy_j}`).
    col_scale: Option<Vec<f64>>,
    /// Per-row output scales (the Cauchy `e^{λx_i}`).
    row_scale: Option<Vec<f64>>,
}

struct RatBlock {
    /// First shift (column) index this block covers.
    j0: usize,
    /// Basis numerators `B_j`, coefficients low→high in `u`.
    basis: Vec<Vec<f64>>,
    /// `1 / D(u_i)` per evaluation point.
    inv_den: Vec<f64>,
}

impl RationalPlan {
    /// Build the plan for `f = P/Q` over the cross block `(xs, ys)`.
    pub fn build(num: &[f64], den: &[f64], xs: &[f64], ys: &[f64], opts: &RationalOpts) -> Self {
        let rows = xs.len();
        let cols = ys.len();
        let mut plan = RationalPlan {
            u: Vec::new(),
            blocks: Vec::new(),
            rows,
            cols,
            coeff_len: 1,
            col_scale: None,
            row_scale: None,
        };
        if rows == 0 || cols == 0 {
            return plan;
        }
        // Same scaled domain as `rational_cross_apply`: evaluating at
        // |u| ≤ 1 is what keeps coefficient-basis polynomials usable in
        // f64.
        let (lo, hi) = xs
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        let c0 = 0.5 * (lo + hi);
        let s = (0.5 * (hi - lo)).max(1.0);
        plan.u = xs.iter().map(|&x| (x - c0) / s).collect();
        let shift_scale = |poly: &[f64], y: f64| -> Vec<f64> {
            let mut cs = taylor_shift(poly, c0 + y);
            let mut sk = 1.0;
            for coef in cs.iter_mut() {
                *coef *= sk;
                sk *= s;
            }
            cs
        };
        let block = opts.block.max(1);
        for j0 in (0..cols).step_by(block) {
            let hi_j = (j0 + block).min(cols);
            let m = hi_j - j0;
            // Per-shift scaled numerator/denominator, with an exact
            // power-of-two normalisation of each Q-shift (applied to the
            // matching P-shift, so every ratio is untouched).
            let mut ps: Vec<Vec<f64>> = Vec::with_capacity(m);
            let mut qs: Vec<Vec<f64>> = Vec::with_capacity(m);
            for j in j0..hi_j {
                let mut q = shift_scale(den, ys[j]);
                let mut p = shift_scale(num, ys[j]);
                let mx = q.iter().fold(0.0f64, |a, &c| a.max(c.abs()));
                if mx.is_finite() && mx > 0.0 {
                    let alpha = (-mx.log2().round()).exp2();
                    q.iter_mut().for_each(|c| *c *= alpha);
                    p.iter_mut().for_each(|c| *c *= alpha);
                }
                ps.push(p);
                qs.push(q);
            }
            // Prefix/suffix products of the Q-shifts give every
            // `Π_{l≠j} Q_l` in O(m) polynomial products.
            let mut pre: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
            pre.push(vec![1.0]);
            for q in &qs {
                let next = poly_mul_real(pre.last().unwrap(), q);
                pre.push(next);
            }
            let mut suf: Vec<Vec<f64>> = vec![Vec::new(); m + 1];
            suf[m] = vec![1.0];
            for i in (0..m).rev() {
                suf[i] = poly_mul_real(&qs[i], &suf[i + 1]);
            }
            let dpoly = pre[m].clone();
            let basis: Vec<Vec<f64>> = (0..m)
                .map(|i| poly_mul_real(&poly_mul_real(&pre[i], &suf[i + 1]), &ps[i]))
                .collect();
            for b in &basis {
                plan.coeff_len = plan.coeff_len.max(b.len());
            }
            let inv_den: Vec<f64> = plan
                .u
                .iter()
                .map(|&ui| 1.0 / crate::ftfi::functions::horner(&dpoly, ui))
                .collect();
            plan.blocks.push(RatBlock { j0, basis, inv_den });
        }
        plan
    }

    /// Build the Cauchy-LDR plan for `f(x) = e^{λx}/(x+c)`: the rational
    /// core `1/(x+c)` with the exponential factored into per-column
    /// field weights and per-row output scales
    /// (`e^{λ(x+y)} = e^{λx}·e^{λy}`).
    pub fn build_cauchy(lambda: f64, c: f64, xs: &[f64], ys: &[f64], opts: &RationalOpts) -> Self {
        let mut plan = Self::build(&[1.0], &[c, 1.0], xs, ys, opts);
        plan.col_scale = Some(ys.iter().map(|&y| (lambda * y).exp()).collect());
        plan.row_scale = Some(xs.iter().map(|&x| (lambda * x).exp()).collect());
        plan
    }

    /// Coefficient-scratch demand of the apply step.
    pub fn coeff_len(&self) -> usize {
        self.coeff_len
    }

    /// Allocation-free apply: `v` is `cols×d` row-major, `out` is
    /// `rows×d` (fully overwritten, dirty-on-entry ok), `w` is the
    /// caller's coefficient scratch (`≥ coeff_len`). At
    /// [`Precision::F64`] this is bit-identical to
    /// [`RationalPlan::apply`] — same code path. The coefficient
    /// combination `w = Σ_j v_j·B_j` is lane-chunked
    /// (`linalg/lanes.rs`); the Horner evaluation against `1/D(u_i)`
    /// stays scalar f64 at both tiers (its intermediates feed further
    /// multiplies, so f32 rounding would compound).
    pub(crate) fn apply_into(&self, v: &[f64], d: usize, out: &mut [f64], w: &mut [f64], prec: Precision) {
        assert_eq!(v.len(), self.cols * d);
        assert_eq!(out.len(), self.rows * d);
        out.iter_mut().for_each(|o| *o = 0.0);
        let w = &mut w[..self.coeff_len];
        for blk in &self.blocks {
            for ch in 0..d {
                w.iter_mut().for_each(|x| *x = 0.0);
                for (jj, bpoly) in blk.basis.iter().enumerate() {
                    let j = blk.j0 + jj;
                    let mut coef = v[j * d + ch];
                    if let Some(cs) = &self.col_scale {
                        coef *= cs[j];
                    }
                    if coef == 0.0 {
                        continue;
                    }
                    lanes::axpy_prec(prec, coef, bpoly, &mut w[..bpoly.len()]);
                }
                for (i, (&ui, &idv)) in self.u.iter().zip(&blk.inv_den).enumerate() {
                    out[i * d + ch] += crate::ftfi::functions::horner(w, ui) * idv;
                }
            }
        }
        if let Some(rs) = &self.row_scale {
            for (i, &r) in rs.iter().enumerate() {
                for o in &mut out[i * d..(i + 1) * d] {
                    *o *= r;
                }
            }
        }
    }

    /// Allocating convenience wrapper over [`RationalPlan::apply_into`].
    pub fn apply(&self, v: &Matrix) -> Matrix {
        assert_eq!(v.rows(), self.cols);
        let d = v.cols();
        let mut out = Matrix::zeros(self.rows, d);
        let mut w = vec![0.0; self.coeff_len];
        self.apply_into(v.data(), d, out.data_mut(), &mut w, Precision::F64);
        out
    }
}

/// One node of the D&C merge: shared denominator + per-channel numerators,
/// with a shared power-of-two log-scale.
struct RatNode {
    nums: Vec<Poly>,
    den: Poly,
}

impl RatNode {
    /// Renormalise so max |coeff| across den is ~1; apply the *same*
    /// factor to numerators so every ratio N/D is unchanged.
    fn renorm(&mut self) {
        let m = self
            .den
            .coeffs
            .iter()
            .map(|c| c.abs())
            .fold(0.0f64, f64::max);
        if m > 0.0 && (m > 1e8 || m < 1e-8) {
            let s = Complex::new(1.0 / m, 0.0);
            self.den = self.den.scale(s);
            for n in self.nums.iter_mut() {
                *n = n.scale(s);
            }
        }
    }

    fn merge(a: RatNode, b: RatNode) -> RatNode {
        let den = a.den.mul(&b.den);
        let nums = a
            .nums
            .iter()
            .zip(&b.nums)
            .map(|(na, nb)| na.mul(&b.den).add(&nb.mul(&a.den)))
            .collect();
        let mut node = RatNode { nums, den };
        node.renorm();
        node
    }
}

/// Compute `out[i][ch] = Σ_j V[j][ch] · P(x_i+y_j)/Q(x_i+y_j)` using the
/// fast rational-sum machinery. `num`/`den` are the coefficients of P/Q.
pub fn rational_cross_apply(
    num: &[f64],
    den: &[f64],
    xs: &[f64],
    ys: &[f64],
    v: &Matrix,
    opts: &RationalOpts,
) -> Matrix {
    assert_eq!(v.rows(), ys.len());
    let d = v.cols();
    let mut out = Matrix::zeros(xs.len(), d);
    if xs.is_empty() || ys.is_empty() {
        return out;
    }
    // Centre and scale the evaluation domain to u ∈ [-1, 1]: building the
    // merged polynomials in the variable u = (x - c)/s keeps |u| ≤ 1 at
    // evaluation time, which is what makes the coefficient-basis products
    // usable in f64 (evaluating a degree-2·block polynomial at x = 5
    // directly would amplify cancellation by 5^{deg}).
    let (lo, hi_x) = xs
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
    let c0 = 0.5 * (lo + hi_x);
    let s = (0.5 * (hi_x - lo)).max(1.0);
    let xpts: Vec<Complex> = xs.iter().map(|&x| Complex::new((x - c0) / s, 0.0)).collect();
    // One subproduct tree shared by every block & channel evaluation.
    let tree = if xpts.len() > 16 { Some(SubproductTree::build(&xpts)) } else { None };

    // p(x + y) with x = c0 + s·u  ⇒  shift by c0 + y, then scale powers.
    let shift_scale = |poly: &[f64], y: f64| -> Vec<f64> {
        let mut cs = taylor_shift(poly, c0 + y);
        let mut sk = 1.0;
        for coef in cs.iter_mut() {
            *coef *= sk;
            sk *= s;
        }
        cs
    };

    for block in (0..ys.len()).step_by(opts.block.max(1)) {
        let hi = (block + opts.block.max(1)).min(ys.len());
        // Build leaves for this block.
        let mut nodes: Vec<RatNode> = (block..hi)
            .map(|j| {
                let pj = Poly::from_real(&shift_scale(num, ys[j]));
                let qj = Poly::from_real(&shift_scale(den, ys[j]));
                let nums = (0..d)
                    .map(|ch| pj.scale(Complex::new(v.get(j, ch), 0.0)))
                    .collect();
                RatNode { nums, den: qj }
            })
            .collect();
        // Pairwise D&C merge.
        while nodes.len() > 1 {
            let mut next = Vec::with_capacity(nodes.len().div_ceil(2));
            let mut it = nodes.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(RatNode::merge(a, b)),
                    None => next.push(a),
                }
            }
            nodes = next;
        }
        let root = nodes.pop().unwrap();
        // Evaluate shared denominator once, then each channel numerator.
        let den_vals = multipoint_eval(&root.den, &xpts, tree.as_ref());
        for (ch, numpoly) in root.nums.iter().enumerate() {
            let num_vals = multipoint_eval(numpoly, &xpts, tree.as_ref());
            for (i, (nv, dv)) in num_vals.iter().zip(&den_vals).enumerate() {
                out.add_at(i, ch, (*nv * dv.inv()).re);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ftfi::functions::FDist;
    use crate::ml::rng::Pcg;

    #[test]
    fn taylor_shift_matches_direct_eval() {
        let mut rng = Pcg::seed(1);
        for _ in 0..20 {
            let deg = rng.range(0, 6);
            let coeffs = rng.normal_vec(deg + 1);
            let c = rng.uniform_in(-3.0, 3.0);
            let shifted = taylor_shift(&coeffs, c);
            for _ in 0..5 {
                let x = rng.uniform_in(-2.0, 2.0);
                let want = crate::ftfi::functions::horner(&coeffs, x + c);
                let got = crate::ftfi::functions::horner(&shifted, x);
                assert!((want - got).abs() < 1e-9 * (1.0 + want.abs()), "{want} vs {got}");
            }
        }
    }

    #[test]
    fn rational_matches_dense_small() {
        let mut rng = Pcg::seed(2);
        // f(x) = 1/(1 + 0.3 x²) — the paper's mesh kernel.
        let num = vec![1.0];
        let den = vec![1.0, 0.0, 0.3];
        let f = FDist::Rational { num: num.clone(), den: den.clone() };
        for &(a, b, d) in &[(7usize, 9usize, 1usize), (30, 25, 3), (1, 40, 2)] {
            let xs = rng.uniform_vec(a, 0.0, 5.0);
            let ys = rng.uniform_vec(b, 0.0, 5.0);
            let v = Matrix::randn(b, d, &mut rng);
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            let got = rational_cross_apply(&num, &den, &xs, &ys, &v, &RationalOpts::default());
            assert!(
                got.max_abs_diff(&want) < 1e-7 * (1.0 + want.frobenius()),
                "a={a} b={b}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn rational_matches_dense_across_blocks() {
        // b larger than the block size so the block-summing path runs.
        let mut rng = Pcg::seed(3);
        let num = vec![0.5, 1.0];
        let den = vec![2.0, 1.0, 0.25];
        let f = FDist::Rational { num: num.clone(), den: den.clone() };
        let xs = rng.uniform_vec(150, 0.0, 10.0);
        let ys = rng.uniform_vec(300, 0.0, 10.0);
        let v = Matrix::randn(300, 2, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = rational_cross_apply(
            &num,
            &den,
            &xs,
            &ys,
            &v,
            &RationalOpts { block: 8 },
        );
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-6, "relative error {rel}");

        // Documented instability: a big block visibly degrades accuracy.
        let loose = rational_cross_apply(
            &num,
            &den,
            &xs,
            &ys,
            &v,
            &RationalOpts { block: 128 },
        );
        let rel_loose = loose.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel_loose > rel, "expected degradation, got {rel} vs {rel_loose}");
    }

    /// The prepared plan (basis-polynomial form) matches the dense
    /// reference on the same cases the legacy D&C path is pinned on, and
    /// its `apply` / `apply_into` surfaces agree bitwise.
    #[test]
    fn rational_plan_matches_dense_and_its_into_form() {
        let mut rng = Pcg::seed(12);
        let num = vec![1.0];
        let den = vec![1.0, 0.0, 0.3];
        let f = FDist::Rational { num: num.clone(), den: den.clone() };
        for &(a, b, d) in &[(7usize, 9usize, 1usize), (30, 25, 3), (1, 40, 2), (150, 300, 2)] {
            let xs = rng.uniform_vec(a, 0.0, 5.0);
            let ys = rng.uniform_vec(b, 0.0, 5.0);
            let v = Matrix::randn(b, d, &mut rng);
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            let plan = RationalPlan::build(&num, &den, &xs, &ys, &RationalOpts::default());
            let got = plan.apply(&v);
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-6, "a={a} b={b} d={d}: rel={rel}");
            let mut out = vec![f64::NAN; a * d];
            let mut w = vec![0.0; plan.coeff_len()];
            plan.apply_into(v.data(), d, &mut out, &mut w, Precision::F64);
            assert_eq!(out, got.data(), "apply_into must be bit-identical to apply");
        }
    }

    /// The Cauchy plan (exp weights folded into the rational core).
    #[test]
    fn cauchy_plan_matches_dense() {
        let mut rng = Pcg::seed(13);
        let (lambda, c) = (-0.3, 1.5);
        let f = FDist::ExpOverLinear { lambda, c };
        for &(a, b, d) in &[(9usize, 12usize, 1usize), (50, 40, 3), (200, 180, 2)] {
            let xs = rng.uniform_vec(a, 0.0, 6.0);
            let ys = rng.uniform_vec(b, 0.0, 6.0);
            let v = Matrix::randn(b, d, &mut rng);
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            let plan = RationalPlan::build_cauchy(lambda, c, &xs, &ys, &RationalOpts::default());
            let got = plan.apply(&v);
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-6, "a={a} b={b} d={d}: rel={rel}");
        }
    }

    #[test]
    fn rational_plan_degenerate_shapes() {
        let plan = RationalPlan::build(&[1.0], &[1.0, 1.0], &[], &[1.0], &RationalOpts::default());
        assert_eq!(plan.apply(&Matrix::zeros(1, 2)).rows(), 0);
        let plan = RationalPlan::build(&[1.0], &[1.0, 1.0], &[1.0], &[], &RationalOpts::default());
        let out = plan.apply(&Matrix::zeros(0, 2));
        assert_eq!(out.rows(), 1);
        assert!(out.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn degenerate_shapes() {
        let out = rational_cross_apply(
            &[1.0],
            &[1.0, 1.0],
            &[],
            &[1.0],
            &Matrix::zeros(1, 2),
            &RationalOpts::default(),
        );
        assert_eq!(out.rows(), 0);
        let out = rational_cross_apply(
            &[1.0],
            &[1.0, 1.0],
            &[1.0],
            &[],
            &Matrix::zeros(0, 2),
            &RationalOpts::default(),
        );
        assert_eq!(out.rows(), 1);
        assert!(out.data().iter().all(|&x| x == 0.0));
    }
}
