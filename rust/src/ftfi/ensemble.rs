//! Randomized tree-ensemble field integration — the paper's application
//! (a): approximating graph-metric integrals by tree-metric ones (Fig.
//! 4/5), served through an *ensemble* of low-distortion random
//! embeddings instead of the single MST.
//!
//! A single random 2-HST (FRT or Bartal) dominates the graph metric with
//! `O(log n)` *expected* distortion, but any one sample can be badly
//! stretched for particular pairs. Averaging the field integration over
//! `m` independently sampled trees is the classic variance-reduction
//! move for FRT-style embeddings (Fakcharoenphol–Rao–Talwar; see also
//! "Efficient Graph Field Integrators Meet Point Clouds"):
//!
//! ```text
//! out = (1/m) · Σ_i restrict_i( FTFI_{T_i}( f, lift_i(x) ) )
//! ```
//!
//! where `lift_i` places the field on tree `T_i`'s leaves (zeros on
//! Steiner nodes) and `restrict_i` reads the result back at the original
//! vertices. Each per-tree integration is the exact polylog-linear FTFI
//! of §3, so the whole ensemble costs `m` fast integrations plus one
//! `O(n²)` all-pairs preprocessing (shared by every sampled tree).
//!
//! **Determinism contract.** Sampling is driven by one [`Pcg`] stream
//! per ensemble member, derived only from `(seed, member index)` — never
//! from thread scheduling — and the member outputs are averaged in
//! member order. Combined with the work pool's bit-exact guarantee for
//! each per-tree integration, a fixed `(seed, trees)` pair produces
//! **bit-identical** output for any thread count.
//!
//! **Parallelism.** The ensemble adds a fourth fan-out axis — *across
//! trees* — on the same shared [`WorkPool`] that drives the intra-tree
//! recursion forks, the prepare fan-out and the batch axis, so stacked
//! budgets compose instead of oversubscribing (tokens are shared by
//! nested regions).

use crate::ftfi::cordial::CrossPolicy;
use crate::ftfi::functions::FDist;
use crate::ftfi::{FieldIntegrator, FtfiError, TreeFieldIntegrator};
use crate::graph::shortest_path::all_pairs;
use crate::graph::Graph;
use crate::linalg::lanes::Precision;
use crate::linalg::matrix::Matrix;
use crate::ml::rng::Pcg;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
use crate::tree::bartal::bartal_tree_with_dists;
use crate::tree::frt::{frt_tree_with_dists, TreeEmbedding};
use crate::tree::integrator_tree::PreparedPlans;
use std::sync::Arc;

/// Base stream id for per-member [`Pcg`] generators: member `i` samples
/// from `Pcg::new(seed, ENSEMBLE_STREAM + i)`, so streams are pairwise
/// distinct and depend only on `(seed, i)`.
const ENSEMBLE_STREAM: u64 = 0x7f4a_7c15_0bcd_ef17;

/// Which random low-distortion embedding the ensemble samples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnsembleMethod {
    /// Fakcharoenphol–Rao–Talwar 2-HSTs (`tree/frt.rs`).
    Frt,
    /// Bartal low-diameter-decomposition trees (`tree/bartal.rs`).
    Bartal,
}

impl EnsembleMethod {
    /// Parse a method name as written in config files / CLI flags.
    pub fn parse(name: &str) -> Result<EnsembleMethod, FtfiError> {
        match name.to_ascii_lowercase().as_str() {
            "frt" => Ok(EnsembleMethod::Frt),
            "bartal" => Ok(EnsembleMethod::Bartal),
            other => Err(FtfiError::InvalidInput(format!(
                "unknown ensemble method {other:?} (frt|bartal)"
            ))),
        }
    }
}

impl std::fmt::Display for EnsembleMethod {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnsembleMethod::Frt => write!(f, "frt"),
            EnsembleMethod::Bartal => write!(f, "bartal"),
        }
    }
}

/// One sampled tree: the embedding plus its preprocessed integrator
/// (both built once, at ensemble construction).
struct Member {
    emb: TreeEmbedding,
    tfi: TreeFieldIntegrator,
}

/// Per-ensemble counters (the `ItStats` analogue for the tree axis) —
/// used by tests to pin that the ensemble engaged its parallel axes and
/// by the benches to report structure sizes.
#[derive(Debug, Clone, Default)]
pub struct EnsembleStats {
    /// Ensemble size `m`.
    pub trees: usize,
    /// Total embedding-tree vertices across members (Steiner included).
    pub tree_vertices_total: usize,
    /// Total Steiner (embedding-added) nodes across members.
    pub steiner_total: usize,
    /// Cross-term plans built across all members' IntegratorTrees.
    pub plan_builds: usize,
    /// Pool-scoped fork counter (see [`crate::tree::integrator_tree::ItStats::par_forks`]).
    pub par_forks: usize,
    /// Pool-scoped helper-task counter (tree-axis + batch-axis maps).
    pub par_tasks: usize,
}

/// Fallible builder for [`EnsembleFieldIntegrator`].
pub struct EnsembleFieldIntegratorBuilder<'a> {
    graph: &'a Graph,
    trees: usize,
    seed: u64,
    method: EnsembleMethod,
    leaf_threshold: usize,
    policy: CrossPolicy,
    threads: usize,
    precision: Precision,
    pool: Option<Arc<WorkPool>>,
}

impl<'a> EnsembleFieldIntegratorBuilder<'a> {
    /// Ensemble size `m ≥ 1` (default 4).
    pub fn trees(mut self, m: usize) -> Self {
        self.trees = m;
        self
    }

    /// Sampling seed (default 0). Fixed `(seed, trees)` ⇒ bit-identical
    /// outputs for any thread count.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Embedding family (default [`EnsembleMethod::Frt`]).
    pub fn method(mut self, method: EnsembleMethod) -> Self {
        self.method = method;
        self
    }

    /// Leaf threshold `t ≥ 2` of every member's IntegratorTree
    /// (default 32).
    pub fn leaf_threshold(mut self, t: usize) -> Self {
        self.leaf_threshold = t;
        self
    }

    /// Cross-term strategy policy shared by all members.
    pub fn policy(mut self, policy: CrossPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Worker threads (`0` = auto — see
    /// [`crate::ftfi::TreeFieldIntegratorBuilder::threads`]). One pool
    /// drives the tree axis, every member's recursion forks and the
    /// batch axis.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Share an existing work pool (takes precedence over
    /// [`EnsembleFieldIntegratorBuilder::threads`]).
    pub fn pool(mut self, pool: Arc<WorkPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Serving tier. The ensemble backend only supports the default
    /// [`Precision::F64`] tier — member averaging has not been
    /// qualified against f32 products — so `build()` rejects
    /// [`Precision::F32`] with [`FtfiError::InvalidInput`].
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Validate, run all-pairs once, sample `trees` embeddings (fanned
    /// out across the pool — per-member RNG streams keep the sampling
    /// independent of scheduling) and preprocess one
    /// [`TreeFieldIntegrator`] per tree.
    pub fn build(self) -> Result<EnsembleFieldIntegrator, FtfiError> {
        if self.trees == 0 {
            return Err(FtfiError::InvalidInput(
                "ensemble needs at least one tree (trees ≥ 1)".into(),
            ));
        }
        if self.precision != Precision::F64 {
            return Err(FtfiError::InvalidInput(format!(
                "the ensemble backend only supports the f64 tier, got precision = {}",
                self.precision.as_str()
            )));
        }
        self.policy.validate()?;
        if self.leaf_threshold < 2 {
            return Err(FtfiError::InvalidInput(format!(
                "leaf_threshold must be ≥ 2, got {}",
                self.leaf_threshold
            )));
        }
        if !self.graph.is_connected() {
            return Err(FtfiError::DisconnectedGraph);
        }
        let n = self.graph.n();
        let pool = self.pool.unwrap_or_else(|| Arc::new(WorkPool::with_auto(self.threads)));
        // One O(n²) all-pairs pass shared by every sampled tree.
        let dists = all_pairs(self.graph);
        let idx: Vec<u64> = (0..self.trees as u64).collect();
        let method = self.method;
        let seed = self.seed;
        let leaf_threshold = self.leaf_threshold;
        let policy = &self.policy;
        let build_one = |_slot: usize, &member: &u64| -> Result<Member, FtfiError> {
            let mut rng = Pcg::new(seed, ENSEMBLE_STREAM.wrapping_add(member));
            let emb = match method {
                EnsembleMethod::Frt => frt_tree_with_dists(n, &dists, &mut rng),
                EnsembleMethod::Bartal => bartal_tree_with_dists(n, &dists, &mut rng),
            };
            let tfi = TreeFieldIntegrator::builder(&emb.tree)
                .leaf_threshold(leaf_threshold)
                .policy(policy.clone())
                .pool(Arc::clone(&pool))
                .build()?;
            Ok(Member { emb, tfi })
        };
        let members = pool.map(&idx, build_one);
        let members: Vec<Member> = members.into_iter().collect::<Result<_, FtfiError>>()?;
        Ok(EnsembleFieldIntegrator { members, n, seed, method, pool })
    }
}

/// Field integration on a general graph via averaging over an ensemble
/// of random low-distortion tree embeddings (FRT or Bartal). Exposes the
/// same build → (prepare) → integrate lifecycle as the single-tree
/// integrators and plugs into everything written against
/// [`FieldIntegrator`] (the serving executors, the benches, …).
pub struct EnsembleFieldIntegrator {
    members: Vec<Member>,
    /// Original-graph vertex count.
    n: usize,
    seed: u64,
    method: EnsembleMethod,
    /// One pool for every axis (tree fan-out, recursion forks, prepare
    /// fan-out, batch fan-out) — shared with every member's integrator.
    pool: Arc<WorkPool>,
}

impl EnsembleFieldIntegrator {
    /// Start building an ensemble integrator for `graph`.
    pub fn builder(graph: &Graph) -> EnsembleFieldIntegratorBuilder<'_> {
        EnsembleFieldIntegratorBuilder {
            graph,
            trees: 4,
            seed: 0,
            method: EnsembleMethod::Frt,
            leaf_threshold: 32,
            policy: CrossPolicy::default(),
            threads: 0,
            precision: Precision::F64,
            pool: None,
        }
    }

    /// Number of original-graph vertices.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Ensemble size `m`.
    pub fn trees(&self) -> usize {
        self.members.len()
    }

    /// The sampling seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The embedding family in use.
    pub fn method(&self) -> EnsembleMethod {
        self.method
    }

    /// The shared work pool.
    pub fn pool(&self) -> &Arc<WorkPool> {
        &self.pool
    }

    /// Member `i`'s embedding (benches measure distortion through it).
    pub fn embedding(&self, i: usize) -> &TreeEmbedding {
        &self.members[i].emb
    }

    /// Per-ensemble counters. The `par_*` fields are pool-scoped
    /// lifetime aggregates (compare deltas on shared pools).
    pub fn stats(&self) -> EnsembleStats {
        let ps = self.pool.stats();
        let mut st = EnsembleStats {
            trees: self.members.len(),
            par_forks: ps.forks,
            par_tasks: ps.helper_tasks,
            ..EnsembleStats::default()
        };
        for m in &self.members {
            st.tree_vertices_total += m.emb.tree.n();
            st.steiner_total += m.emb.n_steiner();
            st.plan_builds += m.tfi.stats().plan_builds;
        }
        st
    }

    fn check_rows(&self, rows: usize) -> Result<(), FtfiError> {
        if rows != self.n {
            return Err(FtfiError::ShapeMismatch { expected: self.n, got: rows });
        }
        Ok(())
    }

    /// Run `per_member` for every member (fanned across the pool when
    /// the problem is big enough to pay for helper threads) and average
    /// the results **in member order** — the reduction order never
    /// depends on the thread count, so outputs stay bit-identical.
    fn average<F>(&self, cols: usize, per_member: F) -> Result<Matrix, FtfiError>
    where
        F: Fn(usize, &Member) -> Result<Matrix, FtfiError> + Sync,
    {
        let outs: Vec<Result<Matrix, FtfiError>> =
            if self.members.len() < 2 || self.n < PAR_MAP_MIN_N {
                self.members.iter().enumerate().map(|(i, m)| per_member(i, m)).collect()
            } else {
                self.pool.map(&self.members, per_member)
            };
        let mut acc = Matrix::zeros(self.n, cols);
        for out in outs {
            acc.axpy(1.0, &out?);
        }
        // lint: allow(mixed-precision-cast) — member-count averaging, not a tier cast
        acc.scale(1.0 / self.members.len() as f64);
        Ok(acc)
    }

    /// `out[v] = (1/m)·Σ_i Σ_u f(dist_{T_i}(v,u))·x[u]` — the averaged
    /// tree-metric approximation of the graph-metric integral. Re-plans
    /// every member's cross blocks per call; prefer
    /// [`EnsembleFieldIntegrator::prepare`] when `f` is reused.
    pub fn try_integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.check_rows(x.rows())?;
        self.average(x.cols(), |_, m| {
            let lifted = m.emb.lift_field(x);
            let y = m.tfi.try_integrate(f, &lifted)?;
            Ok(m.emb.restrict_field(&y))
        })
    }

    /// Scalar-field convenience.
    pub fn try_integrate_vec(&self, f: &FDist, x: &[f64]) -> Result<Vec<f64>, FtfiError> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        Ok(self.try_integrate(f, &m)?.into_vec())
    }

    /// Freeze `f` into per-member prepared plans: every member's cross
    /// blocks are planned exactly once, here, and reused by all
    /// subsequent integrations on the handle (the serving pattern).
    pub fn prepare(&self, f: &FDist) -> Result<PreparedEnsembleIntegrator<'_>, FtfiError> {
        self.prepare_with_channels(f, 1)
    }

    /// [`EnsembleFieldIntegrator::prepare`] with a field-width hint for
    /// the planners' cost model.
    pub fn prepare_with_channels(
        &self,
        f: &FDist,
        channels: usize,
    ) -> Result<PreparedEnsembleIntegrator<'_>, FtfiError> {
        let plans = self.pool.map(&self.members, |_, m| m.tfi.prepare_plans(f, channels));
        let plans: Vec<PreparedPlans> = plans.into_iter().collect::<Result<_, FtfiError>>()?;
        Ok(PreparedEnsembleIntegrator { ens: self, plans })
    }
}

impl FieldIntegrator for EnsembleFieldIntegrator {
    fn n(&self) -> usize {
        self.n
    }
    fn integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.try_integrate(f, x)
    }
    fn work_pool(&self) -> Option<&Arc<WorkPool>> {
        Some(&self.pool)
    }
}

/// An ensemble with all members' cross-block plans frozen for one `f` —
/// the product of [`EnsembleFieldIntegrator::prepare`].
pub struct PreparedEnsembleIntegrator<'a> {
    ens: &'a EnsembleFieldIntegrator,
    plans: Vec<PreparedPlans>,
}

impl PreparedEnsembleIntegrator<'_> {
    /// Integrate one tensor field with the frozen `f`: lift → per-tree
    /// prepared integration → restrict → average, fanned across trees.
    pub fn integrate(&self, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.ens.check_rows(x.rows())?;
        self.ens.average(x.cols(), |i, m| {
            let lifted = m.emb.lift_field(x);
            let y = m.tfi.integrate_prepared(&lifted, &self.plans[i])?;
            Ok(m.emb.restrict_field(&y))
        })
    }

    /// Integrate a batch of fields, reusing every member's plans. Fields
    /// fan out across the pool (each field then walks the members
    /// serially — nested regions share the one token budget); results
    /// keep the input order and are bit-identical to serial calls.
    pub fn integrate_batch(&self, xs: &[&Matrix]) -> Result<Vec<Matrix>, FtfiError> {
        if self.ens.n < PAR_MAP_MIN_N {
            return xs.iter().map(|x| self.integrate(x)).collect();
        }
        self.ens.pool.map(xs, |_, x| self.integrate(x)).into_iter().collect()
    }

    /// Scalar-field convenience.
    pub fn integrate_vec(&self, x: &[f64]) -> Result<Vec<f64>, FtfiError> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        Ok(self.integrate(&m)?.into_vec())
    }

    /// Number of original-graph vertices.
    pub fn n(&self) -> usize {
        self.ens.n
    }

    /// Cross-term plans built at prepare time, summed over members.
    pub fn plans_built(&self) -> usize {
        self.plans.iter().map(|p| p.plans_built()).sum()
    }

    /// Steady-state workspace footprint for a `d`-channel field, summed
    /// over the members' reusable arenas (each member's prepared handle
    /// owns its own slab/scratch pool — see `DESIGN.md` §Memory layout).
    pub fn workspace_bytes(&self, d: usize) -> usize {
        self.plans.iter().map(|p| p.workspace_bytes(d)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::btfi;
    use crate::graph::generators;

    fn test_graph(n: usize, seed: u64) -> Graph {
        let mut rng = Pcg::seed(seed);
        generators::path_plus_random_edges(n, n / 2, &mut rng)
    }

    /// The ensemble output is exactly the member-order average of the
    /// per-tree integrals (lift → integrate → restrict), each pinned
    /// against the brute tree oracle.
    #[test]
    fn ensemble_average_matches_per_member_oracle() {
        let g = test_graph(40, 1);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let mut rng = Pcg::seed(2);
        let x = Matrix::randn(40, 2, &mut rng);
        for method in [EnsembleMethod::Frt, EnsembleMethod::Bartal] {
            let ens = EnsembleFieldIntegrator::builder(&g)
                .trees(3)
                .seed(7)
                .method(method)
                .build()
                .unwrap();
            let mut want = Matrix::zeros(40, 2);
            for i in 0..ens.trees() {
                let emb = ens.embedding(i);
                let lifted = emb.lift_field(&x);
                let y = btfi(&emb.tree, &f, &lifted);
                want.axpy(1.0, &emb.restrict_field(&y));
            }
            want.scale(1.0 / 3.0);
            let got = ens.try_integrate(&f, &x).unwrap();
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-9, "{method}: rel {rel}");
        }
    }

    #[test]
    fn prepared_path_matches_replanning_path_and_batches() {
        let g = test_graph(60, 3);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(4).seed(11).build().unwrap();
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let prepared = ens.prepare(&f).unwrap();
        assert!(prepared.plans_built() > 0, "embedding trees must have cross blocks");
        assert!(prepared.workspace_bytes(2) > 0, "members must size their arenas");
        assert_eq!(prepared.n(), 60);
        let mut rng = Pcg::seed(4);
        let xs: Vec<Matrix> = (0..3).map(|_| Matrix::randn(60, 2, &mut rng)).collect();
        for x in &xs {
            let a = ens.try_integrate(&f, x).unwrap();
            let b = prepared.integrate(x).unwrap();
            let drift = a.frobenius_diff(&b) / (1.0 + b.frobenius());
            assert!(drift < 1e-12, "prepared vs replanning drift {drift}");
        }
        let refs: Vec<&Matrix> = xs.iter().collect();
        let batch = prepared.integrate_batch(&refs).unwrap();
        assert_eq!(batch.len(), xs.len());
        for (x, got) in xs.iter().zip(&batch) {
            let want = prepared.integrate(x).unwrap();
            assert!(*got == want, "batch output must equal the single-field path");
        }
        // Per-ensemble counters: structure + planning visible.
        let st = ens.stats();
        assert_eq!(st.trees, 4);
        assert!(st.tree_vertices_total >= 4 * 60);
        assert!(st.plan_builds > 0);
    }

    /// Fixed `(seed, m)` reproduces bit-identically; a different seed
    /// samples different trees.
    #[test]
    fn seed_determinism_and_sensitivity() {
        let g = test_graph(50, 5);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let mut rng = Pcg::seed(6);
        let x = Matrix::randn(50, 1, &mut rng);
        let a = EnsembleFieldIntegrator::builder(&g).trees(3).seed(42).build().unwrap();
        let b = EnsembleFieldIntegrator::builder(&g).trees(3).seed(42).build().unwrap();
        let ya = a.try_integrate(&f, &x).unwrap();
        let yb = b.try_integrate(&f, &x).unwrap();
        assert!(ya == yb, "same (seed, m) must reproduce bit-identically");
        let c = EnsembleFieldIntegrator::builder(&g).trees(3).seed(43).build().unwrap();
        let yc = c.try_integrate(&f, &x).unwrap();
        assert!(
            ya.max_abs_diff(&yc) > 0.0,
            "different seeds must sample different ensembles"
        );
    }

    /// The acceptance pin: fixed `(seed, m)` ⇒ bit-identical output for
    /// any thread count, on both embedding families, replanning and
    /// prepared paths — and the parallel tree axis actually engages.
    #[test]
    fn thread_count_bit_identical() {
        let g = test_graph(300, 8);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let mut rng = Pcg::seed(9);
        let x = Matrix::randn(300, 2, &mut rng);
        for method in [EnsembleMethod::Frt, EnsembleMethod::Bartal] {
            let serial = EnsembleFieldIntegrator::builder(&g)
                .trees(4)
                .seed(21)
                .method(method)
                .threads(1)
                .build()
                .unwrap();
            let par = EnsembleFieldIntegrator::builder(&g)
                .trees(4)
                .seed(21)
                .method(method)
                .threads(4)
                .build()
                .unwrap();
            let a = serial.try_integrate(&f, &x).unwrap();
            let b = par.try_integrate(&f, &x).unwrap();
            assert!(a == b, "{method}: replanning path must be bit-identical");
            let ps = serial.prepare(&f).unwrap();
            let pp = par.prepare(&f).unwrap();
            let a = ps.integrate(&x).unwrap();
            let b = pp.integrate(&x).unwrap();
            assert!(a == b, "{method}: prepared path must be bit-identical");
            let st = par.stats();
            assert!(
                st.par_forks + st.par_tasks > 0,
                "{method}: the parallel engine never engaged"
            );
            let st = serial.stats();
            assert_eq!(st.par_forks + st.par_tasks, 0, "threads(1) must stay serial");
        }
    }

    #[test]
    fn error_paths_are_typed() {
        // trees = 0.
        let g = test_graph(10, 12);
        assert!(matches!(
            EnsembleFieldIntegrator::builder(&g).trees(0).build(),
            Err(FtfiError::InvalidInput(_))
        ));
        // Disconnected graph.
        let dg = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        assert!(matches!(
            EnsembleFieldIntegrator::builder(&dg).build(),
            Err(FtfiError::DisconnectedGraph)
        ));
        // Shape mismatch on both integrate paths.
        let ens = EnsembleFieldIntegrator::builder(&g).trees(2).build().unwrap();
        let f = FDist::Identity;
        let bad = Matrix::zeros(9, 1);
        assert!(matches!(
            ens.try_integrate(&f, &bad),
            Err(FtfiError::ShapeMismatch { expected: 10, got: 9 })
        ));
        let prepared = ens.prepare(&f).unwrap();
        assert!(matches!(
            prepared.integrate(&bad),
            Err(FtfiError::ShapeMismatch { expected: 10, got: 9 })
        ));
    }

    #[test]
    fn singleton_graph_ensemble() {
        let g = Graph::from_edges(1, &[]);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(2).build().unwrap();
        let f = FDist::Exponential { lambda: -1.0, scale: 2.0 };
        let out = ens.try_integrate_vec(&f, &[3.0]).unwrap();
        // Single vertex: out = f(0)·x = 2·3.
        assert!((out[0] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn works_through_the_field_integrator_trait() {
        let g = test_graph(30, 14);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(2).seed(1).build().unwrap();
        let backend: &dyn FieldIntegrator = &ens;
        assert_eq!(backend.n(), 30);
        let mut rng = Pcg::seed(15);
        let x = Matrix::randn(30, 1, &mut rng);
        let via_trait = backend.integrate(&FDist::Identity, &x).unwrap();
        let direct = ens.try_integrate(&FDist::Identity, &x).unwrap();
        assert!(via_trait == direct);
        assert!(ens.work_pool().is_some(), "executors must be able to share the pool");
    }

    #[test]
    fn method_parsing() {
        assert_eq!(EnsembleMethod::parse("frt").unwrap(), EnsembleMethod::Frt);
        assert_eq!(EnsembleMethod::parse("Bartal").unwrap(), EnsembleMethod::Bartal);
        assert!(matches!(
            EnsembleMethod::parse("mst"),
            Err(FtfiError::InvalidInput(_))
        ));
        assert_eq!(EnsembleMethod::Frt.to_string(), "frt");
        assert_eq!(EnsembleMethod::Bartal.to_string(), "bartal");
    }
}
