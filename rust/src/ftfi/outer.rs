//! Outer-product ("0-cordial") cross-term multiplication.
//!
//! When `f(x+y) = Σ_r g_r(x)·h_r(y)` exactly (polynomial, exponential,
//! trigonometric f and their products — §3.2.1), the cross matrix
//! `C[i][j] = f(x_i + y_j)` is a sum of `r` outer products and `C·V`
//! costs `O((a+b)·d·r)` by associativity (Fig. 2 of the paper).

use crate::ftfi::functions::Separable;
use crate::linalg::lanes::{self, Precision};
use crate::linalg::matrix::Matrix;

/// Compute `C·V` where `C[i][j] = Σ_r g_r(xs[i])·h_r(ys[j])` and `V` is
/// `ys.len() × d`. Output is `xs.len() × d`.
pub fn apply_separable(sep: &Separable, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
    assert_eq!(v.rows(), ys.len());
    let d = v.cols();
    let mut out = Matrix::zeros(xs.len(), d);
    let mut w = vec![0.0; d];
    apply_separable_into(sep, xs, ys, v.data(), d, out.data_mut(), &mut w, Precision::F64);
    out
}

/// [`apply_separable`] into caller-provided buffers — the
/// allocation-free hot-path variant. `v` is `ys.len()×d` row-major,
/// `out` is `xs.len()×d`; `w_buf` (≥ d) is scratch, dirty-on-entry ok.
/// Both axpy stages (the `h` gather and the `g` scatter) are
/// lane-chunked over the d-channel axis (`linalg/lanes.rs`); at
/// [`Precision::F64`] the function is bit-identical to
/// [`apply_separable`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_separable_into(
    sep: &Separable,
    xs: &[f64],
    ys: &[f64],
    v: &[f64],
    d: usize,
    out: &mut [f64],
    w_buf: &mut [f64],
    prec: Precision,
) {
    assert_eq!(v.len(), ys.len() * d);
    assert_eq!(out.len(), xs.len() * d);
    out.iter_mut().for_each(|o| *o = 0.0);
    // w_r = h_r(ys)^T · V  — a single d-vector per rank-1 term.
    let w = &mut w_buf[..d];
    for (g, h) in sep.g.iter().zip(&sep.h) {
        w.iter_mut().for_each(|x| *x = 0.0);
        for (j, &yj) in ys.iter().enumerate() {
            let hy = h(yj);
            if hy == 0.0 {
                continue;
            }
            lanes::axpy_prec(prec, hy, &v[j * d..(j + 1) * d], w);
        }
        for (i, &xi) in xs.iter().enumerate() {
            let gx = g(xi);
            if gx == 0.0 {
                continue;
            }
            lanes::axpy_prec(prec, gx, w, &mut out[i * d..(i + 1) * d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ftfi::functions::FDist;
    use crate::ml::rng::Pcg;

    #[test]
    fn separable_matches_dense_for_all_zero_cordial_classes() {
        let mut rng = Pcg::seed(1);
        let fs = vec![
            FDist::Identity,
            FDist::Polynomial(vec![2.0, -1.0, 0.5, 0.1]),
            FDist::Exponential { lambda: -0.7, scale: 1.3 },
            FDist::PolyExp { coeffs: vec![1.0, 0.3], lambda: -0.2 },
            FDist::Trig { omega: 0.9, phase: 0.1, scale: 2.0 },
        ];
        for f in &fs {
            let xs = rng.uniform_vec(17, 0.0, 4.0);
            let ys = rng.uniform_vec(23, 0.0, 4.0);
            let v = Matrix::randn(23, 3, &mut rng);
            let want = cross_apply_dense(f, &xs, &ys, &v);
            let sep = f.separable_rank().unwrap();
            let got = apply_separable(&sep, &xs, &ys, &v);
            assert!(
                got.max_abs_diff(&want) < 1e-8 * (1.0 + want.frobenius()),
                "{f:?}: diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn empty_rows_or_cols() {
        let f = FDist::Polynomial(vec![1.0, 1.0]);
        let sep = f.separable_rank().unwrap();
        let v = Matrix::zeros(0, 2);
        let out = apply_separable(&sep, &[1.0, 2.0], &[], &v);
        assert_eq!(out.rows(), 2);
        assert!(out.data().iter().all(|&x| x == 0.0));
    }
}
