//! Hankel (lattice) cross-term multiplication — §A.2.3.
//!
//! When all pivot distances lie on a lattice `{s·δ}` (unit-weight trees:
//! δ=1; positive-rational-weight trees: δ=1/q), the cross matrix
//! `C[i][j] = f(x_i + y_j)` embeds into a Hankel matrix over the lattice
//! and `C·V` becomes a correlation of the f-table with the aggregated
//! field, computed by FFT in `O((T+S) log(T+S) + (a+b)·d)` where `T,S`
//! are the lattice extents. This path works for **any** `f` — the paper's
//! route to `O(N log² N)` integration on unweighted trees for arbitrary f.

use crate::ftfi::functions::FDist;
use crate::linalg::fft::{fft_pow2_cached, ifft_pow2_cached, next_pow2, Complex, TwiddleTable};
use crate::linalg::matrix::Matrix;

/// Detect a common lattice spacing δ for the given values (all must be
/// ≈ non-negative integer multiples of δ). Returns `None` when no lattice
/// with at most `max_points` points covers the range.
pub fn detect_lattice(values: impl Iterator<Item = f64> + Clone, max_points: usize) -> Option<f64> {
    let mut maxv: f64 = 0.0;
    let mut delta: f64 = 0.0;
    for v in values.clone() {
        if v < -1e-12 || !v.is_finite() {
            // Negative or non-finite distances: not a lattice (and not a
            // valid metric) — report inapplicability instead of panicking.
            return None;
        }
        maxv = maxv.max(v);
        if v > 1e-12 {
            delta = if delta == 0.0 { v } else { float_gcd(delta, v, 1e-9 * (1.0 + maxv)) };
        }
    }
    if delta <= 0.0 {
        // All values ~0 — trivially a lattice with a single point.
        return Some(1.0);
    }
    let points = (maxv / delta).round() as usize + 1;
    if points > max_points {
        return None;
    }
    // Verify every value sits on the lattice within tolerance.
    let tol = 1e-7 * delta.max(1e-12);
    for v in values {
        let r = v / delta;
        if (r - r.round()).abs() * delta > tol {
            return None;
        }
    }
    Some(delta)
}

/// Euclidean gcd on floats with rounding correction.
fn float_gcd(mut a: f64, mut b: f64, tol: f64) -> f64 {
    if a < b {
        std::mem::swap(&mut a, &mut b);
    }
    while b > tol {
        let q = (a / b).round();
        let r = (a - q * b).abs();
        a = b;
        b = r;
    }
    a
}

/// Pre-planned lattice application: the f-table FFT, the per-point
/// lattice index maps for both sides, and the FFT twiddle tables are
/// all computed once and shared across all `d` channels (and across
/// C / Cᵀ, which use the same table). A plan is bound to the `(xs, ys)`
/// it was built for — `apply`/`apply_t` must be called with the same
/// point sets (the prepared integrator's invariant; debug-asserted).
pub struct LatticePlan {
    delta: f64,
    /// FFT of the f-table, length `m` (power of two ≥ table len + max(S,T)).
    table_fft: Vec<Complex>,
    m: usize,
    /// table[s] = f(s·δ) for s = 0..=T+S.
    t_max: usize,
    s_max: usize,
    /// Lattice index of every `xs` point (the C-row side).
    row_idx: Vec<u32>,
    /// Lattice index of every `ys` point (the C-column side).
    col_idx: Vec<u32>,
    /// Per-stage twiddles for the length-`m` transforms.
    twiddles: TwiddleTable,
}

impl LatticePlan {
    /// Build a plan for values `xs` (rows) and `ys` (cols) already known
    /// to lie on the lattice `δ`.
    pub fn new(f: &FDist, xs: &[f64], ys: &[f64], delta: f64) -> Self {
        let row_idx: Vec<u32> = xs.iter().map(|&x| (x / delta).round() as u32).collect();
        let col_idx: Vec<u32> = ys.iter().map(|&y| (y / delta).round() as u32).collect();
        let t_max = row_idx.iter().map(|&x| x as usize).max().unwrap_or(0);
        let s_max = col_idx.iter().map(|&y| y as usize).max().unwrap_or(0);
        // lint: allow(mixed-precision-cast) — lattice index to coordinate, planning path
        let table: Vec<f64> = (0..=t_max + s_max).map(|s| f.eval(s as f64 * delta)).collect();
        // Correlation corr[t] = Σ_s table[t+s]·w[s] for a w of length
        // max(S,T)+1 (both directions share the plan): linear convolution
        // of `table` with reversed w, so m ≥ table.len() + max(S,T).
        let m = next_pow2(table.len() + t_max.max(s_max));
        let twiddles = TwiddleTable::new(m);
        let mut table_fft = vec![Complex::ZERO; m];
        for (i, &v) in table.iter().enumerate() {
            table_fft[i].re = v;
        }
        fft_pow2_cached(&mut table_fft, &twiddles, false);
        LatticePlan { delta, table_fft, m, t_max, s_max, row_idx, col_idx, twiddles }
    }

    /// The FFT length — the complex-scratch size [`LatticePlan::apply_into`]
    /// needs (workspace arenas are sized to the max across a plan set).
    pub fn fft_len(&self) -> usize {
        self.m
    }

    /// Debug-build check that `apply`/`apply_t` were handed the point
    /// sets the plan was built for: the cached index maps are only
    /// valid for those (a same-length but different point set would
    /// silently compute the wrong product).
    fn debug_check_points(&self, xs: &[f64], ys: &[f64]) {
        debug_assert!(
            xs.len() == self.row_idx.len()
                && xs
                    .iter()
                    .zip(&self.row_idx)
                    .all(|(&x, &i)| (x / self.delta).round() as u32 == i),
            "LatticePlan applied to xs it was not built for"
        );
        debug_assert!(
            ys.len() == self.col_idx.len()
                && ys
                    .iter()
                    .zip(&self.col_idx)
                    .all(|(&y, &i)| (y / self.delta).round() as u32 == i),
            "LatticePlan applied to ys it was not built for"
        );
    }

    /// `C·V`: rows indexed by `xs`, columns by `ys`, `V` is `ys.len()×d`.
    /// `xs`/`ys` must be the point sets the plan was built for (the
    /// index maps are cached at build time; checked in debug builds).
    pub fn apply(&self, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
        self.debug_check_points(xs, ys);
        let d = v.cols();
        let mut out = Matrix::zeros(xs.len(), d);
        let mut buf = vec![Complex::ZERO; self.m];
        self.apply_dir(false, v.data(), d, out.data_mut(), &mut buf);
        out
    }

    /// `Cᵀ·U`: same table with the roles of xs/ys swapped. Same
    /// built-points binding as [`LatticePlan::apply`].
    pub fn apply_t(&self, xs: &[f64], ys: &[f64], u: &Matrix) -> Matrix {
        self.debug_check_points(xs, ys);
        let d = u.cols();
        let mut out = Matrix::zeros(ys.len(), d);
        let mut buf = vec![Complex::ZERO; self.m];
        self.apply_dir(true, u.data(), d, out.data_mut(), &mut buf);
        out
    }

    /// `C·V` into a caller-provided buffer with caller-provided complex
    /// scratch (`scratch.len() ≥ self.fft_len()`): the allocation-free
    /// hot-path variant of [`LatticePlan::apply`], bit-identical to it.
    /// `v` is `col_idx.len()×d` row-major; `out` is `row_idx.len()×d`.
    pub(crate) fn apply_into(&self, v: &[f64], d: usize, out: &mut [f64], scratch: &mut [Complex]) {
        self.apply_dir(false, v, d, out, &mut scratch[..self.m]);
    }

    /// Shared kernel: `transpose == false` maps columns (`ys`) to rows
    /// (`xs`), `true` the other way round. Every output element is
    /// overwritten, so `out` needs no pre-zeroing.
    fn apply_dir(
        &self,
        transpose: bool,
        v: &[f64],
        d: usize,
        out: &mut [f64],
        buf: &mut [Complex],
    ) {
        let (out_idx, in_idx, in_max) = if transpose {
            (&self.col_idx, &self.row_idx, self.t_max)
        } else {
            (&self.row_idx, &self.col_idx, self.s_max)
        };
        assert_eq!(v.len(), in_idx.len() * d);
        assert_eq!(out.len(), out_idx.len() * d);
        if in_idx.is_empty() || out_idx.is_empty() {
            out.iter_mut().for_each(|o| *o = 0.0);
            return;
        }
        // Process channels two at a time packed into (re, im) — one FFT
        // serves two real convolutions.
        let mut ch = 0;
        while ch < d {
            let pair = ch + 1 < d;
            for c in buf.iter_mut() {
                *c = Complex::ZERO;
            }
            // w[s] aggregated by lattice index; reversed so the
            // convolution computes a correlation with the table.
            for (j, &s) in in_idx.iter().enumerate() {
                let slot = in_max - s as usize;
                buf[slot].re += v[j * d + ch];
                if pair {
                    buf[slot].im += v[j * d + ch + 1];
                }
            }
            fft_pow2_cached(buf, &self.twiddles, false);
            for (b, t) in buf.iter_mut().zip(&self.table_fft) {
                *b = *b * *t;
            }
            ifft_pow2_cached(buf, &self.twiddles);
            if pair {
                // Unpack: conv of (w_re + i·w_im) with real table keeps
                // channels in re/im separately (table is real).
                for (i, &t) in out_idx.iter().enumerate() {
                    let c = buf[t as usize + in_max];
                    out[i * d + ch] = c.re;
                    out[i * d + ch + 1] = c.im;
                }
                ch += 2;
            } else {
                for (i, &t) in out_idx.iter().enumerate() {
                    out[i * d + ch] = buf[t as usize + in_max].re;
                }
                ch += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ml::rng::Pcg;
    use std::sync::Arc;

    #[test]
    fn detect_integer_lattice() {
        let vals = [0.0, 3.0, 1.0, 7.0, 2.0];
        let d = detect_lattice(vals.iter().copied(), 1000).unwrap();
        assert!((d - 1.0).abs() < 1e-9);
    }

    #[test]
    fn detect_rational_lattice() {
        // multiples of 1/4
        let vals = [0.25, 1.5, 0.75, 2.0];
        let d = detect_lattice(vals.iter().copied(), 1000).unwrap();
        assert!((d - 0.25).abs() < 1e-9, "{d}");
    }

    #[test]
    fn reject_irrational_mix() {
        let vals = [1.0, std::f64::consts::SQRT_2];
        assert!(detect_lattice(vals.iter().copied(), 1 << 20).is_none());
    }

    #[test]
    fn reject_oversized_lattice() {
        let vals = [1e-6, 1.0];
        assert!(detect_lattice(vals.iter().copied(), 1000).is_none());
    }

    #[test]
    fn lattice_apply_matches_dense_any_f() {
        let mut rng = Pcg::seed(7);
        // Black-box f that has no separable or rational structure.
        let f = FDist::Custom(Arc::new(|x: f64| (x * 1.3).sin() / (1.0 + x * x) + 0.1 * x));
        for &(a, b, d) in &[(5usize, 9usize, 1usize), (40, 30, 4), (1, 17, 3), (64, 64, 2)] {
            let xs: Vec<f64> = (0..a).map(|_| rng.below(30) as f64 * 0.5).collect();
            let ys: Vec<f64> = (0..b).map(|_| rng.below(30) as f64 * 0.5).collect();
            let v = Matrix::randn(b, d, &mut rng);
            let delta =
                detect_lattice(xs.iter().chain(ys.iter()).copied(), 1 << 16).unwrap();
            let plan = LatticePlan::new(&f, &xs, &ys, delta);
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            let got = plan.apply(&xs, &ys, &v);
            assert!(
                got.max_abs_diff(&want) < 1e-8 * (1.0 + want.frobenius()),
                "a={a} b={b} d={d}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn lattice_apply_t_matches_dense_transpose() {
        let mut rng = Pcg::seed(8);
        let f = FDist::Custom(Arc::new(|x: f64| (-(x)).exp() * (1.0 + x)));
        let xs: Vec<f64> = (0..13).map(|_| rng.below(20) as f64).collect();
        let ys: Vec<f64> = (0..11).map(|_| rng.below(20) as f64).collect();
        let u = Matrix::randn(13, 3, &mut rng);
        let delta = detect_lattice(xs.iter().chain(ys.iter()).copied(), 1 << 16).unwrap();
        let plan = LatticePlan::new(&f, &xs, &ys, delta);
        // Dense transpose: C^T U = apply dense with swapped roles.
        let want = cross_apply_dense(&f, &ys, &xs, &u);
        let got = plan.apply_t(&xs, &ys, &u);
        assert!(got.max_abs_diff(&want) < 1e-8 * (1.0 + want.frobenius()));
    }

    #[test]
    fn apply_into_is_bit_identical_to_apply() {
        let mut rng = Pcg::seed(9);
        let f = FDist::Custom(Arc::new(|x: f64| (0.7 * x).cos() / (1.0 + 0.1 * x)));
        for &(a, b, d) in &[(7usize, 11usize, 1usize), (33, 20, 3), (16, 16, 4)] {
            let xs: Vec<f64> = (0..a).map(|_| rng.below(25) as f64 * 0.5).collect();
            let ys: Vec<f64> = (0..b).map(|_| rng.below(25) as f64 * 0.5).collect();
            let v = Matrix::randn(b, d, &mut rng);
            let delta = detect_lattice(xs.iter().chain(ys.iter()).copied(), 1 << 16).unwrap();
            let plan = LatticePlan::new(&f, &xs, &ys, delta);
            let want = plan.apply(&xs, &ys, &v);
            let mut out = vec![f64::NAN; a * d]; // dirty: apply_into must overwrite
            let mut scratch = vec![Complex::new(3.0, -3.0); plan.fft_len() + 5];
            plan.apply_into(v.data(), d, &mut out, &mut scratch);
            assert_eq!(out, want.data(), "a={a} b={b} d={d}");
        }
    }

    #[test]
    fn all_zero_distances() {
        let f = FDist::Identity;
        let xs = [0.0, 0.0];
        let ys = [0.0];
        let delta = detect_lattice(xs.iter().chain(ys.iter()).copied(), 10).unwrap();
        let plan = LatticePlan::new(&f, &xs, &ys, delta);
        let v = Matrix::from_vec(1, 1, vec![5.0]);
        let got = plan.apply(&xs, &ys, &v);
        assert_eq!(got.rows(), 2);
        assert!(got.get(0, 0).abs() < 1e-12); // f(0+0)=0 for identity
    }
}
