//! Hankel (lattice) cross-term multiplication — §A.2.3.
//!
//! When all pivot distances lie on a lattice `{s·δ}` (unit-weight trees:
//! δ=1; positive-rational-weight trees: δ=1/q), the cross matrix
//! `C[i][j] = f(x_i + y_j)` embeds into a Hankel matrix over the lattice
//! and `C·V` becomes a correlation of the f-table with the aggregated
//! field, computed by FFT in `O((T+S) log(T+S) + (a+b)·d)` where `T,S`
//! are the lattice extents. This path works for **any** `f` — the paper's
//! route to `O(N log² N)` integration on unweighted trees for arbitrary f.

use crate::ftfi::functions::FDist;
use crate::linalg::fft::{fft_pow2, ifft_pow2, next_pow2, Complex};
use crate::linalg::matrix::Matrix;

/// Detect a common lattice spacing δ for the given values (all must be
/// ≈ non-negative integer multiples of δ). Returns `None` when no lattice
/// with at most `max_points` points covers the range.
pub fn detect_lattice(values: impl Iterator<Item = f64> + Clone, max_points: usize) -> Option<f64> {
    let mut maxv: f64 = 0.0;
    let mut delta: f64 = 0.0;
    for v in values.clone() {
        if v < -1e-12 || !v.is_finite() {
            // Negative or non-finite distances: not a lattice (and not a
            // valid metric) — report inapplicability instead of panicking.
            return None;
        }
        maxv = maxv.max(v);
        if v > 1e-12 {
            delta = if delta == 0.0 { v } else { float_gcd(delta, v, 1e-9 * (1.0 + maxv)) };
        }
    }
    if delta <= 0.0 {
        // All values ~0 — trivially a lattice with a single point.
        return Some(1.0);
    }
    let points = (maxv / delta).round() as usize + 1;
    if points > max_points {
        return None;
    }
    // Verify every value sits on the lattice within tolerance.
    let tol = 1e-7 * delta.max(1e-12);
    for v in values {
        let r = v / delta;
        if (r - r.round()).abs() * delta > tol {
            return None;
        }
    }
    Some(delta)
}

/// Euclidean gcd on floats with rounding correction.
fn float_gcd(mut a: f64, mut b: f64, tol: f64) -> f64 {
    if a < b {
        std::mem::swap(&mut a, &mut b);
    }
    while b > tol {
        let q = (a / b).round();
        let r = (a - q * b).abs();
        a = b;
        b = r;
    }
    a
}

/// Pre-planned lattice application: the f-table FFT is computed once and
/// shared across all `d` channels (and across C / Cᵀ, which use the same
/// table).
pub struct LatticePlan {
    delta: f64,
    /// FFT of the f-table, length `m` (power of two ≥ table len + max(S,T)).
    table_fft: Vec<Complex>,
    m: usize,
    /// table[s] = f(s·δ) for s = 0..=T+S.
    t_max: usize,
    s_max: usize,
}

impl LatticePlan {
    /// Build a plan for values `xs` (rows) and `ys` (cols) already known
    /// to lie on the lattice `δ`.
    pub fn new(f: &FDist, xs: &[f64], ys: &[f64], delta: f64) -> Self {
        let t_max = xs.iter().map(|&x| (x / delta).round() as usize).max().unwrap_or(0);
        let s_max = ys.iter().map(|&y| (y / delta).round() as usize).max().unwrap_or(0);
        let table: Vec<f64> = (0..=t_max + s_max).map(|s| f.eval(s as f64 * delta)).collect();
        // Correlation corr[t] = Σ_s table[t+s]·w[s] for a w of length
        // max(S,T)+1 (both directions share the plan): linear convolution
        // of `table` with reversed w, so m ≥ table.len() + max(S,T).
        let m = next_pow2(table.len() + t_max.max(s_max));
        let mut table_fft = vec![Complex::ZERO; m];
        for (i, &v) in table.iter().enumerate() {
            table_fft[i].re = v;
        }
        fft_pow2(&mut table_fft, false);
        LatticePlan { delta, table_fft, m, t_max, s_max }
    }

    /// `C·V`: rows indexed by `xs`, columns by `ys`, `V` is `ys.len()×d`.
    pub fn apply(&self, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
        self.apply_dir(xs, ys, v, self.s_max)
    }

    /// `Cᵀ·U`: same table with the roles of xs/ys swapped.
    pub fn apply_t(&self, xs: &[f64], ys: &[f64], u: &Matrix) -> Matrix {
        self.apply_dir(ys, xs, u, self.t_max)
    }

    fn apply_dir(&self, out_vals: &[f64], in_vals: &[f64], v: &Matrix, in_max: usize) -> Matrix {
        assert_eq!(v.rows(), in_vals.len());
        let d = v.cols();
        let mut out = Matrix::zeros(out_vals.len(), d);
        if in_vals.is_empty() || out_vals.is_empty() {
            return out;
        }
        let in_idx: Vec<usize> =
            in_vals.iter().map(|&y| (y / self.delta).round() as usize).collect();
        let out_idx: Vec<usize> =
            out_vals.iter().map(|&x| (x / self.delta).round() as usize).collect();
        let mut buf = vec![Complex::ZERO; self.m];
        // Process channels two at a time packed into (re, im) — one FFT
        // serves two real convolutions.
        let mut ch = 0;
        while ch < d {
            let pair = ch + 1 < d;
            for c in buf.iter_mut() {
                *c = Complex::ZERO;
            }
            // w[s] aggregated by lattice index; reversed so the
            // convolution computes a correlation with the table.
            for (j, &s) in in_idx.iter().enumerate() {
                let slot = in_max - s;
                buf[slot].re += v.get(j, ch);
                if pair {
                    buf[slot].im += v.get(j, ch + 1);
                }
            }
            fft_pow2(&mut buf, false);
            for (b, t) in buf.iter_mut().zip(&self.table_fft) {
                *b = *b * *t;
            }
            ifft_pow2(&mut buf);
            if pair {
                // Unpack: conv of (w_re + i·w_im) with real table keeps
                // channels in re/im separately (table is real).
                for (i, &t) in out_idx.iter().enumerate() {
                    let c = buf[t + in_max];
                    out.set(i, ch, c.re);
                    out.set(i, ch + 1, c.im);
                }
                ch += 2;
            } else {
                for (i, &t) in out_idx.iter().enumerate() {
                    out.set(i, ch, buf[t + in_max].re);
                }
                ch += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ml::rng::Pcg;
    use std::sync::Arc;

    #[test]
    fn detect_integer_lattice() {
        let vals = [0.0, 3.0, 1.0, 7.0, 2.0];
        let d = detect_lattice(vals.iter().copied(), 1000).unwrap();
        assert!((d - 1.0).abs() < 1e-9);
    }

    #[test]
    fn detect_rational_lattice() {
        // multiples of 1/4
        let vals = [0.25, 1.5, 0.75, 2.0];
        let d = detect_lattice(vals.iter().copied(), 1000).unwrap();
        assert!((d - 0.25).abs() < 1e-9, "{d}");
    }

    #[test]
    fn reject_irrational_mix() {
        let vals = [1.0, std::f64::consts::SQRT_2];
        assert!(detect_lattice(vals.iter().copied(), 1 << 20).is_none());
    }

    #[test]
    fn reject_oversized_lattice() {
        let vals = [1e-6, 1.0];
        assert!(detect_lattice(vals.iter().copied(), 1000).is_none());
    }

    #[test]
    fn lattice_apply_matches_dense_any_f() {
        let mut rng = Pcg::seed(7);
        // Black-box f that has no separable or rational structure.
        let f = FDist::Custom(Arc::new(|x: f64| (x * 1.3).sin() / (1.0 + x * x) + 0.1 * x));
        for &(a, b, d) in &[(5usize, 9usize, 1usize), (40, 30, 4), (1, 17, 3), (64, 64, 2)] {
            let xs: Vec<f64> = (0..a).map(|_| rng.below(30) as f64 * 0.5).collect();
            let ys: Vec<f64> = (0..b).map(|_| rng.below(30) as f64 * 0.5).collect();
            let v = Matrix::randn(b, d, &mut rng);
            let delta =
                detect_lattice(xs.iter().chain(ys.iter()).copied(), 1 << 16).unwrap();
            let plan = LatticePlan::new(&f, &xs, &ys, delta);
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            let got = plan.apply(&xs, &ys, &v);
            assert!(
                got.max_abs_diff(&want) < 1e-8 * (1.0 + want.frobenius()),
                "a={a} b={b} d={d}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn lattice_apply_t_matches_dense_transpose() {
        let mut rng = Pcg::seed(8);
        let f = FDist::Custom(Arc::new(|x: f64| (-(x)).exp() * (1.0 + x)));
        let xs: Vec<f64> = (0..13).map(|_| rng.below(20) as f64).collect();
        let ys: Vec<f64> = (0..11).map(|_| rng.below(20) as f64).collect();
        let u = Matrix::randn(13, 3, &mut rng);
        let delta = detect_lattice(xs.iter().chain(ys.iter()).copied(), 1 << 16).unwrap();
        let plan = LatticePlan::new(&f, &xs, &ys, delta);
        // Dense transpose: C^T U = apply dense with swapped roles.
        let want = cross_apply_dense(&f, &ys, &xs, &u);
        let got = plan.apply_t(&xs, &ys, &u);
        assert!(got.max_abs_diff(&want) < 1e-8 * (1.0 + want.frobenius()));
    }

    #[test]
    fn all_zero_distances() {
        let f = FDist::Identity;
        let xs = [0.0, 0.0];
        let ys = [0.0];
        let delta = detect_lattice(xs.iter().chain(ys.iter()).copied(), 10).unwrap();
        let plan = LatticePlan::new(&f, &xs, &ys, delta);
        let v = Matrix::from_vec(1, 1, vec![5.0]);
        let got = plan.apply(&xs, &ys, &v);
        assert_eq!(got.rows(), 2);
        assert!(got.get(0, 0).abs() < 1e-12); // f(0+0)=0 for identity
    }
}
