//! Brute-force baselines: BTFI (tree) and BGFI (general graph).
//!
//! Both explicitly materialise the `f`-distance matrix (`O(N²)` time and
//! memory for preprocessing) and then perform a dense matrix–tensor
//! multiplication (`O(N²·d)`). They are the comparison targets of
//! Fig. 3 / Fig. 4 / Table 3, and — because FTFI is exact — they double
//! as correctness oracles for the whole fast stack.

use crate::ftfi::functions::FDist;
use crate::graph::shortest_path::all_pairs;
use crate::graph::Graph;
use crate::linalg::matrix::Matrix;
use crate::tree::Tree;

/// Materialise the `f`-distance matrix `M_f^T` of a tree.
pub fn f_distance_matrix_tree(tree: &Tree, f: &FDist) -> Matrix {
    let n = tree.n();
    let d = tree.all_pairs();
    Matrix::from_vec(n, n, d.into_iter().map(|x| f.eval(x)).collect())
}

/// Materialise the `f`-distance matrix `M_f^G` of a general graph
/// (shortest-path metric).
pub fn f_distance_matrix_graph(g: &Graph, f: &FDist) -> Matrix {
    let n = g.n();
    let d = all_pairs(g);
    Matrix::from_vec(n, n, d.into_iter().map(|x| f.eval(x)).collect())
}

/// Brute-force tree-field integration: `out = M_f^T · X`.
pub fn btfi(tree: &Tree, f: &FDist, x: &Matrix) -> Matrix {
    f_distance_matrix_tree(tree, f).matmul(x)
}

/// Brute-force graph-field integration: `out = M_f^G · X`.
pub fn bgfi(g: &Graph, f: &FDist, x: &Matrix) -> Matrix {
    f_distance_matrix_graph(g, f).matmul(x)
}

/// Streaming BTFI: O(N) memory (no N×N matrix), O(N²·d) time — the
/// brute baseline used for the large-N points of Fig. 3 where
/// materialising the distance matrix would not fit.
pub fn btfi_streaming(tree: &Tree, f: &FDist, x: &Matrix) -> Matrix {
    let n = tree.n();
    let d = x.cols();
    let mut out = Matrix::zeros(n, d);
    for v in 0..n {
        let dist = tree.distances_from(v);
        let orow = out.row_mut(v);
        for (j, &dj) in dist.iter().enumerate() {
            let c = f.eval(dj);
            if c == 0.0 {
                continue;
            }
            for (o, &xv) in orow.iter_mut().zip(x.row(j)) {
                *o += c * xv;
            }
        }
    }
    out
}

/// Brute-force reference backend behind the unified
/// [`FieldIntegrator`](crate::ftfi::FieldIntegrator) trait: stores the
/// raw (not `f`-transformed) all-pairs distance matrix once, then
/// evaluates `f` per entry at integration time — `O(N²·d)` per call,
/// any `f`, any metric. The correctness oracle the fast backends are
/// tested against.
pub struct BruteForceIntegrator {
    n: usize,
    /// Row-major `n×n` raw distances.
    dmat: Vec<f64>,
}

impl BruteForceIntegrator {
    /// Reference integrator over a tree metric.
    pub fn from_tree(tree: Tree) -> Self {
        let n = tree.n();
        BruteForceIntegrator { n, dmat: tree.all_pairs() }
    }

    /// Reference integrator over a graph's shortest-path metric.
    pub fn from_graph(g: &Graph) -> Self {
        BruteForceIntegrator { n: g.n(), dmat: all_pairs(g) }
    }
}

impl crate::ftfi::FieldIntegrator for BruteForceIntegrator {
    fn n(&self) -> usize {
        self.n
    }

    fn integrate(
        &self,
        f: &FDist,
        x: &Matrix,
    ) -> Result<Matrix, crate::ftfi::FtfiError> {
        if x.rows() != self.n {
            return Err(crate::ftfi::FtfiError::ShapeMismatch {
                expected: self.n,
                got: x.rows(),
            });
        }
        let d = x.cols();
        let mut out = Matrix::zeros(self.n, d);
        for i in 0..self.n {
            let orow = out.row_mut(i);
            for j in 0..self.n {
                let c = f.eval(self.dmat[i * self.n + j]);
                if c == 0.0 {
                    continue;
                }
                for (o, &v) in orow.iter_mut().zip(x.row(j)) {
                    *o += c * v;
                }
            }
        }
        Ok(out)
    }
}

/// BTFI with separated phases, for benchmarking preprocessing vs
/// integration separately (Fig. 3 reports both).
pub struct BruteTreeIntegrator {
    mat: Matrix,
}

impl BruteTreeIntegrator {
    /// Preprocessing: O(N²) all-pairs + f-transform.
    pub fn new(tree: &Tree, f: &FDist) -> Self {
        BruteTreeIntegrator { mat: f_distance_matrix_tree(tree, f) }
    }

    /// Integration: O(N²·d) dense multiply.
    pub fn integrate(&self, x: &Matrix) -> Matrix {
        self.mat.matmul(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::mst::minimum_spanning_tree;
    use crate::ml::rng::Pcg;

    #[test]
    fn btfi_on_two_vertex_tree() {
        let t = Tree::from_edges(2, &[(0, 1, 2.0)]);
        let f = FDist::Identity;
        let x = Matrix::from_vec(2, 1, vec![1.0, 10.0]);
        let out = btfi(&t, &f, &x);
        // out[0] = f(0)*1 + f(2)*10 = 20 ; out[1] = f(2)*1 = 2
        assert!((out.get(0, 0) - 20.0).abs() < 1e-12);
        assert!((out.get(1, 0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bgfi_equals_btfi_on_trees() {
        let mut rng = Pcg::seed(1);
        let t = generators::random_tree(40, 0.5, 1.5, &mut rng);
        let g = t.to_graph();
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let x = Matrix::randn(40, 2, &mut rng);
        let a = btfi(&t, &f, &x);
        let b = bgfi(&g, &f, &x);
        assert!(a.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn bgfi_uses_graph_metric_not_tree_metric() {
        // A cycle: graph distance 0→3 is 1 via the closing edge, but the
        // MST must route the long way.
        let g = Graph::from_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 1.01)],
        );
        let f = FDist::Identity;
        let x = Matrix::from_vec(4, 1, vec![0.0, 0.0, 0.0, 1.0]);
        let gout = bgfi(&g, &f, &x);
        assert!((gout.get(0, 0) - 1.01).abs() < 1e-12);
        let t = minimum_spanning_tree(&g);
        let tout = btfi(&t, &f, &x);
        assert!((tout.get(0, 0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn streaming_matches_materialised() {
        let mut rng = Pcg::seed(3);
        let t = generators::random_tree(60, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let x = Matrix::randn(60, 2, &mut rng);
        assert!(btfi_streaming(&t, &f, &x).max_abs_diff(&btfi(&t, &f, &x)) < 1e-10);
    }

    #[test]
    fn brute_force_integrator_matches_free_functions() {
        use crate::ftfi::FieldIntegrator;
        let mut rng = Pcg::seed(4);
        let t = generators::random_tree(30, 0.2, 1.0, &mut rng);
        let f = FDist::inverse_quadratic(0.3);
        let x = Matrix::randn(30, 2, &mut rng);
        let bi = BruteForceIntegrator::from_tree(t.clone());
        assert!(bi.integrate(&f, &x).unwrap().max_abs_diff(&btfi(&t, &f, &x)) < 1e-12);
        let g = t.to_graph();
        let bg = BruteForceIntegrator::from_graph(&g);
        assert!(bg.integrate(&f, &x).unwrap().max_abs_diff(&bgfi(&g, &f, &x)) < 1e-12);
        // Shape mismatch is a typed error, not a panic.
        assert!(bi.integrate(&f, &Matrix::zeros(29, 1)).is_err());
    }

    #[test]
    fn phase_separated_matches_oneshot() {
        let mut rng = Pcg::seed(2);
        let t = generators::random_tree(30, 0.1, 1.0, &mut rng);
        let f = FDist::inverse_quadratic(0.5);
        let x = Matrix::randn(30, 3, &mut rng);
        let pre = BruteTreeIntegrator::new(&t, &f);
        assert!(pre.integrate(&x).max_abs_diff(&btfi(&t, &f, &x)) < 1e-12);
    }
}
