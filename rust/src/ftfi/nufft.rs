//! Non-uniform FFT approximate integration — §A.2.2.
//!
//! Implements Gaussian-gridding NUFFTs (Greengard & Lee 2004):
//!
//! - **type 1** (non-uniform → uniform): `F(k) = Σ_j c_j e^{-2πi k x_j}`
//!   for integer frequencies `k ∈ [-M/2, M/2)`;
//! - **type 2** (uniform → non-uniform): `g(x_i) = Σ_k F(k) e^{-2πi k x_i}`;
//!
//! and on top of them the paper's convolution pipeline for the sinc
//! kernel `f(t) = sin(πt)/(πt)` (whose inverse FT is the indicator of
//! `[-1/2, 1/2]`): `Σ_j v_j f(x_i + y_j)` is evaluated as a quadrature of
//! `ρ(ω)·R(ω)` with `R` computed by a type-1 transform at the quadrature
//! nodes and the outer evaluation by a type-2 transform — all in
//! polylog-linear time.

use crate::linalg::fft::{fft_pow2, next_pow2, Complex};
use crate::linalg::matrix::Matrix;

/// Gaussian-gridding parameters: oversampling ratio 2, spreading width
/// `MSP` grid points each side — gives ~1e-9 single-precision-grade
/// accuracy (Greengard & Lee, Table 1).
const MSP: usize = 12;

/// Type-1 NUFFT: `F[k + m/2] = Σ_j c[j]·e^{-2πi k x[j]}` for
/// `k = -m/2 .. m/2 - 1`. Positions `x[j]` must lie in `[0, 1)`.
pub fn nufft1(x: &[f64], c: &[Complex], m: usize) -> Vec<Complex> {
    assert_eq!(x.len(), c.len());
    assert!(m.is_power_of_two(), "m must be a power of two");
    let mr = 2 * m; // oversampled fine grid
    // Greengard–Lee optimal width for oversampling R=2, translated to
    // the e^{2\u03c0ikx} convention: \u03c4 = Msp/(12\u03c0m\u00b2) (correction \u2264 e^{\u03c0} at k=m/2).
    // lint: allow(mixed-precision-cast) — grid-size to spreading width, not field data
    let tau = MSP as f64 / (12.0 * std::f64::consts::PI * (m * m) as f64);
    let mut fine = vec![Complex::ZERO; mr];
    // Spread each source onto the fine grid with the Gaussian kernel.
    let h = 1.0 / mr as f64;
    for (&xj, &cj) in x.iter().zip(c) {
        debug_assert!((0.0..1.0).contains(&xj), "positions must be in [0,1), got {xj}");
        let center = (xj / h).round() as isize;
        for l in -(MSP as isize)..=(MSP as isize) {
            let idx = (center + l).rem_euclid(mr as isize) as usize;
            // lint: allow(mixed-precision-cast) — grid index to coordinate, not field data
            let t = xj - (center + l) as f64 * h;
            let w = (-t * t / (4.0 * tau)).exp();
            fine[idx] += cj.scale(w);
        }
    }
    // FFT of the fine grid (periodic), then pick centred frequencies and
    // deconvolve the Gaussian: its FT is √(4πτ)·e^{-4π²τ k²... } — with
    // our convention the correction factor is e^{τ(2πk)²}/ (normalisation).
    // FINE[k] = Σ_n fine[n]·e^{-2πik·x_n} ≈ (1/h)·(F·ĝ)(k) with
    // ĝ(k) = √(4πτ)·e^{-(2πk)²τ}, so F(k) = FINE[k]·e^{(2πk)²τ}/(mr·√(4πτ)).
    fft_pow2(&mut fine, false);
    // lint: allow(mixed-precision-cast) — grid-size normalisation, not field data
    let norm = 1.0 / ((4.0 * std::f64::consts::PI * tau).sqrt() * mr as f64);
    (0..m)
        .map(|i| {
            let k = i as isize - (m / 2) as isize;
            let idx = (k.rem_euclid(mr as isize)) as usize;
            // lint: allow(mixed-precision-cast) — frequency index to angle, not field data
            let corr = ((2.0 * std::f64::consts::PI * k as f64).powi(2) * tau).exp();
            fine[idx].scale(corr * norm)
        })
        .collect()
}

/// Type-2 NUFFT: `g[i] = Σ_{k=-m/2}^{m/2-1} F[k + m/2]·e^{-2πi k x[i]}`.
pub fn nufft2(x: &[f64], f: &[Complex]) -> Vec<Complex> {
    let m = f.len();
    assert!(m.is_power_of_two());
    let mr = 2 * m;
    // Greengard–Lee optimal width for oversampling R=2, translated to
    // the e^{2\u03c0ikx} convention: \u03c4 = Msp/(12\u03c0m\u00b2) (correction \u2264 e^{\u03c0} at k=m/2).
    // lint: allow(mixed-precision-cast) — grid-size to spreading width, not field data
    let tau = MSP as f64 / (12.0 * std::f64::consts::PI * (m * m) as f64);
    // Deconvolve, place on the fine grid spectrum, inverse-transform.
    let mut spec = vec![Complex::ZERO; mr];
    for i in 0..m {
        let k = i as isize - (m / 2) as isize;
        // lint: allow(mixed-precision-cast) — frequency index to angle, not field data
        let corr = ((2.0 * std::f64::consts::PI * k as f64).powi(2) * tau).exp();
        let idx = k.rem_euclid(mr as isize) as usize;
        spec[idx] = f[i].scale(corr);
    }
    // e^{-2πi k x} sampled via the conjugate transform of the fine grid:
    // fine[n] = Σ_k spec[k] e^{-2πi k n / mr} — a forward DFT of spec.
    fft_pow2(&mut spec, false);
    let fine = spec;
    // lint: allow(mixed-precision-cast) — grid spacing from grid size, not field data
    let h = 1.0 / mr as f64;
    // g(x_i) = (h/√(4πτ))·Σ_n fine[n]·g_τ(x_i - x_n): the quadrature of
    // the smoothed spectrum against the spreading Gaussian.
    let gauss_norm = h / (4.0 * std::f64::consts::PI * tau).sqrt();
    x.iter()
        .map(|&xi| {
            debug_assert!((0.0..1.0).contains(&xi));
            let center = (xi / h).round() as isize;
            let mut acc = Complex::ZERO;
            for l in -(MSP as isize)..=(MSP as isize) {
                let idx = (center + l).rem_euclid(mr as isize) as usize;
                // lint: allow(mixed-precision-cast) — grid index to coordinate, not field data
                let t = xi - (center + l) as f64 * h;
                let w = (-t * t / (4.0 * tau)).exp();
                acc += fine[idx].scale(w);
            }
            acc.scale(gauss_norm)
        })
        .collect()
}

/// Approximate `out[i][ch] = Σ_j V[j][ch]·sinc(x_i + y_j)` with
/// `sinc(t) = sin(πt)/(πt)`, via the NU-FFT pipeline of §A.2.2
/// (trapezoid quadrature on `ω ∈ [-1/2, 1/2]`).
///
/// `padding` controls the periodisation range (`span = padding·(max|t|+1)`).
/// Because ρ is an indicator (equivalently: sinc decays like `1/t`), the
/// quadrature error is `O(1/padding)` — this is inherent to the §A.2.2
/// scheme for this kernel, not an implementation artifact; the matching
/// convergence test below documents the observed rate.
pub fn sinc_cross_apply(xs: &[f64], ys: &[f64], v: &Matrix, padding: f64) -> Matrix {
    assert_eq!(v.rows(), ys.len());
    let d = v.cols();
    let mut out = Matrix::zeros(xs.len(), d);
    if xs.is_empty() || ys.is_empty() {
        return out;
    }
    // Map positions into [0,1): u = t/span; frequencies scale accordingly.
    let maxv = xs
        .iter()
        .chain(ys.iter())
        .fold(0.0f64, |m, &t| m.max(t.abs()));
    // The quadrature periodises g with period `span`; sinc's 1/t tails
    // make the aliasing error ~1/(π·(span-2·max)).
    let span = padding.max(4.0) * (maxv + 1.0);
    // Quadrature nodes ω_q uniform over [-1/2, 1/2] — these are the
    // *integer* frequencies k of the scaled problem: with positions
    // u = t/span ∈ [0,1), e^{2πi ω t} = e^{2πi (ω·span) u}, and the
    // quadrature spacing 1/r·... Choose r nodes ω_q = q/span for integer
    // q ∈ [-r/2, r/2): covers |ω| ≤ r/(2·span); need r ≥ span to cover
    // the sinc band |ω| ≤ 1/2.
    let r = next_pow2(4 * span.ceil() as usize);
    let uy: Vec<f64> = ys.iter().map(|&y| (y / span).rem_euclid(1.0)).collect();
    let ux: Vec<f64> = xs.iter().map(|&x| (x / span).rem_euclid(1.0)).collect();
    let dw = 1.0 / span; // quadrature spacing in ω
    // Channel-loop buffers hoisted out and refilled per channel (the
    // per-channel body fully overwrites them).
    let mut coeffs = vec![Complex::ZERO; ys.len()];
    let mut integ = vec![Complex::ZERO; r];
    for ch in 0..d {
        // R(ω_q) = Σ_j v_j e^{2πi ω_q y_j} = conj(type-1 with coeffs conj(v)).
        for (j, c) in coeffs.iter_mut().enumerate() {
            *c = Complex::new(v.get(j, ch), 0.0);
        }
        let rw = nufft1(&uy, &coeffs, r);
        // Multiply by ρ(ω)=1_{|ω|≤1/2} and the quadrature weight.
        // rw[k] = Σ_j v_j·e^{-2πik·u_y} = R(ω_{-k}), so the wanted sum
        // Σ_q R(ω_q)·e^{+2πiq·u_x} rewrites (q = -k) as
        // Σ_k rw[k]·e^{-2πik·u_x} — exactly a type-2 transform of rw
        // itself, no index flip. Trapezoid half-weight at |ω| = 1/2.
        for (i, (slot, val)) in integ.iter_mut().zip(&rw).enumerate() {
            let k = i as isize - (r / 2) as isize;
            // lint: allow(mixed-precision-cast) — quadrature index to frequency, not field data
            let omega = k as f64 / span;
            *slot = if omega.abs() <= 0.5 + 1e-12 {
                let w = if (omega.abs() - 0.5).abs() < 1e-12 { 0.5 * dw } else { dw };
                val.scale(w)
            } else {
                Complex::ZERO
            };
        }
        // g(x_i) = Σ_k ρR(ω_k)·e^{-2πi ω_k x_i}·dω — a type-2 transform.
        let g = nufft2(&ux, &integ);
        for (i, gi) in g.iter().enumerate() {
            out.set(i, ch, gi.re);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    fn naive_type1(x: &[f64], c: &[Complex], m: usize) -> Vec<Complex> {
        (0..m)
            .map(|i| {
                let k = i as isize - (m / 2) as isize;
                let mut acc = Complex::ZERO;
                for (&xj, &cj) in x.iter().zip(c) {
                    acc += cj
                        * Complex::cis(-2.0 * std::f64::consts::PI * k as f64 * xj);
                }
                acc
            })
            .collect()
    }

    #[test]
    fn type1_matches_naive() {
        let mut rng = Pcg::seed(1);
        let n = 50;
        let m = 64;
        let x: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let c: Vec<Complex> = (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
        let want = naive_type1(&x, &c, m);
        let got = nufft1(&x, &c, m);
        for (g, w) in got.iter().zip(&want) {
            assert!((*g - *w).abs() < 1e-6 * (1.0 + w.abs()), "{g:?} vs {w:?}");
        }
    }

    #[test]
    fn type2_matches_naive() {
        let mut rng = Pcg::seed(2);
        let m = 32;
        let f: Vec<Complex> = (0..m).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
        let x: Vec<f64> = (0..40).map(|_| rng.uniform()).collect();
        let got = nufft2(&x, &f);
        for (i, &xi) in x.iter().enumerate() {
            let mut want = Complex::ZERO;
            for (ki, &fk) in f.iter().enumerate() {
                let k = ki as isize - (m / 2) as isize;
                want += fk * Complex::cis(-2.0 * std::f64::consts::PI * k as f64 * xi);
            }
            assert!((got[i] - want).abs() < 1e-6 * (1.0 + want.abs()));
        }
    }

    fn sinc_max_err(padding: f64, seed: u64) -> f64 {
        let mut rng = Pcg::seed(seed);
        let sinc = |t: f64| {
            if t.abs() < 1e-12 {
                1.0
            } else {
                (std::f64::consts::PI * t).sin() / (std::f64::consts::PI * t)
            }
        };
        let xs = rng.uniform_vec(25, 0.0, 4.0);
        let ys = rng.uniform_vec(30, 0.0, 4.0);
        let v = Matrix::randn(30, 2, &mut rng);
        let got = sinc_cross_apply(&xs, &ys, &v, padding);
        let mut err = 0.0f64;
        for i in 0..xs.len() {
            for ch in 0..2 {
                let want: f64 =
                    (0..ys.len()).map(|j| v.get(j, ch) * sinc(xs[i] + ys[j])).sum();
                err = err.max((got.get(i, ch) - want).abs());
            }
        }
        err
    }

    #[test]
    fn sinc_pipeline_approximates_direct_sum() {
        // O(1/padding) aliasing: padding 64 should land well under 0.05.
        let e = sinc_max_err(64.0, 3);
        assert!(e < 0.05, "max err {e}");
    }

    #[test]
    fn sinc_pipeline_error_decays_with_padding() {
        let e4 = sinc_max_err(4.0, 5);
        let e64 = sinc_max_err(64.0, 5);
        assert!(e64 < e4 * 0.5, "no decay: {e4} -> {e64}");
    }
}
