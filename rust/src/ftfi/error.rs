//! The FTFI error taxonomy — the typed failure surface of the fallible
//! builder / prepare / integrate API (see `DESIGN.md` §Errors).
//!
//! Design rule: anything reachable from user input (graph topology,
//! field shapes, forced strategies, policy knobs) is an [`FtfiError`];
//! panics are reserved for internal invariant violations. The serving
//! coordinator maps these into `ServerError::Exec` at the worker
//! boundary so a malformed request can never take a worker thread down.

use crate::ftfi::cordial::Strategy;
use std::fmt;

/// Typed errors for the fallible FTFI surface.
#[derive(Debug, Clone, PartialEq)]
pub enum FtfiError {
    /// The input graph is not connected, so no spanning tree (and hence
    /// no MST metric) exists.
    DisconnectedGraph,
    /// A tensor field's row count does not match the integrator's vertex
    /// count (or an input buffer is not a multiple of it).
    ShapeMismatch { expected: usize, got: usize },
    /// A strategy forced through `CrossPolicy::force` does not apply to
    /// the given `f` / distance structure.
    StrategyInapplicable { strategy: Strategy, reason: &'static str },
    /// A structurally invalid input: non-finite edge weights, bad policy
    /// knobs, unparseable configuration values, …
    InvalidInput(String),
}

impl fmt::Display for FtfiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FtfiError::DisconnectedGraph => {
                write!(f, "graph is disconnected: MST metric requires a connected graph")
            }
            FtfiError::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: integrator expects {expected} rows, field has {got}")
            }
            FtfiError::StrategyInapplicable { strategy, reason } => {
                write!(f, "forced strategy {strategy:?} is inapplicable: {reason}")
            }
            FtfiError::InvalidInput(msg) => write!(f, "invalid input: {msg}"),
        }
    }
}

impl std::error::Error for FtfiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = FtfiError::ShapeMismatch { expected: 10, got: 7 };
        let s = e.to_string();
        assert!(s.contains("10") && s.contains("7"), "{s}");
        let e = FtfiError::StrategyInapplicable {
            strategy: Strategy::Lattice,
            reason: "no common distance lattice",
        };
        assert!(e.to_string().contains("Lattice"));
    }

    #[test]
    fn error_trait_object() {
        let e: Box<dyn std::error::Error> = Box::new(FtfiError::DisconnectedGraph);
        assert!(e.to_string().contains("disconnected"));
    }
}
