//! Strategy dispatch for cross-term multiplication `C·V`,
//! `C[i][j] = f(x_i + y_j)` — Definition 3.2's "cordiality" made
//! operational. Each `f` class maps to its fastest exact multiplier;
//! a cost model arbitrates between the structured paths and the dense
//! fallback (dense wins for small blocks — the same reason the paper
//! raises the leaf threshold `t` above the theoretical 6, §4.1).

use crate::ftfi::cauchy::cauchy_cross_apply;
use crate::ftfi::chebyshev::{adaptive_expansion, ChebExpansion};
use crate::ftfi::functions::FDist;
use crate::ftfi::hankel::{detect_lattice, LatticePlan};
use crate::ftfi::outer::apply_separable;
use crate::ftfi::rational::{rational_cross_apply, RationalOpts};
use crate::ftfi::vandermonde::expquad_cross_apply;
use crate::linalg::matrix::Matrix;

/// Which multiplier handled (or should handle) a cross product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Materialise `C` and multiply — O(a·b·d).
    Dense,
    /// Exact low-rank outer products (0-cordial f).
    Separable,
    /// Hankel/FFT over a common distance lattice (any f).
    Lattice,
    /// Fast rational sums + multipoint evaluation ((2+ε)-cordial f).
    RationalSum,
    /// Cauchy-like LDR for e^{λx}/(x+c) (2-cordial).
    Cauchy,
    /// diag·Vandermonde·diag for e^{ux²+vx+w} with lattice columns.
    Vandermonde,
    /// Barycentric Chebyshev low-rank expansion (smooth f, spectrally
    /// stable; the practical fast path for rational kernels in f64).
    Chebyshev,
}

/// Tunables for strategy selection.
#[derive(Clone, Debug)]
pub struct CrossPolicy {
    /// Below `a·b ≤ dense_cutoff` always multiply densely.
    pub dense_cutoff: usize,
    /// Maximum lattice points before the Hankel path is rejected.
    pub lattice_max_points: usize,
    /// Rational/Cauchy divide-and-conquer options.
    pub rational: RationalOpts,
    /// Probe-error tolerance for accepting a Chebyshev expansion.
    pub cheb_tol: f64,
    /// Maximum Chebyshev rank before falling back.
    pub cheb_max_rank: usize,
    /// Force one strategy (ablation benches); panics if inapplicable.
    pub force: Option<Strategy>,
}

impl Default for CrossPolicy {
    fn default() -> Self {
        CrossPolicy {
            dense_cutoff: 4096,
            lattice_max_points: 1 << 18,
            rational: RationalOpts::default(),
            cheb_tol: 1e-9,
            cheb_max_rank: 128,
            force: None,
        }
    }
}

/// Dense reference multiplication (also the fallback). Exact.
pub fn cross_apply_dense(f: &FDist, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
    assert_eq!(v.rows(), ys.len());
    let d = v.cols();
    let mut out = Matrix::zeros(xs.len(), d);
    for (i, &x) in xs.iter().enumerate() {
        let orow = out.row_mut(i);
        for (j, &y) in ys.iter().enumerate() {
            let c = f.eval(x + y);
            if c == 0.0 {
                continue;
            }
            for (o, &vv) in orow.iter_mut().zip(v.row(j)) {
                *o += c * vv;
            }
        }
    }
    out
}

/// An execution plan: the chosen strategy together with any expensive
/// artifacts built while choosing it (the Chebyshev expansion in
/// particular — building it twice was the top hot-spot of the first perf
/// pass, see EXPERIMENTS.md §Perf).
pub enum Plan {
    Dense,
    Separable,
    Lattice(f64),
    RationalSum,
    Cauchy,
    Vandermonde(f64),
    Chebyshev(ChebExpansion),
}

impl Plan {
    pub fn strategy(&self) -> Strategy {
        match self {
            Plan::Dense => Strategy::Dense,
            Plan::Separable => Strategy::Separable,
            Plan::Lattice(_) => Strategy::Lattice,
            Plan::RationalSum => Strategy::RationalSum,
            Plan::Cauchy => Strategy::Cauchy,
            Plan::Vandermonde(_) => Strategy::Vandermonde,
            Plan::Chebyshev(_) => Strategy::Chebyshev,
        }
    }
}

/// Build the execution plan for the given shapes/values.
pub fn make_plan(f: &FDist, xs: &[f64], ys: &[f64], d: usize, policy: &CrossPolicy) -> Plan {
    if let Some(s) = policy.force {
        return match s {
            Strategy::Dense => Plan::Dense,
            Strategy::Separable => Plan::Separable,
            Strategy::Lattice => {
                let delta = detect_lattice(
                    xs.iter().chain(ys.iter()).copied(),
                    policy.lattice_max_points,
                )
                .expect("forced lattice strategy without a lattice");
                Plan::Lattice(delta)
            }
            Strategy::RationalSum => Plan::RationalSum,
            Strategy::Cauchy => Plan::Cauchy,
            Strategy::Vandermonde => {
                let delta = detect_lattice(ys.iter().copied(), policy.lattice_max_points)
                    .expect("forced vandermonde strategy without a column lattice");
                Plan::Vandermonde(delta)
            }
            Strategy::Chebyshev => {
                match adaptive_expansion(f, xs, ys, policy.cheb_tol, policy.cheb_max_rank) {
                    Some(exp) => Plan::Chebyshev(exp),
                    None => Plan::Dense, // forced-but-inapplicable: stay correct
                }
            }
        };
    }
    let (a, b) = (xs.len(), ys.len());
    if a * b <= policy.dense_cutoff {
        return Plan::Dense;
    }
    // Exact low-rank beats everything when available.
    if f.separable_rank().is_some() {
        return Plan::Separable;
    }
    // A common lattice admits the any-f Hankel path; take it when its
    // FFT cost undercuts dense.
    if let Some(delta) =
        detect_lattice(xs.iter().chain(ys.iter()).copied(), policy.lattice_max_points)
    {
        let maxv = xs.iter().chain(ys.iter()).fold(0.0f64, |m, &v| m.max(v));
        let pts = (maxv / delta).round() as usize + 1;
        let fft_cost = 4 * pts * (usize::BITS - pts.leading_zeros()) as usize * d.div_ceil(2);
        let dense_cost = a * b * d;
        if fft_cost < dense_cost {
            return Plan::Lattice(delta);
        }
    }
    // Smooth non-separable kernels: Chebyshev low-rank is the stable,
    // polylog-free-lunch path. Accept it when the adaptive probe converges
    // — and carry the built expansion so apply never rebuilds it.
    match f {
        FDist::Rational { .. }
        | FDist::ExpOverLinear { .. }
        | FDist::ExpQuadratic { .. }
        | FDist::Custom(_) => {
            if let Some(exp) =
                adaptive_expansion(f, xs, ys, policy.cheb_tol, policy.cheb_max_rank)
            {
                return Plan::Chebyshev(exp);
            }
        }
        _ => {}
    }
    match f {
        FDist::Rational { .. } => Plan::RationalSum,
        FDist::ExpOverLinear { .. } => Plan::Cauchy,
        FDist::ExpQuadratic { .. } => {
            // Vandermonde needs only the *columns* on a lattice.
            match detect_lattice(ys.iter().copied(), policy.lattice_max_points) {
                Some(delta) => Plan::Vandermonde(delta),
                None => Plan::Dense,
            }
        }
        _ => Plan::Dense,
    }
}

/// Pick a strategy for the given shapes/values (thin wrapper over
/// [`make_plan`], kept for the ablation bench and tests).
pub fn choose_strategy(f: &FDist, xs: &[f64], ys: &[f64], d: usize, policy: &CrossPolicy) -> Strategy {
    make_plan(f, xs, ys, d, policy).strategy()
}

/// `C·V` with the best applicable strategy. For `Cᵀ·U` call with the
/// roles of `xs`/`ys` swapped — `f(x+y)` is symmetric in its arguments.
pub fn cross_apply(f: &FDist, xs: &[f64], ys: &[f64], v: &Matrix, policy: &CrossPolicy) -> Matrix {
    let plan = make_plan(f, xs, ys, v.cols(), policy);
    apply_plan(&plan, f, xs, ys, v, policy)
}

/// Execute a previously built plan (the IntegratorTree builds one plan
/// per node side and reuses it across calls via `cross_apply`'s wrapper;
/// exposed for callers that amortise planning).
pub fn apply_plan(
    plan: &Plan,
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    v: &Matrix,
    policy: &CrossPolicy,
) -> Matrix {
    match plan {
        Plan::Dense => cross_apply_dense(f, xs, ys, v),
        Plan::Separable => {
            let sep = f.separable_rank().expect("separable strategy for non-separable f");
            apply_separable(&sep, xs, ys, v)
        }
        Plan::Lattice(delta) => LatticePlan::new(f, xs, ys, *delta).apply(xs, ys, v),
        Plan::RationalSum => match f {
            FDist::Rational { num, den } => {
                rational_cross_apply(num, den, xs, ys, v, &policy.rational)
            }
            _ => panic!("rational strategy for non-rational f"),
        },
        Plan::Cauchy => match f {
            FDist::ExpOverLinear { lambda, c } => {
                cauchy_cross_apply(*lambda, *c, xs, ys, v, &policy.rational)
            }
            _ => panic!("cauchy strategy for wrong f"),
        },
        Plan::Vandermonde(delta) => match f {
            FDist::ExpQuadratic { u, v: vc, w } => {
                expquad_cross_apply(*u, *vc, *w, xs, ys, *delta, v)
            }
            _ => panic!("vandermonde strategy for wrong f"),
        },
        Plan::Chebyshev(exp) => exp.cross_apply(f, xs, ys, v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    fn policy_no_dense() -> CrossPolicy {
        CrossPolicy { dense_cutoff: 0, ..Default::default() }
    }

    #[test]
    fn dispatch_matches_dense_across_classes() {
        let mut rng = Pcg::seed(11);
        let fs = vec![
            FDist::Identity,
            FDist::Polynomial(vec![1.0, 0.5, -0.25]),
            FDist::Exponential { lambda: -0.4, scale: 1.0 },
            FDist::Trig { omega: 0.8, phase: 0.0, scale: 1.0 },
            FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.5] },
            FDist::ExpOverLinear { lambda: -0.2, c: 1.0 },
        ];
        for f in &fs {
            let xs = rng.uniform_vec(60, 0.0, 5.0);
            let ys = rng.uniform_vec(70, 0.0, 5.0);
            let v = Matrix::randn(70, 2, &mut rng);
            let want = cross_apply_dense(f, &xs, &ys, &v);
            let got = cross_apply(f, &xs, &ys, &v, &policy_no_dense());
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-6, "{f:?}: rel={rel}");
        }
    }

    #[test]
    fn lattice_strategy_chosen_for_custom_f_on_integers() {
        let f = FDist::Custom(std::sync::Arc::new(|x: f64| (x + 1.0).ln()));
        let xs: Vec<f64> = (0..100).map(|i| (i % 13) as f64).collect();
        let ys: Vec<f64> = (0..100).map(|i| (i % 17) as f64).collect();
        let s = choose_strategy(&f, &xs, &ys, 4, &policy_no_dense());
        assert_eq!(s, Strategy::Lattice);
        let mut rng = Pcg::seed(3);
        let v = Matrix::randn(100, 4, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = cross_apply(&f, &xs, &ys, &v, &policy_no_dense());
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }

    #[test]
    fn small_blocks_go_dense() {
        let f = FDist::Exponential { lambda: 1.0, scale: 1.0 };
        let s = choose_strategy(&f, &[1.0, 2.0], &[1.0], 1, &CrossPolicy::default());
        assert_eq!(s, Strategy::Dense);
    }

    #[test]
    fn expquad_vandermonde_on_mixed_lattice() {
        let mut rng = Pcg::seed(4);
        let f = FDist::ExpQuadratic { u: -0.1, v: 0.0, w: 0.0 };
        let xs = rng.uniform_vec(50, 0.0, 3.0); // arbitrary rows
        let ys: Vec<f64> = (0..60).map(|_| rng.below(10) as f64 * 0.5).collect();
        // Smooth kernels now prefer Chebyshev by default...
        let s = choose_strategy(&f, &xs, &ys, 1, &policy_no_dense());
        assert_eq!(s, Strategy::Chebyshev);
        // ...but the Vandermonde LDR path must stay exact when forced.
        let forced = CrossPolicy { force: Some(Strategy::Vandermonde), ..policy_no_dense() };
        let v = Matrix::randn(60, 1, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = cross_apply(&f, &xs, &ys, &v, &forced);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-7);
        let got_cheb = cross_apply(&f, &xs, &ys, &v, &policy_no_dense());
        assert!(got_cheb.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-7);
    }

    #[test]
    fn transpose_via_swap() {
        let mut rng = Pcg::seed(5);
        let f = FDist::Polynomial(vec![0.0, 1.0, 0.2]);
        let xs = rng.uniform_vec(8, 0.0, 2.0);
        let ys = rng.uniform_vec(6, 0.0, 2.0);
        let u = Matrix::randn(8, 2, &mut rng);
        // C^T U computed as cross_apply(ys, xs).
        let got = cross_apply(&f, &ys, &xs, &u, &CrossPolicy::default());
        // Reference: build dense C, transpose, multiply.
        let c = Matrix::from_fn(8, 6, |i, j| f.eval(xs[i] + ys[j]));
        let want = c.transpose().matmul(&u);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }
}
