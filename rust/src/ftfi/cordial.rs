//! Strategy dispatch for cross-term multiplication `C·V`,
//! `C[i][j] = f(x_i + y_j)` — Definition 3.2's "cordiality" made
//! operational. Each `f` class maps to its fastest exact multiplier;
//! a cost model arbitrates between the structured paths and the dense
//! fallback (dense wins for small blocks — the same reason the paper
//! raises the leaf threshold `t` above the theoretical 6, §4.1).
//!
//! Planning and execution are split: [`try_make_plan`] does all the
//! expensive, input-dependent work (Chebyshev probe loops, lattice
//! detection + FFT tables, separable decompositions) and returns a
//! [`Plan`] that owns those artifacts; [`apply_plan`] is the cheap,
//! panic-free execution step. The prepared-integrator API caches `Plan`s
//! across calls — see `DESIGN.md` §Lifecycle.

use crate::ftfi::chebyshev::{adaptive_expansion, ChebExpansion};
use crate::ftfi::error::FtfiError;
use crate::ftfi::functions::{FDist, Separable};
use crate::ftfi::hankel::{detect_lattice, LatticePlan};
use crate::ftfi::outer::{apply_separable, apply_separable_into};
use crate::ftfi::rational::{RationalOpts, RationalPlan};
use crate::ftfi::vandermonde::expquad_cross_apply;
use crate::linalg::fft::Complex;
use crate::linalg::lanes::{self, Precision};
use crate::linalg::matrix::Matrix;

/// Which multiplier handled (or should handle) a cross product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Materialise `C` and multiply — O(a·b·d).
    Dense,
    /// Exact low-rank outer products (0-cordial f).
    Separable,
    /// Hankel/FFT over a common distance lattice (any f).
    Lattice,
    /// Fast rational sums + multipoint evaluation ((2+ε)-cordial f).
    RationalSum,
    /// Cauchy-like LDR for e^{λx}/(x+c) (2-cordial).
    Cauchy,
    /// diag·Vandermonde·diag for e^{ux²+vx+w} with lattice columns.
    Vandermonde,
    /// Barycentric Chebyshev low-rank expansion (smooth f, spectrally
    /// stable; the practical fast path for rational kernels in f64).
    Chebyshev,
}

/// Tunables for strategy selection.
#[derive(Clone, Debug)]
pub struct CrossPolicy {
    /// Below `a·b ≤ dense_cutoff` always multiply densely.
    pub dense_cutoff: usize,
    /// Maximum lattice points before the Hankel path is rejected.
    pub lattice_max_points: usize,
    /// Rational/Cauchy divide-and-conquer options.
    pub rational: RationalOpts,
    /// Probe-error tolerance for accepting a Chebyshev expansion.
    pub cheb_tol: f64,
    /// Maximum Chebyshev rank before falling back.
    pub cheb_max_rank: usize,
    /// Force one strategy (ablation benches); planning returns
    /// [`FtfiError::StrategyInapplicable`] if it does not apply.
    pub force: Option<Strategy>,
}

impl Default for CrossPolicy {
    fn default() -> Self {
        CrossPolicy {
            dense_cutoff: 4096,
            lattice_max_points: 1 << 18,
            rational: RationalOpts::default(),
            cheb_tol: 1e-9,
            cheb_max_rank: 128,
            force: None,
        }
    }
}

impl CrossPolicy {
    /// Validate the policy knobs (called by the integrator builders).
    pub fn validate(&self) -> Result<(), FtfiError> {
        if !self.cheb_tol.is_finite() || self.cheb_tol <= 0.0 {
            return Err(FtfiError::InvalidInput(format!(
                "cheb_tol must be a positive finite number, got {}",
                self.cheb_tol
            )));
        }
        if self.cheb_max_rank < 2 {
            return Err(FtfiError::InvalidInput(format!(
                "cheb_max_rank must be ≥ 2, got {}",
                self.cheb_max_rank
            )));
        }
        if self.lattice_max_points == 0 {
            return Err(FtfiError::InvalidInput(
                "lattice_max_points must be ≥ 1".to_string(),
            ));
        }
        Ok(())
    }
}

/// Dense reference multiplication (also the fallback). Exact.
pub fn cross_apply_dense(f: &FDist, xs: &[f64], ys: &[f64], v: &Matrix) -> Matrix {
    assert_eq!(v.rows(), ys.len());
    let d = v.cols();
    let mut out = Matrix::zeros(xs.len(), d);
    cross_apply_dense_into(f, xs, ys, v.data(), d, out.data_mut(), Precision::F64);
    out
}

/// [`cross_apply_dense`] into a caller-provided buffer — the
/// allocation-free hot-path variant. `v` is `ys.len()×d` row-major,
/// `out` is `xs.len()×d`, dirty-on-entry ok. The inner axpy is
/// lane-chunked over the d-channel axis (`linalg/lanes.rs`); at
/// [`Precision::F64`] it is bit-identical to [`cross_apply_dense`].
pub(crate) fn cross_apply_dense_into(
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    v: &[f64],
    d: usize,
    out: &mut [f64],
    prec: Precision,
) {
    assert_eq!(v.len(), ys.len() * d);
    assert_eq!(out.len(), xs.len() * d);
    out.iter_mut().for_each(|o| *o = 0.0);
    for (i, &x) in xs.iter().enumerate() {
        let orow = &mut out[i * d..(i + 1) * d];
        for (j, &y) in ys.iter().enumerate() {
            let c = f.eval(x + y);
            if c == 0.0 {
                continue;
            }
            lanes::axpy_prec(prec, c, &v[j * d..(j + 1) * d], orow);
        }
    }
}

/// An execution plan: the chosen strategy together with every expensive
/// artifact built while choosing it — the Chebyshev expansion, the
/// lattice FFT table, the separable decomposition, the kernel
/// parameters. Building these twice was the top hot-spot of the first
/// perf pass (see `DESIGN.md` §Numerics), and owning them here is what
/// makes plans cacheable across repeated integrations.
pub enum Plan {
    Dense,
    Separable(Separable),
    Lattice(LatticePlan),
    /// Prepared basis-polynomial rational sums ([`RationalPlan`]): the
    /// shift products and denominator-inverse tables are frozen here,
    /// so applying is allocation-free.
    RationalSum(RationalPlan),
    /// The Cauchy-LDR case riding the same prepared rational core with
    /// its exponential factors as per-row/column scales.
    Cauchy(RationalPlan),
    Vandermonde { u: f64, v: f64, w: f64, delta: f64 },
    Chebyshev(ChebExpansion),
}

impl Plan {
    pub fn strategy(&self) -> Strategy {
        match self {
            Plan::Dense => Strategy::Dense,
            Plan::Separable(_) => Strategy::Separable,
            Plan::Lattice(_) => Strategy::Lattice,
            Plan::RationalSum(_) => Strategy::RationalSum,
            Plan::Cauchy(_) => Strategy::Cauchy,
            Plan::Vandermonde { .. } => Strategy::Vandermonde,
            Plan::Chebyshev(_) => Strategy::Chebyshev,
        }
    }
}

/// Build the execution plan for the given shapes/values. Returns
/// [`FtfiError::StrategyInapplicable`] when a forced strategy does not
/// apply to `f` / the distance structure; with `force: None` the
/// automatic selection always succeeds (dense is the universal
/// fallback).
pub fn try_make_plan(
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    d: usize,
    policy: &CrossPolicy,
) -> Result<Plan, FtfiError> {
    if let Some(s) = policy.force {
        return match s {
            Strategy::Dense => Ok(Plan::Dense),
            Strategy::Separable => match f.separable_rank() {
                Some(sep) => Ok(Plan::Separable(sep)),
                None => Err(FtfiError::StrategyInapplicable {
                    strategy: s,
                    reason: "f has no exact separable decomposition (not 0-cordial)",
                }),
            },
            Strategy::Lattice => match detect_lattice(
                xs.iter().chain(ys.iter()).copied(),
                policy.lattice_max_points,
            ) {
                Some(delta) => Ok(Plan::Lattice(LatticePlan::new(f, xs, ys, delta))),
                None => Err(FtfiError::StrategyInapplicable {
                    strategy: s,
                    reason: "distances share no common lattice within the point budget",
                }),
            },
            Strategy::RationalSum => match f {
                FDist::Rational { num, den } => {
                    let plan = RationalPlan::build(num, den, xs, ys, &policy.rational);
                    Ok(Plan::RationalSum(plan))
                }
                _ => Err(FtfiError::StrategyInapplicable {
                    strategy: s,
                    reason: "rational-sum multiplier requires FDist::Rational",
                }),
            },
            Strategy::Cauchy => match f {
                FDist::ExpOverLinear { lambda, c } => {
                    let plan = RationalPlan::build_cauchy(*lambda, *c, xs, ys, &policy.rational);
                    Ok(Plan::Cauchy(plan))
                }
                _ => Err(FtfiError::StrategyInapplicable {
                    strategy: s,
                    reason: "Cauchy-LDR multiplier requires FDist::ExpOverLinear",
                }),
            },
            Strategy::Vandermonde => match f {
                FDist::ExpQuadratic { u, v, w } => {
                    match detect_lattice(ys.iter().copied(), policy.lattice_max_points) {
                        Some(delta) => {
                            Ok(Plan::Vandermonde { u: *u, v: *v, w: *w, delta })
                        }
                        None => Err(FtfiError::StrategyInapplicable {
                            strategy: s,
                            reason: "column distances are not on a lattice",
                        }),
                    }
                }
                _ => Err(FtfiError::StrategyInapplicable {
                    strategy: s,
                    reason: "Vandermonde multiplier requires FDist::ExpQuadratic",
                }),
            },
            Strategy::Chebyshev => {
                match adaptive_expansion(f, xs, ys, policy.cheb_tol, policy.cheb_max_rank) {
                    Some(exp) => Ok(Plan::Chebyshev(exp)),
                    None => Err(FtfiError::StrategyInapplicable {
                        strategy: s,
                        reason: "Chebyshev probe did not converge within cheb_max_rank \
                                 (pole on the distance range?)",
                    }),
                }
            }
        };
    }
    let (a, b) = (xs.len(), ys.len());
    if a * b <= policy.dense_cutoff {
        return Ok(Plan::Dense);
    }
    // Exact low-rank beats everything when available.
    if let Some(sep) = f.separable_rank() {
        return Ok(Plan::Separable(sep));
    }
    // A common lattice admits the any-f Hankel path; take it when its
    // FFT cost undercuts dense.
    if let Some(delta) =
        detect_lattice(xs.iter().chain(ys.iter()).copied(), policy.lattice_max_points)
    {
        let maxv = xs.iter().chain(ys.iter()).fold(0.0f64, |m, &v| m.max(v));
        let pts = (maxv / delta).round() as usize + 1;
        let fft_cost = 4 * pts * (usize::BITS - pts.leading_zeros()) as usize * d.div_ceil(2);
        let dense_cost = a * b * d;
        if fft_cost < dense_cost {
            return Ok(Plan::Lattice(LatticePlan::new(f, xs, ys, delta)));
        }
    }
    // Smooth non-separable kernels: Chebyshev low-rank is the stable,
    // polylog-free-lunch path. Accept it when the adaptive probe converges
    // — and carry the built expansion so apply never rebuilds it.
    match f {
        FDist::Rational { .. }
        | FDist::ExpOverLinear { .. }
        | FDist::ExpQuadratic { .. }
        | FDist::Custom(_) => {
            if let Some(exp) =
                adaptive_expansion(f, xs, ys, policy.cheb_tol, policy.cheb_max_rank)
            {
                return Ok(Plan::Chebyshev(exp));
            }
        }
        _ => {}
    }
    Ok(match f {
        FDist::Rational { num, den } => {
            Plan::RationalSum(RationalPlan::build(num, den, xs, ys, &policy.rational))
        }
        FDist::ExpOverLinear { lambda, c } => {
            Plan::Cauchy(RationalPlan::build_cauchy(*lambda, *c, xs, ys, &policy.rational))
        }
        FDist::ExpQuadratic { u, v, w } => {
            // Vandermonde needs only the *columns* on a lattice.
            match detect_lattice(ys.iter().copied(), policy.lattice_max_points) {
                Some(delta) => Plan::Vandermonde { u: *u, v: *v, w: *w, delta },
                None => Plan::Dense,
            }
        }
        _ => Plan::Dense,
    })
}

/// Infallible planning shim for callers that know their (forced)
/// strategy applies; panics otherwise. Prefer [`try_make_plan`].
pub fn make_plan(f: &FDist, xs: &[f64], ys: &[f64], d: usize, policy: &CrossPolicy) -> Plan {
    try_make_plan(f, xs, ys, d, policy)
        .expect("make_plan: forced strategy inapplicable (use try_make_plan for a Result)")
}

/// Pick a strategy for the given shapes/values (thin wrapper over
/// [`try_make_plan`], kept for the ablation bench and tests).
pub fn choose_strategy(
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    d: usize,
    policy: &CrossPolicy,
) -> Strategy {
    make_plan(f, xs, ys, d, policy).strategy()
}

/// `C·V` with the best applicable strategy. For `Cᵀ·U` call with the
/// roles of `xs`/`ys` swapped — `f(x+y)` is symmetric in its arguments.
pub fn try_cross_apply(
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    v: &Matrix,
    policy: &CrossPolicy,
) -> Result<Matrix, FtfiError> {
    let plan = try_make_plan(f, xs, ys, v.cols(), policy)?;
    Ok(apply_plan(&plan, f, xs, ys, v, policy))
}

/// Infallible [`try_cross_apply`] shim; panics on a forced-inapplicable
/// strategy. Kept for benches and tests that force known-good strategies.
pub fn cross_apply(f: &FDist, xs: &[f64], ys: &[f64], v: &Matrix, policy: &CrossPolicy) -> Matrix {
    try_cross_apply(f, xs, ys, v, policy)
        .expect("cross_apply: forced strategy inapplicable (use try_cross_apply for a Result)")
}

/// Execute a previously built plan. Panic-free: every input-dependent
/// failure mode was resolved at planning time, and the plan owns its
/// artifacts (expansion, FFT table, decomposition, kernel parameters).
/// A plan is bound to the `(xs, ys)` it was planned for — `Lattice`
/// plans cache their per-point index maps at build time (applying one
/// to a different point set is debug-asserted there), and
/// `RationalSum`/`Cauchy` plans freeze their scaled evaluation points
/// and denominator-inverse tables, so for those variants the `xs`/`ys`
/// arguments are documentation only: passing different same-length
/// point sets would silently evaluate at the build-time points. The
/// prepared integrator upholds the binding by construction.
pub fn apply_plan(
    plan: &Plan,
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    v: &Matrix,
    _policy: &CrossPolicy,
) -> Matrix {
    match plan {
        Plan::Dense => cross_apply_dense(f, xs, ys, v),
        Plan::Separable(sep) => apply_separable(sep, xs, ys, v),
        Plan::Lattice(lp) => lp.apply(xs, ys, v),
        Plan::RationalSum(rp) | Plan::Cauchy(rp) => rp.apply(v),
        Plan::Vandermonde { u, v: vc, w, delta } => {
            // lint: infallible because the only failure mode is
            // `v.rows() != ys.len()`, which planning already validated —
            // `try_make_plan` is handed `v.cols()` against the same
            // `(xs, ys)` this plan is bound to.
            expquad_cross_apply(*u, *vc, *w, xs, ys, *delta, v)
                .expect("Vandermonde plan bound to these points")
        }
        Plan::Chebyshev(exp) => exp.cross_apply(f, xs, ys, v),
    }
}

/// Reusable per-task scratch for [`apply_plan_into`]: the complex FFT
/// buffer of the lattice multiplier, the Chebyshev aggregation/basis
/// buffers and the separable rank-1 accumulator. Sized once (from the
/// maxima over a prepared plan set) and checked out per integration
/// task, so the steady-state hot path performs no heap allocation.
#[derive(Default)]
pub struct CrossScratch {
    pub(crate) cplx: Vec<Complex>,
    pub(crate) cheb_w: Vec<f64>,
    pub(crate) cheb_basis: Vec<f64>,
    pub(crate) sep_w: Vec<f64>,
    /// Rational/Cauchy numerator-coefficient accumulator
    /// ([`RationalPlan::apply_into`]).
    pub(crate) rat_w: Vec<f64>,
}

impl CrossScratch {
    pub fn new() -> Self {
        CrossScratch::default()
    }

    /// Grow (never shrink) every buffer to the given plan-set maxima.
    /// After the first call with the steady-state sizes, further calls
    /// are no-ops — this is what makes checkout allocation-free.
    pub(crate) fn ensure(&mut self, fft_len: usize, cheb_rank: usize, rat_len: usize, d: usize) {
        if self.cplx.len() < fft_len {
            self.cplx.resize(fft_len, Complex::ZERO);
        }
        if self.cheb_w.len() < cheb_rank * d {
            self.cheb_w.resize(cheb_rank * d, 0.0);
        }
        if self.cheb_basis.len() < cheb_rank {
            self.cheb_basis.resize(cheb_rank, 0.0);
        }
        if self.sep_w.len() < d {
            self.sep_w.resize(d, 0.0);
        }
        if self.rat_w.len() < rat_len {
            self.rat_w.resize(rat_len, 0.0);
        }
    }
}

/// The complex-FFT / Chebyshev-rank / rational-coefficient scratch
/// demand of one plan — used to size [`CrossScratch`] arenas at prepare
/// time.
pub(crate) fn plan_scratch_demand(plan: &Plan) -> (usize, usize, usize) {
    match plan {
        Plan::Lattice(lp) => (lp.fft_len(), 0, 0),
        Plan::Chebyshev(exp) => (0, exp.rank(), 0),
        Plan::RationalSum(rp) | Plan::Cauchy(rp) => (0, 0, rp.coeff_len()),
        _ => (0, 0, 0),
    }
}

/// [`apply_plan`] into a caller-provided buffer: the workspace hot path.
/// `v` is `ys.len()×d` row-major, `out` is `xs.len()×d` (dirty on entry
/// is fine — every strategy fully overwrites it). Bit-identical to
/// [`apply_plan`] for every strategy.
///
/// The Dense / Separable / Lattice / Chebyshev / RationalSum / Cauchy
/// multipliers — everything the default policy can plan on the prepared
/// hot path plus the forced LDR reference paths — run fully
/// allocation-free through `scratch` (the rational paths via the
/// basis-polynomial tables their [`RationalPlan`] froze at plan time).
/// Only the Vandermonde multiplier keeps its allocating implementation
/// behind a temporary-[`Matrix`] shim: `expquad_cross_apply` rebuilds
/// its diag·Vandermonde·diag factors from the lattice structure per
/// call, and arena-ifying that would mean caching a dense `pts×b`
/// Vandermonde product table of unbounded size for a forced-only path —
/// not worth the workspace footprint.
///
/// `prec` selects the compute tier of the elementwise product kernels
/// (Dense / Separable / Chebyshev / RationalSum / Cauchy). The Lattice
/// multiplier's FFT and the Vandermonde shim stay f64 at both tiers:
/// their intermediates feed back into further products (FFT stages,
/// Horner steps over the transform), so per-product f32 rounding would
/// compound instead of rounding once per output — see DESIGN.md.
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_plan_into(
    plan: &Plan,
    f: &FDist,
    xs: &[f64],
    ys: &[f64],
    v: &[f64],
    d: usize,
    out: &mut [f64],
    policy: &CrossPolicy,
    scratch: &mut CrossScratch,
    prec: Precision,
) {
    match plan {
        Plan::Dense => cross_apply_dense_into(f, xs, ys, v, d, out, prec),
        Plan::Separable(sep) => {
            apply_separable_into(sep, xs, ys, v, d, out, &mut scratch.sep_w, prec)
        }
        Plan::Lattice(lp) => lp.apply_into(v, d, out, &mut scratch.cplx),
        Plan::Chebyshev(exp) => {
            let (w, basis) = (&mut scratch.cheb_w, &mut scratch.cheb_basis);
            exp.cross_apply_into(f, xs, ys, v, d, out, w, basis, prec)
        }
        Plan::RationalSum(rp) | Plan::Cauchy(rp) => {
            rp.apply_into(v, d, out, &mut scratch.rat_w, prec)
        }
        other => {
            // lint: allow(alloc-in-hot-path) — the documented Vandermonde
            // shim (see the fn doc above): this arm materialises a
            // temporary Matrix because the multiplier rebuilds its
            // factors per call; arena-ifying it is not worth the
            // workspace footprint.
            let vm = Matrix::from_vec(ys.len(), d, v.to_vec());
            let m = apply_plan(other, f, xs, ys, &vm, policy);
            out.copy_from_slice(m.data());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    fn policy_no_dense() -> CrossPolicy {
        CrossPolicy { dense_cutoff: 0, ..Default::default() }
    }

    #[test]
    fn dispatch_matches_dense_across_classes() {
        let mut rng = Pcg::seed(11);
        let fs = vec![
            FDist::Identity,
            FDist::Polynomial(vec![1.0, 0.5, -0.25]),
            FDist::Exponential { lambda: -0.4, scale: 1.0 },
            FDist::Trig { omega: 0.8, phase: 0.0, scale: 1.0 },
            FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, 0.5] },
            FDist::ExpOverLinear { lambda: -0.2, c: 1.0 },
        ];
        for f in &fs {
            let xs = rng.uniform_vec(60, 0.0, 5.0);
            let ys = rng.uniform_vec(70, 0.0, 5.0);
            let v = Matrix::randn(70, 2, &mut rng);
            let want = cross_apply_dense(f, &xs, &ys, &v);
            let got = cross_apply(f, &xs, &ys, &v, &policy_no_dense());
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-6, "{f:?}: rel={rel}");
        }
    }

    #[test]
    fn lattice_strategy_chosen_for_custom_f_on_integers() {
        let f = FDist::Custom(std::sync::Arc::new(|x: f64| (x + 1.0).ln()));
        let xs: Vec<f64> = (0..100).map(|i| (i % 13) as f64).collect();
        let ys: Vec<f64> = (0..100).map(|i| (i % 17) as f64).collect();
        let s = choose_strategy(&f, &xs, &ys, 4, &policy_no_dense());
        assert_eq!(s, Strategy::Lattice);
        let mut rng = Pcg::seed(3);
        let v = Matrix::randn(100, 4, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = cross_apply(&f, &xs, &ys, &v, &policy_no_dense());
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }

    #[test]
    fn small_blocks_go_dense() {
        let f = FDist::Exponential { lambda: 1.0, scale: 1.0 };
        let s = choose_strategy(&f, &[1.0, 2.0], &[1.0], 1, &CrossPolicy::default());
        assert_eq!(s, Strategy::Dense);
    }

    #[test]
    fn expquad_vandermonde_on_mixed_lattice() {
        let mut rng = Pcg::seed(4);
        let f = FDist::ExpQuadratic { u: -0.1, v: 0.0, w: 0.0 };
        let xs = rng.uniform_vec(50, 0.0, 3.0); // arbitrary rows
        let ys: Vec<f64> = (0..60).map(|_| rng.below(10) as f64 * 0.5).collect();
        // Smooth kernels now prefer Chebyshev by default...
        let s = choose_strategy(&f, &xs, &ys, 1, &policy_no_dense());
        assert_eq!(s, Strategy::Chebyshev);
        // ...but the Vandermonde LDR path must stay exact when forced.
        let forced = CrossPolicy { force: Some(Strategy::Vandermonde), ..policy_no_dense() };
        let v = Matrix::randn(60, 1, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = cross_apply(&f, &xs, &ys, &v, &forced);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-7);
        let got_cheb = cross_apply(&f, &xs, &ys, &v, &policy_no_dense());
        assert!(got_cheb.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-7);
    }

    #[test]
    fn transpose_via_swap() {
        let mut rng = Pcg::seed(5);
        let f = FDist::Polynomial(vec![0.0, 1.0, 0.2]);
        let xs = rng.uniform_vec(8, 0.0, 2.0);
        let ys = rng.uniform_vec(6, 0.0, 2.0);
        let u = Matrix::randn(8, 2, &mut rng);
        // C^T U computed as cross_apply(ys, xs).
        let got = cross_apply(&f, &ys, &xs, &u, &CrossPolicy::default());
        // Reference: build dense C, transpose, multiply.
        let c = Matrix::from_fn(8, 6, |i, j| f.eval(xs[i] + ys[j]));
        let want = c.transpose().matmul(&u);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn forced_inapplicable_strategies_error_not_panic() {
        let v = Matrix::zeros(3, 1);
        let xs = [1.0, std::f64::consts::SQRT_2];
        let ys = [0.5, 1.5, 2.5];
        // Separable forced on a non-separable f.
        let p = CrossPolicy {
            force: Some(Strategy::Separable),
            ..CrossPolicy::default()
        };
        let f = FDist::inverse_quadratic(0.5);
        assert!(matches!(
            try_cross_apply(&f, &xs, &ys, &v, &p),
            Err(FtfiError::StrategyInapplicable { strategy: Strategy::Separable, .. })
        ));
        // Lattice forced on irrational points.
        let p = CrossPolicy { force: Some(Strategy::Lattice), ..CrossPolicy::default() };
        assert!(matches!(
            try_cross_apply(&f, &xs, &ys, &v, &p),
            Err(FtfiError::StrategyInapplicable { strategy: Strategy::Lattice, .. })
        ));
        // RationalSum forced on a non-rational f.
        let p = CrossPolicy { force: Some(Strategy::RationalSum), ..CrossPolicy::default() };
        let g = FDist::Exponential { lambda: -1.0, scale: 1.0 };
        assert!(matches!(
            try_cross_apply(&g, &xs, &ys, &v, &p),
            Err(FtfiError::StrategyInapplicable { strategy: Strategy::RationalSum, .. })
        ));
        // Chebyshev forced with a pole on the range.
        let p = CrossPolicy { force: Some(Strategy::Chebyshev), ..CrossPolicy::default() };
        let pole = FDist::Rational { num: vec![1.0], den: vec![0.0, 1.0] };
        assert!(matches!(
            try_cross_apply(&pole, &[0.0, 1.0], &[0.0, 1.0, 2.0], &v, &p),
            Err(FtfiError::StrategyInapplicable { strategy: Strategy::Chebyshev, .. })
        ));
    }

    /// The workspace-scratch execution path must be bit-identical to the
    /// allocating one for every strategy (the prepared hot path swaps
    /// one for the other under a bit-identity contract).
    #[test]
    fn apply_plan_into_is_bit_identical_for_every_strategy() {
        let mut rng = Pcg::seed(21);
        let xs: Vec<f64> = (0..40).map(|_| rng.below(30) as f64 * 0.25).collect();
        let ys: Vec<f64> = (0..35).map(|_| rng.below(30) as f64 * 0.25).collect();
        let v = Matrix::randn(35, 3, &mut rng);
        let cases: Vec<(FDist, Strategy)> = vec![
            (FDist::Exponential { lambda: -0.4, scale: 1.0 }, Strategy::Dense),
            (FDist::Exponential { lambda: -0.4, scale: 1.0 }, Strategy::Separable),
            (FDist::inverse_quadratic(0.3), Strategy::Lattice),
            (FDist::inverse_quadratic(0.3), Strategy::Chebyshev),
            (FDist::inverse_quadratic(0.3), Strategy::RationalSum),
            (FDist::ExpOverLinear { lambda: -0.2, c: 1.0 }, Strategy::Cauchy),
            (FDist::gaussian(0.2), Strategy::Vandermonde),
        ];
        for (f, s) in cases {
            let policy = CrossPolicy { force: Some(s), dense_cutoff: 0, ..Default::default() };
            let plan = try_make_plan(&f, &xs, &ys, 3, &policy).expect("forced applicable");
            let want = apply_plan(&plan, &f, &xs, &ys, &v, &policy);
            let mut out = vec![f64::NAN; xs.len() * 3];
            let mut scratch = CrossScratch::new();
            let (fft, cheb, rat) = plan_scratch_demand(&plan);
            scratch.ensure(fft, cheb, rat, 3);
            apply_plan_into(
                &plan,
                &f,
                &xs,
                &ys,
                v.data(),
                3,
                &mut out,
                &policy,
                &mut scratch,
                Precision::F64,
            );
            assert_eq!(out, want.data(), "{s:?} must be bit-identical");
        }
    }

    #[test]
    fn policy_validation() {
        assert!(CrossPolicy::default().validate().is_ok());
        let bad = CrossPolicy { cheb_tol: -1.0, ..CrossPolicy::default() };
        assert!(matches!(bad.validate(), Err(FtfiError::InvalidInput(_))));
        let bad = CrossPolicy { cheb_max_rank: 1, ..CrossPolicy::default() };
        assert!(matches!(bad.validate(), Err(FtfiError::InvalidInput(_))));
    }
}
