//! Streaming field integration: a stateful session that owns the
//! current field and its cached integral and serves sparse updates
//! through the delta fast path.
//!
//! FTFI is linear in the field, so a client that mutates `k` rows per
//! tick (the robotics-masking / interactive-mesh serving scenario) does
//! not need a full `O(n · polylog(n) · d)` re-integration: the exact
//! change of the output is `integrate(Δ)`, and the sparse delta pass
//! ([`crate::tree::integrator_tree::IntegratorTree::integrate_delta_prepared_into_pooled`])
//! computes it touching only the `O(k log n)` IntegratorTree nodes
//! whose slot regions contain a changed row, for
//! `O(k · polylog(n) · d + n · d)` per update.
//!
//! **Drift policy.** Each delta application adds one float-rounding
//! layer to the cached output (the delta is exact in real arithmetic,
//! so drift grows only at machine-epsilon scale per update — the
//! superposition harness in `tests/ftfi_delta.rs` states the per-update
//! ULP budget). To keep it bounded *and testable*, the session counts
//! updates and performs a full bit-exact re-integration every
//! `refresh_every` updates; the state right after a refresh is
//! **bit-identical** to a cold `integrate` of the current field (pinned
//! by the mutation-sequence tests). `refresh_every = 0` disables the
//! policy (delta-only, drift unbounded).
//!
//! **Cumulative-from-base materialisation (PR 10).** The session keeps
//! `base` — the output at the last refresh — and a *cumulative* dirty
//! set / delta matrix covering every write since then; each update
//! materialises `out = base + integrate(Δ_cumulative)` in one delta
//! pass instead of accumulating one rounding layer per update. Because
//! the delta staging (`dx += new − old`, first-seen dirty order) runs
//! the identical floating-point op sequence whether updates are applied
//! one call at a time or fused into a window
//! ([`StreamingIntegrator::apply_updates_fused`]), the materialised
//! output after the window is **bit-identical** either way — fusion is
//! a pure work-skipping optimisation, pinned by `tests/serving_cache.rs`.

use crate::ftfi::error::FtfiError;
use crate::ftfi::TreeFieldIntegrator;
use crate::linalg::matrix::Matrix;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::RwLock;
use crate::tree::integrator_tree::{ItStats, PreparedPlans, ReplanStats};
use std::sync::Arc;

/// One `(integrator, plans)` pair shared — read-mostly — by every
/// streaming session riding the same tree. Integrations take the read
/// lock; an edge re-plan takes the write lock, patches the tree and the
/// plans in lockstep ([`TreeFieldIntegrator::replan_edge_prepared`],
/// so the handle never goes stale relative to its tree) and bumps a
/// generation counter sessions use to notice that their *cached output*
/// no longer reflects the current edge weights.
///
/// Lock ordering (shared with the coordinator): a session mutex is
/// always acquired **before** this lock, and this lock is never held
/// while acquiring a session mutex.
pub struct SharedPlans {
    cell: RwLock<(TreeFieldIntegrator, PreparedPlans)>,
    epoch: AtomicU64,
}

impl SharedPlans {
    /// Wrap an integrator and the plans it prepared.
    pub fn new(tfi: TreeFieldIntegrator, plans: PreparedPlans) -> Self {
        SharedPlans { cell: RwLock::new((tfi, plans)), epoch: AtomicU64::new(0) }
    }

    /// Generation counter: bumped once per weight-changing re-plan
    /// (validation failures and same-weight no-ops leave it unmoved).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Run `f` against the current integrator/plans pair under the read
    /// lock. Errors only when the lock is poisoned (a panic mid-replan).
    pub fn with<R>(
        &self,
        f: impl FnOnce(&TreeFieldIntegrator, &PreparedPlans) -> R,
    ) -> Result<R, FtfiError> {
        let guard = self.cell.read().map_err(|_| poisoned())?;
        let (tfi, plans) = &*guard;
        Ok(f(tfi, plans))
    }

    /// Reweight one existing tree edge under the write lock, rebuilding
    /// exactly the affected per-node plans (two-phase: a validation or
    /// planning failure leaves both halves untouched and the epoch
    /// unmoved).
    pub fn replan_edge(&self, u: usize, v: usize, w: f64) -> Result<ReplanStats, FtfiError> {
        let mut guard = self.cell.write().map_err(|_| poisoned())?;
        let (tfi, plans) = &mut *guard;
        let st = tfi.replan_edge_prepared(u, v, w, plans)?;
        if st.changed {
            // Published while the write lock is still held, so a reader
            // holding the read lock always sees an epoch consistent
            // with the pair it observes.
            self.epoch.fetch_add(1, Ordering::Release);
        }
        Ok(st)
    }
}

fn poisoned() -> FtfiError {
    FtfiError::InvalidInput("shared plan cell poisoned by a panicked re-plan".to_string())
}

/// A streaming session over one `(tree, f)` pair: owns the current
/// field and the cached output, applies sparse row updates through the
/// delta fast path, and refreshes bit-exactly every `refresh_every`
/// updates. Shares its integrator and prepared plans through a
/// [`SharedPlans`] cell, so many sessions (the serving executor's
/// `max_sessions`) ride one tree, one plan set and one work pool — and
/// all of them observe an edge re-plan issued through any one of them.
pub struct StreamingIntegrator {
    shared: Arc<SharedPlans>,
    /// The [`SharedPlans::epoch`] the cached output was computed under;
    /// when the cell has moved past it (an edge re-plan elsewhere), the
    /// next update recomputes the output bit-exactly instead of
    /// applying a delta against weights that no longer exist.
    plan_epoch: u64,
    /// Current field (`n×d`); row assignments are exact, so this always
    /// equals the field a rebuild-from-scratch oracle would hold.
    field: Matrix,
    /// Cached `integrate(field)` (exact after a refresh, within the
    /// single-delta-pass rounding budget between refreshes).
    out: Matrix,
    /// Output at the last full refresh: every materialisation rebuilds
    /// `out = base + integrate(Δ_cumulative)` from here, so drift never
    /// compounds across updates and fused windows are bit-identical to
    /// unfused ones.
    base: Matrix,
    /// Dense delta staging, cumulative since `base`: only the rows in
    /// `dirty` are meaningful; they are re-zeroed on first touch per
    /// refresh era.
    dx: Matrix,
    /// Delta-output buffer (`Δout = integrate(Δ)`).
    dout: Matrix,
    /// Unique rows touched since the last refresh, in first-seen order.
    dirty: Vec<u32>,
    /// Per-vertex era stamps deduplicating rows within one refresh era.
    stamp: Vec<u32>,
    epoch: u32,
    refresh_every: usize,
    since_refresh: usize,
    updates: usize,
    refreshes: usize,
}

/// Outcome counters of one (possibly fused) update window — see
/// [`StreamingIntegrator::apply_updates_fused`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FusionStats {
    /// Successful logical updates the window absorbed.
    pub fused: usize,
    /// Dirty-row delta applications skipped versus serving each member
    /// through its own `apply_update` call.
    pub rows_saved: usize,
}

impl StreamingIntegrator {
    /// Open a session: validates the initial field against the shared
    /// integrator/plans pair and pays one full integration to seed the
    /// cached output.
    pub fn new(
        shared: Arc<SharedPlans>,
        field: Matrix,
        refresh_every: usize,
    ) -> Result<Self, FtfiError> {
        let n = shared.with(|tfi, _| tfi.n())?;
        if field.rows() != n {
            return Err(FtfiError::ShapeMismatch { expected: n, got: field.rows() });
        }
        if field.cols() == 0 {
            return Err(FtfiError::InvalidInput(
                "streaming session needs at least one field channel".to_string(),
            ));
        }
        let d = field.cols();
        let mut out = Matrix::zeros(n, d);
        let plan_epoch = shared
            .with(|tfi, plans| {
                tfi.integrate_prepared_into(&field, plans, &mut out).map(|_| shared.epoch())
            })??;
        let base = out.clone();
        Ok(StreamingIntegrator {
            shared,
            plan_epoch,
            field,
            out,
            base,
            dx: Matrix::zeros(n, d),
            dout: Matrix::zeros(n, d),
            dirty: Vec::new(),
            stamp: vec![0; n],
            epoch: 1,
            refresh_every,
            since_refresh: 0,
            updates: 0,
            refreshes: 0,
        })
    }

    /// Apply a sparse update: set the listed field rows to `values`
    /// (`rows.len()×d`; duplicate rows within one call apply in order,
    /// last write wins) and return the refreshed output. Runs the delta
    /// fast path unless this update hits the `refresh_every` boundary
    /// (or a sibling session re-planned an edge), in which case the
    /// output is recomputed bit-exactly from the current field. A
    /// failed update (bad row / shape) changes nothing — the session
    /// stays serviceable. Allocation-free when warmed: this is the
    /// one-member form of [`StreamingIntegrator::apply_updates_fused`]
    /// — the identical staging / refresh / delta op sequence, without
    /// the window's per-member verdict vector.
    pub fn apply_update(&mut self, rows: &[u32], values: &Matrix) -> Result<&Matrix, FtfiError> {
        let shared = Arc::clone(&self.shared);
        shared.with(|tfi, plans| -> Result<(), FtfiError> {
            let cur = shared.epoch();
            let stale = cur != self.plan_epoch;
            self.stage(rows, values)?;
            self.updates += 1;
            self.since_refresh += 1;
            let cadence = self.refresh_every > 0 && self.since_refresh >= self.refresh_every;
            if stale || cadence {
                tfi.integrate_prepared_into(&self.field, plans, &mut self.out)?;
                self.base.data_mut().copy_from_slice(self.out.data());
                self.clear_dirty();
                self.plan_epoch = cur;
                self.since_refresh = 0;
                self.refreshes += 1;
            } else if !self.dirty.is_empty() {
                tfi.integrate_delta_prepared_into(&self.dirty, &self.dx, plans, &mut self.dout)?;
                self.out.data_mut().copy_from_slice(self.base.data());
                self.out.axpy(1.0, &self.dout);
            }
            Ok(())
        })??;
        Ok(&self.out)
    }

    /// Apply a whole batch window of updates for this session in one
    /// fused pass. Members apply in FIFO order with full per-member
    /// semantics — duplicate rows last-write-wins, a malformed member
    /// fails alone without staging anything, the `refresh_every` cadence
    /// fires at exactly the members it would fire at under one-by-one
    /// [`StreamingIntegrator::apply_update`] calls — but the cumulative
    /// delta pass and the `base → out` materialisation run only once,
    /// at the end of the window (or at each refresh boundary inside it).
    /// The output after the window is **bit-identical** to applying the
    /// members through individual calls: the staging arithmetic is the
    /// same op sequence either way, and intermediate materialisations
    /// never feed back into the state. Returns one verdict per member
    /// plus the fusion savings ([`FusionStats::rows_saved`] counts the
    /// dirty rows of every skipped intermediate pass).
    pub fn apply_updates_fused(
        &mut self,
        updates: &[(&[u32], &Matrix)],
    ) -> (Vec<Result<(), FtfiError>>, FusionStats) {
        let mut results = Vec::with_capacity(updates.len());
        let mut stats = FusionStats::default();
        if updates.is_empty() {
            return (results, stats);
        }
        let shared = Arc::clone(&self.shared);
        let run = shared.with(|tfi, plans| -> Result<(), FtfiError> {
            // The read lock is held for the whole window, so the plan
            // epoch cannot move mid-window: staleness (an edge re-plan
            // through a sibling session) is noticed once, up front —
            // exactly where the first unfused call would notice it.
            let cur = shared.epoch();
            let mut stale = cur != self.plan_epoch;
            let mut pending = false;
            for (i, (rows, values)) in updates.iter().enumerate() {
                if let Err(e) = self.stage(rows, values) {
                    results.push(Err(e));
                    continue;
                }
                self.updates += 1;
                self.since_refresh += 1;
                let cadence =
                    self.refresh_every > 0 && self.since_refresh >= self.refresh_every;
                if stale || cadence {
                    // Refresh boundary: recompute bit-exactly from the
                    // current field and start a new delta era, exactly
                    // as the unfused call at this member would.
                    tfi.integrate_prepared_into(&self.field, plans, &mut self.out)?;
                    self.base.data_mut().copy_from_slice(self.out.data());
                    self.clear_dirty();
                    self.plan_epoch = cur;
                    self.since_refresh = 0;
                    self.refreshes += 1;
                    stale = false;
                    pending = false;
                } else {
                    if i + 1 < updates.len() {
                        // This member's delta pass is fused away — in
                        // unfused serving it would have re-integrated
                        // the whole cumulative dirty set.
                        stats.rows_saved += self.dirty.len();
                    }
                    pending = true;
                }
                stats.fused += 1;
                results.push(Ok(()));
            }
            if pending && !self.dirty.is_empty() {
                tfi.integrate_delta_prepared_into(&self.dirty, &self.dx, plans, &mut self.dout)?;
                self.out.data_mut().copy_from_slice(self.base.data());
                self.out.axpy(1.0, &self.dout);
            }
            Ok(())
        });
        let err = match run {
            Ok(Ok(())) => None,
            Ok(Err(e)) | Err(e) => Some(e),
        };
        if let Some(e) = err {
            // A mid-window integration failure (poisoned plan cell) is a
            // session-level fault: the cached output can no longer be
            // trusted, so every member that did not already fail its own
            // validation reports the window error.
            let msg = format!("fused window failed: {e}");
            for r in results.iter_mut() {
                if r.is_ok() {
                    *r = Err(FtfiError::InvalidInput(msg.clone()));
                }
            }
            while results.len() < updates.len() {
                results.push(Err(FtfiError::InvalidInput(msg.clone())));
            }
            stats = FusionStats::default();
        }
        (results, stats)
    }

    /// Validate one update and stage its writes: Δ row `+= new − old`
    /// (accumulated across duplicates and across the whole refresh era),
    /// and the field row itself is *assigned* — the session field always
    /// bit-matches a rebuild-from-scratch oracle's. A validation failure
    /// stages nothing.
    fn stage(&mut self, rows: &[u32], values: &Matrix) -> Result<(), FtfiError> {
        let n = self.field.rows();
        let d = self.field.cols();
        if values.rows() != rows.len() {
            return Err(FtfiError::ShapeMismatch { expected: rows.len(), got: values.rows() });
        }
        if values.cols() != d {
            return Err(FtfiError::InvalidInput(format!(
                "update has {} channels, session field has {d}",
                values.cols()
            )));
        }
        for &v in rows {
            if v as usize >= n {
                return Err(FtfiError::InvalidInput(format!(
                    "update row {v} out of range (n = {n})"
                )));
            }
        }
        for (i, &v) in rows.iter().enumerate() {
            let vi = v as usize;
            if self.stamp[vi] != self.epoch {
                self.stamp[vi] = self.epoch;
                self.dirty.push(v);
                self.dx.row_mut(vi).iter_mut().for_each(|x| *x = 0.0);
            }
            let new_row = values.row(i);
            let old_row = self.field.row_mut(vi);
            let dx_row = &mut self.dx.data_mut()[vi * d..(vi + 1) * d];
            for c in 0..d {
                dx_row[c] += new_row[c] - old_row[c];
                old_row[c] = new_row[c];
            }
        }
        Ok(())
    }

    /// Start a new delta era (the cumulative dirty set resets; row
    /// stamps are invalidated by bumping the era counter).
    fn clear_dirty(&mut self) {
        self.dirty.clear();
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
    }

    /// Force a full bit-exact re-integration of the current field (the
    /// drift policy calls this automatically every `refresh_every`
    /// updates, and any update after an edge re-plan triggers it).
    pub fn refresh(&mut self) -> Result<&Matrix, FtfiError> {
        let shared = Arc::clone(&self.shared);
        shared.with(|tfi, plans| {
            self.plan_epoch = shared.epoch();
            tfi.integrate_prepared_into(&self.field, plans, &mut self.out)
        })??;
        self.base.data_mut().copy_from_slice(self.out.data());
        self.clear_dirty();
        self.since_refresh = 0;
        self.refreshes += 1;
        Ok(&self.out)
    }

    /// Rebind this session to a different shared plan cell over the
    /// *same* graph size and channel count (the multi-graph cache path:
    /// a client re-opens its session onto another cached graph). The
    /// field carries over unchanged and the output is re-integrated
    /// bit-exactly under the new plans (counting toward
    /// [`StreamingIntegrator::refreshes`]). All session buffers are
    /// reused — a migration between cached graphs allocates nothing.
    /// On shape mismatch or integration failure the session is restored
    /// onto its previous plans, still serviceable.
    pub fn migrate(&mut self, to: Arc<SharedPlans>) -> Result<&Matrix, FtfiError> {
        let (n, d) = to.with(|_, plans| (plans.n(), plans.channels()))?;
        if n != self.field.rows() {
            return Err(FtfiError::ShapeMismatch { expected: self.field.rows(), got: n });
        }
        if d != self.field.cols() {
            return Err(FtfiError::InvalidInput(format!(
                "target plans prepared for {d} channels, session field has {}",
                self.field.cols()
            )));
        }
        let old = std::mem::replace(&mut self.shared, to);
        if let Err(e) = self.refresh().map(|_| ()) {
            self.shared = old;
            self.refresh()?;
            return Err(e);
        }
        Ok(&self.out)
    }

    /// Reweight one tree edge of the shared metric (delegates to
    /// [`SharedPlans::replan_edge`] — every session on this plan set
    /// sees the change). When the weight actually changes, this
    /// session's cached output is invalidated and refreshed bit-exactly
    /// right here (counting toward [`StreamingIntegrator::refreshes`]);
    /// sibling sessions refresh lazily on their next update. A rejected
    /// replan (out-of-range vertex, non-tree edge, bad weight) returns
    /// [`FtfiError::InvalidInput`] and leaves the plans, the tree and
    /// this session untouched; reassigning the current weight is a
    /// no-op.
    pub fn update_edge(&mut self, u: usize, v: usize, w: f64) -> Result<ReplanStats, FtfiError> {
        let st = self.shared.replan_edge(u, v, w)?;
        if st.changed {
            self.refresh()?;
        }
        Ok(st)
    }

    /// The shared integrator/plans cell this session rides.
    pub fn shared(&self) -> &Arc<SharedPlans> {
        &self.shared
    }

    /// The cached output (`integrate(field)` up to the bounded drift).
    pub fn output(&self) -> &Matrix {
        &self.out
    }

    /// The current field.
    pub fn field(&self) -> &Matrix {
        &self.field
    }

    /// Vertices of the underlying metric.
    pub fn n(&self) -> usize {
        self.field.rows()
    }

    /// Field channels this session was opened with.
    pub fn channels(&self) -> usize {
        self.field.cols()
    }

    /// The configured refresh cadence (`0` = never).
    pub fn refresh_every(&self) -> usize {
        self.refresh_every
    }

    /// Updates applied over the session lifetime.
    pub fn updates_applied(&self) -> usize {
        self.updates
    }

    /// Full re-integrations performed (drift policy + explicit
    /// [`StreamingIntegrator::refresh`] calls).
    pub fn refreshes(&self) -> usize {
        self.refreshes
    }

    /// Updates since the last full re-integration (the current drift
    /// depth).
    pub fn updates_since_refresh(&self) -> usize {
        self.since_refresh
    }

    /// Integrator statistics with the streaming counters filled in:
    /// `delta_nodes_visited` and the replan counters from the shared
    /// tree (pool-scoped lifetime aggregates — compare deltas),
    /// `delta_refreshes` from this session. A poisoned plan cell yields
    /// zeroed tree counters rather than a panic.
    pub fn stats(&self) -> ItStats {
        let mut st = self.shared.with(|tfi, _| tfi.stats()).unwrap_or_default();
        st.delta_refreshes = self.refreshes;
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::BruteForceIntegrator;
    use crate::ftfi::functions::FDist;
    use crate::ftfi::FieldIntegrator;
    use crate::graph::generators::random_tree;
    use crate::ml::rng::Pcg;

    fn session(
        n: usize,
        d: usize,
        refresh_every: usize,
        seed: u64,
    ) -> (StreamingIntegrator, BruteForceIntegrator, FDist) {
        let mut rng = Pcg::seed(seed);
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
        let plans = tfi.prepare_plans(&f, d).unwrap();
        let shared = Arc::new(SharedPlans::new(tfi, plans));
        let brute = BruteForceIntegrator::from_tree(tree);
        let field = Matrix::randn(n, d, &mut rng);
        let s = StreamingIntegrator::new(shared, field, refresh_every).unwrap();
        (s, brute, f)
    }

    #[test]
    fn updates_track_the_brute_oracle() {
        let (mut s, brute, f) = session(120, 2, 8, 1);
        let mut rng = Pcg::seed(2);
        for step in 0..20 {
            let k = [0usize, 1, 3, 7][rng.below(4)];
            let mut rows = Vec::new();
            while rows.len() < k {
                let v = rng.below(120) as u32;
                if !rows.contains(&v) {
                    rows.push(v);
                }
            }
            let vals = Matrix::randn(k, 2, &mut rng);
            let out = s.apply_update(&rows, &vals).unwrap().clone();
            let want = brute.integrate(&f, s.field()).unwrap();
            let rel = out.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-8, "step {step}: drifted to rel {rel}");
        }
        assert_eq!(s.updates_applied(), 20);
        assert!(s.stats().delta_refreshes >= 2, "refresh_every=8 over 20 updates");
    }

    #[test]
    fn refresh_boundary_is_bit_identical_to_cold_integrate() {
        let mut rng = Pcg::seed(3);
        let tree = random_tree(150, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
        let plans = tfi.prepare_plans(&f, 2).unwrap();
        let shared = Arc::new(SharedPlans::new(tfi, plans));
        let field = Matrix::randn(150, 2, &mut rng);
        let mut s = StreamingIntegrator::new(Arc::clone(&shared), field, 5).unwrap();
        let mut rng = Pcg::seed(4);
        for step in 1..=11 {
            let rows = [rng.below(150) as u32];
            let vals = Matrix::randn(1, 2, &mut rng);
            s.apply_update(&rows, &vals).unwrap();
            let cold = shared
                .with(|tfi, plans| tfi.integrate_prepared(s.field(), plans).unwrap())
                .unwrap();
            if step % 5 == 0 {
                assert!(
                    *s.output() == cold,
                    "step {step}: post-refresh state must be bit-identical to cold integrate"
                );
            } else {
                // Between refreshes drift stays at rounding scale.
                let rel = s.output().frobenius_diff(&cold) / (1.0 + cold.frobenius());
                assert!(rel < 1e-11, "step {step}: rel {rel}");
            }
        }
        assert_eq!(s.refreshes(), 2);
        assert_eq!(s.updates_since_refresh(), 1);
    }

    #[test]
    fn duplicate_rows_in_one_update_apply_in_order() {
        let (mut s, brute, f) = session(40, 1, 0, 5);
        // Same row three times: last write wins on the field.
        let rows = [7u32, 7, 7];
        let vals = Matrix::from_vec(3, 1, vec![1.0, -2.0, 5.0]);
        s.apply_update(&rows, &vals).unwrap();
        assert_eq!(s.field().get(7, 0), 5.0);
        let want = brute.integrate(&f, s.field()).unwrap();
        let rel = s.output().frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-9, "rel {rel}");
    }

    #[test]
    fn degenerate_sessions_and_updates() {
        // n = 1 singleton metric.
        let (mut s, brute, f) = session(1, 2, 2, 6);
        let out = s.apply_update(&[0], &Matrix::from_vec(1, 2, vec![3.0, -1.0])).unwrap();
        let want = brute.integrate(&f, &Matrix::from_vec(1, 2, vec![3.0, -1.0])).unwrap();
        assert!(out.frobenius_diff(&want) < 1e-12);
        // k = 0 no-op still counts toward the refresh cadence.
        s.apply_update(&[], &Matrix::zeros(0, 2)).unwrap();
        assert_eq!(s.refreshes(), 1, "the second update must have hit refresh_every = 2");
        // k = n full-row update.
        let (mut s, brute, f) = session(30, 1, 0, 7);
        let rows: Vec<u32> = (0..30).collect();
        let mut rng = Pcg::seed(8);
        let vals = Matrix::randn(30, 1, &mut rng);
        s.apply_update(&rows, &vals).unwrap();
        let want = brute.integrate(&f, &vals).unwrap();
        let rel = s.output().frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-9, "rel {rel}");
    }

    #[test]
    fn malformed_updates_fail_without_corrupting_the_session() {
        let (mut s, brute, f) = session(50, 2, 0, 9);
        let before = s.output().clone();
        // Row out of range.
        assert!(matches!(
            s.apply_update(&[50], &Matrix::zeros(1, 2)),
            Err(FtfiError::InvalidInput(_))
        ));
        // Shape mismatches.
        assert!(matches!(
            s.apply_update(&[0], &Matrix::zeros(2, 2)),
            Err(FtfiError::ShapeMismatch { .. })
        ));
        assert!(matches!(
            s.apply_update(&[0], &Matrix::zeros(1, 3)),
            Err(FtfiError::InvalidInput(_))
        ));
        assert!(*s.output() == before, "failed updates must not move the output");
        assert_eq!(s.updates_applied(), 0);
        // The session still serves good updates.
        let out = s.apply_update(&[0], &Matrix::from_vec(1, 2, vec![1.0, 2.0])).unwrap().clone();
        let want = brute.integrate(&f, s.field()).unwrap();
        assert!(out.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }

    #[test]
    fn new_validates_the_initial_field() {
        let mut rng = Pcg::seed(10);
        let tree = random_tree(20, 0.1, 1.0, &mut rng);
        let f = FDist::Identity;
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let plans = tfi.prepare_plans(&f, 1).unwrap();
        let shared = Arc::new(SharedPlans::new(tfi, plans));
        assert!(matches!(
            StreamingIntegrator::new(Arc::clone(&shared), Matrix::zeros(19, 1), 4),
            Err(FtfiError::ShapeMismatch { expected: 20, got: 19 })
        ));
        assert!(StreamingIntegrator::new(shared, Matrix::zeros(20, 1), 4).is_ok());
    }

    #[test]
    fn edge_replans_compose_with_field_updates() {
        let mut rng = Pcg::seed(21);
        let mut tree = random_tree(90, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
        let plans = tfi.prepare_plans(&f, 2).unwrap();
        let shared = Arc::new(SharedPlans::new(tfi, plans));
        let field = Matrix::randn(90, 2, &mut rng);
        let mut s = StreamingIntegrator::new(Arc::clone(&shared), field, 6).unwrap();
        let mut rng = Pcg::seed(22);
        for step in 0..16 {
            if step % 3 == 2 {
                let (eu, ev, ew) = tree.edges()[rng.below(tree.edges().len())];
                let w = ew * (0.5 + rng.uniform());
                let st = s.update_edge(eu as usize, ev as usize, w).unwrap();
                assert!(st.changed && st.nodes_visited >= 1, "step {step}");
                assert!(tree.set_edge_weight(eu as usize, ev as usize, w).is_some());
            } else {
                let rows = [rng.below(90) as u32];
                let vals = Matrix::randn(1, 2, &mut rng);
                s.apply_update(&rows, &vals).unwrap();
            }
            // Oracle: brute-force on the *mutated* tree and current field.
            let brute = BruteForceIntegrator::from_tree(tree.clone());
            let want = brute.integrate(&f, s.field()).unwrap();
            let rel = s.output().frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-8, "step {step}: rel {rel}");
        }
    }

    #[test]
    fn sibling_sessions_observe_a_replan_lazily() {
        let mut rng = Pcg::seed(23);
        let mut tree = random_tree(70, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
        let plans = tfi.prepare_plans(&f, 1).unwrap();
        let shared = Arc::new(SharedPlans::new(tfi, plans));
        let fa = Matrix::randn(70, 1, &mut rng);
        let fb = Matrix::randn(70, 1, &mut rng);
        let mut a = StreamingIntegrator::new(Arc::clone(&shared), fa, 0).unwrap();
        let mut b = StreamingIntegrator::new(Arc::clone(&shared), fb, 0).unwrap();
        let (eu, ev, ew) = tree.edges()[5];
        a.update_edge(eu as usize, ev as usize, ew * 3.0).unwrap();
        assert!(tree.set_edge_weight(eu as usize, ev as usize, ew * 3.0).is_some());
        assert_eq!(a.refreshes(), 1, "the replanning session refreshes eagerly");
        assert_eq!(b.refreshes(), 0, "siblings have not noticed yet");
        // B's next update — even an empty one — notices the epoch bump
        // and recomputes bit-exactly under the new weights.
        b.apply_update(&[], &Matrix::zeros(0, 1)).unwrap();
        assert_eq!(b.refreshes(), 1, "stale plans force a full refresh");
        let brute = BruteForceIntegrator::from_tree(tree);
        for (s, name) in [(&a, "a"), (&b, "b")] {
            let want = brute.integrate(&f, s.field()).unwrap();
            let rel = s.output().frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-8, "session {name}: rel {rel}");
        }
    }

    /// Fusing a window of updates into one delta pass must be
    /// **bit-identical** to applying the members through individual
    /// `apply_update` calls — including when the `refresh_every`
    /// cadence fires mid-window and when a malformed member fails
    /// alone. This is the core contract the serving-side fusion
    /// (`StreamingFieldExecutor::exec_update_group`) rides on.
    #[test]
    fn fused_windows_are_bit_identical_to_sequential_calls() {
        for (seed, refresh_every) in [(31u64, 0usize), (32, 3), (33, 1)] {
            let n = 80;
            let d = 2;
            let (mut fused, _, _) = session(n, d, refresh_every, seed);
            let (mut seq, _, _) = session(n, d, refresh_every, seed);
            let mut rng = Pcg::seed(seed ^ 0x5eed);
            for window in 0..6 {
                let members: Vec<(Vec<u32>, Matrix)> = (0..4)
                    .map(|_| {
                        let k = 1 + rng.below(3);
                        // Deliberately allow duplicates within and
                        // across members.
                        let rows: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
                        let vals = Matrix::randn(k, d, &mut rng);
                        (rows, vals)
                    })
                    .collect();
                let refs: Vec<(&[u32], &Matrix)> =
                    members.iter().map(|(r, v)| (r.as_slice(), v)).collect();
                let (verdicts, stats) = fused.apply_updates_fused(&refs);
                assert!(verdicts.iter().all(|v| v.is_ok()), "window {window}");
                assert_eq!(stats.fused, members.len());
                for (rows, vals) in &members {
                    seq.apply_update(rows, vals).unwrap();
                }
                assert!(
                    *fused.output() == *seq.output(),
                    "REPRO seed={seed} refresh_every={refresh_every} window={window}: \
                     fused output must be bit-identical to sequential"
                );
                assert!(*fused.field() == *seq.field());
                assert_eq!(fused.refreshes(), seq.refreshes());
                assert_eq!(fused.updates_since_refresh(), seq.updates_since_refresh());
                assert_eq!(fused.updates_applied(), seq.updates_applied());
            }
            if refresh_every == 0 {
                // No cadence refresh ever fires, so every non-last
                // member's delta pass is fused away.
                let refs: Vec<(&[u32], &Matrix)> = Vec::new();
                let (v, s) = fused.apply_updates_fused(&refs);
                assert!(v.is_empty() && s == FusionStats::default());
            }
        }
    }

    #[test]
    fn fused_window_member_failures_stay_isolated() {
        let (mut fused, _, _) = session(50, 2, 0, 41);
        let (mut seq, _, _) = session(50, 2, 0, 41);
        let good_a = (vec![3u32, 9], Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]));
        let bad = (vec![50u32], Matrix::zeros(1, 2)); // row out of range
        let good_b = (vec![7u32], Matrix::from_vec(1, 2, vec![-1.0, 0.5]));
        let refs: Vec<(&[u32], &Matrix)> = vec![
            (good_a.0.as_slice(), &good_a.1),
            (bad.0.as_slice(), &bad.1),
            (good_b.0.as_slice(), &good_b.1),
        ];
        let (verdicts, stats) = fused.apply_updates_fused(&refs);
        assert!(verdicts[0].is_ok());
        assert!(matches!(verdicts[1], Err(FtfiError::InvalidInput(_))));
        assert!(verdicts[2].is_ok());
        assert_eq!(stats.fused, 2, "only successful members count");
        assert!(stats.rows_saved >= 1, "the first member's pass was fused away");
        seq.apply_update(&good_a.0, &good_a.1).unwrap();
        assert!(seq.apply_update(&bad.0, &bad.1).is_err());
        seq.apply_update(&good_b.0, &good_b.1).unwrap();
        assert!(*fused.output() == *seq.output(), "failed member must not skew the window");
        assert_eq!(fused.updates_applied(), 2);
    }

    /// Migration rebinds a session to another plan cell of the same
    /// shape: the field carries over, the output is re-integrated
    /// bit-exactly under the new plans, and a shape-mismatched target
    /// leaves the session serviceable on its old plans.
    #[test]
    fn migrate_rebinds_to_a_same_shape_cell_bit_exactly() {
        let n = 60;
        let d = 2;
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let cell = |seed: u64, n: usize| {
            let mut rng = Pcg::seed(seed);
            let tree = random_tree(n, 0.1, 1.0, &mut rng);
            let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
            let plans = tfi.prepare_plans(&f, d).unwrap();
            Arc::new(SharedPlans::new(tfi, plans))
        };
        let a = cell(51, n);
        let b = cell(52, n);
        let mut rng = Pcg::seed(53);
        let field = Matrix::randn(n, d, &mut rng);
        let mut s = StreamingIntegrator::new(Arc::clone(&a), field.clone(), 0).unwrap();
        s.apply_update(&[5], &Matrix::from_vec(1, d, vec![2.0, -3.0])).unwrap();
        let carried = s.field().clone();
        s.migrate(Arc::clone(&b)).unwrap();
        assert_eq!(s.refreshes(), 1, "migration pays one full refresh");
        assert!(*s.field() == carried, "the field must carry over unchanged");
        let fresh = StreamingIntegrator::new(Arc::clone(&b), carried, 0).unwrap();
        assert!(
            *s.output() == *fresh.output(),
            "migrated output must be bit-identical to a fresh session on the target"
        );
        // A wrong-size target is rejected and the session stays on `b`.
        let small = cell(54, n / 2);
        let before = s.output().clone();
        assert!(matches!(
            s.migrate(small),
            Err(FtfiError::ShapeMismatch { .. })
        ));
        assert!(Arc::ptr_eq(s.shared(), &b), "failed migration must not rebind");
        assert!(*s.output() == before);
        s.apply_update(&[1], &Matrix::from_vec(1, d, vec![0.5, 0.5])).unwrap();
    }

    #[test]
    fn malformed_replans_fail_without_touching_plans_or_session() {
        let (mut s, brute, f) = session(60, 2, 0, 24);
        let before = s.output().clone();
        let epoch = s.shared().epoch();
        // Find a non-tree-adjacent pair for the rejection cases.
        let n = s.n();
        for (u, v, w) in [
            (n, 0, 1.0),                // endpoint out of range
            (0, n + 7, 1.0),            // endpoint out of range
            (3, 3, 1.0),                // self-loop is never a tree edge
            (0, 1, f64::NAN),           // bad weights on whatever (0,1) is
            (0, 1, f64::INFINITY),
            (0, 1, -1.0),
            (0, 1, 0.0),
        ] {
            let got = s.update_edge(u, v, w);
            assert!(
                matches!(got, Err(FtfiError::InvalidInput(_))),
                "({u}, {v}, {w}) must be rejected as InvalidInput, got {got:?}"
            );
        }
        assert_eq!(s.shared().epoch(), epoch, "rejected replans must not bump the epoch");
        assert_eq!(s.refreshes(), 0);
        assert!(*s.output() == before, "rejected replans must not move the output");
        // The session still serves updates against the untouched plans.
        let out = s.apply_update(&[0], &Matrix::from_vec(1, 2, vec![1.0, 2.0])).unwrap().clone();
        let want = brute.integrate(&f, s.field()).unwrap();
        assert!(out.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }
}
