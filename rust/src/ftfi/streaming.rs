//! Streaming field integration: a stateful session that owns the
//! current field and its cached integral and serves sparse updates
//! through the delta fast path.
//!
//! FTFI is linear in the field, so a client that mutates `k` rows per
//! tick (the robotics-masking / interactive-mesh serving scenario) does
//! not need a full `O(n · polylog(n) · d)` re-integration: the exact
//! change of the output is `integrate(Δ)`, and the sparse delta pass
//! ([`crate::tree::integrator_tree::IntegratorTree::integrate_delta_prepared_into_pooled`])
//! computes it touching only the `O(k log n)` IntegratorTree nodes
//! whose slot regions contain a changed row, for
//! `O(k · polylog(n) · d + n · d)` per update.
//!
//! **Drift policy.** Each delta application adds one float-rounding
//! layer to the cached output (the delta is exact in real arithmetic,
//! so drift grows only at machine-epsilon scale per update — the
//! superposition harness in `tests/ftfi_delta.rs` states the per-update
//! ULP budget). To keep it bounded *and testable*, the session counts
//! updates and performs a full bit-exact re-integration every
//! `refresh_every` updates; the state right after a refresh is
//! **bit-identical** to a cold `integrate` of the current field (pinned
//! by the mutation-sequence tests). `refresh_every = 0` disables the
//! policy (delta-only, drift unbounded).

use crate::ftfi::error::FtfiError;
use crate::ftfi::TreeFieldIntegrator;
use crate::linalg::matrix::Matrix;
use crate::tree::integrator_tree::{ItStats, PreparedPlans};
use std::sync::Arc;

/// A streaming session over one `(tree, f)` pair: owns the current
/// field and the cached output, applies sparse row updates through the
/// delta fast path, and refreshes bit-exactly every `refresh_every`
/// updates. Shares its integrator and prepared plans via `Arc`, so many
/// sessions (the serving executor's `max_sessions`) ride one tree, one
/// plan set and one work pool.
pub struct StreamingIntegrator {
    tfi: Arc<TreeFieldIntegrator>,
    plans: Arc<PreparedPlans>,
    /// Current field (`n×d`); row assignments are exact, so this always
    /// equals the field a rebuild-from-scratch oracle would hold.
    field: Matrix,
    /// Cached `integrate(field)` (exact after a refresh, within the
    /// accumulated-rounding drift budget between refreshes).
    out: Matrix,
    /// Dense delta staging: only the rows touched by the current update
    /// are meaningful; they are re-zeroed on first touch per update.
    dx: Matrix,
    /// Delta-output buffer (`Δout = integrate(Δ)`).
    dout: Matrix,
    /// Unique rows touched by the current update.
    dirty: Vec<u32>,
    /// Per-vertex epoch stamps deduplicating rows within one update.
    stamp: Vec<u32>,
    epoch: u32,
    refresh_every: usize,
    since_refresh: usize,
    updates: usize,
    refreshes: usize,
}

impl StreamingIntegrator {
    /// Open a session: validates the initial field against the
    /// integrator/plans pair and pays one full integration to seed the
    /// cached output.
    pub fn new(
        tfi: Arc<TreeFieldIntegrator>,
        plans: Arc<PreparedPlans>,
        field: Matrix,
        refresh_every: usize,
    ) -> Result<Self, FtfiError> {
        let n = tfi.n();
        if field.rows() != n {
            return Err(FtfiError::ShapeMismatch { expected: n, got: field.rows() });
        }
        if field.cols() == 0 {
            return Err(FtfiError::InvalidInput(
                "streaming session needs at least one field channel".to_string(),
            ));
        }
        let d = field.cols();
        let mut out = Matrix::zeros(n, d);
        tfi.integrate_prepared_into(&field, &plans, &mut out)?;
        Ok(StreamingIntegrator {
            tfi,
            plans,
            field,
            out,
            dx: Matrix::zeros(n, d),
            dout: Matrix::zeros(n, d),
            dirty: Vec::new(),
            stamp: vec![0; n],
            epoch: 0,
            refresh_every,
            since_refresh: 0,
            updates: 0,
            refreshes: 0,
        })
    }

    /// Apply a sparse update: set the listed field rows to `values`
    /// (`rows.len()×d`; duplicate rows within one call apply in order,
    /// last write wins) and return the refreshed output. Runs the delta
    /// fast path unless this update hits the `refresh_every` boundary,
    /// in which case the output is recomputed bit-exactly from the
    /// current field. A failed update (bad row / shape) changes nothing
    /// — the session stays serviceable.
    pub fn apply_update(&mut self, rows: &[u32], values: &Matrix) -> Result<&Matrix, FtfiError> {
        let n = self.field.rows();
        let d = self.field.cols();
        if values.rows() != rows.len() {
            return Err(FtfiError::ShapeMismatch { expected: rows.len(), got: values.rows() });
        }
        if values.cols() != d {
            return Err(FtfiError::InvalidInput(format!(
                "update has {} channels, session field has {d}",
                values.cols()
            )));
        }
        for &v in rows {
            if v as usize >= n {
                return Err(FtfiError::InvalidInput(format!(
                    "update row {v} out of range (n = {n})"
                )));
            }
        }
        // Stage: Δ row = new − old (accumulated across duplicates), and
        // the field row itself is *assigned* — the session field always
        // bit-matches a rebuild-from-scratch oracle's.
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
        self.dirty.clear();
        for (i, &v) in rows.iter().enumerate() {
            let vi = v as usize;
            if self.stamp[vi] != self.epoch {
                self.stamp[vi] = self.epoch;
                self.dirty.push(v);
                self.dx.row_mut(vi).iter_mut().for_each(|x| *x = 0.0);
            }
            let new_row = values.row(i);
            let old_row = self.field.row_mut(vi);
            let dx_row = &mut self.dx.data_mut()[vi * d..(vi + 1) * d];
            for c in 0..d {
                dx_row[c] += new_row[c] - old_row[c];
                old_row[c] = new_row[c];
            }
        }
        self.updates += 1;
        self.since_refresh += 1;
        if self.refresh_every > 0 && self.since_refresh >= self.refresh_every {
            self.refresh()?;
        } else if !self.dirty.is_empty() {
            self.tfi.integrate_delta_prepared_into(
                &self.dirty,
                &self.dx,
                &self.plans,
                &mut self.dout,
            )?;
            self.out.axpy(1.0, &self.dout);
        }
        Ok(&self.out)
    }

    /// Force a full bit-exact re-integration of the current field (the
    /// drift policy calls this automatically every `refresh_every`
    /// updates).
    pub fn refresh(&mut self) -> Result<&Matrix, FtfiError> {
        self.tfi.integrate_prepared_into(&self.field, &self.plans, &mut self.out)?;
        self.since_refresh = 0;
        self.refreshes += 1;
        Ok(&self.out)
    }

    /// The cached output (`integrate(field)` up to the bounded drift).
    pub fn output(&self) -> &Matrix {
        &self.out
    }

    /// The current field.
    pub fn field(&self) -> &Matrix {
        &self.field
    }

    /// Vertices of the underlying metric.
    pub fn n(&self) -> usize {
        self.field.rows()
    }

    /// Field channels this session was opened with.
    pub fn channels(&self) -> usize {
        self.field.cols()
    }

    /// The configured refresh cadence (`0` = never).
    pub fn refresh_every(&self) -> usize {
        self.refresh_every
    }

    /// Updates applied over the session lifetime.
    pub fn updates_applied(&self) -> usize {
        self.updates
    }

    /// Full re-integrations performed (drift policy + explicit
    /// [`StreamingIntegrator::refresh`] calls).
    pub fn refreshes(&self) -> usize {
        self.refreshes
    }

    /// Updates since the last full re-integration (the current drift
    /// depth).
    pub fn updates_since_refresh(&self) -> usize {
        self.since_refresh
    }

    /// Integrator statistics with the streaming counters filled in:
    /// `delta_nodes_visited` from the shared tree (pool-scoped lifetime
    /// aggregate — compare deltas), `delta_refreshes` from this session.
    pub fn stats(&self) -> ItStats {
        let mut st = self.tfi.stats();
        st.delta_refreshes = self.refreshes;
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::BruteForceIntegrator;
    use crate::ftfi::functions::FDist;
    use crate::ftfi::FieldIntegrator;
    use crate::graph::generators::random_tree;
    use crate::ml::rng::Pcg;

    fn session(
        n: usize,
        d: usize,
        refresh_every: usize,
        seed: u64,
    ) -> (StreamingIntegrator, BruteForceIntegrator, FDist) {
        let mut rng = Pcg::seed(seed);
        let tree = random_tree(n, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
        let tfi = Arc::new(tfi);
        let plans = Arc::new(tfi.prepare_plans(&f, d).unwrap());
        let brute = BruteForceIntegrator::from_tree(tree);
        let field = Matrix::randn(n, d, &mut rng);
        let s = StreamingIntegrator::new(tfi, plans, field, refresh_every).unwrap();
        (s, brute, f)
    }

    #[test]
    fn updates_track_the_brute_oracle() {
        let (mut s, brute, f) = session(120, 2, 8, 1);
        let mut rng = Pcg::seed(2);
        for step in 0..20 {
            let k = [0usize, 1, 3, 7][rng.below(4)];
            let mut rows = Vec::new();
            while rows.len() < k {
                let v = rng.below(120) as u32;
                if !rows.contains(&v) {
                    rows.push(v);
                }
            }
            let vals = Matrix::randn(k, 2, &mut rng);
            let out = s.apply_update(&rows, &vals).unwrap().clone();
            let want = brute.integrate(&f, s.field()).unwrap();
            let rel = out.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-8, "step {step}: drifted to rel {rel}");
        }
        assert_eq!(s.updates_applied(), 20);
        assert!(s.stats().delta_refreshes >= 2, "refresh_every=8 over 20 updates");
    }

    #[test]
    fn refresh_boundary_is_bit_identical_to_cold_integrate() {
        let mut rng = Pcg::seed(3);
        let tree = random_tree(150, 0.1, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).leaf_threshold(8).build().unwrap();
        let tfi = Arc::new(tfi);
        let plans = Arc::new(tfi.prepare_plans(&f, 2).unwrap());
        let field = Matrix::randn(150, 2, &mut rng);
        let mut s =
            StreamingIntegrator::new(Arc::clone(&tfi), Arc::clone(&plans), field, 5).unwrap();
        let mut rng = Pcg::seed(4);
        for step in 1..=11 {
            let rows = [rng.below(150) as u32];
            let vals = Matrix::randn(1, 2, &mut rng);
            s.apply_update(&rows, &vals).unwrap();
            let cold = tfi.integrate_prepared(s.field(), &plans).unwrap();
            if step % 5 == 0 {
                assert!(
                    *s.output() == cold,
                    "step {step}: post-refresh state must be bit-identical to cold integrate"
                );
            } else {
                // Between refreshes drift stays at rounding scale.
                let rel = s.output().frobenius_diff(&cold) / (1.0 + cold.frobenius());
                assert!(rel < 1e-11, "step {step}: rel {rel}");
            }
        }
        assert_eq!(s.refreshes(), 2);
        assert_eq!(s.updates_since_refresh(), 1);
    }

    #[test]
    fn duplicate_rows_in_one_update_apply_in_order() {
        let (mut s, brute, f) = session(40, 1, 0, 5);
        // Same row three times: last write wins on the field.
        let rows = [7u32, 7, 7];
        let vals = Matrix::from_vec(3, 1, vec![1.0, -2.0, 5.0]);
        s.apply_update(&rows, &vals).unwrap();
        assert_eq!(s.field().get(7, 0), 5.0);
        let want = brute.integrate(&f, s.field()).unwrap();
        let rel = s.output().frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-9, "rel {rel}");
    }

    #[test]
    fn degenerate_sessions_and_updates() {
        // n = 1 singleton metric.
        let (mut s, brute, f) = session(1, 2, 2, 6);
        let out = s.apply_update(&[0], &Matrix::from_vec(1, 2, vec![3.0, -1.0])).unwrap();
        let want = brute.integrate(&f, &Matrix::from_vec(1, 2, vec![3.0, -1.0])).unwrap();
        assert!(out.frobenius_diff(&want) < 1e-12);
        // k = 0 no-op still counts toward the refresh cadence.
        s.apply_update(&[], &Matrix::zeros(0, 2)).unwrap();
        assert_eq!(s.refreshes(), 1, "the second update must have hit refresh_every = 2");
        // k = n full-row update.
        let (mut s, brute, f) = session(30, 1, 0, 7);
        let rows: Vec<u32> = (0..30).collect();
        let mut rng = Pcg::seed(8);
        let vals = Matrix::randn(30, 1, &mut rng);
        s.apply_update(&rows, &vals).unwrap();
        let want = brute.integrate(&f, &vals).unwrap();
        let rel = s.output().frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-9, "rel {rel}");
    }

    #[test]
    fn malformed_updates_fail_without_corrupting_the_session() {
        let (mut s, brute, f) = session(50, 2, 0, 9);
        let before = s.output().clone();
        // Row out of range.
        assert!(matches!(
            s.apply_update(&[50], &Matrix::zeros(1, 2)),
            Err(FtfiError::InvalidInput(_))
        ));
        // Shape mismatches.
        assert!(matches!(
            s.apply_update(&[0], &Matrix::zeros(2, 2)),
            Err(FtfiError::ShapeMismatch { .. })
        ));
        assert!(matches!(
            s.apply_update(&[0], &Matrix::zeros(1, 3)),
            Err(FtfiError::InvalidInput(_))
        ));
        assert!(*s.output() == before, "failed updates must not move the output");
        assert_eq!(s.updates_applied(), 0);
        // The session still serves good updates.
        let out = s.apply_update(&[0], &Matrix::from_vec(1, 2, vec![1.0, 2.0])).unwrap().clone();
        let want = brute.integrate(&f, s.field()).unwrap();
        assert!(out.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }

    #[test]
    fn new_validates_the_initial_field() {
        let mut rng = Pcg::seed(10);
        let tree = random_tree(20, 0.1, 1.0, &mut rng);
        let f = FDist::Identity;
        let tfi = Arc::new(TreeFieldIntegrator::builder(&tree).build().unwrap());
        let plans = Arc::new(tfi.prepare_plans(&f, 1).unwrap());
        assert!(matches!(
            StreamingIntegrator::new(
                Arc::clone(&tfi),
                Arc::clone(&plans),
                Matrix::zeros(19, 1),
                4
            ),
            Err(FtfiError::ShapeMismatch { expected: 20, got: 19 })
        ));
        assert!(StreamingIntegrator::new(tfi, plans, Matrix::zeros(20, 1), 4).is_ok());
    }
}
