//! The `f` in `f`-distance matrices: a registry of the function classes
//! analysed in §3.2.1 / §A.2.3, each knowing its own cordiality class and
//! therefore which fast cross-term multiplier applies.

use std::fmt;
use std::sync::Arc;

/// A scalar map `f: R → R` applied elementwise to tree distances.
#[derive(Clone)]
pub enum FDist {
    /// `f(x) = x` — the Shortest Path kernel.
    Identity,
    /// `f(x) = Σ_t coeffs[t]·x^t` — 0-cordial (sum of outer products).
    Polynomial(Vec<f64>),
    /// `f(x) = scale·e^{λx}` — 0-cordial (rank-1 outer product).
    Exponential { lambda: f64, scale: f64 },
    /// `f(x) = (Σ_t coeffs[t] x^t)·e^{λx}` — 0-cordial (Hadamard closure,
    /// §A.2.3 "products of exponentials and polynomials").
    PolyExp { coeffs: Vec<f64>, lambda: f64 },
    /// `f(x) = scale·cos(ωx + φ)` — 0-cordial (two complex exponentials);
    /// `φ = -π/2` gives `sin`.
    Trig { omega: f64, phase: f64, scale: f64 },
    /// `f(x) = P(x)/Q(x)` — (2+ε)-cordial via fast rational-sum
    /// combination + multipoint evaluation (Cabello 2022). Coefficients
    /// low→high.
    Rational { num: Vec<f64>, den: Vec<f64> },
    /// `f(x) = e^{λx}/(x+c)` — 2-cordial (Cauchy-like LDR, §3.2.1).
    ExpOverLinear { lambda: f64, c: f64 },
    /// `f(x) = e^{ux² + vx + w}` — fast on lattice (rational-weight)
    /// trees via diag·Vandermonde·diag (§3.2.1).
    ExpQuadratic { u: f64, v: f64, w: f64 },
    /// Arbitrary black-box `f` — fast only on lattice trees (Hankel path,
    /// §A.2.3); dense otherwise.
    Custom(Arc<dyn Fn(f64) -> f64 + Send + Sync>),
}

impl fmt::Debug for FDist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FDist::Identity => write!(f, "Identity"),
            FDist::Polynomial(c) => write!(f, "Polynomial({c:?})"),
            FDist::Exponential { lambda, scale } => {
                write!(f, "Exponential(λ={lambda}, s={scale})")
            }
            FDist::PolyExp { coeffs, lambda } => write!(f, "PolyExp({coeffs:?}, λ={lambda})"),
            FDist::Trig { omega, phase, scale } => {
                write!(f, "Trig(ω={omega}, φ={phase}, s={scale})")
            }
            FDist::Rational { num, den } => write!(f, "Rational({num:?}/{den:?})"),
            FDist::ExpOverLinear { lambda, c } => write!(f, "ExpOverLinear(λ={lambda}, c={c})"),
            FDist::ExpQuadratic { u, v, w } => write!(f, "ExpQuadratic(u={u}, v={v}, w={w})"),
            FDist::Custom(_) => write!(f, "Custom(..)"),
        }
    }
}

/// Evaluate a polynomial (coefficients low→high) by Horner's rule.
#[inline]
pub fn horner(coeffs: &[f64], x: f64) -> f64 {
    let mut acc = 0.0;
    for &c in coeffs.iter().rev() {
        acc = acc * x + c;
    }
    acc
}

impl FDist {
    /// Point evaluation.
    pub fn eval(&self, x: f64) -> f64 {
        match self {
            FDist::Identity => x,
            FDist::Polynomial(c) => horner(c, x),
            FDist::Exponential { lambda, scale } => scale * (lambda * x).exp(),
            FDist::PolyExp { coeffs, lambda } => horner(coeffs, x) * (lambda * x).exp(),
            FDist::Trig { omega, phase, scale } => scale * (omega * x + phase).cos(),
            FDist::Rational { num, den } => horner(num, x) / horner(den, x),
            FDist::ExpOverLinear { lambda, c } => (lambda * x).exp() / (x + c),
            FDist::ExpQuadratic { u, v, w } => (u * x * x + v * x + w).exp(),
            FDist::Custom(f) => f(x),
        }
    }

    /// The paper's mesh-interpolation kernel `f(x) = 1/(1+λx²)` (§4.2).
    pub fn inverse_quadratic(lambda: f64) -> FDist {
        FDist::Rational { num: vec![1.0], den: vec![1.0, 0.0, lambda] }
    }

    /// Gaussian RBF `e^{-γ x²}` as an ExpQuadratic.
    pub fn gaussian(gamma: f64) -> FDist {
        FDist::ExpQuadratic { u: -gamma, v: 0.0, w: 0.0 }
    }

    /// `sin(ωx)` as a Trig.
    pub fn sin(omega: f64) -> FDist {
        FDist::Trig { omega, phase: -std::f64::consts::FRAC_PI_2, scale: 1.0 }
    }

    /// The exact low-rank separable decomposition `f(x+y) = Σ_r g_r(x)·h_r(y)`
    /// when one exists ("0-cordial" classes). Returns `None` for classes
    /// that need the FFT/LDR machinery instead.
    pub fn separable_rank(&self) -> Option<Separable> {
        match self {
            FDist::Identity => {
                // x + y = x·1 + 1·y.
                Some(Separable {
                    g: vec![Arc::new(|x: f64| x), Arc::new(|_| 1.0)],
                    h: vec![Arc::new(|_| 1.0), Arc::new(|y: f64| y)],
                })
            }
            FDist::Polynomial(coeffs) => Some(poly_separable(coeffs, 0.0)),
            FDist::Exponential { lambda, scale } => {
                let (l, s) = (*lambda, *scale);
                Some(Separable {
                    g: vec![Arc::new(move |x: f64| s * (l * x).exp())],
                    h: vec![Arc::new(move |y: f64| (l * y).exp())],
                })
            }
            FDist::PolyExp { coeffs, lambda } => {
                // (Σ a_t (x+y)^t)·e^{λ(x+y)}: take the polynomial separable
                // pieces and multiply both sides by the exponentials
                // (Hadamard product of outer products is an outer product).
                let mut sep = poly_separable(coeffs, 0.0);
                let l = *lambda;
                sep.g = sep
                    .g
                    .into_iter()
                    .map(|g| {
                        let g = g.clone();
                        Arc::new(move |x: f64| g(x) * (l * x).exp()) as ScalarFn
                    })
                    .collect();
                sep.h = sep
                    .h
                    .into_iter()
                    .map(|h| {
                        let h = h.clone();
                        Arc::new(move |y: f64| h(y) * (l * y).exp()) as ScalarFn
                    })
                    .collect();
                Some(sep)
            }
            FDist::Trig { omega, phase, scale } => {
                // cos(ω(x+y)+φ) = cos(ωx+φ)cos(ωy) − sin(ωx+φ)sin(ωy).
                let (o, p, s) = (*omega, *phase, *scale);
                Some(Separable {
                    g: vec![
                        Arc::new(move |x: f64| s * (o * x + p).cos()),
                        Arc::new(move |x: f64| -s * (o * x + p).sin()),
                    ],
                    h: vec![
                        Arc::new(move |y: f64| (o * y).cos()),
                        Arc::new(move |y: f64| (o * y).sin()),
                    ],
                })
            }
            _ => None,
        }
    }
}

pub type ScalarFn = Arc<dyn Fn(f64) -> f64 + Send + Sync>;

/// An exact separable decomposition `f(x+y) = Σ_r g[r](x)·h[r](y)`.
pub struct Separable {
    pub g: Vec<ScalarFn>,
    pub h: Vec<ScalarFn>,
}

impl Separable {
    pub fn rank(&self) -> usize {
        self.g.len()
    }
}

/// Binomial expansion of `Σ_t a_t (x+y)^t` into `Σ_u x^u · h_u(y)` with
/// `h_u(y) = Σ_{t≥u} a_t·C(t,u)·y^{t−u}` — rank `deg+1`.
fn poly_separable(coeffs: &[f64], _shift: f64) -> Separable {
    let deg = coeffs.len().saturating_sub(1);
    let mut g: Vec<ScalarFn> = Vec::with_capacity(deg + 1);
    let mut h: Vec<ScalarFn> = Vec::with_capacity(deg + 1);
    for u in 0..=deg {
        g.push(Arc::new(move |x: f64| x.powi(u as i32)));
        // h_u(y) coefficients: for t in u..=deg, a_t * C(t,u) * y^{t-u}.
        let mut hc = Vec::with_capacity(deg - u + 1);
        for t in u..=deg {
            hc.push(coeffs[t] * binomial(t, u));
        }
        h.push(Arc::new(move |y: f64| horner(&hc, y)));
    }
    Separable { g, h }
}

/// Binomial coefficient as f64 (exact for the small degrees we use).
pub fn binomial(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        // lint: allow(mixed-precision-cast) — integer combinatorics, not field data
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    #[test]
    fn eval_known_values() {
        assert_eq!(FDist::Identity.eval(3.5), 3.5);
        assert_eq!(FDist::Polynomial(vec![1.0, 2.0, 3.0]).eval(2.0), 1.0 + 4.0 + 12.0);
        assert!((FDist::Exponential { lambda: -1.0, scale: 2.0 }.eval(0.0) - 2.0).abs() < 1e-12);
        assert!((FDist::inverse_quadratic(0.5).eval(2.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((FDist::ExpOverLinear { lambda: 0.0, c: 2.0 }.eval(2.0) - 0.25).abs() < 1e-12);
        assert!((FDist::gaussian(1.0).eval(1.0) - (-1.0f64).exp()).abs() < 1e-12);
        assert!((FDist::sin(1.0).eval(std::f64::consts::FRAC_PI_2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(5, 2), 10.0);
        assert_eq!(binomial(6, 0), 1.0);
        assert_eq!(binomial(4, 4), 1.0);
        assert_eq!(binomial(3, 5), 0.0);
    }

    /// Every separable decomposition must reproduce f(x+y) exactly.
    #[test]
    fn separable_reconstructs_f() {
        let mut rng = Pcg::seed(1);
        let fs = vec![
            FDist::Identity,
            FDist::Polynomial(vec![0.5, -1.0, 2.0, 0.25]),
            FDist::Exponential { lambda: 0.3, scale: 1.7 },
            FDist::PolyExp { coeffs: vec![1.0, -0.5, 0.2], lambda: -0.4 },
            FDist::Trig { omega: 1.3, phase: 0.4, scale: 0.9 },
            FDist::sin(0.7),
        ];
        for f in &fs {
            let sep = f.separable_rank().expect("should be separable");
            for _ in 0..50 {
                let x = rng.uniform_in(0.0, 3.0);
                let y = rng.uniform_in(0.0, 3.0);
                let direct = f.eval(x + y);
                let via: f64 = sep.g.iter().zip(&sep.h).map(|(g, h)| g(x) * h(y)).sum();
                assert!(
                    (direct - via).abs() < 1e-9 * (1.0 + direct.abs()),
                    "{f:?} at ({x},{y}): {direct} vs {via}"
                );
            }
        }
    }

    #[test]
    fn non_separable_classes_return_none() {
        assert!(FDist::Rational { num: vec![1.0], den: vec![1.0, 1.0] }.separable_rank().is_none());
        assert!(FDist::ExpOverLinear { lambda: 1.0, c: 1.0 }.separable_rank().is_none());
        assert!(FDist::ExpQuadratic { u: -1.0, v: 0.0, w: 0.0 }.separable_rank().is_none());
        assert!(FDist::Custom(Arc::new(|x| x.sin())).separable_rank().is_none());
    }

    #[test]
    fn custom_closure() {
        let f = FDist::Custom(Arc::new(|x| x * x + 1.0));
        assert_eq!(f.eval(2.0), 5.0);
    }
}
