//! Fast Tree-Field Integrators — the paper's core contribution.
//!
//! The public entry point is [`TreeFieldIntegrator`]: build once per tree
//! (`O(N log N)` — §3.1), then integrate any number of tensor fields with
//! any `f` in polylog-linear time (§3.2). For general graphs use
//! [`GraphFieldIntegrator`], which routes through the minimum spanning
//! tree exactly as the paper's experiments do (§4).

pub mod brute;
pub mod cauchy;
pub mod chebyshev;
pub mod cordial;
pub mod functions;
pub mod hankel;
pub mod nufft;
pub mod outer;
pub mod rational;
pub mod rff;
pub mod vandermonde;

use crate::ftfi::cordial::CrossPolicy;
use crate::ftfi::functions::FDist;
use crate::graph::mst::minimum_spanning_tree;
use crate::graph::Graph;
use crate::linalg::matrix::Matrix;
use crate::tree::integrator_tree::{IntegratorTree, ItStats};
use crate::tree::Tree;

/// Fast exact integration of tensor fields on a weighted tree.
pub struct TreeFieldIntegrator {
    it: IntegratorTree,
    policy: CrossPolicy,
    n: usize,
}

impl TreeFieldIntegrator {
    /// Preprocess the tree with default options.
    pub fn new(tree: &Tree) -> Self {
        Self::with_options(tree, 32, CrossPolicy::default())
    }

    /// Preprocess with an explicit leaf threshold and cross-term policy.
    pub fn with_options(tree: &Tree, leaf_threshold: usize, policy: CrossPolicy) -> Self {
        TreeFieldIntegrator {
            it: IntegratorTree::with_leaf_threshold(tree, leaf_threshold),
            policy,
            n: tree.n(),
        }
    }

    /// `out[v] = Σ_u f(dist_T(v,u))·x[u]` for a tensor field `x ∈ R^{N×d}`.
    pub fn integrate(&self, f: &FDist, x: &Matrix) -> Matrix {
        self.it.integrate(f, x, &self.policy)
    }

    /// Scalar-field convenience.
    pub fn integrate_vec(&self, f: &FDist, x: &[f64]) -> Vec<f64> {
        self.it.integrate_vec(f, x, &self.policy)
    }

    /// Number of tree vertices.
    pub fn n(&self) -> usize {
        self.n
    }

    /// IntegratorTree structure statistics.
    pub fn stats(&self) -> ItStats {
        self.it.stats()
    }

    /// Mutable access to the policy (ablation benches flip strategies).
    pub fn policy_mut(&mut self) -> &mut CrossPolicy {
        &mut self.policy
    }
}

/// Integration on a general graph via its MST metric (the paper's §4
/// recipe: replace `dist_G` by `dist_MST`, then run FTFI exactly).
pub struct GraphFieldIntegrator {
    tree: Tree,
    inner: TreeFieldIntegrator,
}

impl GraphFieldIntegrator {
    /// Build the MST and preprocess it. Requires a connected graph.
    pub fn new(g: &Graph) -> Self {
        let tree = minimum_spanning_tree(g);
        let inner = TreeFieldIntegrator::new(&tree);
        GraphFieldIntegrator { tree, inner }
    }

    /// Integrate using the MST metric.
    pub fn integrate(&self, f: &FDist, x: &Matrix) -> Matrix {
        self.inner.integrate(f, x)
    }

    /// The spanning tree in use.
    pub fn tree(&self) -> &Tree {
        &self.tree
    }

    /// The underlying tree integrator.
    pub fn tree_integrator(&self) -> &TreeFieldIntegrator {
        &self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::btfi;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;

    #[test]
    fn graph_integrator_matches_btfi_on_its_mst() {
        let mut rng = Pcg::seed(1);
        let g = generators::path_plus_random_edges(120, 60, &mut rng);
        let gfi = GraphFieldIntegrator::new(&g);
        let f = FDist::Exponential { lambda: -0.2, scale: 1.0 };
        let x = Matrix::randn(120, 2, &mut rng);
        let want = btfi(gfi.tree(), &f, &x);
        let got = gfi.integrate(&f, &x);
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-9);
    }

    #[test]
    fn reusable_across_fields_and_functions() {
        let mut rng = Pcg::seed(2);
        let t = generators::random_tree(80, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::new(&t);
        for seed in 0..3u64 {
            let mut r2 = Pcg::seed(seed);
            let x = Matrix::randn(80, 1, &mut r2);
            for f in [
                FDist::Identity,
                FDist::Polynomial(vec![0.0, 1.0, 0.5]),
                FDist::Exponential { lambda: -1.0, scale: 1.0 },
            ] {
                let got = tfi.integrate(&f, &x);
                let want = btfi(&t, &f, &x);
                assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-9);
            }
        }
    }
}
