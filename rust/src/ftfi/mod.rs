//! Fast Tree-Field Integrators — the paper's core contribution.
//!
//! The public entry point is [`TreeFieldIntegrator`]: build once per tree
//! (`O(N log N)` — §3.1) through the fallible builder, then integrate any
//! number of tensor fields with any `f` in polylog-linear time (§3.2).
//! For repeated integrations with the *same* `f` — the serving
//! coordinator's pattern, and the inner loops of Sinkhorn / GW — call
//! [`TreeFieldIntegrator::prepare`] to freeze the per-block cross plans
//! into a [`PreparedIntegrator`] handle. For general graphs use
//! [`GraphFieldIntegrator`], which routes through the minimum spanning
//! tree exactly as the paper's experiments do (§4), or
//! [`EnsembleFieldIntegrator`], which averages over an ensemble of
//! random low-distortion FRT/Bartal embeddings (Fig. 4/5's baselines,
//! promoted to a servable backend).
//!
//! Lifecycle (`DESIGN.md` §Lifecycle):
//!
//! ```text
//! TreeFieldIntegrator::builder(&tree)      GraphFieldIntegrator::builder(&graph)
//!     .leaf_threshold(t).policy(p)             .leaf_threshold(t).policy(p)
//!     .build()?            // structure         .build()?   // MST + structure
//!        │
//!        ├─ try_integrate(&f, &x)?             // plans every block, every call
//!        └─ prepare(&f)? → PreparedIntegrator  // plans once per (f, block)
//!               ├─ integrate(&x)?              // reuses cached plans
//!               └─ integrate_batch(&[&x…])?
//! ```
//!
//! Every failure mode reachable from user input is a typed
//! [`FtfiError`]; the legacy panicking constructors are kept as
//! deprecated shims.

pub mod brute;
pub mod cauchy;
pub mod chebyshev;
pub mod cordial;
pub mod ensemble;
pub mod error;
pub mod functions;
pub mod hankel;
pub mod nufft;
pub mod outer;
pub mod rational;
pub mod rff;
pub mod streaming;
pub mod vandermonde;

pub use ensemble::{EnsembleFieldIntegrator, EnsembleMethod, PreparedEnsembleIntegrator};
pub use error::FtfiError;
pub use streaming::{SharedPlans, StreamingIntegrator};
pub use crate::linalg::lanes::Precision;
pub use crate::tree::integrator_tree::ReplanStats;

use crate::ftfi::cordial::CrossPolicy;
use crate::ftfi::functions::FDist;
use crate::graph::mst::try_minimum_spanning_tree;
use crate::graph::Graph;
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
use crate::tree::integrator_tree::{IntegratorTree, ItStats, PreparedPlans};
use crate::tree::Tree;
use std::sync::Arc;

/// The unified integration interface: everything that can compute
/// `out[v] = Σ_u f(dist(v,u))·x[u]` over some metric. Implemented by
/// [`TreeFieldIntegrator`] (tree metric, fast), [`GraphFieldIntegrator`]
/// (MST metric of a graph, fast), [`EnsembleFieldIntegrator`] (averaged
/// random-tree-ensemble metric of a graph), the brute-force reference
/// [`brute::BruteForceIntegrator`], and `Arc<I>` for any implementor
/// (shared backends) — so the coordinator batcher, the benches and the
/// examples can program against one trait and swap backends freely.
pub trait FieldIntegrator {
    /// Number of vertices of the underlying metric space.
    fn n(&self) -> usize;

    /// `out[v] = Σ_u f(dist(v,u))·x[u]` for a tensor field `x ∈ R^{N×d}`.
    fn integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError>;

    /// The work pool driving this integrator's parallel paths, when it
    /// has one. Executors reuse it so their batch fan-out and the
    /// integrator's internal recursion forks draw on **one** thread
    /// budget — two stacked auto-sized pools would oversubscribe.
    fn work_pool(&self) -> Option<&Arc<WorkPool>> {
        None
    }

    /// Scalar-field convenience.
    fn integrate_vec(&self, f: &FDist, x: &[f64]) -> Result<Vec<f64>, FtfiError> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        Ok(self.integrate(f, &m)?.into_vec())
    }
}

/// Shared handles integrate too: serving workers wrap one expensive
/// backend (e.g. an [`EnsembleFieldIntegrator`] with its sampled trees)
/// in an `Arc` instead of rebuilding it per worker.
impl<I: FieldIntegrator + ?Sized> FieldIntegrator for Arc<I> {
    fn n(&self) -> usize {
        (**self).n()
    }
    fn integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        (**self).integrate(f, x)
    }
    fn work_pool(&self) -> Option<&Arc<WorkPool>> {
        (**self).work_pool()
    }
    fn integrate_vec(&self, f: &FDist, x: &[f64]) -> Result<Vec<f64>, FtfiError> {
        (**self).integrate_vec(f, x)
    }
}

/// Fast exact integration of tensor fields on a weighted tree.
pub struct TreeFieldIntegrator {
    it: IntegratorTree,
    policy: CrossPolicy,
    n: usize,
    /// Serving tier frozen into every plan this integrator prepares.
    precision: Precision,
    /// The work pool driving every parallel path (recursion forks,
    /// prepare fan-out, batch fan-out). Shared by prepared handles.
    pool: Arc<WorkPool>,
}

/// Fallible builder for [`TreeFieldIntegrator`] — validates the policy
/// knobs and the tree weights before paying the `O(N log N)`
/// preprocessing cost.
pub struct TreeFieldIntegratorBuilder<'a> {
    tree: &'a Tree,
    leaf_threshold: usize,
    policy: CrossPolicy,
    threads: usize,
    precision: Precision,
    pool: Option<Arc<WorkPool>>,
}

impl<'a> TreeFieldIntegratorBuilder<'a> {
    /// Leaf threshold `t ≥ 2` of the IntegratorTree (default 32).
    pub fn leaf_threshold(mut self, t: usize) -> Self {
        self.leaf_threshold = t;
        self
    }

    /// Cross-term strategy policy (default [`CrossPolicy::default`]).
    pub fn policy(mut self, policy: CrossPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Worker threads for the parallel integrate / prepare / batch
    /// paths. `0` (the default) resolves automatically: `FTFI_THREADS`
    /// if set, else all available cores. `1` forces serial execution.
    /// Outputs are bit-identical for every setting.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Share an existing work pool instead of building one — e.g. one
    /// pool across all serving workers so the process cannot
    /// oversubscribe the machine. Takes precedence over
    /// [`TreeFieldIntegratorBuilder::threads`].
    pub fn pool(mut self, pool: Arc<WorkPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Serving tier for the prepared hot path (default
    /// [`Precision::F64`]). [`Precision::F32`] computes cross-term
    /// products in f32 while accumulating in f64 — faster on
    /// bandwidth-bound fields, accurate to the ULP budgets pinned in
    /// `tests/ftfi_precision.rs`. The default tier stays bit-identical
    /// to the pre-tier kernels.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Validate and preprocess. Errors instead of panicking on bad
    /// policy knobs, a too-small leaf threshold or non-finite weights.
    pub fn build(self) -> Result<TreeFieldIntegrator, FtfiError> {
        self.policy.validate()?;
        if self.leaf_threshold < 2 {
            return Err(FtfiError::InvalidInput(format!(
                "leaf_threshold must be ≥ 2, got {}",
                self.leaf_threshold
            )));
        }
        // `Tree::from_edges` already asserts positive weights, so the
        // `w <= 0.0` arm is defense-in-depth for future constructors;
        // the finiteness check is the live one (NaN/±inf distances would
        // poison lattice detection and the Chebyshev probe).
        for &(u, v, w) in self.tree.edges() {
            if !w.is_finite() || w <= 0.0 {
                return Err(FtfiError::InvalidInput(format!(
                    "tree edge ({u},{v}) has non-positive or non-finite weight {w}"
                )));
            }
        }
        let threads = self.threads;
        let pool = self.pool.unwrap_or_else(|| Arc::new(WorkPool::with_auto(threads)));
        Ok(TreeFieldIntegrator {
            it: IntegratorTree::with_leaf_threshold(self.tree, self.leaf_threshold),
            policy: self.policy,
            n: self.tree.n(),
            precision: self.precision,
            pool,
        })
    }
}

impl TreeFieldIntegrator {
    /// Start building an integrator for `tree`.
    pub fn builder(tree: &Tree) -> TreeFieldIntegratorBuilder<'_> {
        TreeFieldIntegratorBuilder {
            tree,
            leaf_threshold: 32,
            policy: CrossPolicy::default(),
            threads: 0,
            precision: Precision::F64,
            pool: None,
        }
    }

    /// Preprocess the tree with default options.
    #[deprecated(note = "use `TreeFieldIntegrator::builder(&tree).build()` for a Result")]
    pub fn new(tree: &Tree) -> Self {
        Self::builder(tree).build().expect("TreeFieldIntegrator::new: invalid tree")
    }

    /// Preprocess with an explicit leaf threshold and cross-term policy.
    #[deprecated(
        note = "use `TreeFieldIntegrator::builder(&tree).leaf_threshold(t).policy(p).build()`"
    )]
    pub fn with_options(tree: &Tree, leaf_threshold: usize, policy: CrossPolicy) -> Self {
        Self::builder(tree)
            .leaf_threshold(leaf_threshold.max(2))
            .policy(policy)
            .build()
            .expect("TreeFieldIntegrator::with_options: invalid tree or policy")
    }

    /// `out[v] = Σ_u f(dist_T(v,u))·x[u]` for a tensor field
    /// `x ∈ R^{N×d}`. Re-plans every cross block on every call; prefer
    /// [`TreeFieldIntegrator::prepare`] when `f` is reused.
    pub fn try_integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.it.try_integrate_pooled(f, x, &self.policy, &self.pool)
    }

    /// Scalar-field convenience.
    pub fn try_integrate_vec(&self, f: &FDist, x: &[f64]) -> Result<Vec<f64>, FtfiError> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        Ok(self.try_integrate(f, &m)?.into_vec())
    }

    /// Infallible integration shim.
    #[deprecated(note = "use `try_integrate` (Result) or `prepare` (cached plans)")]
    pub fn integrate(&self, f: &FDist, x: &Matrix) -> Matrix {
        self.try_integrate(f, x).expect("integration failed (use try_integrate for a Result)")
    }

    /// Infallible scalar-field shim.
    #[deprecated(note = "use `try_integrate_vec`")]
    pub fn integrate_vec(&self, f: &FDist, x: &[f64]) -> Vec<f64> {
        self.try_integrate_vec(f, x)
            .expect("integration failed (use try_integrate_vec for a Result)")
    }

    /// Freeze `f` into a [`PreparedIntegrator`]: every cross-block plan
    /// (Chebyshev expansion, lattice FFT table, separable decomposition,
    /// rational options) is built exactly once, here, and reused by all
    /// subsequent `integrate` calls on the handle.
    pub fn prepare(&self, f: &FDist) -> Result<PreparedIntegrator<'_>, FtfiError> {
        self.prepare_with_channels(f, 1)
    }

    /// [`TreeFieldIntegrator::prepare`] with a field-width hint for the
    /// planning cost model (`channels` = expected `d`; correctness does
    /// not depend on it).
    pub fn prepare_with_channels(
        &self,
        f: &FDist,
        channels: usize,
    ) -> Result<PreparedIntegrator<'_>, FtfiError> {
        let plans =
            self.it.prepare_pooled_with(f, channels, &self.policy, self.precision, &self.pool)?;
        Ok(PreparedIntegrator { it: &self.it, plans, pool: Arc::clone(&self.pool) })
    }

    /// Lower-level prepare: returns the raw [`PreparedPlans`] (no borrow
    /// of `self`), for owners that store integrator and plans side by
    /// side — e.g. the coordinator's field executor.
    pub fn prepare_plans(&self, f: &FDist, channels: usize) -> Result<PreparedPlans, FtfiError> {
        self.it.prepare_pooled_with(f, channels, &self.policy, self.precision, &self.pool)
    }

    /// Integrate with plans from [`TreeFieldIntegrator::prepare_plans`].
    pub fn integrate_prepared(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.it.integrate_prepared_pooled(x, plans, &self.pool)
    }

    /// Zero-allocation prepared integration into a caller-provided
    /// `n×d` matrix (see
    /// [`crate::tree::integrator_tree::IntegratorTree::integrate_prepared_into_pooled`]).
    pub fn integrate_prepared_into(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        self.it.integrate_prepared_into_pooled(x, plans, &self.pool, out)
    }

    /// Sparse delta integration with plans from
    /// [`TreeFieldIntegrator::prepare_plans`]: the exact
    /// `integrate(Δ)` for a delta field supported on `rows` (`dx` is
    /// dense `n×d`; only the listed rows are read), touching only the
    /// O(k log n) IT nodes whose slot regions contain a changed row.
    /// With every row listed the result is bit-identical to
    /// [`TreeFieldIntegrator::integrate_prepared`] on `dx`. See
    /// [`crate::tree::integrator_tree::IntegratorTree::integrate_delta_prepared`].
    pub fn integrate_delta_prepared(
        &self,
        rows: &[u32],
        dx: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.it.integrate_delta_prepared_pooled(rows, dx, plans, &self.pool)
    }

    /// Zero-allocation sparse delta integration into a caller-provided
    /// `n×d` matrix — the streaming hot path (a warmed serial k = 1
    /// update performs no heap allocation).
    pub fn integrate_delta_prepared_into(
        &self,
        rows: &[u32],
        dx: &Matrix,
        plans: &PreparedPlans,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        self.it.integrate_delta_prepared_into_pooled(rows, dx, plans, &self.pool, out)
    }

    /// Reweight one existing tree edge in place (§ "Dynamic graphs &
    /// edge re-plans" in DESIGN.md): only the O(log n) separator nodes
    /// whose pivot-distance tables see the edge are recomputed; slot
    /// layout, vertex→slot maps and workspace sizing survive untouched.
    /// Outstanding [`PreparedPlans`] handles are invalidated (their next
    /// use returns a typed staleness error) — use
    /// [`TreeFieldIntegrator::replan_edge_prepared`] to patch a handle
    /// in lockstep instead. Validation failures (out-of-range vertex,
    /// non-tree edge, non-finite/non-positive weight) return
    /// [`FtfiError::InvalidInput`] and leave everything untouched;
    /// reassigning the current weight is a no-op.
    pub fn replan_edge(&mut self, u: usize, v: usize, w: f64) -> Result<ReplanStats, FtfiError> {
        self.it.replan_edge(u, v, w)
    }

    /// [`TreeFieldIntegrator::replan_edge`] that also rebuilds exactly
    /// the affected per-node plans inside `plans`, keeping the handle
    /// valid across the replan (two-phase: a planning failure leaves
    /// both the tree and the handle untouched). The handle must have
    /// been built by this integrator and be current.
    pub fn replan_edge_prepared(
        &mut self,
        u: usize,
        v: usize,
        w: f64,
        plans: &mut PreparedPlans,
    ) -> Result<ReplanStats, FtfiError> {
        plans.replan_edge(&mut self.it, u, v, w)
    }

    /// The pre-workspace prepared execution path (gathers and allocates
    /// per node). Kept only as the bit-identity reference for the
    /// workspace hot path — equivalence tests and the `hotpath_alloc`
    /// ablation compare against it; the serving stack never calls it.
    pub fn integrate_prepared_legacy(
        &self,
        x: &Matrix,
        plans: &PreparedPlans,
    ) -> Result<Matrix, FtfiError> {
        self.it.integrate_prepared_legacy_pooled(x, plans, &self.pool)
    }

    /// Number of tree vertices.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The work pool driving this integrator's parallel paths (share it
    /// via [`TreeFieldIntegratorBuilder::pool`] to bound a process-wide
    /// thread budget).
    pub fn pool(&self) -> &Arc<WorkPool> {
        &self.pool
    }

    /// IntegratorTree structure statistics (including the plan-build
    /// counter the prepared path freezes and the work pool's parallelism
    /// counters). The `par_*` counters are **pool-scoped** lifetime
    /// aggregates: on a pool shared across integrators they include
    /// every sharer's activity — compare deltas, not absolutes.
    pub fn stats(&self) -> ItStats {
        let mut st = self.it.stats();
        let ps = self.pool.stats();
        st.par_forks = ps.forks;
        st.par_tasks = ps.helper_tasks;
        st
    }

    /// The serving tier frozen into plans this integrator prepares.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The active cross-term policy.
    pub fn policy(&self) -> &CrossPolicy {
        &self.policy
    }

    /// Mutable access to the policy (ablation benches flip strategies).
    pub fn policy_mut(&mut self) -> &mut CrossPolicy {
        &mut self.policy
    }
}

impl FieldIntegrator for TreeFieldIntegrator {
    fn n(&self) -> usize {
        self.n
    }
    fn integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.try_integrate(f, x)
    }
    fn work_pool(&self) -> Option<&Arc<WorkPool>> {
        Some(&self.pool)
    }
}

/// A `(tree, f, policy)` triple with all cross-block plans pre-built:
/// the product of [`TreeFieldIntegrator::prepare`]. `integrate` /
/// `integrate_batch` reuse the cached plans and are panic-free on
/// malformed input.
pub struct PreparedIntegrator<'a> {
    it: &'a IntegratorTree,
    plans: PreparedPlans,
    pool: Arc<WorkPool>,
}

impl PreparedIntegrator<'_> {
    /// Integrate one tensor field with the frozen `f`. On a warmed
    /// handle the only heap allocation is the returned matrix — use
    /// [`PreparedIntegrator::integrate_into`] to eliminate that one too.
    pub fn integrate(&self, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.it.integrate_prepared_pooled(x, &self.plans, &self.pool)
    }

    /// Zero-allocation integration into a caller-provided `n×d` matrix:
    /// the steady-state serving hot path. After one warming call with
    /// the same channel width, a serial call performs **no heap
    /// allocation** (pinned by `tests/hotpath_alloc.rs`); the parallel
    /// path is allocation-free once the plan's fork-scratch stock has
    /// reached its peak concurrency.
    pub fn integrate_into(&self, x: &Matrix, out: &mut Matrix) -> Result<(), FtfiError> {
        self.it.integrate_prepared_into_pooled(x, &self.plans, &self.pool, out)
    }

    /// Bytes of one fully-sized reusable workspace for a `d`-channel
    /// field (slabs + aggregate arena + cross-multiplier scratch).
    pub fn workspace_bytes(&self, d: usize) -> usize {
        self.plans.workspace_bytes(d)
    }

    /// Sparse delta integration against the frozen plans: the exact
    /// `integrate(Δ)` for a delta supported on `rows` (see
    /// [`TreeFieldIntegrator::integrate_delta_prepared`]). Linearity
    /// makes `integrate(x + Δ) = integrate(x) + integrate_delta(rows, Δ)`
    /// up to float rounding — the streaming update identity.
    pub fn integrate_delta(&self, rows: &[u32], dx: &Matrix) -> Result<Matrix, FtfiError> {
        self.it.integrate_delta_prepared_pooled(rows, dx, &self.plans, &self.pool)
    }

    /// Zero-allocation [`PreparedIntegrator::integrate_delta`] into a
    /// caller-provided `n×d` matrix.
    pub fn integrate_delta_into(
        &self,
        rows: &[u32],
        dx: &Matrix,
        out: &mut Matrix,
    ) -> Result<(), FtfiError> {
        self.it.integrate_delta_prepared_into_pooled(rows, dx, &self.plans, &self.pool, out)
    }

    /// Integrate a batch of fields, reusing the plans for every one.
    /// Fields fan out across the work pool (the serving batch axis)
    /// unless the metric is too small to justify helper threads; each
    /// result is bit-identical to a serial [`Self::integrate`] call,
    /// and results keep the input order.
    pub fn integrate_batch(&self, xs: &[&Matrix]) -> Result<Vec<Matrix>, FtfiError> {
        if self.plans.n() < PAR_MAP_MIN_N {
            return xs.iter().map(|x| self.integrate(x)).collect();
        }
        self.pool.map(xs, |_, x| self.integrate(x)).into_iter().collect()
    }

    /// Scalar-field convenience.
    pub fn integrate_vec(&self, x: &[f64]) -> Result<Vec<f64>, FtfiError> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        Ok(self.integrate(&m)?.into_vec())
    }

    /// The frozen function.
    pub fn f(&self) -> &FDist {
        self.plans.f()
    }

    /// Number of tree vertices.
    pub fn n(&self) -> usize {
        self.plans.n()
    }

    /// Cross-term plans built at prepare time (2 per internal IT node).
    pub fn plans_built(&self) -> usize {
        self.plans.plans_built()
    }

    /// The serving tier frozen into these plans.
    pub fn precision(&self) -> Precision {
        self.plans.precision()
    }
}

/// Integration on a general graph via its MST metric (the paper's §4
/// recipe: replace `dist_G` by `dist_MST`, then run FTFI exactly).
pub struct GraphFieldIntegrator {
    tree: Tree,
    inner: TreeFieldIntegrator,
}

/// Fallible builder for [`GraphFieldIntegrator`].
pub struct GraphFieldIntegratorBuilder<'a> {
    graph: &'a Graph,
    leaf_threshold: usize,
    policy: CrossPolicy,
    threads: usize,
    precision: Precision,
    pool: Option<Arc<WorkPool>>,
}

impl<'a> GraphFieldIntegratorBuilder<'a> {
    /// Leaf threshold `t ≥ 2` of the IntegratorTree (default 32).
    pub fn leaf_threshold(mut self, t: usize) -> Self {
        self.leaf_threshold = t;
        self
    }

    /// Cross-term strategy policy (default [`CrossPolicy::default`]).
    pub fn policy(mut self, policy: CrossPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Worker threads for the parallel paths (`0` = auto — see
    /// [`TreeFieldIntegratorBuilder::threads`]).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Share an existing work pool (see
    /// [`TreeFieldIntegratorBuilder::pool`]).
    pub fn pool(mut self, pool: Arc<WorkPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Serving tier. The graph backend only supports the default
    /// [`Precision::F64`] tier — its MST accuracy envelope has not been
    /// qualified for f32 products — so `build()` rejects
    /// [`Precision::F32`] with [`FtfiError::InvalidInput`].
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Build the MST and preprocess it. Returns
    /// [`FtfiError::DisconnectedGraph`] instead of asserting when the
    /// graph has no spanning tree.
    pub fn build(self) -> Result<GraphFieldIntegrator, FtfiError> {
        if self.precision != Precision::F64 {
            return Err(FtfiError::InvalidInput(format!(
                "the graph backend only supports the f64 tier, got precision = {}",
                self.precision.as_str()
            )));
        }
        let tree = try_minimum_spanning_tree(self.graph)?;
        let mut builder = TreeFieldIntegrator::builder(&tree)
            .leaf_threshold(self.leaf_threshold)
            .policy(self.policy)
            .threads(self.threads);
        if let Some(pool) = self.pool {
            builder = builder.pool(pool);
        }
        let inner = builder.build()?;
        Ok(GraphFieldIntegrator { tree, inner })
    }
}

impl GraphFieldIntegrator {
    /// Start building an integrator for `graph`.
    pub fn builder(graph: &Graph) -> GraphFieldIntegratorBuilder<'_> {
        GraphFieldIntegratorBuilder {
            graph,
            leaf_threshold: 32,
            policy: CrossPolicy::default(),
            threads: 0,
            precision: Precision::F64,
            pool: None,
        }
    }

    /// Build with default options; `Err(DisconnectedGraph)` if the graph
    /// is not connected.
    pub fn try_new(g: &Graph) -> Result<Self, FtfiError> {
        Self::builder(g).build()
    }

    /// Build the MST and preprocess it. Panics on a disconnected graph.
    #[deprecated(note = "use `GraphFieldIntegrator::try_new` or `::builder` for a Result")]
    pub fn new(g: &Graph) -> Self {
        Self::try_new(g).expect("GraphFieldIntegrator::new: disconnected graph")
    }

    /// Integrate using the MST metric.
    pub fn try_integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.inner.try_integrate(f, x)
    }

    /// Infallible integration shim.
    #[deprecated(note = "use `try_integrate` (Result) or `prepare` (cached plans)")]
    pub fn integrate(&self, f: &FDist, x: &Matrix) -> Matrix {
        self.try_integrate(f, x).expect("integration failed (use try_integrate for a Result)")
    }

    /// Freeze `f` into a prepared handle over the MST metric.
    pub fn prepare(&self, f: &FDist) -> Result<PreparedIntegrator<'_>, FtfiError> {
        self.inner.prepare(f)
    }

    /// The spanning tree in use.
    pub fn tree(&self) -> &Tree {
        &self.tree
    }

    /// The underlying tree integrator.
    pub fn tree_integrator(&self) -> &TreeFieldIntegrator {
        &self.inner
    }
}

impl FieldIntegrator for GraphFieldIntegrator {
    fn n(&self) -> usize {
        self.tree.n()
    }
    fn integrate(&self, f: &FDist, x: &Matrix) -> Result<Matrix, FtfiError> {
        self.try_integrate(f, x)
    }
    fn work_pool(&self) -> Option<&Arc<WorkPool>> {
        Some(self.inner.pool())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::brute::{btfi, BruteForceIntegrator};
    use crate::graph::generators;
    use crate::ml::rng::Pcg;

    #[test]
    fn graph_integrator_matches_btfi_on_its_mst() {
        let mut rng = Pcg::seed(1);
        let g = generators::path_plus_random_edges(120, 60, &mut rng);
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let f = FDist::Exponential { lambda: -0.2, scale: 1.0 };
        let x = Matrix::randn(120, 2, &mut rng);
        let want = btfi(gfi.tree(), &f, &x);
        let got = gfi.try_integrate(&f, &x).unwrap();
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-9);
    }

    #[test]
    fn reusable_across_fields_and_functions() {
        let mut rng = Pcg::seed(2);
        let t = generators::random_tree(80, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&t).build().unwrap();
        for seed in 0..3u64 {
            let mut r2 = Pcg::seed(seed);
            let x = Matrix::randn(80, 1, &mut r2);
            for f in [
                FDist::Identity,
                FDist::Polynomial(vec![0.0, 1.0, 0.5]),
                FDist::Exponential { lambda: -1.0, scale: 1.0 },
            ] {
                let got = tfi.try_integrate(&f, &x).unwrap();
                let want = btfi(&t, &f, &x);
                assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-9);
            }
        }
    }

    #[test]
    fn prepared_handle_matches_replanning_path() {
        let mut rng = Pcg::seed(3);
        let t = generators::random_tree(200, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&t).leaf_threshold(8).build().unwrap();
        let f = FDist::inverse_quadratic(0.8);
        let prepared = tfi.prepare(&f).unwrap();
        assert_eq!(prepared.n(), 200);
        assert!(prepared.plans_built() > 0);
        let xs: Vec<Matrix> = (0..4).map(|_| Matrix::randn(200, 2, &mut rng)).collect();
        let refs: Vec<&Matrix> = xs.iter().collect();
        let batch = prepared.integrate_batch(&refs).unwrap();
        for (x, got) in xs.iter().zip(&batch) {
            let want = tfi.try_integrate(&f, x).unwrap();
            assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-12);
        }
    }

    /// The zero-allocation `integrate_into` surface agrees bit-for-bit
    /// with `integrate`, across repeated calls on one handle (workspace
    /// reuse must not leak state) and with the legacy reference path.
    #[test]
    fn integrate_into_matches_integrate_bitwise() {
        let mut rng = Pcg::seed(7);
        let t = generators::random_tree(300, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&t).leaf_threshold(8).build().unwrap();
        let f = FDist::inverse_quadratic(0.5);
        let prepared = tfi.prepare_with_channels(&f, 2).unwrap();
        assert!(prepared.workspace_bytes(2) > 0);
        let plans = tfi.prepare_plans(&f, 2).unwrap();
        let mut out = Matrix::zeros(300, 2);
        for _ in 0..3 {
            let x = Matrix::randn(300, 2, &mut rng);
            let want = prepared.integrate(&x).unwrap();
            prepared.integrate_into(&x, &mut out).unwrap();
            assert!(out == want, "integrate_into must be bit-identical to integrate");
            let legacy = tfi.integrate_prepared_legacy(&x, &plans).unwrap();
            let new = tfi.integrate_prepared(&x, &plans).unwrap();
            assert!(new == legacy, "workspace path must be bit-identical to legacy");
        }
    }

    /// The prepared handle's delta surface: superposition holds at
    /// rounding scale and a full-rows delta is bit-identical to a full
    /// integration (no branch of the sparse pass skips).
    #[test]
    fn prepared_delta_superposes_and_degenerates_to_full_integration() {
        let mut rng = Pcg::seed(8);
        let t = generators::random_tree(200, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&t).leaf_threshold(8).build().unwrap();
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let prepared = tfi.prepare_with_channels(&f, 2).unwrap();
        let x = Matrix::randn(200, 2, &mut rng);
        let rows = [3u32, 77, 150];
        let mut dx = Matrix::zeros(200, 2);
        for &v in &rows {
            for c in 0..2 {
                dx.set(v as usize, c, rng.normal());
            }
        }
        let mut x2 = x.clone();
        x2.axpy(1.0, &dx);
        let full = prepared.integrate(&x2).unwrap();
        let mut approx = prepared.integrate(&x).unwrap();
        approx.axpy(1.0, &prepared.integrate_delta(&rows, &dx).unwrap());
        let rel = approx.frobenius_diff(&full) / (1.0 + full.frobenius());
        assert!(rel < 1e-11, "superposition drifted to rel {rel}");
        let all: Vec<u32> = (0..200).collect();
        let want = prepared.integrate(&dx).unwrap();
        let got = prepared.integrate_delta(&all, &dx).unwrap();
        assert!(got == want, "full-rows delta must be bit-identical");
        let mut out = Matrix::zeros(200, 2);
        prepared.integrate_delta_into(&all, &dx, &mut out).unwrap();
        assert!(out == want, "integrate_delta_into must agree bitwise");
    }

    #[test]
    fn disconnected_graph_is_an_error() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        assert!(matches!(
            GraphFieldIntegrator::try_new(&g),
            Err(FtfiError::DisconnectedGraph)
        ));
    }

    #[test]
    fn builder_rejects_bad_options() {
        let t = Tree::path(&[1.0, 1.0, 1.0]);
        assert!(matches!(
            TreeFieldIntegrator::builder(&t).leaf_threshold(1).build(),
            Err(FtfiError::InvalidInput(_))
        ));
        let bad_policy = CrossPolicy { cheb_max_rank: 0, ..CrossPolicy::default() };
        assert!(matches!(
            TreeFieldIntegrator::builder(&t).policy(bad_policy).build(),
            Err(FtfiError::InvalidInput(_))
        ));
    }

    #[test]
    fn trait_unifies_fast_and_brute_backends() {
        let mut rng = Pcg::seed(4);
        let g = generators::path_plus_random_edges(60, 30, &mut rng);
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let brute = BruteForceIntegrator::from_tree(gfi.tree().clone());
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let x = Matrix::randn(60, 2, &mut rng);
        let backends: Vec<&dyn FieldIntegrator> = vec![&gfi, &brute];
        let outs: Vec<Matrix> =
            backends.iter().map(|b| b.integrate(&f, &x).unwrap()).collect();
        assert_eq!(backends[0].n(), backends[1].n());
        assert!(outs[0].frobenius_diff(&outs[1]) / (1.0 + outs[1].frobenius()) < 1e-9);
    }

    #[test]
    fn threads_knob_and_pool_sharing() {
        let mut rng = Pcg::seed(6);
        let t = generators::random_tree(600, 0.1, 1.0, &mut rng);
        let shared = Arc::new(WorkPool::new(2));
        let a = TreeFieldIntegrator::builder(&t).pool(Arc::clone(&shared)).build().unwrap();
        let b = TreeFieldIntegrator::builder(&t).threads(1).build().unwrap();
        assert_eq!(a.pool().threads(), 2);
        assert_eq!(b.pool().threads(), 1);
        let f = FDist::Exponential { lambda: -0.2, scale: 1.0 };
        let x = Matrix::randn(600, 2, &mut rng);
        let ya = a.try_integrate(&f, &x).unwrap();
        let yb = b.try_integrate(&f, &x).unwrap();
        assert!(ya == yb, "thread count must not change the output bits");
        assert!(a.stats().par_forks > 0, "n=600 ≥ fork cutoff: the pool must fork");
        assert_eq!(b.stats().par_forks, 0, "a threads(1) integrator must stay serial");
    }

    /// The legacy panicking constructors keep working (shim coverage).
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_work() {
        let mut rng = Pcg::seed(5);
        let t = generators::random_tree(40, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::new(&t);
        let x = Matrix::randn(40, 1, &mut rng);
        let f = FDist::Identity;
        let a = tfi.integrate(&f, &x);
        let b = tfi.try_integrate(&f, &x).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-15);
        let g = t.to_graph();
        let gfi = GraphFieldIntegrator::new(&g);
        let c = gfi.integrate(&f, &x);
        assert!(c.max_abs_diff(&a) < 1e-9);
    }
}
