//! Vandermonde cross-term multiplication for exponentiated-quadratic
//! `f(x) = e^{ux² + vx + w}` on trees whose *column* distances lie on a
//! lattice (§3.2.1, last paragraph).
//!
//! With `y_j = b_j·δ` (b_j ∈ N) the cross matrix factors as
//! `C = e^w · D1 · V · D2` where `D1 = diag(e^{u x_i² + v x_i})`,
//! `D2 = diag(e^{u y_j² + v y_j})` and `V[i][j] = r_i^{b_j}` is a
//! generalized Vandermonde matrix with nodes `r_i = e^{2u x_i δ}`.
//! The paper's "column embedding" completes the exponent set `{b_j}` to
//! consecutive integers — operationally:
//!
//! - `V·v`  = evaluation of the sparse polynomial `p(t) = Σ_j v_j t^{b_j}`
//!   at the nodes `r_i`  → fast multipoint evaluation;
//! - `Vᵀ·u` = the power sums `Σ_i u_i r_i^{b_j}` → coefficients of the
//!   generating function `Σ_i u_i/(1 − r_i t)`, expanded to degree
//!   `max b_j` by one polynomial division (numerator/denominator built by
//!   divide-and-conquer products).
//!
//! Crucially the row nodes `x_i` may be **arbitrary reals** — only the
//! columns need the lattice, which is why this beats the Hankel embedding
//! when the lattice denominator `p` is large (`p ≫ log N`).

use crate::ftfi::error::FtfiError;
use crate::linalg::fft::Complex;
use crate::linalg::matrix::Matrix;
use crate::linalg::polynomial::{multipoint_eval, Poly};

/// `C·V` with `C[i][j] = e^{u(x_i+y_j)² + v(x_i+y_j) + w}`; `ys` must lie
/// on the lattice `{b·delta}`.
///
/// Fails with [`FtfiError::ShapeMismatch`] when `val` does not have one
/// row per column node.
pub fn expquad_cross_apply(
    u: f64,
    vcoef: f64,
    w: f64,
    xs: &[f64],
    ys: &[f64],
    delta: f64,
    val: &Matrix,
) -> Result<Matrix, FtfiError> {
    if val.rows() != ys.len() {
        return Err(FtfiError::ShapeMismatch { expected: ys.len(), got: val.rows() });
    }
    let d = val.cols();
    let mut out = Matrix::zeros(xs.len(), d);
    if xs.is_empty() || ys.is_empty() {
        return Ok(out);
    }
    let b: Vec<usize> = ys.iter().map(|&y| (y / delta).round() as usize).collect();
    // lint: infallible because the ys-emptiness early-return above
    // guarantees `b` is non-empty.
    let deg = *b.iter().max().unwrap();
    let nodes: Vec<Complex> =
        xs.iter().map(|&x| Complex::new((2.0 * u * x * delta).exp(), 0.0)).collect();
    let d1: Vec<f64> = xs.iter().map(|&x| (u * x * x + vcoef * x + w).exp()).collect();
    let d2: Vec<f64> = ys.iter().map(|&y| (u * y * y + vcoef * y).exp()).collect();
    for ch in 0..d {
        // Sparse polynomial p(t) = Σ_j D2[j]·V[j][ch] · t^{b_j}.
        let mut coeffs = vec![Complex::ZERO; deg + 1];
        for (j, &bj) in b.iter().enumerate() {
            coeffs[bj].re += d2[j] * val.get(j, ch);
        }
        let p = Poly::new(coeffs);
        let evals = multipoint_eval(&p, &nodes, None);
        for (i, e) in evals.iter().enumerate() {
            out.set(i, ch, d1[i] * e.re);
        }
    }
    Ok(out)
}

/// `Cᵀ·U` for the same matrix: power sums via the generating-function
/// trick, processed in blocks of `block` rows for stability.
///
/// Fails with [`FtfiError::ShapeMismatch`] when `uval` does not have one
/// row per row node.
pub fn expquad_cross_apply_t(
    u: f64,
    vcoef: f64,
    w: f64,
    xs: &[f64],
    ys: &[f64],
    delta: f64,
    uval: &Matrix,
    block: usize,
) -> Result<Matrix, FtfiError> {
    if uval.rows() != xs.len() {
        return Err(FtfiError::ShapeMismatch { expected: xs.len(), got: uval.rows() });
    }
    let d = uval.cols();
    let mut out = Matrix::zeros(ys.len(), d);
    if xs.is_empty() || ys.is_empty() {
        return Ok(out);
    }
    let b: Vec<usize> = ys.iter().map(|&y| (y / delta).round() as usize).collect();
    // lint: infallible because the ys-emptiness early-return above
    // guarantees `b` is non-empty.
    let deg = *b.iter().max().unwrap();
    let nodes: Vec<f64> = xs.iter().map(|&x| (2.0 * u * x * delta).exp()).collect();
    let d1: Vec<f64> = xs.iter().map(|&x| (u * x * x + vcoef * x + w).exp()).collect();
    let d2: Vec<f64> = ys.iter().map(|&y| (u * y * y + vcoef * y).exp()).collect();

    // Accumulate power sums s_ch[e] = Σ_i (D1·U)[i][ch] · r_i^e, e=0..deg.
    let mut sums = Matrix::zeros(deg + 1, d);
    for lo in (0..xs.len()).step_by(block.max(1)) {
        let hi = (lo + block.max(1)).min(xs.len());
        // B(t) = Π_i (1 - r_i t) by divide-and-conquer.
        let mut dens: Vec<Poly> = (lo..hi)
            .map(|i| Poly::new(vec![Complex::ONE, Complex::new(-nodes[i], 0.0)]))
            .collect();
        // Per-channel numerators A_ch(t) = Σ_i w_i Π_{k≠i} (1 - r_k t).
        let mut nums: Vec<Vec<Poly>> = (lo..hi)
            .map(|i| {
                (0..d)
                    .map(|ch| {
                        Poly::new(vec![Complex::new(d1[i] * uval.get(i, ch), 0.0)])
                    })
                    .collect()
            })
            .collect();
        while dens.len() > 1 {
            let mut nd = Vec::with_capacity(dens.len().div_ceil(2));
            let mut nn = Vec::with_capacity(dens.len().div_ceil(2));
            let mut di = dens.into_iter();
            let mut ni = nums.into_iter();
            while let Some(da) = di.next() {
                // lint: infallible because `nums` is built with exactly
                // one entry per `dens` entry and both shrink in lockstep.
                let na = ni.next().unwrap();
                match (di.next(), ni.next()) {
                    (Some(db), Some(nb)) => {
                        nn.push(
                            na.iter()
                                .zip(&nb)
                                .map(|(x, y)| x.mul(&db).add(&y.mul(&da)))
                                .collect::<Vec<_>>(),
                        );
                        nd.push(da.mul(&db));
                    }
                    _ => {
                        nn.push(na);
                        nd.push(da);
                    }
                }
            }
            dens = nd;
            nums = nn;
        }
        // lint: infallible because the halving loop above only exits
        // once exactly one denominator (and numerator set) remains.
        let den = dens.pop().unwrap();
        let chans = nums.pop().unwrap();
        // Power series A/B mod t^{deg+1}.
        let inv = den.inverse_mod(deg + 1);
        for (ch, a) in chans.iter().enumerate() {
            let series = a.mul(&inv);
            for e in 0..=deg {
                if let Some(c) = series.coeffs.get(e) {
                    sums.add_at(e, ch, c.re);
                }
            }
        }
    }
    for (j, &bj) in b.iter().enumerate() {
        for ch in 0..d {
            out.set(j, ch, d2[j] * sums.get(bj, ch));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ftfi::functions::FDist;
    use crate::ml::rng::Pcg;

    #[test]
    fn vandermonde_forward_matches_dense() {
        let mut rng = Pcg::seed(1);
        let (u, v, w) = (-0.15, 0.05, 0.2);
        let f = FDist::ExpQuadratic { u, v, w };
        let delta = 0.25;
        // xs arbitrary reals, ys on the δ-lattice.
        let xs = rng.uniform_vec(30, 0.0, 4.0);
        let ys: Vec<f64> = (0..25).map(|_| rng.below(20) as f64 * delta).collect();
        let val = Matrix::randn(25, 3, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &val);
        let got = expquad_cross_apply(u, v, w, &xs, &ys, delta, &val).unwrap();
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-8, "rel={rel}");
    }

    #[test]
    fn vandermonde_transpose_matches_dense() {
        let mut rng = Pcg::seed(2);
        let (u, v, w) = (-0.2, 0.0, 0.0);
        let f = FDist::ExpQuadratic { u, v, w };
        let delta = 0.5;
        let xs = rng.uniform_vec(40, 0.0, 3.0);
        let ys: Vec<f64> = (0..30).map(|_| rng.below(12) as f64 * delta).collect();
        let uval = Matrix::randn(40, 2, &mut rng);
        // Dense C^T U = dense apply with swapped roles.
        let want = cross_apply_dense(&f, &ys, &xs, &uval);
        let got = expquad_cross_apply_t(u, v, w, &xs, &ys, delta, &uval, 16).unwrap();
        let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
        assert!(rel < 1e-7, "rel={rel}");
    }

    #[test]
    fn gaussian_kernel_case() {
        // Pure Gaussian e^{-γ(x+y)²}: the mask class highlighted for the
        // best TopViT variants (§4.4).
        let mut rng = Pcg::seed(3);
        let f = FDist::gaussian(0.3);
        let (u, v, w) = (-0.3, 0.0, 0.0);
        let delta = 1.0; // unit-weight grid MST distances
        let xs: Vec<f64> = (0..20).map(|_| rng.below(10) as f64).collect();
        let ys: Vec<f64> = (0..20).map(|_| rng.below(10) as f64).collect();
        let val = Matrix::randn(20, 1, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &val);
        let got = expquad_cross_apply(u, v, w, &xs, &ys, delta, &val).unwrap();
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let val = Matrix::zeros(3, 1);
        let err = expquad_cross_apply(-0.1, 0.0, 0.0, &[0.0, 1.0], &[0.0, 1.0], 1.0, &val);
        assert!(matches!(err, Err(FtfiError::ShapeMismatch { expected: 2, got: 3 })));
        let err = expquad_cross_apply_t(-0.1, 0.0, 0.0, &[0.0, 1.0], &[0.0], 1.0, &val, 8);
        assert!(matches!(err, Err(FtfiError::ShapeMismatch { expected: 2, got: 3 })));
    }
}
