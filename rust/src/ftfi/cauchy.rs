//! Cauchy-like low-displacement-rank cross-term multiplication for
//! `f(x) = e^{λx}/(x+c)` — the 2-cordial case of §3.2.1 (Fig. 2, right).
//!
//! The cross matrix factors as
//! `C[i][j] = e^{λx_i} · Ĉ[i][j] · e^{λy_j}` with
//! `Ĉ[i][j] = 1/((x_i + c/2) + (y_j + c/2))` — a Cauchy-like matrix whose
//! displacement `Δ_{D1,D2}(Ĉ) = D1·Ĉ + Ĉ·D2` (D1 = diag(x_i + c/2),
//! D2 = diag(y_j + c/2)) has rank one. Multiplication reduces to the
//! rational-sum machinery with `P = 1`, `Q = x + c` (Pan 2000):
//! `Σ_j w_j/(x_i + c + y_j)` is a rational sum evaluated at all `x_i` in
//! `O((a+b) log²)`.

use crate::ftfi::rational::{rational_cross_apply, RationalOpts};
use crate::linalg::matrix::Matrix;

/// Compute `out[i][ch] = Σ_j V[j][ch] · e^{λ(x_i+y_j)}/(x_i + y_j + c)`.
///
/// Standalone per-call reference. The prepared hot path uses
/// [`crate::ftfi::rational::RationalPlan::build_cauchy`] instead, which
/// freezes the shift products, the denominator-inverse table and the
/// `e^{λx}`/`e^{λy}` scale vectors at plan time so the apply step is
/// allocation-free.
pub fn cauchy_cross_apply(
    lambda: f64,
    c: f64,
    xs: &[f64],
    ys: &[f64],
    v: &Matrix,
    opts: &RationalOpts,
) -> Matrix {
    assert_eq!(v.rows(), ys.len());
    // Fold e^{λ y_j} into the weights, pull e^{λ x_i} out of the sum.
    let mut vw = v.clone();
    for (j, &yj) in ys.iter().enumerate() {
        let s = (lambda * yj).exp();
        for val in vw.row_mut(j) {
            *val *= s;
        }
    }
    let mut out = rational_cross_apply(&[1.0], &[c, 1.0], xs, ys, &vw, opts);
    for (i, &xi) in xs.iter().enumerate() {
        let s = (lambda * xi).exp();
        for val in out.row_mut(i) {
            *val *= s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftfi::cordial::cross_apply_dense;
    use crate::ftfi::functions::FDist;
    use crate::ml::rng::Pcg;

    #[test]
    fn cauchy_matches_dense() {
        let mut rng = Pcg::seed(4);
        let (lambda, c) = (-0.3, 1.5);
        let f = FDist::ExpOverLinear { lambda, c };
        for &(a, b, d) in &[(9usize, 12usize, 1usize), (50, 40, 3), (200, 180, 2)] {
            let xs = rng.uniform_vec(a, 0.0, 6.0);
            let ys = rng.uniform_vec(b, 0.0, 6.0);
            let v = Matrix::randn(b, d, &mut rng);
            let want = cross_apply_dense(&f, &xs, &ys, &v);
            let got = cauchy_cross_apply(lambda, c, &xs, &ys, &v, &RationalOpts::default());
            let rel = got.frobenius_diff(&want) / (1.0 + want.frobenius());
            assert!(rel < 1e-6, "a={a} b={b} d={d}: rel={rel}");
        }
    }

    #[test]
    fn pure_reciprocal_case() {
        // λ = 0 reduces to a plain Cauchy matrix.
        let mut rng = Pcg::seed(5);
        let f = FDist::ExpOverLinear { lambda: 0.0, c: 2.0 };
        let xs = rng.uniform_vec(20, 0.0, 3.0);
        let ys = rng.uniform_vec(25, 0.0, 3.0);
        let v = Matrix::randn(25, 1, &mut rng);
        let want = cross_apply_dense(&f, &xs, &ys, &v);
        let got = cauchy_cross_apply(0.0, 2.0, &xs, &ys, &v, &RationalOpts::default());
        assert!(got.frobenius_diff(&want) / (1.0 + want.frobenius()) < 1e-8);
    }
}
