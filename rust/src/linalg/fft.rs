//! Complex FFT: iterative radix-2 Cooley–Tukey plus Bluestein's algorithm
//! for arbitrary lengths, and real-valued convolution/correlation on top.
//!
//! This is the computational backbone of the cordial-function fast paths
//! (Hankel multiplication, polynomial arithmetic for the rational
//! multipoint evaluator, NU-FFT gridding). No external crates are
//! available offline, so the transform is implemented from scratch; it is
//! exercised heavily by the property tests at the bottom of this file.

use crate::linalg::lanes;
use std::f64::consts::PI;

/// A complex number. Minimal by design — only the operations the FFT and
/// polynomial code need.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    /// e^{i theta}.
    #[inline]
    pub fn cis(theta: f64) -> Self {
        Complex { re: theta.cos(), im: theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }

    /// Multiplicative inverse.
    #[inline]
    pub fn inv(self) -> Self {
        let d = self.norm_sqr();
        Complex { re: self.re / d, im: -self.im / d }
    }
}

impl std::ops::Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl std::ops::Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl std::ops::Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl std::ops::AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, o: Complex) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl std::ops::Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex { re: -self.re, im: -self.im }
    }
}

/// Next power of two >= n (n >= 1).
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Concatenated per-stage twiddles for a length-`n` transform (stage
/// tables of length 1, 2, …, n/2 — `n − 1` entries total). Shared by
/// the one-shot and cached transforms so there is exactly one twiddle
/// formula in the crate.
fn fft_stage_twiddles(n: usize, sign: f64) -> Vec<Complex> {
    let mut t = Vec::with_capacity(n.saturating_sub(1));
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        // lint: allow(mixed-precision-cast) — exact usize→f64 twiddle
        // angle construction, not a precision-tier rounding.
        let step = sign * 2.0 * PI / len as f64;
        for k in 0..half {
            // lint: allow(mixed-precision-cast) — exact small-int widen.
            t.push(Complex::cis(step * k as f64));
        }
        len <<= 1;
    }
    t
}

/// The shared radix-2 kernel: bit-reversal permutation + butterflies
/// over a precomputed stage-twiddle table (layout of
/// [`fft_stage_twiddles`]). `buf.len()` must be a power of two ≥ 2.
fn fft_kernel(buf: &mut [Complex], stages: &[Complex]) {
    let n = buf.len();
    let shift = (n.leading_zeros() + 1) as u32;
    for i in 0..n {
        let j = (i.reverse_bits() >> shift) as usize;
        if i < j {
            buf.swap(i, j);
        }
    }
    let mut off = 0;
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        let twiddles = &stages[off..off + half];
        // Walk the stage as disjoint `len`-wide blocks and hand each
        // block's lo/hi halves to the lane-chunked butterfly. Per-k
        // arithmetic is unchanged, so output stays bit-identical to the
        // pre-lane indexed loop (pinned by `cached_twiddles_are_bit_identical`
        // and the naive-DFT property tests below).
        let mut rest = &mut buf[..];
        while !rest.is_empty() {
            let (block, tail) = rest.split_at_mut(len);
            let (lo, hi) = block.split_at_mut(half);
            lanes::butterfly(lo, hi, twiddles);
            rest = tail;
        }
        off += half;
        len <<= 1;
    }
}

/// In-place iterative radix-2 FFT. `buf.len()` must be a power of two.
/// `inverse` applies the conjugate transform *without* the 1/n scaling
/// (callers that need a true inverse use [`ifft_pow2`]). Builds its
/// stage-twiddle table per call — repeated same-length transforms
/// should precompute a [`TwiddleTable`] and use [`fft_pow2_cached`]
/// (bit-identical output).
pub fn fft_pow2(buf: &mut [Complex], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft_pow2 length {n} not a power of two");
    if n <= 1 {
        return;
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let stages = fft_stage_twiddles(n, sign);
    fft_kernel(buf, &stages);
}

/// True inverse FFT (power-of-two length): conjugate transform scaled by 1/n.
pub fn ifft_pow2(buf: &mut [Complex]) {
    let n = buf.len();
    fft_pow2(buf, true);
    // lint: allow(mixed-precision-cast) — exact 1/n scaling constant.
    let s = 1.0 / n as f64;
    for x in buf.iter_mut() {
        *x = x.scale(s);
    }
}

/// Precomputed per-stage twiddle factors for one fixed power-of-two
/// transform length, both directions. [`fft_pow2`] rebuilds its stage
/// tables (a `Vec<Complex>` plus O(n) trig calls) on every call; a plan
/// that runs the same-length transform thousands of times (the lattice
/// cross multiplier of the prepared hot path) builds a `TwiddleTable`
/// once and calls [`fft_pow2_cached`] instead. The cached entries are
/// produced by the exact same `cis(step·k)` formula, so cached and
/// uncached transforms are bit-identical.
pub struct TwiddleTable {
    n: usize,
    /// Forward twiddles, stages concatenated (len 1, 2, 4, … n/2 — total n−1).
    fwd: Vec<Complex>,
    /// Conjugate-transform twiddles, same layout.
    inv: Vec<Complex>,
}

impl TwiddleTable {
    /// Build the tables for transforms of length `n` (a power of two).
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "TwiddleTable length {n} not a power of two");
        TwiddleTable { n, fwd: fft_stage_twiddles(n, -1.0), inv: fft_stage_twiddles(n, 1.0) }
    }

    /// The transform length the tables were built for.
    pub fn fft_len(&self) -> usize {
        self.n
    }
}

/// [`fft_pow2`] with the stage twiddles taken from a precomputed
/// [`TwiddleTable`] instead of being rebuilt: zero heap traffic per
/// call, bit-identical output (same [`fft_kernel`], same
/// [`fft_stage_twiddles`] values). `buf.len()` must equal the table
/// length.
pub fn fft_pow2_cached(buf: &mut [Complex], tw: &TwiddleTable, inverse: bool) {
    let n = buf.len();
    assert_eq!(n, tw.n, "fft_pow2_cached: buffer length {n} != table length {}", tw.n);
    if n <= 1 {
        return;
    }
    fft_kernel(buf, if inverse { &tw.inv } else { &tw.fwd });
}

/// True inverse FFT over a precomputed [`TwiddleTable`] (see
/// [`fft_pow2_cached`]).
pub fn ifft_pow2_cached(buf: &mut [Complex], tw: &TwiddleTable) {
    let n = buf.len();
    fft_pow2_cached(buf, tw, true);
    // lint: allow(mixed-precision-cast) — exact 1/n scaling constant.
    let s = 1.0 / n as f64;
    for x in buf.iter_mut() {
        *x = x.scale(s);
    }
}

/// FFT of arbitrary length via Bluestein's chirp-z transform.
/// Returns the DFT of `x` (forward, e^{-2πi jk/n} convention).
pub fn fft_any(x: &[Complex]) -> Vec<Complex> {
    czt(x, false)
}

/// Inverse DFT of arbitrary length (scaled by 1/n).
pub fn ifft_any(x: &[Complex]) -> Vec<Complex> {
    let n = x.len();
    let mut y = czt(x, true);
    // lint: allow(mixed-precision-cast) — exact 1/n scaling constant.
    let s = 1.0 / n as f64;
    for v in y.iter_mut() {
        *v = v.scale(s);
    }
    y
}

/// Bluestein chirp-z: expresses an arbitrary-length DFT as a convolution,
/// evaluated with power-of-two FFTs.
fn czt(x: &[Complex], inverse: bool) -> Vec<Complex> {
    let n = x.len();
    if n == 0 {
        return Vec::new();
    }
    if n.is_power_of_two() {
        let mut buf = x.to_vec();
        fft_pow2(&mut buf, inverse);
        return buf;
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    // chirp[k] = e^{sign·iπk²/n} (forward: e^{-iπk²/n}); use k² mod 2n to
    // avoid precision loss from huge arguments.
    let chirp: Vec<Complex> = (0..n)
        .map(|k| {
            // lint: allow(mixed-precision-cast) — exact int→f64 chirp
            // angle (k² mod 2n < 2n fits f64 exactly at our sizes).
            let kk = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
            Complex::cis(sign * PI * kk / n as f64)
        })
        .collect();
    let m = next_pow2(2 * n - 1);
    let mut a = vec![Complex::ZERO; m];
    let mut b = vec![Complex::ZERO; m];
    for k in 0..n {
        a[k] = x[k] * chirp[k];
        b[k] = chirp[k].conj();
    }
    for k in 1..n {
        b[m - k] = chirp[k].conj();
    }
    fft_pow2(&mut a, false);
    fft_pow2(&mut b, false);
    for k in 0..m {
        a[k] = a[k] * b[k];
    }
    ifft_pow2(&mut a);
    (0..n).map(|k| a[k] * chirp[k]).collect()
}

/// Real linear convolution: `out[k] = Σ_i a[i] b[k-i]`, length a+b-1.
/// Uses FFT when the product size justifies it, otherwise the direct sum.
pub fn convolve_real(a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let out_len = a.len() + b.len() - 1;
    // Direct convolution wins for small inputs (measured crossover ~2^7).
    if a.len().min(b.len()) <= 32 || out_len <= 128 {
        let mut out = vec![0.0; out_len];
        for (i, &ai) in a.iter().enumerate() {
            for (j, &bj) in b.iter().enumerate() {
                out[i + j] += ai * bj;
            }
        }
        return out;
    }
    let m = next_pow2(out_len);
    let mut fa = vec![Complex::ZERO; m];
    let mut fb = vec![Complex::ZERO; m];
    for (i, &v) in a.iter().enumerate() {
        fa[i].re = v;
    }
    for (i, &v) in b.iter().enumerate() {
        fb[i].re = v;
    }
    fft_pow2(&mut fa, false);
    fft_pow2(&mut fb, false);
    for k in 0..m {
        fa[k] = fa[k] * fb[k];
    }
    ifft_pow2(&mut fa);
    fa[..out_len].iter().map(|c| c.re).collect()
}

/// Complex linear convolution (used by polynomial multiplication over C).
pub fn convolve_complex(a: &[Complex], b: &[Complex]) -> Vec<Complex> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let out_len = a.len() + b.len() - 1;
    if a.len().min(b.len()) <= 24 {
        let mut out = vec![Complex::ZERO; out_len];
        for (i, &ai) in a.iter().enumerate() {
            for (j, &bj) in b.iter().enumerate() {
                out[i + j] += ai * bj;
            }
        }
        return out;
    }
    let m = next_pow2(out_len);
    let mut fa = vec![Complex::ZERO; m];
    let mut fb = vec![Complex::ZERO; m];
    fa[..a.len()].copy_from_slice(a);
    fb[..b.len()].copy_from_slice(b);
    fft_pow2(&mut fa, false);
    fft_pow2(&mut fb, false);
    for k in 0..m {
        fa[k] = fa[k] * fb[k];
    }
    ifft_pow2(&mut fa);
    fa.truncate(out_len);
    fa
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    fn naive_dft(x: &[Complex], inverse: bool) -> Vec<Complex> {
        let n = x.len();
        let sign = if inverse { 1.0 } else { -1.0 };
        (0..n)
            .map(|k| {
                let mut acc = Complex::ZERO;
                for (j, &v) in x.iter().enumerate() {
                    acc += v * Complex::cis(sign * 2.0 * PI * (j * k % n) as f64 / n as f64);
                }
                acc
            })
            .collect()
    }

    fn close(a: &[Complex], b: &[Complex], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((*x - *y).abs() < tol, "{x:?} vs {y:?}");
        }
    }

    #[test]
    fn fft_matches_naive_pow2() {
        let mut rng = Pcg::seed(1);
        for &n in &[1usize, 2, 4, 8, 64, 256] {
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let mut got = x.clone();
            fft_pow2(&mut got, false);
            close(&got, &naive_dft(&x, false), 1e-8 * (n as f64));
        }
    }

    #[test]
    fn fft_any_matches_naive_arbitrary() {
        let mut rng = Pcg::seed(2);
        for &n in &[3usize, 5, 6, 7, 12, 17, 100, 129] {
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            close(&fft_any(&x), &naive_dft(&x, false), 1e-7 * (n as f64));
        }
    }

    #[test]
    fn roundtrip_pow2() {
        let mut rng = Pcg::seed(3);
        let x: Vec<Complex> = (0..512).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
        let mut y = x.clone();
        fft_pow2(&mut y, false);
        ifft_pow2(&mut y);
        close(&y, &x, 1e-9);
    }

    #[test]
    fn roundtrip_arbitrary() {
        let mut rng = Pcg::seed(4);
        for &n in &[7usize, 30, 97] {
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            close(&ifft_any(&fft_any(&x)), &x, 1e-8);
        }
    }

    #[test]
    fn convolution_matches_naive() {
        let mut rng = Pcg::seed(5);
        for &(na, nb) in &[(1usize, 1usize), (3, 5), (40, 40), (200, 77), (300, 300)] {
            let a = rng.normal_vec(na);
            let b = rng.normal_vec(nb);
            let got = convolve_real(&a, &b);
            let mut want = vec![0.0; na + nb - 1];
            for i in 0..na {
                for j in 0..nb {
                    want[i + j] += a[i] * b[j];
                }
            }
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-8, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn parseval_energy_conserved() {
        let mut rng = Pcg::seed(6);
        let x: Vec<Complex> = (0..256).map(|_| Complex::new(rng.normal(), 0.0)).collect();
        let energy_t: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let mut y = x;
        fft_pow2(&mut y, false);
        let energy_f: f64 = y.iter().map(|c| c.norm_sqr()).sum::<f64>() / 256.0;
        assert!((energy_t - energy_f).abs() < 1e-8 * energy_t);
    }

    #[test]
    fn impulse_transforms_to_flat_spectrum() {
        let mut x = vec![Complex::ZERO; 64];
        x[0] = Complex::ONE;
        fft_pow2(&mut x, false);
        for c in &x {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn empty_and_degenerate_convolutions() {
        assert!(convolve_real(&[], &[1.0]).is_empty());
        assert_eq!(convolve_real(&[2.0], &[3.0]), vec![6.0]);
    }

    /// The cached-twiddle transform must be *bit-identical* to the
    /// rebuilding one in both directions — the prepared hot path swaps
    /// one for the other and relies on this.
    #[test]
    fn cached_twiddles_are_bit_identical() {
        let mut rng = Pcg::seed(7);
        for &n in &[1usize, 2, 4, 16, 128, 1024] {
            let tw = TwiddleTable::new(n);
            assert_eq!(tw.fft_len(), n);
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            for inverse in [false, true] {
                let mut a = x.clone();
                let mut b = x.clone();
                fft_pow2(&mut a, inverse);
                fft_pow2_cached(&mut b, &tw, inverse);
                for (p, q) in a.iter().zip(&b) {
                    assert!(
                        p.re.to_bits() == q.re.to_bits() && p.im.to_bits() == q.im.to_bits(),
                        "n={n} inverse={inverse}: {p:?} vs {q:?}"
                    );
                }
            }
            let mut a = x.clone();
            let mut b = x.clone();
            ifft_pow2(&mut a);
            ifft_pow2_cached(&mut b, &tw);
            assert_eq!(a, b);
        }
    }
}
