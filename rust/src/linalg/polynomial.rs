//! Polynomial arithmetic over C (FFT multiplication, Newton-iteration
//! division, subproduct trees, fast multipoint evaluation) — the machinery
//! behind the rational-function cordial fast path (Cabello 2022, Lemma 1):
//! given rational functions `R_j(x) = v_j · f(x + y_j)` the values
//! `Σ_j R_j(x_i)` at `a` points are computed in `O((a+b) log² )` by
//! (1) combining the `R_j` into a single rational function with a
//! divide-and-conquer over FFT polynomial multiplications, and
//! (2) evaluating its numerator and denominator at all `x_i` with a
//! remainder tree.
//!
//! Numerical caveat (documented in DESIGN.md): remainder-tree multipoint
//! evaluation is only conditionally stable in f64. The FTFI driver
//! therefore cross-checks magnitudes and falls back to Horner evaluation
//! per point when degrees are small — which is also *faster* below ~2^8.

use crate::linalg::fft::{convolve_complex, Complex};

/// Dense polynomial over C, coefficient order low→high. The zero
/// polynomial is represented by an empty coefficient vector.
#[derive(Clone, Debug, Default)]
pub struct Poly {
    pub coeffs: Vec<Complex>,
}

impl Poly {
    /// Construct and normalise (strip trailing ~zero coefficients).
    pub fn new(coeffs: Vec<Complex>) -> Self {
        let mut p = Poly { coeffs };
        p.normalize();
        p
    }

    /// From real coefficients.
    pub fn from_real(coeffs: &[f64]) -> Self {
        Poly::new(coeffs.iter().map(|&c| Complex::new(c, 0.0)).collect())
    }

    /// The constant-1 polynomial.
    pub fn one() -> Self {
        Poly { coeffs: vec![Complex::ONE] }
    }

    /// Degree; 0 for the zero polynomial by convention.
    pub fn degree(&self) -> usize {
        self.coeffs.len().saturating_sub(1)
    }

    pub fn is_zero(&self) -> bool {
        self.coeffs.is_empty()
    }

    fn normalize(&mut self) {
        while let Some(c) = self.coeffs.last() {
            if c.abs() < 1e-300 {
                self.coeffs.pop();
            } else {
                break;
            }
        }
    }

    /// Horner evaluation at a single point.
    pub fn eval(&self, x: Complex) -> Complex {
        let mut acc = Complex::ZERO;
        for &c in self.coeffs.iter().rev() {
            acc = acc * x + c;
        }
        acc
    }

    /// Product via FFT convolution.
    pub fn mul(&self, other: &Poly) -> Poly {
        if self.is_zero() || other.is_zero() {
            return Poly::default();
        }
        Poly::new(convolve_complex(&self.coeffs, &other.coeffs))
    }

    /// Sum.
    pub fn add(&self, other: &Poly) -> Poly {
        let n = self.coeffs.len().max(other.coeffs.len());
        let mut out = vec![Complex::ZERO; n];
        for (o, &c) in out.iter_mut().zip(&self.coeffs) {
            *o = c;
        }
        for (o, &c) in out.iter_mut().zip(&other.coeffs) {
            *o += c;
        }
        Poly::new(out)
    }

    /// Scalar multiple.
    pub fn scale(&self, s: Complex) -> Poly {
        Poly::new(self.coeffs.iter().map(|&c| c * s).collect())
    }

    /// Coefficients reversed (x^n · p(1/x) for n = len-1).
    fn reversed(&self) -> Poly {
        let mut c = self.coeffs.clone();
        c.reverse();
        Poly::new(c)
    }

    /// Truncate to the first `n` coefficients (mod x^n).
    fn truncated(&self, n: usize) -> Poly {
        Poly::new(self.coeffs.iter().take(n).cloned().collect())
    }

    /// Power-series inverse mod x^n by Newton iteration:
    /// g_{2k} = g_k (2 - f g_k) mod x^{2k}. Requires nonzero constant term.
    pub fn inverse_mod(&self, n: usize) -> Poly {
        assert!(!self.is_zero() && self.coeffs[0].abs() > 1e-300, "inverse of zero constant term");
        let mut g = Poly { coeffs: vec![self.coeffs[0].inv()] };
        let mut k = 1;
        while k < n {
            k = (2 * k).min(n);
            // g = g*(2 - f*g) mod x^k
            let fg = self.truncated(k).mul(&g).truncated(k);
            let mut two_minus = fg.scale(Complex::new(-1.0, 0.0));
            if two_minus.coeffs.is_empty() {
                two_minus.coeffs.push(Complex::ZERO);
            }
            two_minus.coeffs[0] += Complex::new(2.0, 0.0);
            g = g.mul(&two_minus).truncated(k);
        }
        g.truncated(n)
    }

    /// Fast Euclidean division: returns (quotient, remainder) with
    /// deg(rem) < deg(divisor). Uses the reversal + power-series-inverse
    /// trick, O(d log d).
    pub fn divmod(&self, divisor: &Poly) -> (Poly, Poly) {
        assert!(!divisor.is_zero(), "division by zero polynomial");
        let n = self.coeffs.len();
        let m = divisor.coeffs.len();
        if n < m {
            return (Poly::default(), self.clone());
        }
        let qlen = n - m + 1;
        let rev_num = self.reversed();
        let rev_den = divisor.reversed();
        let inv = rev_den.inverse_mod(qlen);
        let mut rev_q = rev_num.mul(&inv).truncated(qlen);
        // reversed() strips leading zeros of q; pad before reversing back.
        rev_q.coeffs.resize(qlen, Complex::ZERO);
        rev_q.coeffs.reverse();
        let q = Poly::new(rev_q.coeffs);
        let r = self.add(&q.mul(divisor).scale(Complex::new(-1.0, 0.0)));
        (q, r.truncated(m - 1))
    }

    /// Remainder only.
    pub fn rem(&self, divisor: &Poly) -> Poly {
        self.divmod(divisor).1
    }
}

/// Subproduct tree over the points `xs`: level 0 holds the monic linear
/// factors `(x - x_i)`, each higher level pairwise products; the root is
/// `Π_i (x - x_i)`.
pub struct SubproductTree {
    /// levels[0] = leaves, levels.last() = [root].
    pub levels: Vec<Vec<Poly>>,
    pub n: usize,
}

impl SubproductTree {
    pub fn build(xs: &[Complex]) -> Self {
        assert!(!xs.is_empty());
        let leaves: Vec<Poly> = xs
            .iter()
            .map(|&x| Poly { coeffs: vec![-x, Complex::ONE] })
            .collect();
        let mut levels = vec![leaves];
        while levels.last().unwrap().len() > 1 {
            let prev = levels.last().unwrap();
            let mut next = Vec::with_capacity(prev.len().div_ceil(2));
            let mut i = 0;
            while i + 1 < prev.len() {
                next.push(prev[i].mul(&prev[i + 1]));
                i += 2;
            }
            if i < prev.len() {
                next.push(prev[i].clone());
            }
            levels.push(next);
        }
        SubproductTree { levels, n: xs.len() }
    }

    /// The root polynomial Π (x - x_i).
    pub fn root(&self) -> &Poly {
        &self.levels.last().unwrap()[0]
    }
}

/// Fast multipoint evaluation of `p` at `xs` via a remainder tree over the
/// subproduct tree; O((n + deg p) log²). Falls back to Horner when that is
/// cheaper (small degree or few points).
pub fn multipoint_eval(p: &Poly, xs: &[Complex], tree: Option<&SubproductTree>) -> Vec<Complex> {
    if xs.is_empty() {
        return Vec::new();
    }
    // Horner is O(n · deg); the remainder tree has large constants. The
    // crossover measured on this machine sits around deg ≈ 128.
    if p.coeffs.len() <= 128 || xs.len() <= 16 {
        return xs.iter().map(|&x| p.eval(x)).collect();
    }
    let owned;
    let tree = match tree {
        Some(t) => t,
        None => {
            owned = SubproductTree::build(xs);
            &owned
        }
    };
    // Conditioning guard: the nodal polynomial's coefficient range decides
    // whether the remainder tree is numerically viable in f64 (uniform
    // points on a wide interval blow up binomially; Chebyshev-like sets
    // stay bounded). Fall back to Horner when risky — slower, stable.
    let root_mag =
        tree.root().coeffs.iter().map(|c| c.abs()).fold(0.0f64, f64::max);
    if !(1e-8..=1e8).contains(&root_mag) {
        return xs.iter().map(|&x| p.eval(x)).collect();
    }
    // Walk the tree top-down, reducing p modulo each node.
    let top = tree.levels.len() - 1;
    let mut rems = vec![p.rem(&tree.levels[top][0])];
    for level in (0..top).rev() {
        let mut next = Vec::with_capacity(tree.levels[level].len());
        for (pi, parent_rem) in rems.iter().enumerate() {
            let l = 2 * pi;
            if l < tree.levels[level].len() {
                next.push(parent_rem.rem(&tree.levels[level][l]));
            }
            let r = 2 * pi + 1;
            if r < tree.levels[level].len() {
                next.push(parent_rem.rem(&tree.levels[level][r]));
            }
        }
        rems = next;
    }
    // Leaf remainders are constants = p(x_i).
    let result: Vec<Complex> = rems
        .iter()
        .map(|r| r.coeffs.first().copied().unwrap_or(Complex::ZERO))
        .collect();
    // Self-check: the remainder tree is only conditionally stable in f64
    // (near-unit-circle nodes degrade the Newton inverse in divmod).
    // Validate a few entries against Horner — three O(deg) evaluations —
    // and fall back wholesale if they disagree.
    let checks = [0, xs.len() / 2, xs.len() - 1];
    for &i in &checks {
        let direct = p.eval(xs[i]);
        if (result[i] - direct).abs() > 1e-6 * (1.0 + direct.abs()) {
            return xs.iter().map(|&x| p.eval(x)).collect();
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    fn rand_poly(rng: &mut Pcg, deg: usize) -> Poly {
        Poly::new((0..=deg).map(|_| Complex::new(rng.normal(), rng.normal())).collect())
    }

    #[test]
    fn mul_matches_naive() {
        let mut rng = Pcg::seed(1);
        let a = rand_poly(&mut rng, 40);
        let b = rand_poly(&mut rng, 37);
        let c = a.mul(&b);
        for &xv in &[0.3, -1.2, 2.0] {
            let x = Complex::new(xv, 0.1);
            let want = a.eval(x) * b.eval(x);
            let got = c.eval(x);
            assert!((got - want).abs() < 1e-6 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn inverse_mod_is_inverse() {
        // Well-conditioned series: decaying coefficients keep the inverse
        // bounded (a random-coefficient f has roots inside the unit disc
        // and an exponentially growing inverse — not a fair fp test).
        let mut rng = Pcg::seed(2);
        let mut f = rand_poly(&mut rng, 20);
        for (k, c) in f.coeffs.iter_mut().enumerate() {
            *c = c.scale(0.4f64.powi(k as i32));
        }
        f.coeffs[0] = Complex::new(1.5, 0.3);
        let g = f.inverse_mod(33);
        let prod = f.mul(&g);
        assert!((prod.coeffs[0] - Complex::ONE).abs() < 1e-9);
        for c in prod.coeffs.iter().take(33).skip(1) {
            assert!(c.abs() < 1e-8, "{c:?}");
        }
    }

    #[test]
    fn divmod_reconstructs() {
        let mut rng = Pcg::seed(3);
        for &(dn, dm) in &[(25usize, 7usize), (64, 33), (10, 10), (5, 9)] {
            let a = rand_poly(&mut rng, dn);
            let b = rand_poly(&mut rng, dm);
            let (q, r) = a.divmod(&b);
            assert!(r.coeffs.len() < b.coeffs.len().max(1));
            let recon = q.mul(&b).add(&r);
            // Relative to the magnitude of the intermediates: q·b can be
            // orders of magnitude larger than a for random inputs.
            let scale = 1.0
                + q.mul(&b).coeffs.iter().map(|c| c.abs()).fold(0.0, f64::max);
            let n = a.coeffs.len().max(recon.coeffs.len());
            for i in 0..n {
                let x = a.coeffs.get(i).copied().unwrap_or(Complex::ZERO);
                let y = recon.coeffs.get(i).copied().unwrap_or(Complex::ZERO);
                assert!((x - y).abs() < 1e-7 * scale, "coef {i}: {x:?} vs {y:?}");
            }
        }
    }

    #[test]
    fn subproduct_root_vanishes_at_points() {
        let mut rng = Pcg::seed(4);
        let xs: Vec<Complex> = (0..13).map(|_| Complex::new(rng.normal(), 0.0)).collect();
        let tree = SubproductTree::build(&xs);
        for &x in &xs {
            assert!(tree.root().eval(x).abs() < 1e-6);
        }
        assert_eq!(tree.root().degree(), 13);
    }

    #[test]
    fn multipoint_matches_horner_small() {
        let mut rng = Pcg::seed(5);
        let p = rand_poly(&mut rng, 50);
        let xs: Vec<Complex> = (0..30).map(|_| Complex::new(rng.uniform_in(-2.0, 2.0), 0.0)).collect();
        let got = multipoint_eval(&p, &xs, None);
        for (g, &x) in got.iter().zip(&xs) {
            let want = p.eval(x);
            assert!((*g - want).abs() < 1e-6 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn multipoint_matches_horner_large_forced_tree() {
        // Degree above the Horner crossover so the remainder tree actually runs.
        let mut rng = Pcg::seed(6);
        let p = rand_poly(&mut rng, 300);
        // A modest set of Chebyshev points keeps the nodal polynomial
        // bounded, so the remainder tree is well-conditioned (larger or
        // uniform sets trip the Horner fallback guard, tested below).
        let xs: Vec<Complex> = (0..48)
            .map(|i| {
                Complex::new((std::f64::consts::PI * (2.0 * i as f64 + 1.0) / 96.0).cos(), 0.0)
            })
            .collect();
        let got = multipoint_eval(&p, &xs, None);
        for (g, &x) in got.iter().zip(&xs) {
            let want = p.eval(x);
            assert!(
                (*g - want).abs() < 1e-4 * (1.0 + want.abs()),
                "x={:?} got={g:?} want={want:?}",
                x
            );
        }
    }

    #[test]
    fn multipoint_fallback_on_ill_conditioned_points() {
        // Uniform wide-interval points have a binomially exploding nodal
        // polynomial; the guard must route to Horner and stay accurate.
        let mut rng = Pcg::seed(8);
        let p = rand_poly(&mut rng, 200);
        let xs: Vec<Complex> =
            (0..300).map(|i| Complex::new(i as f64 * 0.05, 0.0)).collect();
        let got = multipoint_eval(&p, &xs, None);
        for (g, &x) in got.iter().zip(&xs) {
            let want = p.eval(x);
            assert!((*g - want).abs() < 1e-6 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn zero_polynomial_behaviour() {
        let z = Poly::default();
        assert!(z.is_zero());
        assert_eq!(z.eval(Complex::new(3.0, 0.0)), Complex::ZERO);
        let p = Poly::from_real(&[1.0, 2.0]);
        assert!(z.mul(&p).is_zero());
        assert_eq!(z.add(&p).coeffs.len(), 2);
    }
}
