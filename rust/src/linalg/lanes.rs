//! Fixed-width lane helpers for the prepared-path inner kernels.
//!
//! Every inner slice loop of the prepared hot path — leaf multiply,
//! distance-group aggregation, cross-term application (dense /
//! separable / Chebyshev / rational), combine, and the cached-twiddle
//! FFT butterflies — is elementwise over the d-channel axis: output
//! element `i` depends only on input element `i` (plus loop-invariant
//! scalars), so the reduction order per element is independent of how
//! the axis is chunked. This module exploits that: each helper walks
//! its slices as a main loop over [`LANE_WIDTH`]-wide `chunks_exact`
//! blocks plus a scalar tail, the shape LLVM's autovectorizer maps onto
//! SIMD registers. Because chunking cannot change any per-element
//! expression tree (no FMA contraction — `mul_add` is never used — and
//! no reassociation), the lane kernels are **bit-identical** to the
//! scalar loops they replace for any `LANE_WIDTH`; the unit tests at
//! the bottom pin this against the retained `*_scalar` references,
//! which are also the "PR-6 kernel" baseline the `simd_scaling`
//! ablation times against.
//!
//! The module is std-only and `unsafe`-free by design: lane structure
//! comes from `chunks_exact(_mut)`, not intrinsics, so the default
//! build stays dependency-free and portable. The `simd` cargo feature
//! only *widens* the lane (8 instead of 4) for AVX-class targets —
//! lanes themselves are always on, which is what lets the default f64
//! path keep its bit-identity contract while running the new shape.
//!
//! ## The f32 serving tier
//!
//! [`Precision`] selects between the default f64 kernels and an opt-in
//! mixed-precision tier: every *product* is computed in f32 (both
//! factors rounded to f32, multiplied, widened back) while every *sum*
//! accumulates in f64. Pure-addition kernels ([`add_assign`]) are
//! therefore identical in both tiers. The tier matches the serving
//! wire: the coordinator's field protocol is f32 end to end, so inputs
//! already carry only f32 information and the tier's products lose
//! nothing the wire had — see DESIGN.md §"SIMD lanes & precision
//! tiers" for the ULP contract. This module is the *only* place the
//! tier's f32↔f64 casts live (the `mixed-precision-cast` xtask rule
//! fences every other numeric module).

use crate::linalg::fft::Complex;

/// Lane width of the chunked main loops. 4 f64s (one AVX2 register) by
/// default; the `simd` feature widens to 8 (AVX-512 or two fused AVX2
/// ops). Outputs are bit-identical for every width — the feature is a
/// pure codegen hint, never a semantics switch.
pub const LANE_WIDTH: usize = if cfg!(feature = "simd") { 8 } else { 4 };

/// Compute tier of the prepared kernels. Carried by
/// `WorkspaceSizes`/`PreparedPlans` from the builder down to every
/// inner kernel, so one plan set runs one tier consistently.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f64 compute — bit-identical to the pre-lane kernels.
    #[default]
    F64,
    /// f32 products / f64 accumulation — the opt-in serving tier.
    F32,
}

impl Precision {
    /// Parse a config/CLI spelling (`"f64"` / `"f32"`).
    pub fn parse(name: &str) -> Option<Precision> {
        match name {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }

    /// The canonical config spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// `out[i] += src[i]`. Pure addition: the same kernel serves both
/// precision tiers (there is no product to round).
#[inline]
pub fn add_assign(out: &mut [f64], src: &[f64]) {
    debug_assert_eq!(out.len(), src.len());
    let mut oc = out.chunks_exact_mut(LANE_WIDTH);
    let mut sc = src.chunks_exact(LANE_WIDTH);
    for (o, s) in (&mut oc).zip(&mut sc) {
        for i in 0..LANE_WIDTH {
            o[i] += s[i];
        }
    }
    for (o, s) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += *s;
    }
}

/// `out[i] += c * src[i]` — the axpy at the heart of every cross/leaf
/// multiply. No `mul_add`: the separate multiply-then-add is exactly
/// the scalar kernels' expression tree, which is what keeps the lane
/// path bit-identical.
#[inline]
pub fn axpy(c: f64, src: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), src.len());
    let mut oc = out.chunks_exact_mut(LANE_WIDTH);
    let mut sc = src.chunks_exact(LANE_WIDTH);
    for (o, s) in (&mut oc).zip(&mut sc) {
        for i in 0..LANE_WIDTH {
            o[i] += c * s[i];
        }
    }
    for (o, s) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += c * *s;
    }
}

/// The f32-tier axpy: the product is computed in f32 (both factors
/// rounded, multiplied, widened back), the accumulation stays f64.
#[inline]
pub fn axpy_f32(c: f64, src: &[f64], out: &mut [f64]) {
    debug_assert_eq!(out.len(), src.len());
    let cf = c as f32;
    let mut oc = out.chunks_exact_mut(LANE_WIDTH);
    let mut sc = src.chunks_exact(LANE_WIDTH);
    for (o, s) in (&mut oc).zip(&mut sc) {
        for i in 0..LANE_WIDTH {
            o[i] += (cf * s[i] as f32) as f64;
        }
    }
    for (o, s) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += (cf * *s as f32) as f64;
    }
}

/// Tier dispatch for the axpy kernels.
#[inline]
pub fn axpy_prec(prec: Precision, c: f64, src: &[f64], out: &mut [f64]) {
    match prec {
        Precision::F64 => axpy(c, src, out),
        Precision::F32 => axpy_f32(c, src, out),
    }
}

/// The combine update of the nested-dissection recombination:
/// `out[i] = (out[i] + add[i]) - c * sub[i]` — exactly the
/// `src + crr[c] - coeff·piv[c]` expression (left-to-right: the sum
/// first, then the product subtracted) of the pre-lane combine halves.
#[inline]
pub fn combine(out: &mut [f64], add: &[f64], c: f64, sub: &[f64]) {
    debug_assert_eq!(out.len(), add.len());
    debug_assert_eq!(out.len(), sub.len());
    let mut oc = out.chunks_exact_mut(LANE_WIDTH);
    let mut ac = add.chunks_exact(LANE_WIDTH);
    let mut bc = sub.chunks_exact(LANE_WIDTH);
    for ((o, a), s) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANE_WIDTH {
            o[i] = o[i] + a[i] - c * s[i];
        }
    }
    for ((o, a), s) in
        oc.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder())
    {
        *o = *o + *a - c * *s;
    }
}

/// The f32-tier combine: the pivot-correction product `c·sub[i]` is
/// computed in f32, the sums stay f64.
#[inline]
pub fn combine_f32(out: &mut [f64], add: &[f64], c: f64, sub: &[f64]) {
    debug_assert_eq!(out.len(), add.len());
    debug_assert_eq!(out.len(), sub.len());
    let cf = c as f32;
    let mut oc = out.chunks_exact_mut(LANE_WIDTH);
    let mut ac = add.chunks_exact(LANE_WIDTH);
    let mut bc = sub.chunks_exact(LANE_WIDTH);
    for ((o, a), s) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANE_WIDTH {
            o[i] = o[i] + a[i] - (cf * s[i] as f32) as f64;
        }
    }
    for ((o, a), s) in
        oc.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder())
    {
        *o = *o + *a - (cf * *s as f32) as f64;
    }
}

/// Tier dispatch for the combine kernels.
#[inline]
pub fn combine_prec(prec: Precision, out: &mut [f64], add: &[f64], c: f64, sub: &[f64]) {
    match prec {
        Precision::F64 => combine(out, add, c, sub),
        Precision::F32 => combine_f32(out, add, c, sub),
    }
}

/// One FFT stage block: `lo[k], hi[k] ← lo[k] + hi[k]·tw[k],
/// lo[k] − hi[k]·tw[k]`, lane-chunked. Per-`k` arithmetic is exactly
/// the classic butterfly (complex multiply then sum/difference), so
/// the chunked walk is bit-identical to the index loop it replaces.
/// The FFT stays f64 in both precision tiers: its butterflies reuse
/// intermediate values across stages, so rounding products to f32
/// would compound per stage instead of once per output — see DESIGN.md.
#[inline]
pub fn butterfly(lo: &mut [Complex], hi: &mut [Complex], tw: &[Complex]) {
    debug_assert_eq!(lo.len(), hi.len());
    debug_assert_eq!(lo.len(), tw.len());
    let mut lc = lo.chunks_exact_mut(LANE_WIDTH);
    let mut hc = hi.chunks_exact_mut(LANE_WIDTH);
    let mut tc = tw.chunks_exact(LANE_WIDTH);
    for ((l, h), t) in (&mut lc).zip(&mut hc).zip(&mut tc) {
        for i in 0..LANE_WIDTH {
            let u = l[i];
            let v = h[i] * t[i];
            l[i] = u + v;
            h[i] = u - v;
        }
    }
    for ((l, h), t) in
        lc.into_remainder().iter_mut().zip(hc.into_remainder().iter_mut()).zip(tc.remainder())
    {
        let u = *l;
        let v = *h * *t;
        *l = u + v;
        *h = u - v;
    }
}

// ---- scalar references ---------------------------------------------------
//
// The pre-lane loop shapes, kept verbatim: (a) the oracle the unit tests
// pin lane bit-identity against, (b) the "PR-6 kernels" baseline the
// `simd_scaling` ablation times the lane path over.

/// Scalar reference for [`add_assign`] (the pre-lane zip loop).
pub fn add_assign_scalar(out: &mut [f64], src: &[f64]) {
    for (o, &s) in out.iter_mut().zip(src) {
        *o += s;
    }
}

/// Scalar reference for [`axpy`] (the pre-lane zip loop).
pub fn axpy_scalar(c: f64, src: &[f64], out: &mut [f64]) {
    for (o, &v) in out.iter_mut().zip(src) {
        *o += c * v;
    }
}

/// Scalar reference for [`combine`] (the pre-lane indexed loop).
pub fn combine_scalar(out: &mut [f64], add: &[f64], c: f64, sub: &[f64]) {
    for i in 0..out.len() {
        let src = out[i];
        out[i] = src + add[i] - c * sub[i];
    }
}

/// Scalar reference for [`butterfly`] (the pre-lane indexed loop).
pub fn butterfly_scalar(lo: &mut [Complex], hi: &mut [Complex], tw: &[Complex]) {
    for (k, &w) in tw.iter().enumerate() {
        let u = lo[k];
        let v = hi[k] * w;
        lo[k] = u + v;
        hi[k] = u - v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::rng::Pcg;

    /// Lengths that hit the empty, tail-only, exactly-one-lane,
    /// lanes-plus-tail and many-lane shapes for either LANE_WIDTH.
    const SIZES: [usize; 8] = [0, 1, 3, 4, 8, 9, 64, 257];

    fn randv(n: usize, rng: &mut Pcg) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn lane_add_assign_is_bit_identical_to_scalar() {
        let mut rng = Pcg::seed(1);
        for &n in &SIZES {
            let src = randv(n, &mut rng);
            let base = randv(n, &mut rng);
            let mut a = base.clone();
            let mut b = base.clone();
            add_assign(&mut a, &src);
            add_assign_scalar(&mut b, &src);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "REPRO n={n}: lane add_assign diverged from scalar"
            );
        }
    }

    #[test]
    fn lane_axpy_is_bit_identical_to_scalar() {
        let mut rng = Pcg::seed(2);
        for &n in &SIZES {
            for &c in &[0.0, 1.0, -0.37, 1e-12, 3.5e11] {
                let src = randv(n, &mut rng);
                let base = randv(n, &mut rng);
                let mut a = base.clone();
                let mut b = base.clone();
                axpy(c, &src, &mut a);
                axpy_scalar(c, &src, &mut b);
                assert!(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "REPRO n={n} c={c}: lane axpy diverged from scalar"
                );
            }
        }
    }

    #[test]
    fn lane_combine_is_bit_identical_to_scalar() {
        let mut rng = Pcg::seed(3);
        for &n in &SIZES {
            let add = randv(n, &mut rng);
            let sub = randv(n, &mut rng);
            let base = randv(n, &mut rng);
            let c = rng.normal();
            let mut a = base.clone();
            let mut b = base.clone();
            combine(&mut a, &add, c, &sub);
            combine_scalar(&mut b, &add, c, &sub);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "REPRO n={n}: lane combine diverged from scalar"
            );
        }
    }

    #[test]
    fn lane_butterfly_is_bit_identical_to_scalar() {
        let mut rng = Pcg::seed(4);
        for &n in &SIZES {
            let tw: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let lo0: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let hi0: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let (mut la, mut ha) = (lo0.clone(), hi0.clone());
            let (mut lb, mut hb) = (lo0, hi0);
            butterfly(&mut la, &mut ha, &tw);
            butterfly_scalar(&mut lb, &mut hb, &tw);
            let same = |p: &[Complex], q: &[Complex]| {
                p.iter().zip(q).all(|(x, y)| {
                    x.re.to_bits() == y.re.to_bits() && x.im.to_bits() == y.im.to_bits()
                })
            };
            assert!(same(&la, &lb) && same(&ha, &hb), "REPRO n={n}: butterfly diverged");
        }
    }

    /// The f32 tier computes exactly "round both factors to f32,
    /// multiply in f32, widen, accumulate in f64" — element by element,
    /// lane main loop and scalar tail alike.
    #[test]
    fn f32_tier_matches_elementwise_definition() {
        let mut rng = Pcg::seed(5);
        for &n in &SIZES {
            let src = randv(n, &mut rng);
            let base = randv(n, &mut rng);
            let c = rng.normal();
            let mut got = base.clone();
            axpy_f32(c, &src, &mut got);
            for i in 0..n {
                let want = base[i] + (c as f32 * src[i] as f32) as f64;
                assert!(
                    got[i].to_bits() == want.to_bits(),
                    "REPRO n={n} i={i}: axpy_f32 deviates from its definition"
                );
            }
            let add = randv(n, &mut rng);
            let sub = randv(n, &mut rng);
            let mut got = base.clone();
            combine_f32(&mut got, &add, c, &sub);
            for i in 0..n {
                let want = base[i] + add[i] - (c as f32 * sub[i] as f32) as f64;
                assert!(
                    got[i].to_bits() == want.to_bits(),
                    "REPRO n={n} i={i}: combine_f32 deviates from its definition"
                );
            }
        }
    }

    /// Tier dispatch: F64 routes to the bit-identical kernels, F32 to
    /// the mixed-precision ones (they genuinely differ on generic data).
    #[test]
    fn precision_dispatch_routes_both_tiers() {
        let mut rng = Pcg::seed(6);
        let n = 33;
        let src = randv(n, &mut rng);
        let base = randv(n, &mut rng);
        let c = 0.7300001;
        let mut f64_out = base.clone();
        let mut f32_out = base.clone();
        axpy_prec(Precision::F64, c, &src, &mut f64_out);
        axpy_prec(Precision::F32, c, &src, &mut f32_out);
        let mut want = base.clone();
        axpy(c, &src, &mut want);
        assert!(f64_out.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(
            f64_out.iter().zip(&f32_out).any(|(x, y)| x.to_bits() != y.to_bits()),
            "the f32 tier must actually engage (outputs identical to f64)"
        );
        let mut a = base.clone();
        let mut b = base.clone();
        combine_prec(Precision::F64, &mut a, &src, c, &want);
        combine(&mut b, &src, c, &want);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn precision_parses_and_round_trips() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::parse(""), None);
        assert_eq!(Precision::parse(Precision::F64.as_str()), Some(Precision::F64));
        assert_eq!(Precision::parse(Precision::F32.as_str()), Some(Precision::F32));
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn lane_width_is_a_positive_power_of_two() {
        assert!(LANE_WIDTH.is_power_of_two() && LANE_WIDTH >= 2);
    }
}
