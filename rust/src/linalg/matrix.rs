//! Dense row-major matrices over `f64`.
//!
//! Field payloads in this library are tensor fields `X ∈ R^{N×d}` (the
//! paper's `X ∈ R^{N×d1×d2×…}` with trailing dims flattened), so the core
//! type is a simple contiguous row-major matrix with the handful of BLAS-1/2/3
//! operations the integrators, eigensolver, OT solver and classifier need.

use crate::ml::rng::Pcg;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing buffer (must have `rows*cols` elements).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] += v;
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Underlying buffer.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable underlying buffer.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix-matrix product (naive triple loop with the k-j order that
    /// keeps the inner loop streaming over contiguous rows).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = &self.data[i * self.cols..(i + 1) * self.cols];
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| {
                self.row(i).iter().zip(v).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    /// `self^T v` without materialising the transpose.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += vi * a;
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Matrix {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius norm of the difference.
    pub fn frobenius_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Max absolute entry of the difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Gather rows by index into a new matrix.
    pub fn gather_rows(&self, idx: &[u32]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i as usize));
        }
        out
    }

    /// Scatter-add rows of `src` into `self` at the given indices.
    pub fn scatter_add_rows(&mut self, idx: &[u32], src: &Matrix) {
        assert_eq!(idx.len(), src.rows());
        assert_eq!(self.cols, src.cols(), "scatter_add_rows column mismatch");
        for (r, &i) in idx.iter().enumerate() {
            let dst = &mut self.data[i as usize * self.cols..(i as usize + 1) * self.cols];
            for (d, &v) in dst.iter_mut().zip(src.row(r)) {
                *d += v;
            }
        }
    }

    /// Two distinct rows, both mutable — the disjoint borrow needed when
    /// two rows of one matrix are updated from each other in place (the
    /// Jacobi row rotation in `linalg/eigen.rs` is the in-crate user).
    /// Panics if `i == j`.
    pub fn row_pair_mut(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert_ne!(i, j, "row_pair_mut needs two distinct rows");
        let c = self.cols;
        if i < j {
            let (a, b) = self.data.split_at_mut(j * c);
            (&mut a[i * c..(i + 1) * c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(i * c);
            (&mut b[..c], &mut a[j * c..(j + 1) * c])
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Cosine similarity; returns 0 when either vector is (near) zero.
pub fn cosine_similarity(a: &[f64], b: &[f64]) -> f64 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-12 || nb < 1e-12 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg::seed(1);
        let a = Matrix::randn(4, 4, &mut rng);
        let i = Matrix::eye(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matvec_t_consistency() {
        let mut rng = Pcg::seed(2);
        let a = Matrix::randn(5, 7, &mut rng);
        let v = rng.normal_vec(5);
        let want = a.transpose().matvec(&v);
        let got = a.matvec_t(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg::seed(3);
        let a = Matrix::randn(3, 8, &mut rng);
        assert!(a.transpose().transpose().max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Pcg::seed(4);
        let a = Matrix::randn(6, 3, &mut rng);
        let idx = [4u32, 0, 2];
        let g = a.gather_rows(&idx);
        assert_eq!(g.row(0), a.row(4));
        let mut acc = Matrix::zeros(6, 3);
        acc.scatter_add_rows(&idx, &g);
        for (r, &i) in idx.iter().enumerate() {
            assert_eq!(acc.row(i as usize), g.row(r));
        }
    }

    #[test]
    fn row_pair_mut_is_disjoint_and_ordered() {
        let mut rng = Pcg::seed(7);
        let mut a = Matrix::randn(5, 4, &mut rng);
        let want_2 = a.row(2).to_vec();
        let want_4 = a.row(4).to_vec();
        {
            let (r2, r4) = a.row_pair_mut(2, 4);
            assert_eq!(&r2[..], &want_2[..]);
            assert_eq!(&r4[..], &want_4[..]);
            for (x, y) in r2.iter_mut().zip(r4.iter()) {
                *x += *y;
            }
        }
        // Reversed order returns (row i, row j) in argument order.
        let (r4, r2) = a.row_pair_mut(4, 2);
        assert_eq!(&r4[..], &want_4[..]);
        for (got, (w2, w4)) in r2.iter().zip(want_2.iter().zip(&want_4)) {
            assert_eq!(*got, w2 + w4);
        }
    }

    #[test]
    #[should_panic(expected = "distinct rows")]
    fn row_pair_mut_rejects_aliasing() {
        let mut a = Matrix::zeros(3, 2);
        let _ = a.row_pair_mut(1, 1);
    }

    #[test]
    fn frobenius_matches_definition() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 0.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_similarity_basics() {
        assert!((cosine_similarity(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 0.0], &[-2.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine_similarity(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[3.0, 4.0, 5.0]);
        a.scale(0.5);
        assert_eq!(a.data(), &[1.5, 2.0, 2.5]);
    }
}
