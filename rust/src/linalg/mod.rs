//! Dense linear algebra substrates: FFT, matrices, polynomial arithmetic,
//! symmetric eigensolvers.

pub mod eigen;
pub mod fft;
pub mod lanes;
pub mod matrix;
pub mod polynomial;
