//! Symmetric eigensolvers.
//!
//! The graph-classification pipeline (§4.2, following de Lara & Pineau
//! 2018) featurises each graph by the `k` smallest eigenvalues of its
//! (f-transformed) kernel matrix. Two solvers are provided:
//!
//! - [`jacobi_eigenvalues`]: cyclic Jacobi — robust, O(n³), used for the
//!   small kernel matrices typical of TU-style graphs (n ≤ ~500);
//! - [`lanczos_smallest`]: Lanczos with full reorthogonalisation against a
//!   matvec closure — used when only a matrix-vector product is available
//!   (e.g. the FTFI operator itself), avoiding materialising the kernel.

use crate::linalg::matrix::{dot, norm, Matrix};
use crate::ml::rng::Pcg;

/// All eigenvalues of a symmetric matrix via cyclic Jacobi rotations,
/// returned in ascending order. The input is copied.
pub fn jacobi_eigenvalues(m: &Matrix, max_sweeps: usize) -> Vec<f64> {
    assert_eq!(m.rows(), m.cols(), "jacobi needs a square matrix");
    let n = m.rows();
    if n == 0 {
        return Vec::new();
    }
    let mut a = m.clone();
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius mass; stop when negligible.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + a.frobenius()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                // Numerically stable tangent of the rotation angle.
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply the rotation J(p,q,θ)^T A J(p,q,θ).
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                let (row_p, row_q) = a.row_pair_mut(p, q);
                for (apk, aqk) in row_p.iter_mut().zip(row_q.iter_mut()) {
                    let (x, y) = (*apk, *aqk);
                    *apk = c * x - s * y;
                    *aqk = s * x + c * y;
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
    eig.sort_by(|x, y| x.partial_cmp(y).unwrap());
    eig
}

/// Eigenvalues of a symmetric tridiagonal matrix (diagonal `d`,
/// off-diagonal `e`) by bisection with Sturm sequences — ascending order.
pub fn tridiagonal_eigenvalues(d: &[f64], e: &[f64]) -> Vec<f64> {
    let n = d.len();
    if n == 0 {
        return Vec::new();
    }
    assert_eq!(e.len(), n.saturating_sub(1));
    // Gershgorin bounds.
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..n {
        let r = (if i > 0 { e[i - 1].abs() } else { 0.0 })
            + (if i + 1 < n { e[i].abs() } else { 0.0 });
        lo = lo.min(d[i] - r);
        hi = hi.max(d[i] + r);
    }
    // count(x) = number of eigenvalues < x (Sturm sequence sign changes).
    let count = |x: f64| -> usize {
        let mut cnt = 0;
        let mut q = d[0] - x;
        if q < 0.0 {
            cnt += 1;
        }
        for i in 1..n {
            let denom = if q.abs() < 1e-300 { 1e-300_f64.copysign(q) } else { q };
            q = d[i] - x - e[i - 1] * e[i - 1] / denom;
            if q < 0.0 {
                cnt += 1;
            }
        }
        cnt
    };
    (0..n)
        .map(|k| {
            let (mut a, mut b) = (lo, hi);
            for _ in 0..80 {
                let mid = 0.5 * (a + b);
                if count(mid) <= k {
                    a = mid;
                } else {
                    b = mid;
                }
            }
            0.5 * (a + b)
        })
        .collect()
}

/// `k` smallest eigenvalues of a symmetric operator given only a matvec,
/// via Lanczos with full reorthogonalisation. `dim` is the operator size.
///
/// The Krylov dimension is `min(dim, max(2k+10, 3k))`; for the kernel
/// matrices in this repo that is accurate to ~1e-8 on the low end of the
/// spectrum (verified against Jacobi in tests).
pub fn lanczos_smallest(
    dim: usize,
    k: usize,
    mut matvec: impl FnMut(&[f64]) -> Vec<f64>,
    rng: &mut Pcg,
) -> Vec<f64> {
    if dim == 0 || k == 0 {
        return Vec::new();
    }
    let k = k.min(dim);
    let m = dim.min((4 * k + 24).max(6 * k));
    let mut alphas = Vec::with_capacity(m);
    let mut betas: Vec<f64> = Vec::with_capacity(m.saturating_sub(1));
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(m);

    let mut q = rng.normal_vec(dim);
    let nq = norm(&q);
    for v in q.iter_mut() {
        *v /= nq;
    }
    basis.push(q);

    for j in 0..m {
        let mut w = matvec(&basis[j]);
        let alpha = dot(&w, &basis[j]);
        alphas.push(alpha);
        // w -= alpha q_j + beta_{j-1} q_{j-1}
        for (wi, qi) in w.iter_mut().zip(&basis[j]) {
            *wi -= alpha * qi;
        }
        if j > 0 {
            let b = betas[j - 1];
            for (wi, qi) in w.iter_mut().zip(&basis[j - 1]) {
                *wi -= b * qi;
            }
        }
        // Full reorthogonalisation (twice is enough; Parlett).
        for _ in 0..2 {
            for qb in &basis {
                let c = dot(&w, qb);
                for (wi, qi) in w.iter_mut().zip(qb) {
                    *wi -= c * qi;
                }
            }
        }
        let beta = norm(&w);
        if j + 1 == m || beta < 1e-12 {
            break;
        }
        betas.push(beta);
        for wi in w.iter_mut() {
            *wi /= beta;
        }
        basis.push(w);
    }
    let mut eig = tridiagonal_eigenvalues(&alphas, &betas);
    eig.truncate(k);
    eig
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_symmetric(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg::seed(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut s = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                s.set(i, j, 0.5 * (a.get(i, j) + a.get(j, i)));
            }
        }
        s
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 0, 3.0);
        m.set(1, 1, -1.0);
        m.set(2, 2, 2.0);
        let e = jacobi_eigenvalues(&m, 30);
        assert!((e[0] + 1.0).abs() < 1e-10);
        assert!((e[1] - 2.0).abs() < 1e-10);
        assert!((e[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let m = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = jacobi_eigenvalues(&m, 30);
        assert!((e[0] - 1.0).abs() < 1e-10 && (e[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_trace_and_frobenius_invariants() {
        let m = random_symmetric(20, 7);
        let e = jacobi_eigenvalues(&m, 50);
        let trace: f64 = (0..20).map(|i| m.get(i, i)).sum();
        assert!((e.iter().sum::<f64>() - trace).abs() < 1e-8 * (1.0 + trace.abs()));
        let fro2: f64 = m.frobenius().powi(2);
        let sumsq: f64 = e.iter().map(|x| x * x).sum();
        assert!((fro2 - sumsq).abs() < 1e-7 * (1.0 + fro2));
    }

    #[test]
    fn tridiagonal_matches_jacobi() {
        let n = 12;
        let mut rng = Pcg::seed(9);
        let d = rng.normal_vec(n);
        let e: Vec<f64> = rng.normal_vec(n - 1);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, d[i]);
        }
        for i in 0..n - 1 {
            m.set(i, i + 1, e[i]);
            m.set(i + 1, i, e[i]);
        }
        let want = jacobi_eigenvalues(&m, 60);
        let got = tridiagonal_eigenvalues(&d, &e);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7, "{g} vs {w}");
        }
    }

    #[test]
    fn lanczos_matches_jacobi_on_small_spectrum() {
        let n = 40;
        let m = random_symmetric(n, 21);
        let want = jacobi_eigenvalues(&m, 60);
        let mut rng = Pcg::seed(22);
        let got = lanczos_smallest(n, 5, |v| m.matvec(v), &mut rng);
        for (g, w) in got.iter().zip(want.iter().take(5)) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn empty_inputs() {
        assert!(jacobi_eigenvalues(&Matrix::zeros(0, 0), 5).is_empty());
        assert!(tridiagonal_eigenvalues(&[], &[]).is_empty());
        let mut rng = Pcg::seed(1);
        assert!(lanczos_smallest(0, 3, |v| v.to_vec(), &mut rng).is_empty());
    }
}
