//! `ftfi` — the leader binary: launcher + CLI over the whole stack.
//!
//! ```text
//! ftfi integrate  --n 5000 --f exp           FTFI vs brute on a synthetic graph
//! ftfi train      --steps 200 --lr 0.01      train TopViT-mini via PJRT
//! ftfi serve      --requests 500 --batch 8   run the batched inference server
//! ftfi gw         --n 300                    Gromov–Wasserstein demo
//! ftfi info                                  versions, artifact status
//! ```

use ftfi::bench_util::time_once;
use ftfi::cli::Args;
use ftfi::coordinator::{BatchExecutor, BatcherConfig, InferenceServer};
use ftfi::ftfi::brute::BruteTreeIntegrator;
use ftfi::ftfi::functions::FDist;
use ftfi::ftfi::TreeFieldIntegrator;
use ftfi::graph::{generators, mst::minimum_spanning_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::ml::shapes;
use ftfi::ot::gw::{gromov_wasserstein, GwBackend, GwParams};
use ftfi::ot::sinkhorn::uniform_marginal;
use ftfi::runtime::topvit::{TopVit, TopVitExecutor, TRAIN_BATCH};
use ftfi::runtime::Runtime;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("integrate") => cmd_integrate(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("gw") => cmd_gw(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: ftfi <integrate|train|serve|gw|info> [--options]\n\
                 see the module docs in rust/src/main.rs"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_f(name: &str, lambda: f64) -> FDist {
    match name {
        "identity" => FDist::Identity,
        "exp" => FDist::Exponential { lambda: -lambda, scale: 1.0 },
        "invquad" => FDist::inverse_quadratic(lambda),
        "gauss" => FDist::gaussian(lambda),
        "poly" => FDist::Polynomial(vec![1.0, -lambda, lambda * lambda / 4.0]),
        other => panic!("unknown f {other:?} (identity|exp|invquad|gauss|poly)"),
    }
}

fn cmd_integrate(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 5000);
    let extra = args.get_usize("extra-edges", n / 2);
    let d = args.get_usize("channels", 4);
    let f = parse_f(args.get_str("f", "exp"), args.get_f64("lambda", 0.5));
    let mut rng = Pcg::seed(args.get_usize("seed", 0) as u64);

    println!("graph: path({n}) + {extra} random edges; field channels = {d}; f = {f:?}");
    let g = generators::path_plus_random_edges(n, extra, &mut rng);
    let (tree, t_mst) = time_once(|| minimum_spanning_tree(&g));
    let x = Matrix::randn(n, d, &mut rng);

    let (tfi, t_pre) = time_once(|| TreeFieldIntegrator::new(&tree));
    let (fast, t_fast) = time_once(|| tfi.integrate(&f, &x));
    println!("FTFI:  preprocess {t_pre:.3}s (+ MST {t_mst:.3}s), integrate {t_fast:.4}s");

    let (brute, t_bpre) = time_once(|| BruteTreeIntegrator::new(&tree, &f));
    let (slow, t_slow) = time_once(|| brute.integrate(&x));
    println!("BTFI:  preprocess {t_bpre:.3}s, integrate {t_slow:.4}s");
    let rel = fast.frobenius_diff(&slow) / (1.0 + slow.frobenius());
    println!(
        "relative error {rel:.2e}; end-to-end speedup {:.1}x",
        (t_bpre + t_slow) / (t_pre + t_fast)
    );
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_usize("steps", 200);
    let lr = args.get_f64("lr", 0.01) as f32;
    let masked = !args.get_flag("unmasked");
    let params_bin =
        if masked { "topvit_init_masked.bin" } else { "topvit_init_unmasked.bin" };
    let rt = Runtime::cpu()?;
    let mut model = TopVit::load(&rt, "artifacts", params_bin, &[], true)?;
    let mut rng = Pcg::seed(1);
    let data = shapes::dataset(64, &mut rng);
    println!(
        "training TopViT-mini ({}) for {steps} steps, lr {lr}",
        if masked { "masked" } else { "unmasked" }
    );
    for step in 0..steps {
        let (images, labels) = shapes::pack_batch(&data, step * TRAIN_BATCH, TRAIN_BATCH);
        let loss = model.train_step(&images, &labels, lr)?;
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    println!("final mask parameters: {:?}", model.mask_params());
    if let Some(out) = args.get("save") {
        model.params.save_bin(out)?;
        println!("saved parameters to {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let n_requests = args.get_usize("requests", 200);
    let batch = args.get_usize("batch", 8);
    let server = InferenceServer::start(
        vec![Box::new(move || {
            let rt = Runtime::cpu().expect("PJRT client");
            let model = TopVit::load(&rt, "artifacts", "topvit_init_masked.bin", &[8], false)
                .expect("load TopViT");
            Box::new(TopVitExecutor::new(model, 8)) as Box<dyn BatchExecutor>
        })],
        BatcherConfig { batch_size: batch.min(8), batch_timeout: Duration::from_millis(2) },
        1024,
    );
    let mut rng = Pcg::seed(3);
    let data = shapes::dataset(8, &mut rng);
    println!("submitting {n_requests} requests (batch {batch})...");
    let handles: Vec<_> = (0..n_requests)
        .map(|i| server.submit_blocking(data[i % data.len()].pixels.clone()).unwrap())
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    let m = server.metrics();
    println!(
        "served {ok}/{n_requests}: {:.0} req/s, mean batch {:.2}, p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms",
        m.throughput_rps,
        m.mean_batch_size,
        m.latency_p50 * 1e3,
        m.latency_p95 * 1e3,
        m.latency_p99 * 1e3
    );
    server.shutdown();
    Ok(())
}

fn cmd_gw(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 300);
    let mut rng = Pcg::seed(5);
    let ta = generators::random_tree(n, 0.1, 1.0, &mut rng);
    let tb = generators::random_tree(n, 0.1, 1.0, &mut rng);
    let p = uniform_marginal(n);
    for (name, backend) in [("dense", GwBackend::Dense), ("ftfi", GwBackend::Ftfi)] {
        let (r, total) =
            time_once(|| gromov_wasserstein(&ta, &tb, &p, &p, backend, &GwParams::default()));
        println!(
            "{name:>5}: GW {:.5} in {total:.2}s total, {:.2}s field integration ({} CG iters)",
            r.discrepancy, r.integration_seconds, r.iterations
        );
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("ftfi {} — Fast Tree-Field Integrators", env!("CARGO_PKG_VERSION"));
    match Runtime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    for name in [
        "sanity_matmul.hlo.txt",
        "topvit_fwd_b1.hlo.txt",
        "topvit_fwd_b8.hlo.txt",
        "topvit_train_b32.hlo.txt",
        "topvit_init_masked.bin",
    ] {
        let path = std::path::Path::new("artifacts").join(name);
        println!(
            "artifact {name:<28} {}",
            if path.exists() { "present" } else { "MISSING (run `make artifacts`)" }
        );
    }
    Ok(())
}
